//! Vendored, offline-compatible subset of the `anyhow` error API.
//!
//! The build environment has no network registry, so this path crate
//! provides the exact surface the workspace uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension
//! trait for both `Result` and `Option`. Error values carry a message
//! chain (context is prepended, `: `-joined) — no backtraces, no
//! downcasting.

use std::fmt;

/// Drop-in error type: a boxed message chain.
///
/// Deliberately does **not** implement `std::error::Error`, so the
/// blanket `From<E: std::error::Error>` conversion below stays coherent
/// (same design as the real crate).
pub struct Error {
    msg: Box<str>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Self {
            msg: m.to_string().into_boxed_str(),
        }
    }

    /// Prepend a context layer (what `Context::context` does).
    pub fn wrap<C: fmt::Display>(self, ctx: C) -> Self {
        Self::msg(format!("{ctx}: {}", self.msg))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Include the source chain the way `{:#}` would.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error::msg(msg)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — build an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an error from a `Result` function.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// `ensure!(cond, "...")` — bail unless `cond` holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::Error::msg(format!($($arg)*)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_chains_messages() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config: disk on fire");
        let e: Error = None::<()>.with_context(|| "missing flag").unwrap_err();
        assert_eq!(e.to_string(), "missing flag");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {} for {}", 7, "x");
        assert_eq!(e.to_string(), "bad value 7 for x");
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
        fn g(ok: bool) -> Result<u32> {
            ensure!(ok, "must be ok");
            Ok(3)
        }
        assert_eq!(g(true).unwrap(), 3);
        assert!(g(false).is_err());
    }
}
