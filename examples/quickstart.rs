//! Quickstart: the public API in ~60 lines.
//!
//! 1. Protect a quantized GEMM with ABFT (paper Alg 1) and catch an
//!    injected bit flip.
//! 2. Protect an EmbeddingBag (paper Alg 2) the same way.
//! 3. Run a small fully-protected DLRM end to end.
//!
//! Run: `cargo run --release --example quickstart`

use dlrm_abft::abft::{AbftGemm, EbChecksum};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::embedding::{bag_sum_8, QuantTable8};
use dlrm_abft::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::new(42);

    // --- 1. Protected GEMM ---------------------------------------------
    let (m, k, n) = (8, 256, 128);
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    let abft = AbftGemm::new(&b, k, n); // encode once, reuse forever
    let (mut c_temp, verdict) = abft.exec(&a, m);
    println!("clean GEMM: corrupted rows = {:?}", verdict.corrupted_rows);

    c_temp[3 * (n + 1) + 40] ^= 1 << 17; // simulate a soft error in C_temp
    let verdict = abft.verify(&c_temp, m);
    println!("after bit flip: corrupted rows = {:?}", verdict.corrupted_rows);
    abft.recompute_row(&a, 3, &mut c_temp, m); // row-level recovery
    println!("after recompute: clean = {}", abft.verify(&c_temp, m).clean());

    // --- 2. Protected EmbeddingBag --------------------------------------
    let table = QuantTable8::random(10_000, 64, &mut rng);
    let checksum = EbChecksum::build_8(&table); // C_T, precomputed offline
    let indices: Vec<usize> = (0..100).map(|_| rng.gen_range(0, 10_000)).collect();
    let mut r = vec![0f32; 64];
    bag_sum_8(&table, &indices, None, true, &mut r);
    let flagged = checksum.check_bag(&table.alpha, &table.beta, &indices, None, &r);
    println!("clean EB bag flagged = {flagged}");
    let bits = r[10].to_bits() ^ (1 << 29);
    r[10] = f32::from_bits(bits); // soft error in the output
    let flagged = checksum.check_bag(&table.alpha, &table.beta, &indices, None, &r);
    println!("corrupted EB bag flagged = {flagged}");

    // --- 3. Fully-protected DLRM ----------------------------------------
    let model = DlrmModel::random(DlrmConfig {
        num_dense: 8,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![TableConfig { rows: 5_000, pooling: 10 }; 4],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 1,
    });
    let requests = model.synth_requests(4, &mut rng);
    let (scores, report) = model.forward(&requests);
    println!("DLRM scores = {scores:?}");
    println!("DLRM soft-error report = {report:?}");
}
