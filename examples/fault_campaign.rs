//! Fault-injection campaign driver: regenerates Tables II and III at
//! configurable scale and compares against both the paper's measured
//! numbers and the §IV-C analytic bounds.
//!
//! Run: `cargo run --release --example fault_campaign`
//! Env: RUNS (Table II runs/shape, default 25), ROWS (Table III table
//! rows, default 500k), TRIALS (analysis Monte-Carlo, default 500).

use dlrm_abft::abft::analysis;
use dlrm_abft::bench::figures::{run_analysis, run_table2, run_table3};
use dlrm_abft::fault::campaign::{EbCampaignConfig, GemmCampaignConfig};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let runs: usize = env_or("RUNS", 25);
    let rows: usize = env_or("ROWS", 500_000);
    let trials: usize = env_or("TRIALS", 500);
    let mut out = std::io::stdout();

    let cfg = GemmCampaignConfig { runs_per_shape: runs, ..Default::default() };
    let t2 = run_table2(&cfg, 1, &mut out);
    println!();
    let ecfg = EbCampaignConfig { table_rows: rows, ..Default::default() };
    let t3 = run_table3(&ecfg, 1, &mut out);
    println!();
    run_analysis(trials, &mut out);

    println!("\n== analytic context ==");
    println!(
        "Table II 'error in B' is a mix over m ∈ {{1,50,100,150}}; the m=1 analytic floor is {:.2}% \
         (paper measured 95.11% across the same mix)",
        analysis::p_detect_bitflip_in_b(1) * 100.0
    );
    println!(
        "measured: B {:.2}%, C {:.2}%, FP {:.2}% | EB high {:.1}%, low {:.1}%, FP {:.1}%",
        t2.error_in_b.rate() * 100.0,
        t2.error_in_c.rate() * 100.0,
        t2.no_error.rate() * 100.0,
        t3.high_bits.rate() * 100.0,
        t3.low_bits.rate() * 100.0,
        t3.no_error.rate() * 100.0,
    );
}
