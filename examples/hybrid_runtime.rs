//! Cross-layer consistency: the jax/Pallas-lowered artifacts against the
//! native rust operators, through the PJRT runtime.
//!
//! Proves the three-layer story: (1) the `abft_gemm.hlo.txt` artifact
//! (Pallas kernel, interpret-lowered) produces *bit-identical* C_temp to
//! the rust `AbftGemm` on the same encoded operand; (2) corrupting the
//! encoded operand makes the artifact's fused verifier report nonzero
//! residuals; (3) the full `model_b1` DLRM artifact serves a score with
//! clean ABFT evidence.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example hybrid_runtime`

use dlrm_abft::abft::AbftGemm;
use dlrm_abft::runtime::{PjrtEngine, Tensor};
use dlrm_abft::util::rng::Pcg32;

// Shapes fixed by python/compile/aot.py.
const M: usize = 16;
const K: usize = 512;
const N: usize = 512;

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut engine = PjrtEngine::cpu()?;
    let loaded = engine.load_artifact_dir(&dir)?;
    println!("loaded artifacts: {loaded:?}");

    // --- 1. bit-identical protected GEMM --------------------------------
    let mut rng = Pcg32::new(0xCAFE);
    let mut a = vec![0u8; M * K];
    let mut b = vec![0i8; K * N];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    let native = AbftGemm::new(&b, K, N);
    let (c_native, verdict) = native.exec(&a, M);
    assert!(verdict.clean());

    let b_enc = native.packed.to_row_major(); // k×(n+1), checksum packed in
    let out = engine.execute(
        "abft_gemm",
        &[
            Tensor::U8(a.clone(), vec![M, K]),
            Tensor::I8(b_enc.clone(), vec![K, N + 1]),
        ],
    )?;
    let (c_pjrt, residuals) = match (&out[0], &out[1]) {
        (Tensor::I32(c, _), Tensor::I32(r, _)) => (c.clone(), r.clone()),
        other => anyhow::bail!("unexpected artifact outputs: {other:?}"),
    };
    assert_eq!(c_native, c_pjrt, "rust kernel and Pallas artifact disagree");
    assert!(residuals.iter().all(|&r| r == 0));
    println!("1. native AbftGemm == Pallas artifact: bit-identical C_temp ({}x{}), residuals all 0", M, N + 1);

    // --- 2. detection through the artifact ------------------------------
    let mut b_bad = b_enc;
    b_bad[1234] = (b_bad[1234] as u8 ^ 0x20) as i8; // payload bit flip
    let out = engine.execute(
        "abft_gemm",
        &[Tensor::U8(a, vec![M, K]), Tensor::I8(b_bad, vec![K, N + 1])],
    )?;
    let residuals = match &out[1] {
        Tensor::I32(r, _) => r.clone(),
        _ => unreachable!(),
    };
    let flagged = residuals.iter().filter(|&&r| r != 0).count();
    println!("2. corrupted operand: {flagged}/{M} rows flagged by the artifact's fused verifier");
    assert!(flagged >= M - 1, "column corruption should flag nearly all rows");

    // --- 3. full DLRM artifact -------------------------------------------
    let mut rng = Pcg32::new(3);
    let dense: Vec<f32> = (0..8).map(|_| rng.next_f32()).collect();
    let indices: Vec<i32> = (0..2 * 20).map(|_| rng.gen_range(0, 5000) as i32).collect();
    let out = engine.execute(
        "model_b1",
        &[
            Tensor::F32(dense, vec![1, 8]),
            Tensor::I32(indices, vec![1, 2, 20]),
        ],
    )?;
    match (&out[0], &out[1], &out[2]) {
        (Tensor::F32(scores, _), Tensor::I32(gemm_bad, _), Tensor::I32(eb_flagged, _)) => {
            println!(
                "3. model_b1 artifact: score={:.4} gemm_bad_rows={} eb_flagged={}",
                scores[0], gemm_bad[0], eb_flagged[0]
            );
            assert!((0.0..=1.0).contains(&scores[0]));
            assert_eq!(gemm_bad[0], 0);
            assert_eq!(eb_flagged[0], 0);
        }
        other => anyhow::bail!("unexpected model outputs: {other:?}"),
    }
    println!("hybrid_runtime OK — python never ran on this request path");
    Ok(())
}
