//! Self-healing demo: latent memory corruption → proactive scrubbing →
//! repair from the model store.
//!
//! The request path only verifies rows a request touches; with skewed
//! traffic, corrupted *cold* rows would sit undetected (paper §IV-A1's
//! memory-exposure argument). This example closes the loop the paper
//! leaves to ops: snapshot the model (CRC-protected store), inject bit
//! flips into rows no request has touched, let the incremental scrubber
//! find them between batches, and repair from the snapshot.
//!
//! Run: `cargo run --release --example scrub_recovery`

use dlrm_abft::abft::Scrubber;
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    println!("== scrub_recovery: latent-error detection + store repair ==");
    let cfg = DlrmConfig {
        num_dense: 8,
        embedding_dim: 32,
        bottom_mlp: vec![64, 32],
        top_mlp: vec![64],
        tables: vec![TableConfig { rows: 200_000, pooling: 20 }; 4],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 9,
    };
    let mut model = DlrmModel::random(cfg);

    // 1. Persist the model store (the recovery source).
    let store = std::env::temp_dir().join("scrub_recovery_store.dlrm");
    model.save(&store)?;
    println!("model store written: {}", store.display());

    // 2. Latent corruption: flip bits in 25 random rows across tables.
    let mut rng = Pcg32::new(123);
    let mut injected: Vec<(usize, usize)> = Vec::new();
    for _ in 0..25 {
        let t = rng.gen_range(0, model.tables.len());
        let row = rng.gen_range(0, model.tables[t].rows);
        let col = rng.gen_range(0, model.cfg.embedding_dim);
        let bit = rng.gen_range_u32(8);
        let d = model.cfg.embedding_dim;
        model.tables[t].data[row * d + col] ^= 1 << bit;
        injected.push((t, row));
    }
    injected.sort_unstable();
    injected.dedup();
    println!("injected latent bit flips into {} (table, row) pairs", injected.len());

    // 3. Incremental scrubbing, as the serving loop would do between
    //    batches (stride-bounded so each tick stays microseconds-cheap).
    let mut scrubbers: Vec<Scrubber> =
        (0..model.tables.len()).map(|_| Scrubber::new(10_000)).collect();
    let mut found: Vec<(usize, usize)> = Vec::new();
    let t0 = std::time::Instant::now();
    let mut ticks = 0usize;
    while scrubbers.iter().map(|s| s.passes).min().unwrap() == 0 {
        for (t, s) in scrubbers.iter_mut().enumerate() {
            let report = s.scrub_step(&model.tables[t], &model.checksums[t]);
            found.extend(report.corrupted_rows.into_iter().map(|r| (t, r)));
        }
        ticks += 1;
    }
    found.sort_unstable();
    println!(
        "scrubber covered all tables in {ticks} ticks ({:.1} ms total), found {} corrupted rows",
        t0.elapsed().as_secs_f64() * 1e3,
        found.len()
    );
    assert_eq!(found, injected, "scrubber must find exactly the injected rows");

    // 4. Repair: re-fetch the corrupted rows from the store.
    let clean = DlrmModel::load(&store, Protection::DetectRecompute)?;
    let d = model.cfg.embedding_dim;
    for &(t, row) in &found {
        let src = &clean.tables[t].data[row * d..(row + 1) * d];
        model.tables[t].data[row * d..(row + 1) * d].copy_from_slice(src);
    }
    println!("repaired {} rows from the store", found.len());

    // 5. Verify: a full scrub pass is now clean, and inference agrees with
    //    the pristine model.
    for (t, table) in model.tables.iter().enumerate() {
        assert!(Scrubber::full_pass(table, &model.checksums[t]).is_empty());
    }
    let reqs = model.synth_requests(8, &mut rng);
    let (repaired_scores, report) = model.forward(&reqs);
    let (clean_scores, _) = clean.forward(&reqs);
    assert!(report.clean());
    assert_eq!(repaired_scores, clean_scores);
    println!("post-repair scores match the pristine model — recovery complete");
    std::fs::remove_file(&store).ok();
    Ok(())
}
