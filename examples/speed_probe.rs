fn main() {
    use dlrm_abft::gemm::{PackedB, gemm_exec_into};
    use dlrm_abft::util::rng::Pcg32;
    let mut rng = Pcg32::new(1);
    for (m,n,k) in [(150usize,800usize,3200usize),(1,800,3200),(100,512,512),(50,512,256)] {
        let mut a = vec![0u8; m*k]; let mut b = vec![0i8; k*n];
        rng.fill_u8(&mut a); rng.fill_i8(&mut b);
        let p = PackedB::pack(&b, k, n);
        let mut c = vec![0i32; m*n];
        gemm_exec_into(&a,&p,m,&mut c);
        let t0 = std::time::Instant::now();
        let reps = 7;
        for _ in 0..reps { gemm_exec_into(&a,&p,m,&mut c); }
        let dt = t0.elapsed().as_secs_f64()/reps as f64;
        println!("({m},{n},{k}): {:.3} ms, {:.2} Gop/s", dt*1e3, 2.0*(m*n*k) as f64/dt/1e9);
    }
}
