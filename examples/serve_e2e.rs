//! End-to-end serving driver (DESIGN.md E7): the full stack under load.
//!
//! Builds a ~100M-parameter protected DLRM (16 embedding tables × 100k
//! rows × d=64 + MLPs), starts the TCP coordinator with dynamic batching
//! and chaos injection, drives Poisson traffic from concurrent clients,
//! and reports throughput, latency percentiles, and the soft-error
//! detection/recovery ledger. Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example serve_e2e`
//! Env: REQS (default 300), RATE req/s (default 200), CHAOS_P (default 0.1)

use dlrm_abft::bench::workload::poisson_gap;
use dlrm_abft::coordinator::{
    BatchPolicy, ChaosConfig, Client, Engine, ScoreRequest, Server,
};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use dlrm_abft::util::stats::Summary;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn env_or<T: std::str::FromStr>(key: &str, default: T) -> T {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let n_reqs: usize = env_or("REQS", 300);
    let rate: f64 = env_or("RATE", 200.0);
    let chaos_p: f64 = env_or("CHAOS_P", 0.1);

    println!("== serve_e2e: protected DLRM under chaos ==");
    let cfg = DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![512, 256, 64],
        top_mlp: vec![512, 256],
        tables: vec![TableConfig { rows: 100_000, pooling: 40 }; 16],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 2026,
    };
    println!("model: {} parameters ({} tables)", cfg.param_count(), cfg.tables.len());
    let t_build = Instant::now();
    let model = DlrmModel::random(cfg.clone());
    println!(
        "built in {:.1}s, {} MiB of weights",
        t_build.elapsed().as_secs_f64(),
        model.weight_bytes() / (1 << 20)
    );

    let engine = Arc::new(Engine::with_chaos(
        model,
        ChaosConfig { p_weight_flip: chaos_p, p_table_flip: chaos_p / 2.0, seed: 77 },
    ));
    let server = Server::start(
        "127.0.0.1:0",
        Arc::clone(&engine),
        BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(4),
            max_queue: 1024,
            loops: 2,
        },
    )
    .expect("server start");
    println!("serving on {} (chaos p={chaos_p})", server.addr);

    // Drive Poisson traffic from 4 concurrent client threads.
    let addr = server.addr;
    let per_client = n_reqs / 4;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..4u64)
        .map(|cid| {
            let tables = cfg.tables.clone();
            let num_dense = cfg.num_dense;
            std::thread::spawn(move || {
                let mut rng = Pcg32::new(1000 + cid);
                let mut client = Client::connect(&addr).expect("connect");
                let mut lat = Vec::with_capacity(per_client);
                let mut detected = 0usize;
                let mut degraded = 0usize;
                for i in 0..per_client {
                    std::thread::sleep(Duration::from_secs_f64(poisson_gap(rate / 4.0, &mut rng)));
                    let req = ScoreRequest {
                        id: cid * 1_000_000 + i as u64,
                        dense: (0..num_dense).map(|_| rng.next_f32()).collect(),
                        sparse: tables
                            .iter()
                            .map(|t| (0..t.pooling).map(|_| rng.gen_range(0, t.rows)).collect())
                            .collect(),
                    };
                    let t = Instant::now();
                    let resp = client.score(&req).expect("score");
                    lat.push(t.elapsed().as_secs_f64() * 1e3);
                    assert!((0.0..=1.0).contains(&resp.score), "score out of range");
                    detected += resp.detected as usize;
                    degraded += resp.degraded as usize;
                }
                (lat, detected, degraded)
            })
        })
        .collect();

    let mut all_lat = Vec::new();
    let mut detected = 0;
    let mut degraded = 0;
    for h in handles {
        let (lat, det, deg) = h.join().unwrap();
        all_lat.extend(lat);
        detected += det;
        degraded += deg;
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::from(&all_lat);
    println!("\n== results ==");
    println!("requests: {}  wall: {wall:.1}s  throughput: {:.1} req/s", all_lat.len(), all_lat.len() as f64 / wall);
    println!(
        "client latency ms: p50 {:.2}  p95 {:.2}  max {:.2}",
        s.median, s.p95, s.max
    );
    println!("requests served with a detection: {detected}; degraded: {degraded}");

    let mut client = Client::connect(&addr).unwrap();
    let m = client.metrics().unwrap();
    println!("server metrics: {m}");
    let recomputes = m.get("recomputes").and_then(Json::as_usize).unwrap_or(0);
    let detections = m.get("detections").and_then(Json::as_usize).unwrap_or(0);
    println!(
        "\ndetections={detections} recomputes={recomputes} — every transient chaos fault \
         was caught by ABFT and repaired by recompute before responding"
    );
    server.stop();
}
