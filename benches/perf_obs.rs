//! Observability-plane perf harness (PR 7): emits `BENCH_PR7.json`.
//!
//! * Sampling cost — engine `score` req/s and p50/p99 latency with the
//!   span profiler off vs 1-in-64 sampled vs always-on, every policy
//!   site at `Full`. The 1-in-64 column is the production setting; its
//!   req/s cost versus off is the headline number.
//! * Measured overhead — after the always-on leg every site is warm:
//!   the live per-site verify-cost ÷ operator-cost EWMAs from the
//!   policy block, checked against the paper's ceilings (<20% GEMM,
//!   <26% EmbeddingBag).
//! * Stage breakdown — the per-stage span histograms (count, total,
//!   p50/p99) accumulated over the profiled legs.
//!
//! * Flight-recorder idle cost (PR 9, emitted as `BENCH_PR9.json`) —
//!   armed-vs-disarmed `score` throughput on clean traffic, interleaved
//!   A/B legs. Arming must be free when idle: the freeze path hangs off
//!   the fault-only sink emit, so the clean path never consults the
//!   recorder. Acceptance: overhead < 1%.
//!
//! Env: `QUICK=1` shrinks iteration counts; `BENCH_OUT=path` /
//! `BENCH_OUT_PR9=path` override the output files. Run:
//! `cargo bench --bench perf_obs`.

use std::time::Instant;

use dlrm_abft::coordinator::Engine;
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, DlrmRequest, Protection, TableConfig};
use dlrm_abft::gemm::simd_active;
use dlrm_abft::policy::PolicyConfig;
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;

/// Paper §V: full GEMM detection stays below 20% of the operator.
const GEMM_BUDGET: f64 = 0.20;
/// Paper §V: checked EmbeddingBag stays below 26% over a plain gather.
const EB_BUDGET: f64 = 0.26;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// Same shape family as perf_policy's engine model.
fn engine_model() -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![128, 64],
        top_mlp: vec![128],
        tables: vec![TableConfig { rows: 50_000, pooling: 20 }; 4],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0x9047,
    })
}

fn synth(model: &DlrmModel, batch: usize, seed: u64) -> Vec<DlrmRequest> {
    let mut rng = Pcg32::new(seed);
    model.synth_requests(batch, &mut rng)
}

fn quantile_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx] * 1e6
}

/// Throughput with the profiler off / sampled / always-on. Returns the
/// section plus the engine, left warm at sampling 1 for the
/// measured-overhead and stage-breakdown sections.
fn sampling_section(quick: bool) -> (Json, Engine) {
    let iters = if quick { 20 } else { 200 };
    let batch = 16usize;
    let engine = Engine::new(engine_model()).with_policy(PolicyConfig::default());
    let reqs = {
        let model = engine.model.read().unwrap();
        synth(&model, batch, 0x0B57)
    };
    let mut scores = vec![0f32; batch];
    let mut rows = Vec::new();
    let mut rps = Vec::new();
    for (label, n) in [("off", 0u32), ("sampled_1_in_64", 64), ("always_on", 1)] {
        engine.obs().set_sampling(n);
        for _ in 0..3 {
            engine.score(&reqs, &mut scores);
        }
        let mut lats = Vec::with_capacity(iters);
        let t0 = Instant::now();
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(engine.score(&reqs, &mut scores));
            lats.push(t.elapsed().as_secs_f64());
        }
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let r = (iters * batch) as f64 / wall;
        rps.push(r);
        rows.push(Json::obj(vec![
            ("sampling", Json::Str(label.to_string())),
            ("req_per_s", num(round3(r))),
            ("p50_us", num(round3(quantile_us(&lats, 0.50)))),
            ("p99_us", num(round3(quantile_us(&lats, 0.99)))),
        ]));
    }
    // Throughput cost of each profiled setting vs off, in percent
    // (negative = measured faster than off, i.e. run-to-run noise).
    let cost_pct = |r: f64| if r > 0.0 { (rps[0] / r - 1.0) * 100.0 } else { 0.0 };
    let section = Json::obj(vec![
        ("batch", num(batch as f64)),
        ("iters", num(iters as f64)),
        ("by_sampling", Json::Arr(rows)),
        ("sampled_1_in_64_cost_pct", num(round3(cost_pct(rps[1])))),
        ("always_on_cost_pct", num(round3(cost_pct(rps[2])))),
    ]);
    (section, engine)
}

/// The live measured per-site overheads from the policy block, against
/// the paper's class budgets.
fn measured_section(engine: &Engine) -> Json {
    let snap = engine.metrics_snapshot();
    let mut site_rows = Vec::new();
    let (mut gemm_max, mut eb_max) = (0.0f64, 0.0f64);
    if let Some(sites) = snap.path(&["policy", "sites"]).and_then(Json::as_arr) {
        for row in sites {
            let label = row.get("site").and_then(Json::as_str).unwrap_or("?");
            let measured = row.get("overhead_measured").and_then(Json::as_f64);
            if let Some(m) = measured {
                if label.starts_with("gemm/") {
                    gemm_max = gemm_max.max(m);
                } else {
                    eb_max = eb_max.max(m);
                }
            }
            site_rows.push(Json::obj(vec![
                ("site", Json::Str(label.to_string())),
                (
                    "overhead_measured",
                    measured.map_or(Json::Null, |m| num(round3(m))),
                ),
            ]));
        }
    }
    Json::obj(vec![
        ("gemm_budget", num(GEMM_BUDGET)),
        ("eb_budget", num(EB_BUDGET)),
        ("gemm_max_overhead", num(round3(gemm_max))),
        ("eb_max_overhead", num(round3(eb_max))),
        ("gemm_within_budget", Json::Bool(gemm_max > 0.0 && gemm_max <= GEMM_BUDGET)),
        ("eb_within_budget", Json::Bool(eb_max > 0.0 && eb_max <= EB_BUDGET)),
        ("sites", Json::Arr(site_rows)),
    ])
}

/// Armed-vs-disarmed flight recorder on clean traffic: twin engines,
/// interleaved A/B rounds so drift (thermal, frequency, page cache)
/// hits both legs equally. The armed engine carries a full capture pool
/// but never faults, so any measured delta is the cost of *being armed*.
fn flightrec_section(quick: bool) -> Json {
    let iters = if quick { 20 } else { 200 };
    let batch = 16usize;
    let disarmed = Engine::new(engine_model());
    let armed = Engine::new(engine_model());
    armed.arm_flightrec(
        dlrm_abft::obs::DEFAULT_CAPTURES,
        dlrm_abft::detect::Severity::Significant,
    );
    let reqs = {
        let model = disarmed.model.read().unwrap();
        synth(&model, batch, 0x0B58)
    };
    let mut scores = vec![0f32; batch];
    for _ in 0..3 {
        disarmed.score(&reqs, &mut scores);
        armed.score(&reqs, &mut scores);
    }
    let mut wall = [0f64; 2];
    for _ in 0..iters {
        for (i, engine) in [&disarmed, &armed].into_iter().enumerate() {
            let t = Instant::now();
            std::hint::black_box(engine.score(&reqs, &mut scores));
            wall[i] += t.elapsed().as_secs_f64();
        }
    }
    let rps = |w: f64| (iters * batch) as f64 / w;
    let overhead_pct = (wall[1] / wall[0] - 1.0) * 100.0;
    Json::obj(vec![
        ("batch", num(batch as f64)),
        ("iters", num(iters as f64)),
        ("disarmed_req_per_s", num(round3(rps(wall[0])))),
        ("armed_req_per_s", num(round3(rps(wall[1])))),
        ("armed_idle_overhead_pct", num(round3(overhead_pct))),
        // Acceptance: armed-but-idle < 1%. Measured on shared CI iron,
        // so the flag is advisory (noise can exceed the margin); the
        // recorded percentage is the number that matters.
        ("within_1pct", Json::Bool(overhead_pct < 1.0)),
    ])
}

fn host_json() -> Json {
    Json::obj(vec![
        ("avx2", Json::Bool(simd_active())),
        (
            "threads",
            num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
        ),
    ])
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR7.json".into());
    let out_path_pr9 = std::env::var("BENCH_OUT_PR9").unwrap_or_else(|_| "BENCH_PR9.json".into());

    eprintln!("perf_obs: avx2={} quick={quick}", simd_active());
    let (sampling, engine) = sampling_section(quick);
    eprintln!("perf_obs: sampling throughput done");
    let measured = measured_section(&engine);
    let breakdown = engine.obs().stages_json();
    eprintln!("perf_obs: measured overhead + stage breakdown done");

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_obs_pr7".into())),
        ("host", host_json()),
        ("sampling", sampling),
        ("measured_overhead", measured),
        ("stage_breakdown", breakdown),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_obs: wrote {out_path}");

    let flightrec = flightrec_section(quick);
    eprintln!("perf_obs: flight-recorder idle overhead done");
    let doc9 = Json::obj(vec![
        ("bench", Json::Str("perf_flightrec_pr9".into())),
        ("host", host_json()),
        ("flightrec_idle", flightrec),
    ]);
    let text9 = format!("{doc9}");
    std::fs::write(&out_path_pr9, &text9).expect("write bench output");
    println!("{text9}");
    eprintln!("perf_obs: wrote {out_path_pr9}");
}
