//! §Perf bench: naive Alg-2 EB protection vs the fused interleaved-meta
//! layout (the EB hot-path optimization; see abft::eb docs).
//! Env: EB_SCALE=N divides the 4M-row tables.
use dlrm_abft::bench::figures::run_eb_fused_perf;
use dlrm_abft::bench::harness::BenchConfig;

fn main() {
    let scale: usize = std::env::var("EB_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let cfg = BenchConfig { warmup_iters: 2, sample_iters: 11, inner_reps: 1 };
    run_eb_fused_perf(&cfg, scale, &mut std::io::stdout());
}
