//! Shard-layer perf harness: emits `BENCH_PR2.json` so the serving
//! trajectory stays machine-readable across PRs. Covers:
//!
//! * Router throughput — batch forward req/s unsharded vs N ∈ {1, 2, 4}
//!   shards (R = 2): the per-shard fan-out on the global pool vs the
//!   request-parallel local stage.
//! * Failover latency — the first batch that hits a persistently
//!   corrupted primary replica (detect → retry → quarantine → re-serve
//!   shard-batch from the healthy sibling) vs the clean-batch median.
//! * Repair latency — the synchronous re-copy + checksum verify +
//!   re-admit of the quarantined replica.
//!
//! Env: `QUICK=1` shrinks sizes/iterations; `BENCH_OUT=path` overrides
//! the output file. Run: `cargo bench --bench perf_shard`.

use dlrm_abft::bench::harness::{measure, BenchConfig};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, DlrmRequest, Protection, TableConfig};
use dlrm_abft::shard::{ShardPlan, ShardRouter, ShardStore};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

fn bench_model(rows: usize) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![128, 64],
        top_mlp: vec![128],
        tables: vec![TableConfig { rows, pooling: 20 }; 8],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0x5AD2,
    })
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR2.json".into());
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, sample_iters: 3, inner_reps: 1 }
    } else {
        BenchConfig { warmup_iters: 3, sample_iters: 11, inner_reps: 1 }
    };
    let rows = if quick { 4_000 } else { 20_000 };
    let batch = 32usize;

    let model = bench_model(rows);
    let mut rng = Pcg32::new(0x17AF);
    let reqs: Vec<DlrmRequest> = model.synth_requests(batch, &mut rng);

    // Unsharded baseline.
    let local = measure(&cfg, || {}, || {
        std::hint::black_box(model.forward(&reqs));
    });
    let local_rps = batch as f64 / local.median();
    eprintln!("perf_shard: unsharded {local_rps:.1} req/s");

    // Router throughput at N shards × R=2 replicas.
    let mut shard_rows = Vec::new();
    for n in [1usize, 2, 4] {
        let plan = ShardPlan::hash_placement(model.tables.len(), n, 2);
        let store = Arc::new(ShardStore::from_model(&model, plan, 256));
        let router = ShardRouter::new(Arc::clone(&store));
        let routed = measure(&cfg, || {}, || {
            std::hint::black_box(model.forward_with(&reqs, &router));
        });
        let rps = batch as f64 / routed.median();
        eprintln!("perf_shard: N={n} R=2 {rps:.1} req/s");
        shard_rows.push(Json::obj(vec![
            ("num_shards", num(n as f64)),
            ("replicas", num(2.0)),
            ("req_per_s", num(round3(rps))),
            ("vs_unsharded", num(round3(rps / local_rps))),
        ]));
    }

    // Failover latency: corrupt the primary replica of table 0 so the
    // next batch detects persistently, quarantines, and re-serves the
    // shard-batch from the sibling. One-shot by nature (the store heals),
    // so it is timed directly rather than through `measure`.
    let plan = ShardPlan::hash_placement(model.tables.len(), 2, 2);
    let store = Arc::new(ShardStore::from_model(&model, plan, 256));
    let router = ShardRouter::new(Arc::clone(&store));
    let clean = measure(&cfg, || {}, || {
        std::hint::black_box(model.forward_with(&reqs, &router));
    });
    let d = model.cfg.embedding_dim;
    for row in 0..model.tables[0].rows {
        store.flip_table_byte(0, 0, row * d, 0x80);
    }
    let t0 = Instant::now();
    let (_, rep) = model.forward_with(&reqs, &router);
    let failover_batch_s = t0.elapsed().as_secs_f64();
    assert!(rep.shard_failovers >= 1, "failover batch must fail over");

    let t1 = Instant::now();
    let repairs = store.drain_repairs();
    let repair_s = t1.elapsed().as_secs_f64();
    assert!(repairs >= 1);

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_shard_pr2".into())),
        ("quick", Json::Bool(quick)),
        (
            "host",
            Json::obj(vec![(
                "threads",
                num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
            )]),
        ),
        ("rows_per_table", num(rows as f64)),
        ("batch", num(batch as f64)),
        ("unsharded_req_per_s", num(round3(local_rps))),
        ("router", Json::Arr(shard_rows)),
        (
            "failover",
            Json::obj(vec![
                ("clean_batch_us", num(round3(clean.median() * 1e6))),
                ("failover_batch_us", num(round3(failover_batch_s * 1e6))),
                (
                    "failover_added_us",
                    num(round3((failover_batch_s - clean.median()) * 1e6)),
                ),
                ("repair_us", num(round3(repair_s * 1e6))),
            ]),
        ),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_shard: wrote {out_path}");
}
