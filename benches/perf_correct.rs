//! Correction perf harness (PR 6): emits `BENCH_PR6.json`.
//!
//! * Rungs — per-flagged-row latency of the recovery options for a
//!   corrupt GEMM row: `CorrectInPlace` (group localization + one
//!   algebraic entry fix + re-requantize + re-verify) vs `RecomputeUnit`
//!   (full row dot products + re-requantize), plus the batch-level
//!   `FailoverReplica` rung on a sharded store for scale.
//! * EB dual checksum — build and scrub cost of the (C_T, C_W) pair
//!   against a plain single-sum baseline (the pre-PR6 checksum), and the
//!   R=1 self-heal latency on top of a clean scrub pass.
//! * Protected GEMM — measured overhead of the checksum + group columns
//!   over the unprotected kernel vs the §V < 20% budget, next to the
//!   closed-form `AbftGemm::localized_overhead`.
//!
//! Env: `QUICK=1` shrinks iteration counts; `BENCH_OUT=path` overrides
//! the output file. Run: `cargo bench --bench perf_correct`.

use std::sync::Arc;
use std::time::Instant;

use dlrm_abft::abft::{AbftGemm, EbChecksum, Scrubber};
use dlrm_abft::detect::recovery;
use dlrm_abft::dlrm::{AbftLinear, DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::embedding::QuantTable8;
use dlrm_abft::gemm::{gemm_exec_into, simd_active, PackedB};
use dlrm_abft::quant::{quantize_slice_u8, RequantEpilogue};
use dlrm_abft::shard::{ShardPlan, ShardRouter, ShardStore};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// CorrectInPlace vs RecomputeUnit on the same flagged rows, plus the
/// FailoverReplica batch rung for scale. The corrected buffer ends
/// bit-identical to clean after every fix, so each iteration corrupts a
/// fresh random (row, col, bit) without re-copying the accumulator.
fn rungs_section(quick: bool) -> Json {
    let iters = if quick { 300 } else { 3000 };
    let (m, k, n) = (8usize, 256usize, 128usize);
    let mut rng = Pcg32::new(0xC0DE);
    let layer = AbftLinear::random(k, n, false, Protection::DetectRecompute, &mut rng);
    let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32()).collect();
    let (x, xp) = quantize_slice_u8(&xf);
    let (clean_out, _) = layer.forward(&x, m, xp);
    let (clean_c, _) = layer.forward_raw(&x, m);
    let params = layer.requant_params(&x, m, xp);
    let epi = RequantEpilogue {
        spec: params.spec(),
        a_row_sums: &params.a_row_sums,
        b_col_sums: &params.b_col_sums,
        n_out: n,
        relu_floor: 0,
    };
    let abft = layer.abft();
    let nt = abft.n_total();

    let mut c = clean_c.clone();
    let mut out = clean_out.clone();
    let mut t_correct = 0.0;
    for _ in 0..iters {
        let row = rng.gen_range(0, m);
        let col = rng.gen_range(0, n);
        c[row * nt + col] ^= 1 << rng.gen_range_u32(32);
        let t0 = Instant::now();
        let got = recovery::correct_gemm_row(abft, &x, row, m, &epi, &mut c, &mut out);
        t_correct += t0.elapsed().as_secs_f64();
        assert!(got.corrected(), "single flip must correct");
    }
    assert_eq!(c, clean_c, "corrections must restore the clean accumulator");
    let correct_us = t_correct * 1e6 / iters as f64;

    let mut t_recompute = 0.0;
    for _ in 0..iters {
        let row = rng.gen_range(0, m);
        let col = rng.gen_range(0, n);
        c[row * nt + col] ^= 1 << rng.gen_range_u32(32);
        let t0 = Instant::now();
        let ok = recovery::recompute_gemm_row(abft, &x, row, m, &epi, &mut c, &mut out);
        t_recompute += t0.elapsed().as_secs_f64();
        assert!(ok, "recompute must re-verify clean");
    }
    assert_eq!(c, clean_c);
    let recompute_us = t_recompute * 1e6 / iters as f64;

    // FailoverReplica: whole-batch lap restart on a corrupt replica —
    // the rung a sharded EB site falls to when no row can be named.
    let f_iters = if quick { 5 } else { 25 };
    let mut model = DlrmModel::random(DlrmConfig {
        num_dense: 8,
        embedding_dim: 32,
        bottom_mlp: vec![64, 32],
        top_mlp: vec![64],
        tables: vec![TableConfig { rows: 2_000, pooling: 16 }; 2],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0xFA11,
    });
    let reqs = model.synth_requests(8, &mut rng);
    let store = Arc::new(ShardStore::from_model(&model, ShardPlan::hash_placement(2, 1, 2), 256));
    let router = ShardRouter::new(Arc::clone(&store));
    let d = model.cfg.embedding_dim;
    let mut failover_ms = 0.0;
    for _ in 0..f_iters {
        for row in 0..model.tables[0].rows {
            store.flip_table_byte(0, 0, row * d, 0x80);
        }
        let t0 = Instant::now();
        std::hint::black_box(model.forward_with(&reqs, &router));
        failover_ms += t0.elapsed().as_secs_f64() * 1e3;
        store.drain_repairs();
    }
    failover_ms /= f_iters as f64;

    Json::obj(vec![
        ("shape", Json::Str(format!("m{m} k{k} n{n}"))),
        ("iters", num(iters as f64)),
        ("correct_in_place_row_us", num(round3(correct_us))),
        ("recompute_unit_row_us", num(round3(recompute_us))),
        ("recompute_over_correct", num(round3(recompute_us / correct_us))),
        ("failover_replica_batch_ms", num(round3(failover_ms))),
    ])
}

/// Dual (C_T, C_W) checksum vs the single plain sum it replaced: build
/// throughput, scrub-scan throughput, and the R=1 self-heal latency on
/// top of a clean full pass.
fn eb_section(quick: bool) -> Json {
    let (rows, dim) = if quick { (20_000usize, 64usize) } else { (200_000, 64) };
    let iters = if quick { 3 } else { 10 };
    let mut rng = Pcg32::new(0xEB6);
    let table = QuantTable8::random(rows, dim, &mut rng);

    let t0 = Instant::now();
    let mut checksum = EbChecksum::build_8(&table);
    for _ in 1..iters {
        checksum = EbChecksum::build_8(&table);
    }
    let dual_build_s = t0.elapsed().as_secs_f64() / iters as f64;

    // Single-sum baseline: the pre-PR6 checksum walked the same bytes
    // but accumulated only the plain sum.
    let mut c_t = vec![0i32; rows];
    let t0 = Instant::now();
    for _ in 0..iters {
        for (row, slot) in c_t.iter_mut().enumerate() {
            let mut s = 0i32;
            for &q in table.row(row) {
                s += q as i32;
            }
            *slot = s;
        }
        std::hint::black_box(&c_t);
    }
    let single_build_s = t0.elapsed().as_secs_f64() / iters as f64;

    let t0 = Instant::now();
    for _ in 0..iters {
        assert!(Scrubber::full_pass(&table, &checksum).is_empty());
    }
    let dual_scan_s = t0.elapsed().as_secs_f64() / iters as f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        for (row, want) in c_t.iter().enumerate() {
            let mut s = 0i32;
            for &q in table.row(row) {
                s += q as i32;
            }
            assert_eq!(s, *want);
        }
    }
    let single_scan_s = t0.elapsed().as_secs_f64() / iters as f64;

    // Self-heal: one corrupt slot per full pass on an R=1 store — the
    // delta over the clean pass is the localize + rewrite + re-verify.
    let heal_rows = 4_000usize;
    let h_iters = if quick { 5 } else { 20 };
    let model = DlrmModel::random(DlrmConfig {
        num_dense: 4,
        embedding_dim: dim,
        bottom_mlp: vec![16, dim],
        top_mlp: vec![16],
        tables: vec![TableConfig { rows: heal_rows, pooling: 8 }],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0x5E1F,
    });
    let store = ShardStore::from_model(&model, ShardPlan::hash_placement(1, 1, 1), heal_rows);
    let t0 = Instant::now();
    for _ in 0..h_iters {
        assert_eq!(store.scrub_full(), 0);
    }
    let clean_pass_ms = t0.elapsed().as_secs_f64() * 1e3 / h_iters as f64;
    let mut heal_ms = 0.0;
    for i in 0..h_iters {
        store.flip_table_byte(0, 0, (i * 997) % (heal_rows * dim), 0x04);
        let t0 = Instant::now();
        assert_eq!(store.scrub_full(), 1);
        heal_ms += t0.elapsed().as_secs_f64() * 1e3;
    }
    heal_ms /= h_iters as f64;
    assert_eq!(store.quarantined_replicas(), 0, "every flip must self-heal");

    Json::obj(vec![
        ("table", Json::Str(format!("{rows}x{dim}"))),
        ("dual_build_mrows_s", num(round3(rows as f64 / dual_build_s / 1e6))),
        ("single_build_mrows_s", num(round3(rows as f64 / single_build_s / 1e6))),
        ("dual_over_single_build", num(round3(dual_build_s / single_build_s))),
        ("dual_scan_mrows_s", num(round3(rows as f64 / dual_scan_s / 1e6))),
        ("single_scan_mrows_s", num(round3(rows as f64 / single_scan_s / 1e6))),
        ("dual_over_single_scan", num(round3(dual_scan_s / single_scan_s))),
        ("clean_full_pass_ms", num(round3(clean_pass_ms))),
        ("self_heal_full_pass_ms", num(round3(heal_ms))),
        ("self_heal_extra_ms", num(round3(heal_ms - clean_pass_ms))),
    ])
}

/// Measured protected-GEMM overhead (Eq-3b + group checksum columns +
/// verify) over the unprotected kernel, against the § V < 20% budget.
fn gemm_overhead_section(quick: bool) -> Json {
    let iters = if quick { 5 } else { 30 };
    let shapes = [(128usize, 256usize, 512usize), (16, 128, 256), (4, 512, 64)];
    let mut rng = Pcg32::new(0x63E);
    let mut rows = Vec::new();
    let mut worst = 0.0f64;
    for (m, n, k) in shapes {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let plain = PackedB::pack(&b, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let mut c_plain = vec![0i32; m * n];
        let mut c_prot = vec![0i32; m * abft.n_total()];
        for _ in 0..2 {
            gemm_exec_into(&a, &plain, m, &mut c_plain);
            abft.exec_into(&a, m, &mut c_prot);
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            gemm_exec_into(&a, &plain, m, &mut c_plain);
            std::hint::black_box(&c_plain);
        }
        let plain_s = t0.elapsed().as_secs_f64() / iters as f64;
        let t0 = Instant::now();
        for _ in 0..iters {
            assert!(abft.exec_into(&a, m, &mut c_prot).clean());
        }
        let prot_s = t0.elapsed().as_secs_f64() / iters as f64;
        let measured = prot_s / plain_s - 1.0;
        worst = worst.max(measured);
        rows.push(Json::obj(vec![
            ("shape", Json::Str(format!("m{m} n{n} k{k}"))),
            ("plain_us", num(round3(plain_s * 1e6))),
            ("protected_us", num(round3(prot_s * 1e6))),
            ("measured_overhead", num(round3(measured))),
            ("closed_form", num(round3(AbftGemm::localized_overhead(m, n, k)))),
        ]));
    }
    Json::obj(vec![
        ("budget", num(0.20)),
        ("worst_measured_overhead", num(round3(worst))),
        ("within_budget", Json::Bool(worst < 0.20)),
        ("by_shape", Json::Arr(rows)),
    ])
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR6.json".into());

    eprintln!("perf_correct: avx2={} quick={quick}", simd_active());
    let rungs = rungs_section(quick);
    eprintln!("perf_correct: rung latencies done");
    let eb = eb_section(quick);
    eprintln!("perf_correct: EB dual-checksum done");
    let gemm = gemm_overhead_section(quick);
    eprintln!("perf_correct: protected-GEMM overhead done");

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_correct_pr6".into())),
        (
            "host",
            Json::obj(vec![
                ("avx2", Json::Bool(simd_active())),
                (
                    "threads",
                    num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
                ),
            ]),
        ),
        ("rungs", rungs),
        ("eb_dual_checksum", eb),
        ("gemm_overhead", gemm),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_correct: wrote {out_path}");
}
