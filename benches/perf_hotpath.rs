//! Hot-path perf harness for the SIMD + parallel PR: emits
//! `BENCH_PR1.json` so the bench trajectory is machine-readable across
//! PRs. Covers:
//!
//! * GEMM — DLRM shapes (m ∈ {1, 16}, k,n ∈ 256–1024): scalar vs
//!   single-thread SIMD vs auto (SIMD + row-parallel), GFLOP/s and GB/s,
//!   and ABFT-on overhead % (checksum column + row verification).
//! * EmbeddingBag — scalar vs SIMD bags/s, bag-parallel batch, and
//!   fused-ABFT overhead %.
//! * Engine — end-to-end req/s at 1/4/8 concurrent caller threads with
//!   ABFT on and off (the RwLock read path is what lets this scale).
//!
//! Env: `QUICK=1` shrinks iteration counts; `BENCH_OUT=path` overrides
//! the output file. Run: `cargo bench --bench perf_hotpath`.

use dlrm_abft::abft::{AbftGemm, EbChecksum};
use dlrm_abft::bench::harness::{measure, overhead_pct, BenchConfig};
use dlrm_abft::coordinator::{Engine, ScoreRequest};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::embedding::{bag_sum_8, bag_sum_8_scalar, embedding_bag_8, QuantTable8};
use dlrm_abft::gemm::{
    gemm_exec_into, gemm_exec_into_scalar, gemm_exec_into_st, simd_active, PackedB,
};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

fn gemm_section(cfg: &BenchConfig, rng: &mut Pcg32) -> Json {
    let shapes: &[(usize, usize, usize)] = &[
        (1, 256, 256),
        (1, 512, 512),
        (1, 1024, 1024),
        (16, 256, 256),
        (16, 512, 512),
        (16, 1024, 1024),
    ];
    let mut rows = Vec::new();
    for &(m, k, n) in shapes {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let packed = PackedB::pack(&b, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let mut c = vec![0i32; m * n];
        let mut c_abft = vec![0i32; m * (n + 1)];

        let scalar = measure(cfg, || {}, || gemm_exec_into_scalar(&a, &packed, m, &mut c));
        let simd_st = measure(cfg, || {}, || gemm_exec_into_st(&a, &packed, m, &mut c));
        let auto = measure(cfg, || {}, || gemm_exec_into(&a, &packed, m, &mut c));
        let abft_auto = measure(cfg, || {}, || {
            let verdict = abft.exec_into(&a, m, &mut c_abft);
            std::hint::black_box(verdict.clean());
        });

        let flops = 2.0 * (m * k * n) as f64;
        let bytes = (m * k + k * n + 4 * m * n) as f64;
        let t_simd = simd_st.median();
        let t_auto = auto.median();
        rows.push(Json::obj(vec![
            ("m", num(m as f64)),
            ("k", num(k as f64)),
            ("n", num(n as f64)),
            ("scalar_st_us", num(round3(scalar.median() * 1e6))),
            ("simd_st_us", num(round3(t_simd * 1e6))),
            ("auto_us", num(round3(t_auto * 1e6))),
            ("speedup_simd_st", num(round3(scalar.median() / t_simd))),
            ("speedup_auto", num(round3(scalar.median() / t_auto))),
            ("gflops_simd_st", num(round3(flops / t_simd / 1e9))),
            ("gflops_auto", num(round3(flops / t_auto / 1e9))),
            ("gbs_simd_st", num(round3(bytes / t_simd / 1e9))),
            ("abft_overhead_pct", num(round3(overhead_pct(&auto, &abft_auto)))),
        ]));
    }
    Json::Arr(rows)
}

fn eb_section(cfg: &BenchConfig, rng: &mut Pcg32, quick: bool) -> Json {
    let rows = if quick { 50_000 } else { 400_000 };
    let (d, pooling, batch) = (64usize, 100usize, 256usize);
    let table = QuantTable8::random(rows, d, rng);
    let cs = EbChecksum::build_8(&table);
    let fused = cs.clone().fuse(&table);
    let indices: Vec<usize> = (0..batch * pooling).map(|_| rng.gen_range(0, rows)).collect();
    let offsets: Vec<usize> = (0..batch).map(|b| b * pooling).collect();
    let mut out = vec![0f32; d];

    let scalar = measure(cfg, || {}, || {
        for b in 0..batch {
            bag_sum_8_scalar(
                &table,
                &indices[b * pooling..(b + 1) * pooling],
                None,
                true,
                &mut out,
            );
        }
    });
    let simd = measure(cfg, || {}, || {
        for b in 0..batch {
            bag_sum_8(
                &table,
                &indices[b * pooling..(b + 1) * pooling],
                None,
                true,
                &mut out,
            );
        }
    });
    let parallel = measure(cfg, || {}, || {
        std::hint::black_box(embedding_bag_8(&table, &indices, &offsets, None, true));
    });
    let fused_abft = measure(cfg, || {}, || {
        for b in 0..batch {
            let flag = fused.bag_sum_checked(
                &table,
                &indices[b * pooling..(b + 1) * pooling],
                None,
                true,
                &mut out,
            );
            std::hint::black_box(flag);
        }
    });

    let bags = batch as f64;
    Json::obj(vec![
        ("rows", num(rows as f64)),
        ("d", num(d as f64)),
        ("pooling", num(pooling as f64)),
        ("batch", num(bags)),
        ("scalar_bags_per_s", num(round3(bags / scalar.median()))),
        ("simd_bags_per_s", num(round3(bags / simd.median()))),
        ("parallel_bags_per_s", num(round3(bags / parallel.median()))),
        ("speedup_simd", num(round3(scalar.median() / simd.median()))),
        (
            "speedup_parallel",
            num(round3(scalar.median() / parallel.median())),
        ),
        (
            "abft_on_overhead_pct",
            num(round3(overhead_pct(&simd, &fused_abft))),
        ),
    ])
}

/// Per-batch work deliberately below the kernel-parallel thresholds so
/// the 1→4→8 scaling isolates the RwLock read path (lock-free serving),
/// not nested operator parallelism.
fn engine_model(protection: Protection) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![128, 64],
        top_mlp: vec![128],
        tables: vec![TableConfig { rows: 50_000, pooling: 20 }; 4],
        protection,
        dense_range: (0.0, 1.0),
        seed: 0xE11,
    })
}

fn engine_req_per_s(engine: &Arc<Engine>, threads: usize, iters: usize, batch: usize) -> f64 {
    let reqs: Vec<Vec<ScoreRequest>> = (0..threads)
        .map(|t| {
            let model = engine.model.read().unwrap();
            let mut rng = Pcg32::new(0x7000 + t as u64);
            model
                .synth_requests(batch, &mut rng)
                .into_iter()
                .enumerate()
                .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
                .collect()
        })
        .collect();
    // Warmup.
    engine.process_batch(reqs[0].clone());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for tr in &reqs {
            s.spawn(move || {
                for _ in 0..iters {
                    std::hint::black_box(engine.process_batch(tr.clone()));
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    (threads * iters * batch) as f64 / wall
}

fn engine_section(quick: bool) -> Json {
    let iters = if quick { 6 } else { 30 };
    let batch = 16;
    let mut rows = Vec::new();
    let on = Arc::new(Engine::new(engine_model(Protection::DetectRecompute)));
    let off = Arc::new(Engine::new(engine_model(Protection::Off)));
    let mut one_thread = 0.0;
    let mut four_thread = 0.0;
    for threads in [1usize, 4, 8] {
        let abft = engine_req_per_s(&on, threads, iters, batch);
        let plain = engine_req_per_s(&off, threads, iters, batch);
        if threads == 1 {
            one_thread = abft;
        }
        if threads == 4 {
            four_thread = abft;
        }
        rows.push(Json::obj(vec![
            ("threads", num(threads as f64)),
            ("abft_req_per_s", num(round3(abft))),
            ("noabft_req_per_s", num(round3(plain))),
            (
                "abft_overhead_pct",
                num(round3((plain / abft - 1.0) * 100.0)),
            ),
        ]));
    }
    Json::obj(vec![
        ("batch", num(batch as f64)),
        ("iters_per_thread", num(iters as f64)),
        ("by_threads", Json::Arr(rows)),
        ("scaling_1_to_4", {
            let s = if one_thread > 0.0 {
                four_thread / one_thread
            } else {
                0.0
            };
            num(round3(s))
        }),
    ])
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR1.json".into());
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, sample_iters: 3, inner_reps: 1 }
    } else {
        BenchConfig { warmup_iters: 3, sample_iters: 11, inner_reps: 1 }
    };
    let mut rng = Pcg32::new(0xB16B00);

    eprintln!("perf_hotpath: avx2={} quick={quick}", simd_active());
    let gemm = gemm_section(&cfg, &mut rng);
    eprintln!("perf_hotpath: gemm done");
    let eb = eb_section(&cfg, &mut rng, quick);
    eprintln!("perf_hotpath: eb done");
    let engine = engine_section(quick);
    eprintln!("perf_hotpath: engine done");

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_hotpath_pr1".into())),
        (
            "host",
            Json::obj(vec![
                ("avx2", Json::Bool(simd_active())),
                (
                    "threads",
                    num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
                ),
            ]),
        ),
        ("gemm", gemm),
        ("eb", eb),
        ("engine", engine),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_hotpath: wrote {out_path}");
}
