//! Regenerates paper Table III: EmbeddingBag fault-injection campaign
//! (200 high-bit flips, 200 low-bit flips, 400 error-free runs).
//! Env: ROWS=N (default 4,000,000 as in Table I).
use dlrm_abft::bench::figures::{run_table3, run_table3_4bit};
use dlrm_abft::fault::campaign::EbCampaignConfig;

fn main() {
    let rows: usize = std::env::var("ROWS").ok().and_then(|s| s.parse().ok()).unwrap_or(4_000_000);
    let cfg = EbCampaignConfig { table_rows: rows, ..Default::default() };
    run_table3(&cfg, 1, &mut std::io::stdout());
    run_table3_4bit(&cfg, 1, &mut std::io::stdout());
}
