//! Zero-allocation pipeline perf harness (PR 3): emits `BENCH_PR3.json`.
//!
//! * Engine — `Engine::score` req/s at 1/4/8 concurrent caller threads
//!   (the pooled-arena, allocation-free serving core).
//! * Allocations — allocs/request through the legacy allocating wrapper
//!   (`DlrmModel::forward_with`, a fresh arena per call — the pre-PR3
//!   behavior) vs steady-state `Engine::score` (target: 0), counted by a
//!   global counting allocator.
//! * Fused epilogue — per-layer latency of the fused GEMM+requantize+ReLU
//!   kernel vs the two-pass flow (GEMM, then a separate scalar
//!   requantization sweep over the i32 tile), on DLRM layer shapes.
//!
//! Env: `QUICK=1` shrinks iteration counts; `BENCH_OUT=path` overrides
//! the output file. Run: `cargo bench --bench perf_pipeline`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use dlrm_abft::bench::harness::{measure, BenchConfig};
use dlrm_abft::coordinator::Engine;
use dlrm_abft::dlrm::{AbftLinear, DlrmConfig, DlrmModel, DlrmRequest, Protection, TableConfig};
use dlrm_abft::gemm::{gemm_exec_into, simd_active};
use dlrm_abft::quant::{quantize_slice_u8, requantize_exclude_last_col};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use dlrm_abft::util::scratch::GemmScratch;

struct CountingAlloc;
static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// Same shape family as perf_hotpath's engine model: per-batch work below
/// the kernel fan-out gates so thread scaling isolates the serving path.
fn engine_model(protection: Protection) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![128, 64],
        top_mlp: vec![128],
        tables: vec![TableConfig { rows: 50_000, pooling: 20 }; 4],
        protection,
        dense_range: (0.0, 1.0),
        seed: 0xE33,
    })
}

fn synth(model: &DlrmModel, batch: usize, seed: u64) -> Vec<DlrmRequest> {
    let mut rng = Pcg32::new(seed);
    model.synth_requests(batch, &mut rng)
}

fn score_req_per_s(engine: &Arc<Engine>, threads: usize, iters: usize, batch: usize) -> f64 {
    let reqs: Vec<Vec<DlrmRequest>> = {
        let model = engine.model.read().unwrap();
        (0..threads)
            .map(|t| synth(&model, batch, 0x9000 + t as u64))
            .collect()
    };
    // Warmup one arena per caller thread.
    std::thread::scope(|s| {
        for tr in &reqs {
            s.spawn(move || {
                let mut scores = vec![0f32; batch];
                engine.score(tr, &mut scores);
            });
        }
    });
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for tr in &reqs {
            s.spawn(move || {
                let mut scores = vec![0f32; batch];
                for _ in 0..iters {
                    std::hint::black_box(engine.score(tr, &mut scores));
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    (threads * iters * batch) as f64 / wall
}

fn engine_section(quick: bool) -> Json {
    let iters = if quick { 8 } else { 40 };
    let batch = 16;
    let engine = Arc::new(Engine::new(engine_model(Protection::DetectRecompute)));
    let mut rows = Vec::new();
    for threads in [1usize, 4, 8] {
        let rps = score_req_per_s(&engine, threads, iters, batch);
        rows.push(Json::obj(vec![
            ("threads", num(threads as f64)),
            ("req_per_s", num(round3(rps))),
        ]));
    }
    Json::obj(vec![
        ("batch", num(batch as f64)),
        ("iters_per_thread", num(iters as f64)),
        ("by_threads", Json::Arr(rows)),
    ])
}

/// Allocs/request: legacy allocating wrapper vs pooled-arena score path.
fn alloc_section(quick: bool) -> Json {
    let batch = 16usize;
    let iters = if quick { 20 } else { 100 };
    let engine = Engine::new(engine_model(Protection::DetectRecompute));
    let reqs = {
        let model = engine.model.read().unwrap();
        synth(&model, batch, 0xA110)
    };
    let mut scores = vec![0f32; batch];

    // Legacy path: forward_with allocates a fresh arena + every
    // intermediate per call (exactly what every batch paid before PR 3).
    let model = engine.model.read().unwrap();
    model.forward(&reqs); // warmup (lazy pools, table caches)
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..iters {
        std::hint::black_box(model.forward(&reqs));
    }
    let legacy = (ALLOCS.load(Ordering::Relaxed) - before) as f64 / (iters * batch) as f64;
    drop(model);

    // Pooled path: steady-state Engine::score.
    for _ in 0..3 {
        engine.score(&reqs, &mut scores);
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for _ in 0..iters {
        std::hint::black_box(engine.score(&reqs, &mut scores));
    }
    let pooled = (ALLOCS.load(Ordering::Relaxed) - before) as f64 / (iters * batch) as f64;

    Json::obj(vec![
        ("batch", num(batch as f64)),
        ("allocs_per_req_legacy_forward", num(round3(legacy))),
        ("allocs_per_req_engine_score", num(round3(pooled))),
    ])
}

/// Fused epilogue vs two-pass requantization on DLRM layer shapes.
fn fused_section(cfg: &BenchConfig, rng: &mut Pcg32) -> Json {
    let shapes: &[(usize, usize, usize)] = &[(16, 512, 512), (16, 1024, 1024), (1, 512, 512)];
    let mut rows = Vec::new();
    for &(m, k, n) in shapes {
        let layer = AbftLinear::random(k, n, true, Protection::Detect, rng);
        let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32()).collect();
        let (x, xp) = quantize_slice_u8(&xf);
        let mut scratch = GemmScratch::default();
        let mut out = vec![0u8; m * n];
        let fused = measure(cfg, || {}, || {
            std::hint::black_box(layer.forward_into(&x, m, xp, &mut scratch, &mut out));
        });

        // Two-pass: protected GEMM into a reused buffer, then the
        // separate scalar requantize sweep + ReLU clamp (pre-PR3 flow).
        let p = layer.requant_params(&x, m, xp);
        let zero_code = layer.out_qparams.quantize_u8(0.0);
        let mut c_temp = vec![0i32; m * (n + 1)];
        let two_pass = measure(cfg, || {}, || {
            gemm_exec_into(&x, &layer.abft().packed, m, &mut c_temp);
            let mut y = requantize_exclude_last_col(&c_temp, m, n + 1, &p);
            for v in &mut y {
                if *v < zero_code {
                    *v = zero_code;
                }
            }
            std::hint::black_box(y);
        });

        rows.push(Json::obj(vec![
            ("m", num(m as f64)),
            ("k", num(k as f64)),
            ("n", num(n as f64)),
            ("fused_us", num(round3(fused.median() * 1e6))),
            ("two_pass_us", num(round3(two_pass.median() * 1e6))),
            (
                "two_pass_overhead_pct",
                num(round3((two_pass.median() / fused.median() - 1.0) * 100.0)),
            ),
        ]));
    }
    Json::Arr(rows)
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR3.json".into());
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, sample_iters: 3, inner_reps: 1 }
    } else {
        BenchConfig { warmup_iters: 3, sample_iters: 11, inner_reps: 1 }
    };
    let mut rng = Pcg32::new(0x93E11);

    eprintln!("perf_pipeline: avx2={} quick={quick}", simd_active());
    let fused = fused_section(&cfg, &mut rng);
    eprintln!("perf_pipeline: fused epilogue done");
    let allocs = alloc_section(quick);
    eprintln!("perf_pipeline: alloc counts done");
    let engine = engine_section(quick);
    eprintln!("perf_pipeline: engine done");

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_pipeline_pr3".into())),
        (
            "host",
            Json::obj(vec![
                ("avx2", Json::Bool(simd_active())),
                (
                    "threads",
                    num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
                ),
            ]),
        ),
        ("fused_epilogue", fused),
        ("allocations", allocs),
        ("engine_score", engine),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_pipeline: wrote {out_path}");
}
