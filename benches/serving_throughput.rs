//! Serving-layer bench: end-to-end throughput/latency of the coordinator
//! with ABFT on vs off, and under chaos injection — quantifies what the
//! paper's <20% operator overhead means at the service level.
//! Env: REQS=N (default 400), BATCH=N (default 16).

use dlrm_abft::coordinator::{ChaosConfig, Engine, ScoreRequest};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::util::rng::Pcg32;
use std::time::Instant;

fn model(protection: Protection) -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![256, 128, 64],
        top_mlp: vec![256, 64],
        tables: vec![TableConfig { rows: 100_000, pooling: 50 }; 8],
        protection,
        dense_range: (0.0, 1.0),
        seed: 99,
    })
}

fn requests(m: &DlrmModel, n: usize) -> Vec<ScoreRequest> {
    let mut rng = Pcg32::new(7);
    m.synth_requests(n, &mut rng)
        .into_iter()
        .enumerate()
        .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
        .collect()
}

fn drive(engine: &Engine, reqs: &[ScoreRequest], batch: usize) -> (f64, f64) {
    let t0 = Instant::now();
    for chunk in reqs.chunks(batch) {
        let resps = engine.process_batch(chunk.to_vec());
        std::hint::black_box(&resps);
    }
    let dt = t0.elapsed().as_secs_f64();
    let qps = reqs.len() as f64 / dt;
    let mean_lat = engine.metrics.latency.mean_us();
    (qps, mean_lat)
}

fn main() {
    let n: usize = std::env::var("REQS").ok().and_then(|s| s.parse().ok()).unwrap_or(400);
    let batch: usize = std::env::var("BATCH").ok().and_then(|s| s.parse().ok()).unwrap_or(16);
    println!("# Serving throughput ({n} requests, batch {batch}, 8x100k tables, d=64)");

    let m_off = model(Protection::Off);
    let reqs = requests(&m_off, n);
    let e_off = Engine::new(m_off);
    let (qps_off, lat_off) = drive(&e_off, &reqs, batch);
    println!("protection=off              {qps_off:>8.1} req/s  mean_batch_lat {lat_off:>9.0} us");

    let e_on = Engine::new(model(Protection::DetectRecompute));
    let (qps_on, lat_on) = drive(&e_on, &reqs, batch);
    println!("protection=detect_recompute {qps_on:>8.1} req/s  mean_batch_lat {lat_on:>9.0} us");
    println!(
        "service-level ABFT overhead: {:+.2}% qps, {:+.2}% latency",
        (qps_off / qps_on - 1.0) * 100.0,
        (lat_on / lat_off - 1.0) * 100.0
    );

    let e_chaos = Engine::with_chaos(
        model(Protection::DetectRecompute),
        ChaosConfig { p_weight_flip: 0.2, p_table_flip: 0.0, seed: 3 },
    );
    let (qps_c, lat_c) = drive(&e_chaos, &reqs, batch);
    let det = e_chaos.metrics.detections.load(std::sync::atomic::Ordering::Relaxed);
    let rec = e_chaos.metrics.recomputes.load(std::sync::atomic::Ordering::Relaxed);
    let deg = e_chaos.metrics.degraded.load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "chaos p=0.2 weight flips    {qps_c:>8.1} req/s  mean_batch_lat {lat_c:>9.0} us  \
         detections={det} recomputes={rec} degraded={deg}"
    );
}
