//! §Perf roofline report: machine ceilings (microbenchmarks) + GEMM
//! kernel placement. Run: `cargo bench --bench perf_roofline`
use dlrm_abft::bench::harness::BenchConfig;
use dlrm_abft::bench::roofline::run_roofline;

fn main() {
    let cfg = BenchConfig { warmup_iters: 2, sample_iters: 9, inner_reps: 1 };
    run_roofline(&cfg, &mut std::io::stdout());
}
