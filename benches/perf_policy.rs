//! Adaptive detection control-plane perf harness (PR 4): emits
//! `BENCH_PR4.json`.
//!
//! * Modes — engine `score` req/s and p50/p99 latency with every site
//!   pinned at `Full` vs `Sampled(1/8)` vs `BoundOnly` vs `Off` (the
//!   detection-overhead dial the controller turns at runtime).
//! * Escalation — latency of the control loop itself on a sharded
//!   engine: persistent replica fault injected under `Sampled(8)` →
//!   batches served + controller ticks + wall time until the victim
//!   site reads `Full`.
//!
//! Env: `QUICK=1` shrinks iteration counts; `BENCH_OUT=path` overrides
//! the output file. Run: `cargo bench --bench perf_policy`.

use std::time::Instant;

use dlrm_abft::coordinator::Engine;
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, DlrmRequest, Protection, TableConfig};
use dlrm_abft::gemm::simd_active;
use dlrm_abft::policy::{DetectionMode, PolicyConfig};
use dlrm_abft::shard::ShardPlan;
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// Same shape family as perf_pipeline's engine model (EB-heavy: the
/// modes move the most work on the bag path).
fn engine_model() -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![128, 64],
        top_mlp: vec![128],
        tables: vec![TableConfig { rows: 50_000, pooling: 20 }; 4],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0x9047,
    })
}

fn synth(model: &DlrmModel, batch: usize, seed: u64) -> Vec<DlrmRequest> {
    let mut rng = Pcg32::new(seed);
    model.synth_requests(batch, &mut rng)
}

fn quantile_us(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx] * 1e6
}

fn mode_section(quick: bool) -> Json {
    let iters = if quick { 20 } else { 200 };
    let batch = 16usize;
    let engine = Engine::new(engine_model()).with_policy(PolicyConfig::default());
    let sites = engine.policy_sites().expect("policy attached").clone();
    let reqs = {
        let model = engine.model.read().unwrap();
        synth(&model, batch, 0x9001)
    };
    let mut scores = vec![0f32; batch];
    let mut rows = Vec::new();
    for (label, mode) in [
        ("full", DetectionMode::Full),
        ("sampled_1_in_8", DetectionMode::Sampled(8)),
        ("bound_only", DetectionMode::BoundOnly),
        ("off", DetectionMode::Off),
    ] {
        sites.set_all(mode);
        // Warmup (arena growth + caches).
        for _ in 0..3 {
            engine.score(&reqs, &mut scores);
        }
        let mut lats = Vec::with_capacity(iters);
        let t0 = Instant::now();
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(engine.score(&reqs, &mut scores));
            lats.push(t.elapsed().as_secs_f64());
        }
        let wall = t0.elapsed().as_secs_f64();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rows.push(Json::obj(vec![
            ("mode", Json::Str(label.to_string())),
            ("req_per_s", num(round3((iters * batch) as f64 / wall))),
            ("p50_us", num(round3(quantile_us(&lats, 0.50)))),
            ("p99_us", num(round3(quantile_us(&lats, 0.99)))),
        ]));
    }
    sites.set_all(DetectionMode::Full);
    Json::obj(vec![
        ("batch", num(batch as f64)),
        ("iters", num(iters as f64)),
        ("by_mode", Json::Arr(rows)),
    ])
}

/// Injected flag → `Full` mode: the control loop's reaction latency.
fn escalation_section() -> Json {
    let model = DlrmModel::random(DlrmConfig {
        num_dense: 4,
        embedding_dim: 32,
        bottom_mlp: vec![16, 32],
        top_mlp: vec![16],
        tables: vec![TableConfig { rows: 2000, pooling: 8 }; 2],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0xE5C,
    });
    let engine = Engine::new(model)
        .with_shards(ShardPlan::hash_placement(2, 1, 2), 2000)
        .with_policy(PolicyConfig::default());
    let sites = engine.policy_sites().unwrap().clone();
    let store = engine.shard_store().unwrap().clone();
    sites.set_all(DetectionMode::Sampled(8));

    let reqs = {
        let model = engine.model.read().unwrap();
        synth(&model, 8, 0xE5C1)
    };
    let mut scores = vec![0f32; 8];
    engine.score(&reqs, &mut scores); // warmup

    // Persistent fault in replica 0's copy of table 0.
    for row in 0..2000 {
        store.flip_table_byte(0, 0, row * 32, 0x80);
    }
    let t0 = Instant::now();
    let mut batches = 0usize;
    let mut ticks = 0usize;
    while sites.eb[0].cell.load() != DetectionMode::Full && batches < 64 {
        engine.score(&reqs, &mut scores);
        batches += 1;
        engine.policy_tick();
        ticks += 1;
    }
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    store.drain_repairs();
    Json::obj(vec![
        ("escalated", Json::Bool(sites.eb[0].cell.load() == DetectionMode::Full)),
        ("batches_to_full", num(batches as f64)),
        ("ticks_to_full", num(ticks as f64)),
        ("wall_ms", num(round3(wall_ms))),
    ])
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR4.json".into());

    eprintln!("perf_policy: avx2={} quick={quick}", simd_active());
    let modes = mode_section(quick);
    eprintln!("perf_policy: mode throughput done");
    let escalation = escalation_section();
    eprintln!("perf_policy: escalation latency done");

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_policy_pr4".into())),
        (
            "host",
            Json::obj(vec![
                ("avx2", Json::Bool(simd_active())),
                (
                    "threads",
                    num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
                ),
            ]),
        ),
        ("modes", modes),
        ("escalation", escalation),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_policy: wrote {out_path}");
}
