//! Validates the paper's §IV-C closed-form detection probabilities against
//! Monte-Carlo fault injection. Env: TRIALS=N (default 2000).
use dlrm_abft::bench::figures::run_analysis;

fn main() {
    let trials: usize = std::env::var("TRIALS").ok().and_then(|s| s.parse().ok()).unwrap_or(2000);
    run_analysis(trials, &mut std::io::stdout());
}
