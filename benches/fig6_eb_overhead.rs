//! Regenerates paper Fig 6 (a: no prefetch, b: prefetch) over the Table-I
//! EmbeddingBag settings. Run: `cargo bench --bench fig6_eb_overhead`
//! Env: EB_SCALE=N divides the 4M-row tables for quick runs.
use dlrm_abft::bench::figures::run_fig6;
use dlrm_abft::bench::harness::BenchConfig;

fn main() {
    let scale: usize = std::env::var("EB_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(1);
    let cfg = BenchConfig { warmup_iters: 2, sample_iters: 11, inner_reps: 1 };
    run_fig6(&cfg, scale, &mut std::io::stdout());
}
