//! Regenerates paper Fig 5: ABFT overhead of low-precision GEMM across the
//! 28 DLRM shapes. Run: `cargo bench --bench fig5_gemm_overhead`
use dlrm_abft::bench::figures::run_fig5;
use dlrm_abft::bench::harness::BenchConfig;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, sample_iters: 5, inner_reps: 1 }
    } else {
        BenchConfig::default()
    };
    run_fig5(&cfg, &mut std::io::stdout());
}
