//! Serving front-end bench (PR 10, emitted as `BENCH_PR10.json`):
//!
//! * **Concurrency sweep** — the threaded (thread-per-connection) server
//!   at C closed-loop connections vs the epoll event-loop server at 4·C
//!   connections, same model, same per-connection request count.
//!   Acceptance: the async front end sustains 4× the connections at
//!   equal-or-better client-side p99 (`p99_ok`).
//! * **Overload drill** — an SLO-armed engine behind the async server
//!   under sustained closed-loop pressure. A sampler watches the
//!   overload floor and the shed counter: detection must step down
//!   (floor > 0) strictly before the first shed
//!   (`degrade_before_shed`). The connection count stays below the
//!   admission queue bound so every shed is the controller's, not a
//!   queue-full bounce.
//!
//! Env: `QUICK=1` (or `--quick`) shrinks connection counts and the
//! drill duration; `BENCH_OUT=path` overrides the output file. Run:
//! `cargo bench --bench perf_serving_async`.

#[cfg(target_os = "linux")]
mod run {
    use dlrm_abft::coordinator::{
        AsyncServer, BatchPolicy, Client, Engine, ReactorOptions, ScoreRequest, Server,
    };
    use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
    use dlrm_abft::gemm::simd_active;
    use dlrm_abft::policy::{OverloadConfig, PolicyConfig};
    use dlrm_abft::util::json::Json;
    use dlrm_abft::util::rng::Pcg32;
    use std::net::SocketAddr;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::thread;
    use std::time::{Duration, Instant};

    fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Medium model: large enough that batch latency is measurable,
    /// small enough that 30+ closed-loop connections stay responsive.
    fn model() -> DlrmModel {
        DlrmModel::random(DlrmConfig {
            num_dense: 13,
            embedding_dim: 32,
            bottom_mlp: vec![128, 64, 32],
            top_mlp: vec![64, 32],
            tables: vec![TableConfig { rows: 20_000, pooling: 30 }; 4],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 99,
        })
    }

    fn requests(m: &DlrmModel, n: usize, seed: u64) -> Vec<ScoreRequest> {
        let mut rng = Pcg32::new(seed);
        m.synth_requests(n, &mut rng)
            .into_iter()
            .enumerate()
            .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
            .collect()
    }

    fn quantile_us(sorted: &[f64], q: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
        sorted[idx] * 1e6
    }

    /// Closed-loop load: `conns` connections, each scoring `per_conn`
    /// requests back to back. Returns sorted client-side latencies (s)
    /// and the wall time (s).
    fn drive_conns(
        addr: SocketAddr,
        conns: usize,
        per_conn: usize,
        reqs: &Arc<Vec<ScoreRequest>>,
    ) -> (Vec<f64>, f64) {
        let t0 = Instant::now();
        let handles: Vec<_> = (0..conns)
            .map(|c| {
                let reqs = Arc::clone(reqs);
                thread::spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let mut lats = Vec::with_capacity(per_conn);
                    for i in 0..per_conn {
                        let req = &reqs[(c * 31 + i) % reqs.len()];
                        let t = Instant::now();
                        client.score(req).expect("score");
                        lats.push(t.elapsed().as_secs_f64());
                    }
                    lats
                })
            })
            .collect();
        let mut all: Vec<f64> = handles
            .into_iter()
            .flat_map(|h| h.join().expect("load thread"))
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (all, wall)
    }

    fn leg_json(label: &str, conns: usize, lats: &[f64], wall: f64) -> Json {
        Json::obj(vec![
            ("front_end", Json::Str(label.into())),
            ("conns", num(conns as f64)),
            ("requests", num(lats.len() as f64)),
            ("qps", num(lats.len() as f64 / wall)),
            ("p50_us", num(quantile_us(lats, 0.50))),
            ("p99_us", num(quantile_us(lats, 0.99))),
            ("p999_us", num(quantile_us(lats, 0.999))),
        ])
    }

    fn sweep_section(quick: bool) -> Json {
        let base_conns = if quick { 4 } else { 8 };
        let per_conn = if quick { 40 } else { 200 };
        let reqs = Arc::new(requests(&model(), 64, 7));
        let bp = BatchPolicy {
            max_batch: 16,
            max_wait: Duration::from_millis(1),
            max_queue: 1024,
            loops: 2,
        };

        let t_engine = Arc::new(Engine::new(model()));
        let t_server = Server::start("127.0.0.1:0", Arc::clone(&t_engine), bp).expect("threaded");
        let (t_lats, t_wall) = drive_conns(t_server.addr, base_conns, per_conn, &reqs);
        t_server.stop();
        eprintln!(
            "perf_serving_async: threaded {base_conns} conns p99 {:.0} us",
            quantile_us(&t_lats, 0.99)
        );

        let a_engine = Arc::new(Engine::new(model()));
        let a_server =
            AsyncServer::start("127.0.0.1:0", Arc::clone(&a_engine), bp, ReactorOptions::default())
                .expect("async");
        let (a_lats, a_wall) = drive_conns(a_server.addr, base_conns * 4, per_conn, &reqs);
        a_server.stop();
        eprintln!(
            "perf_serving_async: async {} conns p99 {:.0} us",
            base_conns * 4,
            quantile_us(&a_lats, 0.99)
        );

        let t_p99 = quantile_us(&t_lats, 0.99);
        let a_p99 = quantile_us(&a_lats, 0.99);
        Json::obj(vec![
            ("threaded", leg_json("threaded", base_conns, &t_lats, t_wall)),
            ("async_4x", leg_json("epoll", base_conns * 4, &a_lats, a_wall)),
            // Advisory (noise can exceed the margin on shared CI
            // runners); the recorded quantiles are the numbers that
            // matter.
            ("p99_ok", Json::Bool(a_p99 <= t_p99 * 1.05)),
        ])
    }

    /// Sustained overload against an SLO-armed engine. 24 closed-loop
    /// connections against a queue bound of 32: in-flight never reaches
    /// the bound (no queue-full bounce — every shed is the
    /// controller's), while the standing depth sits above the
    /// `queue_frac` pressure line and `should_shed`'s depth watermark,
    /// so the floor walk is observable strictly before the first shed.
    fn drill_section(quick: bool) -> Json {
        let conns = 24usize;
        let secs = if quick { 3.0 } else { 8.0 };
        let engine = Arc::new(
            Engine::new(model())
                .with_policy(PolicyConfig::default())
                .with_overload(OverloadConfig::for_slo_ms(1)),
        );
        let ctl = Arc::clone(engine.overload().expect("overload armed"));
        let bp = BatchPolicy {
            max_batch: 1,
            max_wait: Duration::from_millis(1),
            max_queue: 32,
            loops: 1,
        };
        let server =
            AsyncServer::start("127.0.0.1:0", Arc::clone(&engine), bp, ReactorOptions::default())
                .expect("async");
        let addr = server.addr;
        let stop = Arc::new(AtomicBool::new(false));

        let sampler = {
            let ctl = Arc::clone(&ctl);
            let engine = Arc::clone(&engine);
            let stop = Arc::clone(&stop);
            thread::spawn(move || {
                let t0 = Instant::now();
                let (mut first_degrade_ms, mut first_shed_ms) = (-1.0f64, -1.0f64);
                let mut floor_max = 0u32;
                while !stop.load(Ordering::Relaxed) {
                    let ms = t0.elapsed().as_secs_f64() * 1e3;
                    let lvl = ctl.floor().level();
                    if lvl > 0 && first_degrade_ms < 0.0 {
                        first_degrade_ms = ms;
                    }
                    if engine.metrics.shed.load(Ordering::Relaxed) > 0 && first_shed_ms < 0.0 {
                        first_shed_ms = ms;
                    }
                    floor_max = floor_max.max(lvl);
                    thread::sleep(Duration::from_millis(10));
                }
                (first_degrade_ms, first_shed_ms, floor_max)
            })
        };

        let reqs = Arc::new(requests(&model(), 64, 11));
        let workers: Vec<_> = (0..conns)
            .map(|c| {
                let reqs = Arc::clone(&reqs);
                let stop = Arc::clone(&stop);
                thread::spawn(move || {
                    let mut client = Client::connect(&addr).expect("connect");
                    let (mut served, mut rejected) = (0u64, 0u64);
                    let mut i = 0usize;
                    while !stop.load(Ordering::Relaxed) {
                        let req = &reqs[(c * 17 + i) % reqs.len()];
                        i += 1;
                        match client.score(req) {
                            Ok(_) => served += 1,
                            Err(_) => {
                                // Overload bounce: back off briefly and
                                // keep pressing.
                                rejected += 1;
                                thread::sleep(Duration::from_millis(5));
                            }
                        }
                    }
                    (served, rejected)
                })
            })
            .collect();

        thread::sleep(Duration::from_secs_f64(secs));
        stop.store(true, Ordering::Relaxed);
        let (mut served, mut rejected) = (0u64, 0u64);
        for w in workers {
            let (s, r) = w.join().expect("drill worker");
            served += s;
            rejected += r;
        }
        let (first_degrade_ms, first_shed_ms, floor_max) = sampler.join().expect("sampler");
        let shed = engine.metrics.shed.load(Ordering::Relaxed);
        let state = ctl.state().as_str().to_string();
        let p99_us = ctl.last_p99_us();
        server.stop();
        eprintln!(
            "perf_serving_async: drill served={served} shed={shed} floor_max={floor_max} \
             first_degrade={first_degrade_ms:.0}ms first_shed={first_shed_ms:.0}ms"
        );

        let degrade_before_shed =
            first_degrade_ms >= 0.0 && (first_shed_ms < 0.0 || first_degrade_ms < first_shed_ms);
        Json::obj(vec![
            ("conns", num(conns as f64)),
            ("duration_s", num(secs)),
            ("served", num(served as f64)),
            ("client_rejected", num(rejected as f64)),
            ("shed", num(shed as f64)),
            ("floor_max", num(floor_max as f64)),
            ("first_degrade_ms", num(first_degrade_ms)),
            ("first_shed_ms", num(first_shed_ms)),
            ("final_state", Json::Str(state)),
            ("window_p99_us", num(p99_us as f64)),
            ("degrade_before_shed", Json::Bool(degrade_before_shed)),
        ])
    }

    fn host_json() -> Json {
        Json::obj(vec![
            ("avx2", Json::Bool(simd_active())),
            (
                "threads",
                num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
            ),
        ])
    }

    pub fn main_impl() {
        let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
            || std::env::args().any(|a| a == "--quick");
        let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR10.json".into());
        eprintln!("perf_serving_async: avx2={} quick={quick}", simd_active());

        let sweep = sweep_section(quick);
        eprintln!("perf_serving_async: concurrency sweep done");
        let drill = drill_section(quick);
        eprintln!("perf_serving_async: overload drill done");

        let doc = Json::obj(vec![
            ("bench", Json::Str("perf_serving_async_pr10".into())),
            ("host", host_json()),
            ("concurrency", sweep),
            ("overload_drill", drill),
        ]);
        let text = format!("{doc}");
        std::fs::write(&out_path, &text).expect("write bench output");
        println!("{text}");
        eprintln!("perf_serving_async: wrote {out_path}");
    }
}

#[cfg(target_os = "linux")]
fn main() {
    run::main_impl();
}

#[cfg(not(target_os = "linux"))]
fn main() {
    eprintln!("perf_serving_async: the epoll front end is linux-only; nothing to measure");
}
