//! Regenerates paper Table II: GEMM fault-injection campaign
//! (bit flips in B after encoding, in C_temp, and error-free controls).
//! Env: RUNS=N (default 100 = the paper's 2800-sample campaign).
use dlrm_abft::bench::figures::run_table2;
use dlrm_abft::fault::campaign::GemmCampaignConfig;

fn main() {
    let runs: usize = std::env::var("RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(100);
    let threads: usize = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cfg = GemmCampaignConfig { runs_per_shape: runs, ..Default::default() };
    run_table2(&cfg, threads, &mut std::io::stdout());
}
