//! Design-choice ablations (DESIGN.md E6): BLAS-3 packed checksum vs
//! BLAS-2, 32-bit checksum, encode-A, DMR; modulus sweep.
use dlrm_abft::bench::figures::run_ablations;
use dlrm_abft::bench::harness::BenchConfig;

fn main() {
    run_ablations(&BenchConfig::default(), &mut std::io::stdout());
}
