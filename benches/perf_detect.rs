//! Fault-event pipeline perf harness (PR 5): emits `BENCH_PR5.json`.
//!
//! * Journal — record throughput (events/s), single-threaded and with 4
//!   concurrent writers (the lock-free ring's contention story).
//! * Fault path — per-call latency of a persistently-flagging protected
//!   layer with the sink attached vs detached: the cost of journaling a
//!   detection on top of detecting it.
//! * Ladder — per-rung recovery latencies: `RecomputeUnit` (row
//!   recompute + re-requantize), `RetryBatch` (a full batch forward),
//!   `FailoverReplica` (router lap restart on a corrupt replica), and
//!   `QuarantineAndRepair` (store repair — row-granular single-row vs
//!   whole-copy heavy corruption, the PR 5 repair satellite).
//!
//! Env: `QUICK=1` shrinks iteration counts; `BENCH_OUT=path` overrides
//! the output file. Run: `cargo bench --bench perf_detect`.

use std::sync::Arc;
use std::time::Instant;

use dlrm_abft::detect::{
    Detector, EventSink, FaultEvent, Journal, Recovery, Resolution, Severity, SiteCtx, SiteId,
    UnitRef,
};
use dlrm_abft::dlrm::{AbftLinear, DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::gemm::simd_active;
use dlrm_abft::policy::DetectionMode;
use dlrm_abft::quant::QParams;
use dlrm_abft::shard::{ShardPlan, ShardRouter, ShardStore};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use dlrm_abft::util::scratch::GemmScratch;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

fn sample_event(i: u32) -> FaultEvent {
    FaultEvent {
        tick: i as u64,
        ctl_tick: 0,
        flow: i as u64 + 1,
        site: SiteId::Eb(i % 8),
        unit: UnitRef::Bag { request: i, replica: i % 2 },
        detector: Detector::EbBound,
        severity: Severity::Significant,
        resolution: Resolution::Recovered(Recovery::FailoverReplica),
    }
}

fn journal_section(quick: bool) -> Json {
    let events = if quick { 200_000u32 } else { 2_000_000 };
    let journal = Journal::with_capacity(1024);
    let t0 = Instant::now();
    for i in 0..events {
        journal.record(&sample_event(i));
    }
    let single = events as f64 / t0.elapsed().as_secs_f64();

    let journal = Arc::new(Journal::with_capacity(1024));
    let writers = 4usize;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..writers)
        .map(|w| {
            let j = Arc::clone(&journal);
            std::thread::spawn(move || {
                for i in 0..events / writers as u32 {
                    j.record(&sample_event(w as u32 * 1_000_000 + i));
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let multi = journal.total() as f64 / t0.elapsed().as_secs_f64();
    Json::obj(vec![
        ("capacity", num(1024.0)),
        ("record_per_s_1thread", num(round3(single))),
        ("record_per_s_4threads", num(round3(multi))),
    ])
}

/// One layer whose packed B carries a persistent payload fault — every
/// forward flags and escalates (the worst-case fault path).
fn faulty_layer(k: usize, n: usize) -> AbftLinear {
    let mut rng = Pcg32::new(0xFA17);
    let mut layer = AbftLinear::random(k, n, true, Protection::DetectRecompute, &mut rng);
    let idx = layer.abft().packed.offset(1, 1);
    let data = layer.abft_mut().packed.data_mut();
    data[idx] = (data[idx] as u8 ^ 0x40) as i8;
    layer
}

fn fault_path_section(quick: bool) -> Json {
    let iters = if quick { 200 } else { 2000 };
    let (m, k, n) = (8usize, 256usize, 128usize);
    let layer = faulty_layer(k, n);
    let x = vec![200u8; m * k];
    let xp = QParams::fit_u8(0.0, 1.0);
    let mut out = vec![0u8; m * n];
    let mut scratch = GemmScratch::default();
    let mut rows = Vec::new();
    for (label, sink) in [
        ("sink_detached", EventSink::detached()),
        ("sink_attached", EventSink::with_capacity(1024)),
    ] {
        // Warmup.
        for _ in 0..3 {
            layer.forward_policied(
                &x,
                m,
                xp,
                DetectionMode::Full,
                SiteCtx::new(&sink, SiteId::Gemm(0), None),
                &mut scratch,
                &mut out,
            );
        }
        let t0 = Instant::now();
        for _ in 0..iters {
            std::hint::black_box(layer.forward_policied(
                &x,
                m,
                xp,
                DetectionMode::Full,
                SiteCtx::new(&sink, SiteId::Gemm(0), None),
                &mut scratch,
                &mut out,
            ));
        }
        let us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
        rows.push(Json::obj(vec![
            ("config", Json::Str(label.to_string())),
            ("flagging_forward_us", num(round3(us))),
        ]));
    }
    Json::obj(vec![
        ("shape", Json::Str(format!("m{m} k{k} n{n}, every row flags"))),
        ("iters", num(iters as f64)),
        ("by_config", Json::Arr(rows)),
    ])
}

fn ladder_section(quick: bool) -> Json {
    let iters = if quick { 20 } else { 100 };

    // RecomputeUnit + RetryBatch on a persistently-corrupt local model.
    let mut model = DlrmModel::random(DlrmConfig {
        num_dense: 8,
        embedding_dim: 32,
        bottom_mlp: vec![64, 32],
        top_mlp: vec![64],
        tables: vec![TableConfig { rows: 5_000, pooling: 16 }; 2],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0x1AD0,
    });
    let mut rng = Pcg32::new(0xBEEF);
    let reqs = model.synth_requests(8, &mut rng);
    // Clean batch forward = the RetryBatch rung's cost.
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(model.forward(&reqs));
    }
    let retry_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;

    // Persistent table corruption: per-flagging-batch cost (detect +
    // recompute rung + escalation emission, amortized per batch).
    let victim = reqs[0].sparse[0][0];
    model.tables[0].data[victim * model.cfg.embedding_dim] ^= 0x80;
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(model.forward(&reqs));
    }
    let recompute_ms = t0.elapsed().as_secs_f64() * 1e3 / iters as f64;
    model.tables[0].data[victim * model.cfg.embedding_dim] ^= 0x80; // restore

    // FailoverReplica: router lap restart on a corrupt replica.
    model.events = EventSink::with_capacity(1 << 14);
    let store = Arc::new(ShardStore::from_model(&model, ShardPlan::hash_placement(2, 1, 2), 512));
    let router = ShardRouter::new(Arc::clone(&store));
    let d = model.cfg.embedding_dim;
    let mut failover_ms = 0.0;
    for _ in 0..iters {
        for row in 0..model.tables[0].rows {
            store.flip_table_byte(0, 0, row * d, 0x80);
        }
        let t0 = Instant::now();
        std::hint::black_box(model.forward_with(&reqs, &router));
        failover_ms += t0.elapsed().as_secs_f64() * 1e3;
        store.drain_repairs(); // heals replica 0 back for the next round
    }
    failover_ms /= iters as f64;

    // QuarantineAndRepair: row-granular (1 dirty row) vs whole-copy
    // (heavy corruption) repair latency.
    let mut granular_ms = 0.0;
    for _ in 0..iters {
        store.flip_table_byte(0, 0, victim * d, 0x01);
        store.quarantine(0, 0);
        let t0 = Instant::now();
        store.drain_repairs();
        granular_ms += t0.elapsed().as_secs_f64() * 1e3;
    }
    granular_ms /= iters as f64;
    let rows0 = model.tables[0].rows;
    let mut whole_ms = 0.0;
    for _ in 0..iters {
        for row in 0..rows0 {
            store.flip_table_byte(0, 0, row * d, 0x80);
        }
        store.quarantine(0, 0);
        let t0 = Instant::now();
        store.drain_repairs();
        whole_ms += t0.elapsed().as_secs_f64() * 1e3;
    }
    whole_ms /= iters as f64;

    Json::obj(vec![
        ("retry_batch_forward_ms", num(round3(retry_ms))),
        ("recompute_rung_batch_ms", num(round3(recompute_ms))),
        ("failover_batch_ms", num(round3(failover_ms))),
        ("repair_row_granular_ms", num(round3(granular_ms))),
        ("repair_whole_copy_ms", num(round3(whole_ms))),
        ("repaired_rows_total", num(store.stats.repaired_rows.load(std::sync::atomic::Ordering::Relaxed) as f64)),
    ])
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR5.json".into());

    eprintln!("perf_detect: avx2={} quick={quick}", simd_active());
    let journal = journal_section(quick);
    eprintln!("perf_detect: journal throughput done");
    let fault_path = fault_path_section(quick);
    eprintln!("perf_detect: fault-path latency done");
    let ladder = ladder_section(quick);
    eprintln!("perf_detect: ladder latencies done");

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_detect_pr5".into())),
        (
            "host",
            Json::obj(vec![
                ("avx2", Json::Bool(simd_active())),
                (
                    "threads",
                    num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
                ),
            ]),
        ),
        ("journal", journal),
        ("fault_path", fault_path),
        ("ladder", ladder),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_detect: wrote {out_path}");
}
