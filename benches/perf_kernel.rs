//! Kernel-tier perf harness for the tier-2 dispatch PR: emits
//! `BENCH_PR8.json` so the bench trajectory stays machine-readable.
//! Covers:
//!
//! * GEMM per tier — short-k DLRM shapes (k ∈ {64, 128, 256}) with
//!   acc16-certifiable weights, single-thread GFLOP/s under each kernel
//!   tier cap (scalar / avx2 / acc16 / avx512) plus the resolved tier
//!   each cap actually dispatches on this host. The acceptance headline
//!   is `speedup_acc16_vs_avx2` on the short-k rows (target ≥ 1.5×
//!   where the tier is available).
//! * Protected-GEMM overhead per tier — interleaved A/B samples
//!   (plain exec vs ABFT exec + verify) against the paper's 20% budget.
//! * Engine — end-to-end req/s with the default (highest) tier vs
//!   capped at avx2, protection on.
//!
//! Env: `QUICK=1` shrinks iteration counts; `BENCH_OUT=path` overrides
//! the output file. Run: `cargo bench --bench perf_kernel`.

use dlrm_abft::abft::AbftGemm;
use dlrm_abft::bench::harness::{measure, measure_pair, overhead_pct, BenchConfig};
use dlrm_abft::coordinator::{Engine, ScoreRequest};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
use dlrm_abft::gemm::{
    gemm_exec_into, gemm_exec_into_st, select_tier, set_kernel_tier_override, simd_active,
    KernelTier, PackedB,
};
use dlrm_abft::util::json::Json;
use dlrm_abft::util::rng::Pcg32;
use std::sync::Arc;
use std::time::Instant;

const ALL_TIERS: [KernelTier; 4] = [
    KernelTier::Scalar,
    KernelTier::Avx2,
    KernelTier::Acc16,
    KernelTier::Avx512,
];

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn round3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}

/// Weights in [-8, 8] so every pack carries an acc16 saturation proof
/// (worst pair 255·16 per window slot — certifiable at spill cadence 8)
/// while still exercising signed arithmetic on every tier.
fn small_weights(rng: &mut Pcg32, len: usize) -> Vec<i8> {
    (0..len)
        .map(|_| (rng.gen_range(0, 17) as i32 - 8) as i8)
        .collect()
}

/// Short-k DLRM shapes: MLP layers after feature interaction sit in
/// this k range, which is exactly where the acc16 tier is admissible.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 64, 256),
    (1, 128, 512),
    (16, 64, 256),
    (16, 128, 256),
    (16, 256, 512),
    (64, 128, 512),
    (64, 256, 512),
];

fn tier_section(cfg: &BenchConfig, rng: &mut Pcg32) -> Json {
    let mut rows = Vec::new();
    for &(m, k, n) in SHAPES {
        let mut a = vec![0u8; m * k];
        rng.fill_u8(&mut a);
        let b = small_weights(rng, k * n);
        let packed = PackedB::pack(&b, k, n);
        assert!(
            packed.acc16_proof().is_some(),
            "bench weights must certify acc16 ({m},{k},{n})"
        );
        let mut c = vec![0i32; m * packed.n_total()];

        let flops = 2.0 * (m * k * n) as f64;
        let mut fields: Vec<(&str, Json)> = vec![
            ("m", num(m as f64)),
            ("k", num(k as f64)),
            ("n", num(n as f64)),
        ];
        let mut medians = [0.0f64; 4];
        for (i, cap) in ALL_TIERS.iter().enumerate() {
            set_kernel_tier_override(Some(*cap));
            let resolved = select_tier(&packed);
            let t = measure(cfg, || {}, || gemm_exec_into_st(&a, &packed, m, &mut c));
            medians[i] = t.median();
            match cap {
                KernelTier::Scalar => {
                    fields.push(("gflops_scalar", num(round3(flops / medians[i] / 1e9))))
                }
                KernelTier::Avx2 => {
                    fields.push(("gflops_avx2", num(round3(flops / medians[i] / 1e9))))
                }
                KernelTier::Acc16 => {
                    fields.push(("resolved_acc16", Json::Str(resolved.as_str().into())));
                    fields.push(("gflops_acc16", num(round3(flops / medians[i] / 1e9))));
                }
                KernelTier::Avx512 => {
                    fields.push(("resolved_avx512", Json::Str(resolved.as_str().into())));
                    fields.push(("gflops_avx512", num(round3(flops / medians[i] / 1e9))));
                }
            }
        }
        set_kernel_tier_override(None);
        fields.push(("speedup_acc16_vs_avx2", num(round3(medians[1] / medians[2]))));
        fields.push((
            "speedup_avx512_vs_avx2",
            num(round3(medians[1] / medians[3])),
        ));
        rows.push(Json::obj(fields));
    }
    Json::Arr(rows)
}

fn overhead_section(cfg: &BenchConfig, rng: &mut Pcg32) -> Json {
    // One representative short-k shape per the paper's serving regime.
    let (m, k, n) = (16usize, 256usize, 512usize);
    let mut a = vec![0u8; m * k];
    rng.fill_u8(&mut a);
    let b = small_weights(rng, k * n);
    let packed = PackedB::pack(&b, k, n);
    let abft = AbftGemm::new(&b, k, n);
    let mut c = vec![0i32; m * packed.n_total()];
    let mut c_abft = vec![0i32; m * abft.packed.n_total()];

    let mut rows = Vec::new();
    for cap in ALL_TIERS {
        set_kernel_tier_override(Some(cap));
        let resolved = select_tier(&abft.packed);
        let (plain, protected) = measure_pair(
            cfg,
            || {},
            || gemm_exec_into(&a, &packed, m, &mut c),
            || {
                let verdict = abft.exec_into(&a, m, &mut c_abft);
                std::hint::black_box(verdict.clean());
            },
        );
        let oh = overhead_pct(&plain, &protected);
        rows.push(Json::obj(vec![
            ("cap", Json::Str(cap.as_str().into())),
            ("resolved", Json::Str(resolved.as_str().into())),
            ("plain_us", num(round3(plain.median() * 1e6))),
            ("protected_us", num(round3(protected.median() * 1e6))),
            ("overhead_pct", num(round3(oh))),
            ("within_20pct_budget", Json::Bool(oh < 20.0)),
        ]));
    }
    set_kernel_tier_override(None);
    Json::obj(vec![
        ("m", num(m as f64)),
        ("k", num(k as f64)),
        ("n", num(n as f64)),
        ("budget_pct", num(20.0)),
        ("by_tier", Json::Arr(rows)),
    ])
}

/// Short-k MLP stack so the acc16 tier is admissible end-to-end.
fn engine_model() -> DlrmModel {
    DlrmModel::random(DlrmConfig {
        num_dense: 13,
        embedding_dim: 64,
        bottom_mlp: vec![128, 64],
        top_mlp: vec![128],
        tables: vec![TableConfig { rows: 50_000, pooling: 20 }; 4],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 0xE88,
    })
}

fn engine_req_per_s(engine: &Arc<Engine>, iters: usize, batch: usize) -> f64 {
    let reqs: Vec<ScoreRequest> = {
        let model = engine.model.read().unwrap();
        let mut rng = Pcg32::new(0x8000);
        model
            .synth_requests(batch, &mut rng)
            .into_iter()
            .enumerate()
            .map(|(i, r)| ScoreRequest { id: i as u64, dense: r.dense, sparse: r.sparse })
            .collect()
    };
    engine.process_batch(reqs.clone()); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(engine.process_batch(reqs.clone()));
    }
    (iters * batch) as f64 / t0.elapsed().as_secs_f64()
}

fn engine_section(quick: bool) -> Json {
    let iters = if quick { 4 } else { 20 };
    let batch = 16;
    let engine = Arc::new(Engine::new(engine_model()));

    set_kernel_tier_override(None);
    let best = engine_req_per_s(&engine, iters, batch);
    set_kernel_tier_override(Some(KernelTier::Avx2));
    let avx2 = engine_req_per_s(&engine, iters, batch);
    set_kernel_tier_override(None);

    Json::obj(vec![
        ("batch", num(batch as f64)),
        ("iters", num(iters as f64)),
        ("best_tier_req_per_s", num(round3(best))),
        ("avx2_cap_req_per_s", num(round3(avx2))),
        ("speedup_best_vs_avx2", num(round3(best / avx2))),
    ])
}

fn main() {
    let quick = std::env::var("QUICK").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--quick");
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_PR8.json".into());
    let cfg = if quick {
        BenchConfig { warmup_iters: 1, sample_iters: 3, inner_reps: 1 }
    } else {
        BenchConfig { warmup_iters: 3, sample_iters: 11, inner_reps: 1 }
    };
    let mut rng = Pcg32::new(0xC0FFEE);

    // Which tier would the host dispatch with no cap? Probe on a small
    // certified pack so acc16 eligibility is visible too.
    let probe_b = small_weights(&mut rng, 64 * 32);
    let probe = PackedB::pack(&probe_b, 64, 32);
    set_kernel_tier_override(None);
    let host_tier = select_tier(&probe);

    eprintln!(
        "perf_kernel: avx2={} host_tier={} quick={quick}",
        simd_active(),
        host_tier.as_str()
    );
    let tiers = tier_section(&cfg, &mut rng);
    eprintln!("perf_kernel: tier grid done");
    let overhead = overhead_section(&cfg, &mut rng);
    eprintln!("perf_kernel: overhead done");
    let engine = engine_section(quick);
    eprintln!("perf_kernel: engine done");

    let doc = Json::obj(vec![
        ("bench", Json::Str("perf_kernel_pr8".into())),
        (
            "host",
            Json::obj(vec![
                ("avx2", Json::Bool(simd_active())),
                ("best_tier", Json::Str(host_tier.as_str().into())),
                (
                    "threads",
                    num(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(0) as f64),
                ),
            ]),
        ),
        ("gemm_tiers", tiers),
        ("protected_overhead", overhead),
        ("engine", engine),
    ]);
    let text = format!("{doc}");
    std::fs::write(&out_path, &text).expect("write bench output");
    println!("{text}");
    eprintln!("perf_kernel: wrote {out_path}");
}
