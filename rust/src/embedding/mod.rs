//! Quantized embedding substrate: tables (8/4-bit, per-row scale+bias) and
//! the EmbeddingBag operator (paper §III-C).

pub mod bag;
pub mod table;

pub use bag::{
    bag_sum_4, bag_sum_8, bag_sum_8_scalar, embedding_bag_4, embedding_bag_8, PREFETCH_DISTANCE,
};
pub use table::{QuantTable4, QuantTable8};
