//! The EmbeddingBag operator (paper §III-C): gather rows of a quantized
//! table by an index set and reduce them (plain or weighted sum), with an
//! optional software-prefetch path (Fig 6 benchmarks both).
//!
//! The dequant-accumulate inner loop (`out[j] += α·q[j] + β`) is
//! vectorized 8-wide with AVX2 when the host has it: load 8 u8 codes,
//! widen to i32, convert to f32, then `mul`/`add` in **the same per-lane
//! operation order as the scalar loop** — elements are independent, so
//! the SIMD path is bit-identical to the scalar path (a fused
//! multiply-add would round differently and is deliberately not used).
//! [`embedding_bag_8`] additionally fans out over bags on the global
//! thread pool for large batches; bags write disjoint output rows, so
//! parallel results are bit-identical too.
//!
//! Batch convention follows PyTorch's `EmbeddingBag(indices, offsets)`:
//! `offsets[b]..offsets[b+1]` delimits bag `b`'s slice of `indices`.

use super::table::{QuantTable4, QuantTable8};

/// How far ahead of the current lookup to issue prefetches.
pub const PREFETCH_DISTANCE: usize = 8;

/// Fan-out threshold, hoisted to the threadpool module so every gate
/// retunes in one place; re-exported here for the EB call sites.
pub(crate) use crate::util::threadpool::EB_PAR_MIN_WORK;

#[inline]
fn prefetch_row(data: &[u8], offset: usize) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        if offset < data.len() {
            core::arch::x86_64::_mm_prefetch(
                data.as_ptr().add(offset) as *const i8,
                core::arch::x86_64::_MM_HINT_T0,
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (data, offset);
    }
}

/// `out[j] += a·row[j] + b` over a full row — scalar reference order.
#[inline]
pub(crate) fn axpb_accumulate_scalar(out: &mut [f32], row: &[u8], a: f32, b: f32) {
    for (o, &q) in out.iter_mut().zip(row) {
        *o += a * q as f32 + b;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpb_accumulate_avx2(out: &mut [f32], row: &[u8], a: f32, b: f32) {
    use core::arch::x86_64::*;
    let d = out.len();
    debug_assert_eq!(row.len(), d);
    let av = _mm256_set1_ps(a);
    let bv = _mm256_set1_ps(b);
    let mut j = 0usize;
    while j + 8 <= d {
        let q8 = _mm_loadl_epi64(row.as_ptr().add(j) as *const __m128i);
        let qf = _mm256_cvtepi32_ps(_mm256_cvtepu8_epi32(q8));
        // Same rounding sequence as the scalar loop: (a·q) + b, then +=.
        let t = _mm256_add_ps(_mm256_mul_ps(av, qf), bv);
        let o = _mm256_loadu_ps(out.as_ptr().add(j));
        _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_add_ps(o, t));
        j += 8;
    }
    while j < d {
        *out.get_unchecked_mut(j) += a * *row.get_unchecked(j) as f32 + b;
        j += 1;
    }
}

/// The selected row accumulate routine: `fn(out, row, α, β)`.
pub(crate) type AxpbFn = fn(&mut [f32], &[u8], f32, f32);

#[cfg(target_arch = "x86_64")]
fn axpb_accumulate_avx2_checked(out: &mut [f32], row: &[u8], a: f32, b: f32) {
    // SAFETY: private; only handed out by `select_axpb`, which verified
    // AVX2 on this host first.
    unsafe { axpb_accumulate_avx2(out, row, a, b) };
}

/// Pick the dequant-accumulate routine once (per bag/batch) so the hot
/// loop makes a direct call instead of re-probing the cpu feature per
/// gathered row. Both routines are bit-identical (see module docs).
pub(crate) fn select_axpb() -> AxpbFn {
    #[cfg(target_arch = "x86_64")]
    {
        if crate::gemm::avx2::available() {
            return axpb_accumulate_avx2_checked;
        }
    }
    axpb_accumulate_scalar
}

fn bag_sum_8_impl(
    table: &QuantTable8,
    indices: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
    out: &mut [f32],
    simd: bool,
) {
    let d = table.d;
    assert_eq!(out.len(), d);
    out.fill(0.0);
    if let Some(w) = weights {
        assert_eq!(w.len(), indices.len());
    }
    let row_accum: AxpbFn = if simd {
        select_axpb()
    } else {
        axpb_accumulate_scalar
    };
    for (pos, &idx) in indices.iter().enumerate() {
        assert!(idx < table.rows, "index {idx} out of range");
        if prefetch {
            if let Some(&nxt) = indices.get(pos + PREFETCH_DISTANCE) {
                prefetch_row(&table.data, nxt * d);
            }
        }
        let w = weights.map_or(1.0, |w| w[pos]);
        let a = table.alpha[idx] * w;
        let b = table.beta[idx] * w;
        row_accum(out, table.row(idx), a, b);
    }
}

/// One bag over an 8-bit table: `R = Σ_{i∈I} w_i · (α_i·eb_i + β_i·e_d)`
/// accumulated into `out` (len d), which is zeroed first.
pub fn bag_sum_8(
    table: &QuantTable8,
    indices: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
    out: &mut [f32],
) {
    bag_sum_8_impl(table, indices, weights, prefetch, out, true);
}

/// Always-scalar variant: the reference the SIMD path is tested against
/// and the baseline the perf harness reports speedups over.
pub fn bag_sum_8_scalar(
    table: &QuantTable8,
    indices: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
    out: &mut [f32],
) {
    bag_sum_8_impl(table, indices, weights, prefetch, out, false);
}

/// One bag over a 4-bit table.
pub fn bag_sum_4(
    table: &QuantTable4,
    indices: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
    out: &mut [f32],
) {
    let d = table.d;
    assert_eq!(out.len(), d);
    out.fill(0.0);
    if let Some(w) = weights {
        assert_eq!(w.len(), indices.len());
    }
    let row_bytes = (d + 1) / 2;
    for (pos, &idx) in indices.iter().enumerate() {
        assert!(idx < table.rows, "index {idx} out of range");
        if prefetch {
            if let Some(&nxt) = indices.get(pos + PREFETCH_DISTANCE) {
                prefetch_row(&table.data, nxt * row_bytes);
            }
        }
        let w = weights.map_or(1.0, |w| w[pos]);
        let a = table.alpha[idx] * w;
        let b = table.beta[idx] * w;
        for j in 0..d {
            out[j] += a * table.code(idx, j) as f32 + b;
        }
    }
}

/// Bag `b`'s `[start, end)` slice of the index list.
#[inline]
pub(crate) fn bag_bounds(offsets: &[usize], total: usize, b: usize) -> (usize, usize) {
    let start = offsets[b];
    let end = if b + 1 < offsets.len() {
        offsets[b + 1]
    } else {
        total
    };
    assert!(start <= end && end <= total, "bad offsets");
    (start, end)
}

/// Batched EB over an 8-bit table (PyTorch offsets convention).
/// Output is `batch × d`, row-major; `offsets.len()` is the batch size and
/// `offsets[b+1]` (or `indices.len()` for the last bag) ends bag b.
///
/// Large batches fan out over bags on the global pool (disjoint output
/// rows → bit-identical to the serial loop).
pub fn embedding_bag_8(
    table: &QuantTable8,
    indices: &[usize],
    offsets: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
) -> Vec<f32> {
    let batch = offsets.len();
    let d = table.d;
    let mut out = vec![0f32; batch * d];
    let run_bag = |b: usize, obag: &mut [f32]| {
        let (start, end) = bag_bounds(offsets, indices.len(), b);
        let w = weights.map(|w| &w[start..end]);
        bag_sum_8(table, &indices[start..end], w, prefetch, obag);
    };

    // Bag-chunked fan-out via the shared gate/chunking helper (bags write
    // disjoint rows, so the parallel path stays bit-identical).
    let work = indices.len() * d;
    crate::util::threadpool::global().scope_chunks(&mut out, d, work, EB_PAR_MIN_WORK, |bag0, chunk| {
        for (bi, obag) in chunk.chunks_mut(d).enumerate() {
            run_bag(bag0 + bi, obag);
        }
    });
    out
}

/// Batched EB over a 4-bit table.
pub fn embedding_bag_4(
    table: &QuantTable4,
    indices: &[usize],
    offsets: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
) -> Vec<f32> {
    let batch = offsets.len();
    let d = table.d;
    let mut out = vec![0f32; batch * d];
    for b in 0..batch {
        let (start, end) = bag_bounds(offsets, indices.len(), b);
        let w = weights.map(|w| &w[start..end]);
        bag_sum_4(
            table,
            &indices[start..end],
            w,
            prefetch,
            &mut out[b * d..(b + 1) * d],
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Scalar oracle: dequantize rows fully, then sum in f64.
    fn oracle_8(table: &QuantTable8, indices: &[usize], weights: Option<&[f32]>) -> Vec<f32> {
        let mut out = vec![0f64; table.d];
        for (pos, &i) in indices.iter().enumerate() {
            let w = weights.map_or(1.0, |w| w[pos]) as f64;
            for (j, x) in table.dequantize_row(i).iter().enumerate() {
                out[j] += w * *x as f64;
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }

    #[test]
    fn single_bag_matches_oracle() {
        let mut rng = Pcg32::new(31);
        let table = QuantTable8::random(1000, 64, &mut rng);
        let indices: Vec<usize> = (0..50).map(|_| rng.gen_range(0, 1000)).collect();
        let mut out = vec![0f32; 64];
        bag_sum_8(&table, &indices, None, false, &mut out);
        let exact = oracle_8(&table, &indices, None);
        for (a, b) in out.iter().zip(&exact) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn simd_path_bitwise_equals_scalar() {
        let mut rng = Pcg32::new(38);
        // Odd dims exercise the 8-wide tail; tiny dims the pure-tail case.
        for d in [1usize, 7, 8, 16, 31, 64, 127] {
            let table = QuantTable8::random(500, d, &mut rng);
            let indices: Vec<usize> = (0..80).map(|_| rng.gen_range(0, 500)).collect();
            let weights: Vec<f32> = (0..80).map(|_| rng.next_f32() * 2.0).collect();
            for w in [None, Some(&weights[..])] {
                let mut simd = vec![0f32; d];
                let mut scalar = vec![0f32; d];
                bag_sum_8(&table, &indices, w, false, &mut simd);
                bag_sum_8_scalar(&table, &indices, w, false, &mut scalar);
                assert_eq!(simd, scalar, "d={d} weighted={}", w.is_some());
            }
        }
    }

    #[test]
    fn prefetch_path_bitwise_equal() {
        let mut rng = Pcg32::new(32);
        let table = QuantTable8::random(5000, 128, &mut rng);
        let indices: Vec<usize> = (0..200).map(|_| rng.gen_range(0, 5000)).collect();
        let mut a = vec![0f32; 128];
        let mut b = vec![0f32; 128];
        bag_sum_8(&table, &indices, None, false, &mut a);
        bag_sum_8(&table, &indices, None, true, &mut b);
        assert_eq!(a, b, "prefetch must not change results");
    }

    #[test]
    fn weighted_bag_scales() {
        let mut rng = Pcg32::new(33);
        let table = QuantTable8::random(100, 32, &mut rng);
        let indices = vec![3usize, 7, 7, 42];
        let weights = vec![1.0f32, 0.5, 0.5, 2.0];
        let mut got = vec![0f32; 32];
        bag_sum_8(&table, &indices, Some(&weights), false, &mut got);
        let exact = oracle_8(&table, &indices, Some(&weights));
        for (a, b) in got.iter().zip(&exact) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn batch_offsets_slicing() {
        let mut rng = Pcg32::new(34);
        let table = QuantTable8::random(500, 16, &mut rng);
        let indices = vec![1usize, 2, 3, 10, 20, 30, 40, 99];
        let offsets = vec![0usize, 3, 7]; // bags: [0..3), [3..7), [7..8)
        let out = embedding_bag_8(&table, &indices, &offsets, None, false);
        assert_eq!(out.len(), 3 * 16);
        let mut bag1 = vec![0f32; 16];
        bag_sum_8(&table, &indices[3..7], None, false, &mut bag1);
        assert_eq!(&out[16..32], &bag1[..]);
    }

    #[test]
    fn parallel_batch_bit_identical_to_serial() {
        let mut rng = Pcg32::new(39);
        let (rows, d, batch, pooling) = (4000usize, 64usize, 32usize, 80usize);
        assert!(batch * pooling * d >= super::EB_PAR_MIN_WORK);
        let table = QuantTable8::random(rows, d, &mut rng);
        let indices: Vec<usize> = (0..batch * pooling).map(|_| rng.gen_range(0, rows)).collect();
        let offsets: Vec<usize> = (0..batch).map(|b| b * pooling).collect();
        let par = embedding_bag_8(&table, &indices, &offsets, None, false);
        // Serial reference, bag by bag.
        let mut serial = vec![0f32; batch * d];
        for b in 0..batch {
            bag_sum_8(
                &table,
                &indices[b * pooling..(b + 1) * pooling],
                None,
                false,
                &mut serial[b * d..(b + 1) * d],
            );
        }
        assert_eq!(par, serial);
    }

    #[test]
    fn empty_bag_is_zero() {
        let mut rng = Pcg32::new(35);
        let table = QuantTable8::random(10, 8, &mut rng);
        let out = embedding_bag_8(&table, &[], &[0], None, false);
        assert_eq!(out, vec![0f32; 8]);
    }

    #[test]
    fn four_bit_matches_dequantized_oracle() {
        let mut rng = Pcg32::new(36);
        let table = QuantTable4::random(300, 48, &mut rng);
        let indices: Vec<usize> = (0..40).map(|_| rng.gen_range(0, 300)).collect();
        let mut got = vec![0f32; 48];
        bag_sum_4(&table, &indices, None, true, &mut got);
        let mut exact = vec![0f64; 48];
        for &i in &indices {
            for (j, x) in table.dequantize_row(i).iter().enumerate() {
                exact[j] += *x as f64;
            }
        }
        for (a, b) in got.iter().zip(&exact) {
            assert!((*a as f64 - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_index_panics() {
        let mut rng = Pcg32::new(37);
        let table = QuantTable8::random(10, 8, &mut rng);
        let mut out = vec![0f32; 8];
        bag_sum_8(&table, &[11], None, false, &mut out);
    }
}
