//! The EmbeddingBag operator (paper §III-C): gather rows of a quantized
//! table by an index set and reduce them (plain or weighted sum), with an
//! optional software-prefetch path (Fig 6 benchmarks both).
//!
//! Batch convention follows PyTorch's `EmbeddingBag(indices, offsets)`:
//! `offsets[b]..offsets[b+1]` delimits bag `b`'s slice of `indices`.

use super::table::{QuantTable4, QuantTable8};

/// How far ahead of the current lookup to issue prefetches.
pub const PREFETCH_DISTANCE: usize = 8;

#[inline]
fn prefetch_row(data: &[u8], offset: usize) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        if offset < data.len() {
            core::arch::x86_64::_mm_prefetch(
                data.as_ptr().add(offset) as *const i8,
                core::arch::x86_64::_MM_HINT_T0,
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (data, offset);
    }
}

/// One bag over an 8-bit table: `R = Σ_{i∈I} w_i · (α_i·eb_i + β_i·e_d)`
/// accumulated into `out` (len d), which is zeroed first.
pub fn bag_sum_8(
    table: &QuantTable8,
    indices: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
    out: &mut [f32],
) {
    let d = table.d;
    assert_eq!(out.len(), d);
    out.fill(0.0);
    if let Some(w) = weights {
        assert_eq!(w.len(), indices.len());
    }
    for (pos, &idx) in indices.iter().enumerate() {
        assert!(idx < table.rows, "index {idx} out of range");
        if prefetch {
            if let Some(&nxt) = indices.get(pos + PREFETCH_DISTANCE) {
                prefetch_row(&table.data, nxt * d);
            }
        }
        let w = weights.map_or(1.0, |w| w[pos]);
        let a = table.alpha[idx] * w;
        let b = table.beta[idx] * w;
        let row = table.row(idx);
        for (o, &q) in out.iter_mut().zip(row) {
            *o += a * q as f32 + b;
        }
    }
}

/// One bag over a 4-bit table.
pub fn bag_sum_4(
    table: &QuantTable4,
    indices: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
    out: &mut [f32],
) {
    let d = table.d;
    assert_eq!(out.len(), d);
    out.fill(0.0);
    if let Some(w) = weights {
        assert_eq!(w.len(), indices.len());
    }
    let row_bytes = (d + 1) / 2;
    for (pos, &idx) in indices.iter().enumerate() {
        assert!(idx < table.rows, "index {idx} out of range");
        if prefetch {
            if let Some(&nxt) = indices.get(pos + PREFETCH_DISTANCE) {
                prefetch_row(&table.data, nxt * row_bytes);
            }
        }
        let w = weights.map_or(1.0, |w| w[pos]);
        let a = table.alpha[idx] * w;
        let b = table.beta[idx] * w;
        for j in 0..d {
            out[j] += a * table.code(idx, j) as f32 + b;
        }
    }
}

/// Batched EB over an 8-bit table (PyTorch offsets convention).
/// Output is `batch × d`, row-major; `offsets.len()` is the batch size and
/// `offsets[b+1]` (or `indices.len()` for the last bag) ends bag b.
pub fn embedding_bag_8(
    table: &QuantTable8,
    indices: &[usize],
    offsets: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
) -> Vec<f32> {
    let batch = offsets.len();
    let d = table.d;
    let mut out = vec![0f32; batch * d];
    for b in 0..batch {
        let start = offsets[b];
        let end = if b + 1 < batch { offsets[b + 1] } else { indices.len() };
        assert!(start <= end && end <= indices.len(), "bad offsets");
        let w = weights.map(|w| &w[start..end]);
        bag_sum_8(
            table,
            &indices[start..end],
            w,
            prefetch,
            &mut out[b * d..(b + 1) * d],
        );
    }
    out
}

/// Batched EB over a 4-bit table.
pub fn embedding_bag_4(
    table: &QuantTable4,
    indices: &[usize],
    offsets: &[usize],
    weights: Option<&[f32]>,
    prefetch: bool,
) -> Vec<f32> {
    let batch = offsets.len();
    let d = table.d;
    let mut out = vec![0f32; batch * d];
    for b in 0..batch {
        let start = offsets[b];
        let end = if b + 1 < batch { offsets[b + 1] } else { indices.len() };
        let w = weights.map(|w| &w[start..end]);
        bag_sum_4(
            table,
            &indices[start..end],
            w,
            prefetch,
            &mut out[b * d..(b + 1) * d],
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Scalar oracle: dequantize rows fully, then sum in f64.
    fn oracle_8(table: &QuantTable8, indices: &[usize], weights: Option<&[f32]>) -> Vec<f32> {
        let mut out = vec![0f64; table.d];
        for (pos, &i) in indices.iter().enumerate() {
            let w = weights.map_or(1.0, |w| w[pos]) as f64;
            for (j, x) in table.dequantize_row(i).iter().enumerate() {
                out[j] += w * *x as f64;
            }
        }
        out.into_iter().map(|x| x as f32).collect()
    }

    #[test]
    fn single_bag_matches_oracle() {
        let mut rng = Pcg32::new(31);
        let table = QuantTable8::random(1000, 64, &mut rng);
        let indices: Vec<usize> = (0..50).map(|_| rng.gen_range(0, 1000)).collect();
        let mut out = vec![0f32; 64];
        bag_sum_8(&table, &indices, None, false, &mut out);
        let exact = oracle_8(&table, &indices, None);
        for (a, b) in out.iter().zip(&exact) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn prefetch_path_bitwise_equal() {
        let mut rng = Pcg32::new(32);
        let table = QuantTable8::random(5000, 128, &mut rng);
        let indices: Vec<usize> = (0..200).map(|_| rng.gen_range(0, 5000)).collect();
        let mut a = vec![0f32; 128];
        let mut b = vec![0f32; 128];
        bag_sum_8(&table, &indices, None, false, &mut a);
        bag_sum_8(&table, &indices, None, true, &mut b);
        assert_eq!(a, b, "prefetch must not change results");
    }

    #[test]
    fn weighted_bag_scales() {
        let mut rng = Pcg32::new(33);
        let table = QuantTable8::random(100, 32, &mut rng);
        let indices = vec![3usize, 7, 7, 42];
        let weights = vec![1.0f32, 0.5, 0.5, 2.0];
        let mut got = vec![0f32; 32];
        bag_sum_8(&table, &indices, Some(&weights), false, &mut got);
        let exact = oracle_8(&table, &indices, Some(&weights));
        for (a, b) in got.iter().zip(&exact) {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    fn batch_offsets_slicing() {
        let mut rng = Pcg32::new(34);
        let table = QuantTable8::random(500, 16, &mut rng);
        let indices = vec![1usize, 2, 3, 10, 20, 30, 40, 99];
        let offsets = vec![0usize, 3, 7]; // bags: [0..3), [3..7), [7..8)
        let out = embedding_bag_8(&table, &indices, &offsets, None, false);
        assert_eq!(out.len(), 3 * 16);
        let mut bag1 = vec![0f32; 16];
        bag_sum_8(&table, &indices[3..7], None, false, &mut bag1);
        assert_eq!(&out[16..32], &bag1[..]);
    }

    #[test]
    fn empty_bag_is_zero() {
        let mut rng = Pcg32::new(35);
        let table = QuantTable8::random(10, 8, &mut rng);
        let out = embedding_bag_8(&table, &[], &[0], None, false);
        assert_eq!(out, vec![0f32; 8]);
    }

    #[test]
    fn four_bit_matches_dequantized_oracle() {
        let mut rng = Pcg32::new(36);
        let table = QuantTable4::random(300, 48, &mut rng);
        let indices: Vec<usize> = (0..40).map(|_| rng.gen_range(0, 300)).collect();
        let mut got = vec![0f32; 48];
        bag_sum_4(&table, &indices, None, true, &mut got);
        let mut exact = vec![0f64; 48];
        for &i in &indices {
            for (j, x) in table.dequantize_row(i).iter().enumerate() {
                exact[j] += *x as f64;
            }
        }
        for (a, b) in got.iter().zip(&exact) {
            assert!((*a as f64 - b).abs() < 1e-3 * (1.0 + b.abs()));
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_index_panics() {
        let mut rng = Pcg32::new(37);
        let table = QuantTable8::random(10, 8, &mut rng);
        let mut out = vec![0f32; 8];
        bag_sum_8(&table, &[11], None, false, &mut out);
    }
}
