//! Quantized embedding tables (paper §III-C).
//!
//! Each d-length row is stored as low-precision codes plus one per-row pair
//! of float quantization parameters `(α_i, β_i)`: the real row is
//! `α_i · codes + β_i · e_d`. 8-bit ([`QuantTable8`]) and 4-bit
//! ([`QuantTable4`], nibble-packed) variants are provided — the paper's
//! p ∈ {8, 4} memory-overhead analysis (§V-C).

use crate::quant::{get_nibble, pack_nibbles, QParams4};
use crate::util::rng::Pcg32;

/// 8-bit quantized embedding table: `rows × d` u8 codes, per-row α/β.
#[derive(Clone, Debug)]
pub struct QuantTable8 {
    pub rows: usize,
    pub d: usize,
    pub data: Vec<u8>,
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
}

impl QuantTable8 {
    /// Quantize a float table (rows × d) row-wise.
    pub fn from_float(table: &[f32], rows: usize, d: usize) -> Self {
        assert_eq!(table.len(), rows * d);
        let mut data = vec![0u8; rows * d];
        let mut alpha = vec![0f32; rows];
        let mut beta = vec![0f32; rows];
        for r in 0..rows {
            let row = &table[r * d..(r + 1) * d];
            let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let qp = crate::quant::QParams::fit_u8(lo, hi);
            alpha[r] = qp.alpha;
            beta[r] = qp.beta;
            for (j, &x) in row.iter().enumerate() {
                data[r * d + j] = qp.quantize_u8(x);
            }
        }
        Self {
            rows,
            d,
            data,
            alpha,
            beta,
        }
    }

    /// Synthetic random table — codes uniform in [0,255], α ~ U(0.005,0.02),
    /// β ~ U(-1,1); mirrors the paper's uniform-random evaluation setup.
    pub fn random(rows: usize, d: usize, rng: &mut Pcg32) -> Self {
        let mut data = vec![0u8; rows * d];
        rng.fill_u8(&mut data);
        let alpha = (0..rows).map(|_| 0.005 + 0.015 * rng.next_f32()).collect();
        let beta = (0..rows).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        Self {
            rows,
            d,
            data,
            alpha,
            beta,
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Dequantize one row to f32.
    pub fn dequantize_row(&self, i: usize) -> Vec<f32> {
        let (a, b) = (self.alpha[i], self.beta[i]);
        self.row(i).iter().map(|&q| a * q as f32 + b).collect()
    }

    /// Integer row sum of the stored codes (what ABFT's `C_T` holds).
    pub fn code_row_sum(&self, i: usize) -> i32 {
        self.row(i).iter().map(|&q| q as i32).sum()
    }

    /// Index-weighted integer row sum `Σ_j (j+1)·codes[i][j]` (what the
    /// dual checksum's `C_W` holds). Max value `255·d(d+1)/2` stays well
    /// inside i32 for any realistic embedding dimension.
    pub fn weighted_code_row_sum(&self, i: usize) -> i32 {
        self.row(i)
            .iter()
            .enumerate()
            .map(|(j, &q)| (j as i32 + 1) * q as i32)
            .sum()
    }

    /// Bytes used by codes + qparams.
    pub fn bytes(&self) -> usize {
        self.data.len() + self.rows * 8
    }
}

/// 4-bit quantized embedding table (nibble-packed codes).
#[derive(Clone, Debug)]
pub struct QuantTable4 {
    pub rows: usize,
    pub d: usize,
    /// `rows × ceil(d/2)` packed nibbles.
    pub data: Vec<u8>,
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
    row_bytes: usize,
}

impl QuantTable4 {
    pub fn from_float(table: &[f32], rows: usize, d: usize) -> Self {
        assert_eq!(table.len(), rows * d);
        let row_bytes = (d + 1) / 2;
        let mut data = vec![0u8; rows * row_bytes];
        let mut alpha = vec![0f32; rows];
        let mut beta = vec![0f32; rows];
        for r in 0..rows {
            let row = &table[r * d..(r + 1) * d];
            let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let qp = QParams4::fit(lo, hi);
            alpha[r] = qp.alpha;
            beta[r] = qp.beta;
            let codes: Vec<u8> = row.iter().map(|&x| qp.quantize(x)).collect();
            data[r * row_bytes..(r + 1) * row_bytes].copy_from_slice(&pack_nibbles(&codes));
        }
        Self {
            rows,
            d,
            data,
            alpha,
            beta,
            row_bytes,
        }
    }

    pub fn random(rows: usize, d: usize, rng: &mut Pcg32) -> Self {
        let row_bytes = (d + 1) / 2;
        let mut data = vec![0u8; rows * row_bytes];
        rng.fill_u8(&mut data);
        if d % 2 == 1 {
            // Clear the unused high nibble of each row's last byte so code
            // row sums are well defined.
            for r in 0..rows {
                data[r * row_bytes + row_bytes - 1] &= 0x0f;
            }
        }
        let alpha = (0..rows).map(|_| 0.02 + 0.08 * rng.next_f32()).collect();
        let beta = (0..rows).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        Self {
            rows,
            d,
            data,
            alpha,
            beta,
            row_bytes,
        }
    }

    #[inline]
    pub fn code(&self, row: usize, j: usize) -> u8 {
        get_nibble(&self.data[row * self.row_bytes..(row + 1) * self.row_bytes], j)
    }

    pub fn dequantize_row(&self, i: usize) -> Vec<f32> {
        let (a, b) = (self.alpha[i], self.beta[i]);
        (0..self.d).map(|j| a * self.code(i, j) as f32 + b).collect()
    }

    pub fn code_row_sum(&self, i: usize) -> i32 {
        (0..self.d).map(|j| self.code(i, j) as i32).sum()
    }

    /// Index-weighted row sum (see [`QuantTable8::weighted_code_row_sum`]).
    pub fn weighted_code_row_sum(&self, i: usize) -> i32 {
        (0..self.d).map(|j| (j as i32 + 1) * self.code(i, j) as i32).sum()
    }

    pub fn bytes(&self) -> usize {
        self.data.len() + self.rows * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_float_roundtrip_within_step() {
        let mut rng = Pcg32::new(21);
        let (rows, d) = (10, 16);
        let table: Vec<f32> = (0..rows * d).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        let qt = QuantTable8::from_float(&table, rows, d);
        for r in 0..rows {
            let back = qt.dequantize_row(r);
            for j in 0..d {
                assert!((back[j] - table[r * d + j]).abs() <= qt.alpha[r] * 0.5 + 1e-5);
            }
        }
    }

    #[test]
    fn four_bit_roundtrip_within_step() {
        let mut rng = Pcg32::new(22);
        let (rows, d) = (8, 15); // odd d exercises nibble tail
        let table: Vec<f32> = (0..rows * d).map(|_| rng.next_f32()).collect();
        let qt = QuantTable4::from_float(&table, rows, d);
        for r in 0..rows {
            let back = qt.dequantize_row(r);
            for j in 0..d {
                assert!(
                    (back[j] - table[r * d + j]).abs() <= qt.alpha[r] * 0.5 + 1e-5,
                    "row {r} col {j}"
                );
            }
        }
    }

    #[test]
    fn code_row_sum_matches_manual() {
        let mut rng = Pcg32::new(23);
        let qt = QuantTable8::random(5, 32, &mut rng);
        for r in 0..5 {
            let manual: i32 = qt.row(r).iter().map(|&q| q as i32).sum();
            assert_eq!(qt.code_row_sum(r), manual);
        }
        let q4 = QuantTable4::random(5, 33, &mut rng);
        for r in 0..5 {
            let manual: i32 = (0..33).map(|j| q4.code(r, j) as i32).sum();
            assert_eq!(q4.code_row_sum(r), manual);
        }
    }

    #[test]
    fn memory_overhead_ratio_as_paper() {
        // §V-C: the 32-bit row-sum column costs 32/(p·d) of table memory.
        let mut rng = Pcg32::new(24);
        let d = 128;
        let t8 = QuantTable8::random(1000, d, &mut rng);
        let checksum_bytes = 1000 * 4;
        let ratio = checksum_bytes as f64 / (t8.data.len() as f64);
        assert!((ratio - 32.0 / (8.0 * d as f64)).abs() < 1e-9);
    }
}
