//! The shard router: serves the model's EmbeddingBag stage from the
//! replicated shard store, with the paper's detectors as the control
//! signal for failover.
//!
//! # Serving policy (per bag, `DetectRecompute`)
//!
//! 1. Gather + reduce + Eq-5 verify on the shard's primary (first
//!    healthy) replica — the same fused kernel as the unsharded path, so
//!    clean results are **bit-identical** to [`LocalEbStage`].
//! 2. On a flag: recompute once on the *same* replica. Transient faults
//!    (bus/cache/register) clear here, exactly like the local policy.
//! 3. Still flagged ⇒ the replica's memory is corrupted: quarantine it
//!    (lock-free state flip; other replicas keep serving) and re-serve
//!    the **whole shard-batch** from the next healthy replica — every
//!    value already computed from the corrupt replica is suspect (its
//!    own corruption may sit below the float bound), so nothing from it
//!    is kept. A detected corruption therefore never reaches a served
//!    response while a healthy replica exists.
//! 4. No healthy replica left ⇒ the bag is reported
//!    flagged/unrecovered, which marks the batch degraded upstream —
//!    the R=1 degenerate case.
//!
//! Under `Protection::Detect` the router only reports (no retry, no
//! failover), mirroring the local stage's detect-only semantics; under
//! `Protection::Off` it serves unchecked bags from the primary replica.
//!
//! # Fan-out and merge
//!
//! Shards run in parallel on the global pool (gated like every other
//! fan-out), and within a shard each lap additionally fans out over
//! requests via [`ThreadPool::scope_chunks`] under a single replica
//! read guard — so an N=1 (or placement-skewed) store keeps the same
//! request-level parallelism as the unsharded stage, and the replica
//! lock is taken once per lap, not per bag. Nested scopes are
//! deadlock-free (helping join), so the two levels compose. Each shard
//! job writes into its own dense `batch × slots × d` scratch buffer,
//! pooled in the caller's [`EbScratch`] arena (grow-only, reused across
//! batches — zero steady-state allocation); after the join the scratch
//! rows are **copied** into the model's feature slots. Because every
//! table lives whole on one shard, no float value is ever re-associated
//! across shards — the merge is placement, not arithmetic, hence
//! bit-exact.
//!
//! [`ThreadPool::scope_chunks`]: crate::util::threadpool::ThreadPool::scope_chunks
//!
//! [`LocalEbStage`]: crate::dlrm::LocalEbStage

use crate::detect::{
    recovery, Detector, Recovery, Resolution, Severity, SiteClass, SiteId, UnitRef,
};
use crate::dlrm::scratch::grow;
use crate::dlrm::{DlrmModel, DlrmRequest, EbScratch, EbStage, EbStageReport, Protection};
use crate::embedding::bag_sum_8;
use crate::shard::store::{Shard, ShardStore};
use crate::util::threadpool::EB_PAR_MIN_WORK;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

/// One bag whose flag survived the same-replica retry, staged until the
/// failover's re-serve lap verifies clean — or the ladder exhausts —
/// because the event's resolution is the *terminal* ladder state, and
/// `Recovered(FailoverReplica)` may only be journaled after the
/// re-check passed. Allocation only happens on the fault path — a
/// clean lap never grows the staging vec.
struct PendingBag {
    table: u32,
    request: u32,
    excess: f64,
    threshold: f64,
}

/// Routes EB traffic to shard replicas; plugs into
/// [`DlrmModel::forward_with`] as the [`EbStage`].
pub struct ShardRouter {
    store: Arc<ShardStore>,
}

impl ShardRouter {
    pub fn new(store: Arc<ShardStore>) -> Self {
        Self { store }
    }

    pub fn store(&self) -> &Arc<ShardStore> {
        &self.store
    }

    /// All bags of one shard for the whole batch, written into the
    /// shard's `batch × slots × d` scratch buffer.
    ///
    /// Failover granularity is the **shard-batch**: once a replica is
    /// proven corrupt (a flag that survives the same-replica retry),
    /// every bag this shard already computed for the batch is suspect —
    /// bags whose corruption sits below the float bound would otherwise
    /// slip through while their sibling bag triggered the alarm. So a
    /// failover restarts the whole shard-batch lap on the new primary;
    /// laps are bounded by the replica count (each restart quarantines
    /// one more replica first).
    fn run_shard(
        &self,
        shard: &Shard,
        requests: &[DlrmRequest],
        model: &DlrmModel,
        rep: &mut EbStageReport,
        scratch: &mut [f32],
    ) {
        let d = model.cfg.embedding_dim;
        let protection = model.cfg.protection;
        let policy = &model.policy;
        let slots = shard.tables.len();
        debug_assert_eq!(scratch.len(), requests.len() * slots * d);
        let store = &*self.store;
        let sink = &model.events;
        let max_laps = shard.num_replicas() + 1;
        let mut laps = 0;
        // Bags whose persistent flag triggered a failover, carried
        // across laps with the replica they flagged on. Their events are
        // deferred until a re-serve lap actually verifies clean — a
        // `Recovered(FailoverReplica)` resolution is only journaled
        // after the failover's re-check passed (correlated corruption on
        // the sibling would otherwise turn the claim into a lie).
        let mut staged: Vec<(PendingBag, usize)> = Vec::new();
        loop {
            laps += 1;
            // A lap after the first is the FailoverReplica rung's
            // re-serve: time it as a fault-path span (rare — bypasses
            // the 1-in-n gate).
            let rung_probe = if laps > 1 { model.obs.probe_rare() } else { None };
            let t_lap = rung_probe.map(|_| std::time::Instant::now());
            let primary = store.serving_replica(shard.id);
            // One read guard per lap (not per bag); requests fan out on
            // the pool over disjoint scratch rows — nested scopes are
            // deadlock-free, so this composes with the per-shard spawn.
            // Persistently-flagged bags are staged here until the lap's
            // ladder outcome (failover vs degrade) is known.
            let pending: Mutex<Vec<PendingBag>> = Mutex::new(Vec::new());
            let total = Mutex::new(EbStageReport::default());
            {
                let guard = store.read_replica(shard.id, primary);
                let data = &*guard;
                let work: usize = requests
                    .iter()
                    .flat_map(|r| shard.tables.iter().map(|&t| r.sparse[t].len() * d))
                    .sum();
                crate::util::threadpool::global().scope_chunks(
                    scratch,
                    slots * d,
                    work,
                    EB_PAR_MIN_WORK,
                    |req0, chunk| {
                        let mut local = EbStageReport::default();
                        for (bi, rchunk) in chunk.chunks_mut(slots * d).enumerate() {
                            let req = &requests[req0 + bi];
                            for (slot, &t) in shard.tables.iter().enumerate() {
                                let indices = &req.sparse[t];
                                let out = &mut rchunk[slot * d..(slot + 1) * d];
                                if !protection.enabled() {
                                    bag_sum_8(&data.tables[slot], indices, None, true, out);
                                    continue;
                                }
                                // Per-site policy: the same dispatch as
                                // the local stage (the table is the site
                                // whichever replica serves it).
                                let (telem, check, bound_scale) = policy.eb_bag_policy(t);
                                if !check {
                                    bag_sum_8(&data.tables[slot], indices, None, true, out);
                                    if let Some(tl) = telem {
                                        tl.record(1, 0);
                                    }
                                    continue;
                                }
                                let check0 = data.fused[slot].bag_sum_checked_scaled_ex(
                                    &data.tables[slot],
                                    indices,
                                    None,
                                    true,
                                    bound_scale,
                                    out,
                                );
                                if check0.flagged() {
                                    local.shard_detections += 1;
                                    // Escalation signal: fed at detection
                                    // time through the site's handle,
                                    // independent of the lap's outcome
                                    // and of sink wiring.
                                    if let Some(tl) = telem {
                                        tl.note_flags(1);
                                    }
                                    let severity = Severity::from_eb_margin(
                                        check0.excess,
                                        check0.threshold,
                                    );
                                    let unit = UnitRef::Bag {
                                        request: (req0 + bi) as u32,
                                        replica: primary as u32,
                                    };
                                    if protection == Protection::DetectRecompute {
                                        // Same-replica retry: transient
                                        // faults clear here.
                                        local.recomputed += 1;
                                        let bad = data.fused[slot].bag_sum_checked_scaled(
                                            &data.tables[slot],
                                            indices,
                                            None,
                                            true,
                                            bound_scale,
                                            out,
                                        );
                                        if bad {
                                            // Terminal state unknown until
                                            // the lap decides failover vs
                                            // degrade — stage the event.
                                            pending.lock().unwrap().push(PendingBag {
                                                table: t as u32,
                                                request: (req0 + bi) as u32,
                                                excess: check0.excess,
                                                threshold: check0.threshold,
                                            });
                                        } else {
                                            sink.emit(
                                                SiteId::Eb(t as u32),
                                                unit,
                                                Detector::EbBound,
                                                severity,
                                                Resolution::Recovered(Recovery::RecomputeUnit),
                                            );
                                        }
                                    } else {
                                        // Detect-only: report, serve as-is
                                        // (the local stage's semantics —
                                        // no failover).
                                        local.flagged += 1;
                                        sink.emit(
                                            SiteId::Eb(t as u32),
                                            unit,
                                            Detector::EbBound,
                                            severity,
                                            Resolution::DetectedOnly,
                                        );
                                    }
                                }
                                if let Some(tl) = telem {
                                    tl.record(1, 1);
                                }
                            }
                        }
                        total.lock().unwrap().absorb(&local);
                    },
                );
            }
            if let (Some(p), Some(t0)) = (rung_probe, t_lap) {
                p.span(crate::obs::Stage::FailoverReplica, shard.id as u32, t0);
            }
            let lap_report = total.into_inner().unwrap();
            rep.absorb(&lap_report);
            if lap_report.shard_detections > 0 {
                store
                    .stats
                    .detections
                    .fetch_add(lap_report.shard_detections as u64, Ordering::Relaxed);
            }
            let pending = pending.into_inner().unwrap();
            if pending.is_empty() {
                // This lap verified clean — every bag staged on an
                // earlier (corrupt) lap was re-served here, so its
                // failover re-check has now actually passed and the
                // `Recovered` claim is honest.
                for (bag, replica) in staged.drain(..) {
                    sink.emit(
                        SiteId::Eb(bag.table),
                        UnitRef::Bag { request: bag.request, replica: replica as u32 },
                        Detector::EbBound,
                        Severity::from_eb_margin(bag.excess, bag.threshold),
                        Resolution::Recovered(Recovery::FailoverReplica),
                    );
                }
                return;
            }
            // The same-replica retry rung failed for these bags; the
            // ladder names the next rung for sharded EB traffic —
            // failover to a sibling replica.
            debug_assert_eq!(
                recovery::next_step(SiteClass::EbSharded, Recovery::RecomputeUnit),
                Some(Recovery::FailoverReplica)
            );
            // Persistent corruption on `primary`: quarantine it
            // (lock-free; siblings keep serving) …
            if store.quarantine(shard.id, primary) {
                rep.shard_quarantines += 1;
            }
            // … and re-serve the whole shard-batch from a healthy
            // sibling, discarding everything computed this lap. The
            // events stay staged until that re-serve proves itself.
            if laps < max_laps && store.healthy_replica(shard.id).is_some() {
                staged.extend(pending.into_iter().map(|b| (b, primary)));
                rep.shard_failovers += 1;
                store.stats.failovers.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            // Nowhere to go (R=1 or every replica bad): the ladder is
            // exhausted. Everything staged — including bags whose
            // earlier failover re-serve landed on this proven-corrupt
            // replica — is served degraded, never silently.
            for (bag, replica) in staged.drain(..) {
                sink.emit(
                    SiteId::Eb(bag.table),
                    UnitRef::Bag { request: bag.request, replica: replica as u32 },
                    Detector::EbBound,
                    Severity::from_eb_margin(bag.excess, bag.threshold),
                    Resolution::Degraded,
                );
            }
            for bag in &pending {
                sink.emit(
                    SiteId::Eb(bag.table),
                    UnitRef::Bag { request: bag.request, replica: primary as u32 },
                    Detector::EbBound,
                    Severity::from_eb_margin(bag.excess, bag.threshold),
                    Resolution::Degraded,
                );
            }
            // The batch is marked dirty upstream, one count per
            // persistently-flagged bag of the final lap.
            rep.flagged += pending.len();
            rep.unrecovered += pending.len();
            return;
        }
    }
}

impl EbStage for ShardRouter {
    fn run(
        &self,
        model: &DlrmModel,
        requests: &[DlrmRequest],
        feats: &mut [f32],
        eb: &mut EbScratch,
    ) -> EbStageReport {
        let d = model.cfg.embedding_dim;
        let groups = model.tables.len() + 1;
        let batch = requests.len();
        debug_assert_eq!(feats.len(), batch * groups * d);
        assert_eq!(
            self.store.plan.num_tables(),
            model.tables.len(),
            "router store was built for a different model"
        );
        let shards = self.store.shards();

        // Per-shard fan-out buffers + tallies come from the caller's
        // pooled stage scratch: grown on first use, reused every batch
        // after (the per-batch allocation was a ROADMAP shard open item).
        eb.reset(shards.len());
        for (shard, buf) in shards.iter().zip(eb.bufs.iter_mut()) {
            grow(buf, batch * shard.tables.len() * d);
        }

        let work: usize = requests
            .iter()
            .flat_map(|r| r.sparse.iter())
            .map(|s| s.len() * d)
            .sum();
        let pool = crate::util::threadpool::global();
        let par = self.store.plan.occupied_shards() >= 2 && pool.size() > 1 && work >= EB_PAR_MIN_WORK;
        let jobs = shards
            .iter()
            .zip(eb.bufs.iter_mut())
            .zip(eb.reports.iter_mut())
            .filter(|((shard, _), _)| !shard.tables.is_empty());
        if par {
            pool.scope(|s| {
                for ((shard, buf), rep) in jobs {
                    let scr = &mut buf[..batch * shard.tables.len() * d];
                    s.spawn(move || self.run_shard(shard, requests, model, rep, scr));
                }
            });
        } else {
            for ((shard, buf), rep) in jobs {
                let scr = &mut buf[..batch * shard.tables.len() * d];
                self.run_shard(shard, requests, model, rep, scr);
            }
        }

        // Merge: copy each shard's scratch rows into the global table
        // slots (placement only — bit-exact by construction).
        for (shard, scr) in shards.iter().zip(&eb.bufs) {
            let slots = shard.tables.len();
            for (slot, &t) in shard.tables.iter().enumerate() {
                for b in 0..batch {
                    let src = &scr[(b * slots + slot) * d..(b * slots + slot + 1) * d];
                    let dst_base = b * groups * d + (t + 1) * d;
                    feats[dst_base..dst_base + d].copy_from_slice(src);
                }
            }
        }

        let mut total = EbStageReport::default();
        for r in &eb.reports[..shards.len()] {
            total.absorb(r);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::{DlrmConfig, TableConfig};
    use crate::shard::ShardPlan;
    use crate::util::rng::Pcg32;

    fn model(protection: Protection, seed: u64) -> DlrmModel {
        DlrmModel::random(DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![
                TableConfig { rows: 100, pooling: 5 },
                TableConfig { rows: 80, pooling: 4 },
                TableConfig { rows: 60, pooling: 3 },
            ],
            protection,
            dense_range: (0.0, 1.0),
            seed,
        })
    }

    fn router_for(m: &DlrmModel, n: usize, r: usize) -> ShardRouter {
        let plan = ShardPlan::hash_placement(m.tables.len(), n, r);
        ShardRouter::new(Arc::new(ShardStore::from_model(m, plan, 32)))
    }

    #[test]
    fn routed_scores_bit_identical_to_local() {
        let m = model(Protection::DetectRecompute, 0x11);
        let mut rng = Pcg32::new(1);
        let reqs = m.synth_requests(6, &mut rng);
        let (want, _) = m.forward(&reqs);
        for (n, r) in [(1usize, 1usize), (2, 2), (3, 1), (5, 2)] {
            let router = router_for(&m, n, r);
            let (got, rep) = m.forward_with(&reqs, &router);
            assert_eq!(got, want, "N={n} R={r}");
            assert!(rep.clean());
            assert_eq!(rep.shard_detections, 0);
        }
    }

    #[test]
    fn routed_unprotected_matches_local_unprotected() {
        let m = model(Protection::Off, 0x12);
        let mut rng = Pcg32::new(2);
        let reqs = m.synth_requests(4, &mut rng);
        let (want, _) = m.forward(&reqs);
        let router = router_for(&m, 2, 2);
        let (got, rep) = m.forward_with(&reqs, &router);
        assert_eq!(got, want);
        assert_eq!(rep, crate::dlrm::InferenceReport::default());
    }

    #[test]
    fn persistent_corruption_fails_over_and_matches_clean_scores() {
        let m = model(Protection::DetectRecompute, 0x13);
        let mut rng = Pcg32::new(3);
        let reqs = m.synth_requests(5, &mut rng);
        let (clean, _) = m.forward(&reqs);
        let router = router_for(&m, 2, 2);
        let store = Arc::clone(router.store());
        // Smash the high bit of every row's first code in replica 0 of
        // table 0 — any bag over table 0 must detect persistently.
        let d = m.cfg.embedding_dim;
        let mut shard = 0;
        for row in 0..m.tables[0].rows {
            shard = store.flip_table_byte(0, 0, row * d, 0x80);
        }
        let (got, rep) = m.forward_with(&reqs, &router);
        assert_eq!(got, clean, "failover must serve the clean value");
        assert!(rep.clean(), "router-recovered events must not dirty the batch");
        assert!(rep.shard_detections >= 1);
        assert_eq!(rep.shard_quarantines, 1);
        assert!(rep.shard_failovers >= 1);
        assert_eq!(
            store.replica_state(shard, 0),
            crate::shard::ReplicaState::Quarantined
        );
        // Traffic continues from the healthy replica with no new events.
        let (got2, rep2) = m.forward_with(&reqs, &router);
        assert_eq!(got2, clean);
        assert_eq!(rep2.shard_detections, 0);
        assert_eq!(rep2.shard_quarantines, 0);
    }

    #[test]
    fn r1_unrecovered_marks_batch_dirty() {
        let m = model(Protection::DetectRecompute, 0x14);
        let mut rng = Pcg32::new(4);
        let reqs = m.synth_requests(3, &mut rng);
        let router = router_for(&m, 1, 1);
        let store = Arc::clone(router.store());
        let d = m.cfg.embedding_dim;
        for row in 0..m.tables[1].rows {
            store.flip_table_byte(1, 0, row * d, 0x80);
        }
        let (_, rep) = m.forward_with(&reqs, &router);
        assert!(rep.eb_bags_flagged > 0);
        assert!(rep.eb_bags_unrecovered > 0);
        assert!(!rep.clean());
    }

    #[test]
    fn detect_only_reports_without_failover() {
        let m = model(Protection::Detect, 0x15);
        let mut rng = Pcg32::new(5);
        let reqs = m.synth_requests(3, &mut rng);
        let router = router_for(&m, 2, 2);
        let store = Arc::clone(router.store());
        let d = m.cfg.embedding_dim;
        for row in 0..m.tables[0].rows {
            store.flip_table_byte(0, 0, row * d, 0x80);
        }
        let (_, rep) = m.forward_with(&reqs, &router);
        assert!(rep.eb_bags_flagged > 0);
        assert_eq!(rep.shard_failovers, 0);
        assert_eq!(rep.shard_quarantines, 0);
        assert_eq!(store.quarantined_replicas(), 0);
    }
}
