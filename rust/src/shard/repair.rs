//! Background repair: a dedicated thread that drains the store's repair
//! queue so quarantined replicas are re-copied (checksum-verified — see
//! [`ShardStore::repair`]) and re-admitted **while traffic keeps flowing
//! on the healthy replicas**. The serving path never blocks on a repair:
//! the worker takes the target replica's write lock only for the install,
//! and only quarantined replicas — which the router already skips — are
//! ever written.

use crate::shard::store::ShardStore;
use std::sync::Arc;
use std::thread;

/// Handle to the background repair thread. Dropping it shuts the queue
/// down and joins the thread (a repair in flight completes first).
pub struct RepairWorker {
    store: Arc<ShardStore>,
    handle: Option<thread::JoinHandle<()>>,
}

impl RepairWorker {
    /// Spawn the worker over `store`'s repair queue.
    pub fn spawn(store: Arc<ShardStore>) -> Self {
        let queue_store = Arc::clone(&store);
        let handle = thread::Builder::new()
            .name("shard-repair".into())
            .spawn(move || {
                while let Some((shard, replica)) = queue_store.wait_repair_ticket() {
                    // Outcome lands in the store's stats; NotQuarantined
                    // tickets (stale after a synchronous drain) are no-ops.
                    let _ = queue_store.repair(shard, replica);
                }
            })
            .expect("spawn shard-repair worker");
        Self {
            store,
            handle: Some(handle),
        }
    }
}

impl Drop for RepairWorker {
    fn drop(&mut self) {
        self.store.shutdown_repairs();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
    use crate::shard::{ReplicaState, ShardPlan};
    use std::time::Duration;

    #[test]
    fn worker_repairs_quarantined_replica_in_background() {
        let model = DlrmModel::random(DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![TableConfig { rows: 50, pooling: 4 }],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 9,
        });
        let plan = ShardPlan::hash_placement(1, 1, 2);
        let store = Arc::new(ShardStore::from_model(&model, plan, 16));
        let worker = RepairWorker::spawn(Arc::clone(&store));

        let shard = store.flip_table_byte(0, 1, 3, 0x80);
        assert!(store.quarantine(shard, 1));
        // The worker should repair + re-admit without any synchronous call.
        let mut healthy = false;
        for _ in 0..500 {
            if store.replica_state(shard, 1) == ReplicaState::Healthy {
                healthy = true;
                break;
            }
            thread::sleep(Duration::from_millis(2));
        }
        assert!(healthy, "background repair never re-admitted the replica");
        assert_eq!(store.table_bytes(0, 1), model.tables[0].data);
        drop(worker); // joins cleanly
    }
}
