//! Replicated shard store: the embedding tables partitioned by a
//! [`ShardPlan`], held as R copies per shard, each copy carrying its own
//! fused-ABFT metadata and an incremental scrubber.
//!
//! # Quarantine state machine (per replica)
//!
//! ```text
//!            detection (router persistent-flag, scrub hit)
//!   Healthy ───────────────────────────────────────────────► Quarantined
//!      ▲   \                                                      │
//!      │    └─ R=1 self-heal (scrub hit localized to one slot,    │ repair
//!      │       rewritten in place, both sums re-verified —        │
//!      │       no quarantine; PR 6)                               ▼
//!      └───────────────────────────────────────────────────── Repairing
//!                 (verify failure / no clean source → back to Quarantined)
//! ```
//!
//! * Only **Healthy** replicas serve traffic or act as repair sources.
//! * Quarantine is a lock-free state flip (CAS on an atomic), so flagging
//!   a replica never stalls readers on the other replicas — that is the
//!   zero-downtime property the failover drill tests.
//! * Repair copies from a Healthy replica whose tables pass a **full**
//!   checksum scrub (a replica can be silently corrupted in rows nobody
//!   touched), installs under the target's write lock, re-verifies the
//!   installed bytes against the canonical `C_T` checksums, and only then
//!   re-admits. A dirty source is itself quarantined and queued.
//! * The canonical checksums are store-level and immutable — the paper's
//!   §IV-C assumption that the (much smaller) checksum state is
//!   error-free, now doing double duty as the repair ground truth.

use crate::abft::{EbChecksum, FusedEbAbft, Scrubber};
use crate::detect::{Detector, EventSink, Recovery, Resolution, Severity, SiteId, UnitRef};
use crate::dlrm::DlrmModel;
use crate::embedding::QuantTable8;
use crate::obs::{ObsHandle, Stage};
use crate::policy::PolicyHandle;
use crate::shard::ShardPlan;
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Condvar, Mutex, OnceLock, RwLock, RwLockReadGuard};
use std::time::Instant;

/// Per-replica serving state (stored as an `AtomicU8`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaState {
    Healthy,
    Quarantined,
    Repairing,
}

const HEALTHY: u8 = 0;
const QUARANTINED: u8 = 1;
const REPAIRING: u8 = 2;

impl ReplicaState {
    fn from_u8(v: u8) -> Self {
        match v {
            HEALTHY => ReplicaState::Healthy,
            QUARANTINED => ReplicaState::Quarantined,
            _ => ReplicaState::Repairing,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ReplicaState::Healthy => "healthy",
            ReplicaState::Quarantined => "quarantined",
            ReplicaState::Repairing => "repairing",
        }
    }
}

/// One replica's copy of its shard's tables, slot-indexed per
/// [`ShardPlan::tables_of`]. The fused (α, β, C_T) metadata rides with
/// the copy so the protected bag stays one gather pass per lookup.
#[derive(Clone)]
pub struct ReplicaTables {
    pub tables: Vec<QuantTable8>,
    pub fused: Vec<FusedEbAbft>,
}

struct Replica {
    data: RwLock<ReplicaTables>,
    state: AtomicU8,
    /// One incremental scrubber per slot (proactive cold-row coverage).
    scrub: Mutex<Vec<Scrubber>>,
}

/// One shard: the global table ids it owns and its R replicas.
pub struct Shard {
    pub id: usize,
    /// Global table ids, ascending (slot i ↔ `tables[i]`).
    pub tables: Vec<usize>,
    replicas: Vec<Replica>,
}

impl Shard {
    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }
}

/// Lifetime health counters (all relaxed — they are reporting, not
/// synchronization; the data edges come from the replica locks).
#[derive(Default)]
pub struct ShardStats {
    /// Bags the router flagged while serving.
    pub detections: AtomicU64,
    /// Healthy→Quarantined transitions.
    pub quarantines: AtomicU64,
    /// Bags re-served from a different replica after a persistent flag.
    pub failovers: AtomicU64,
    /// Successful repairs (== re-admissions).
    pub repairs: AtomicU64,
    /// Rows actually rewritten by repairs — with row-granular repair
    /// this is the number of `C_T`-mismatching rows, not whole-shard
    /// copies (see [`ShardStore::repair`]).
    pub repaired_rows: AtomicU64,
    /// Repair attempts that found no clean source or failed verification.
    pub failed_repairs: AtomicU64,
    /// Rows scanned / corrupted rows found by replica scrubbers.
    pub scrubbed_rows: AtomicU64,
    pub scrub_hits: AtomicU64,
    /// Scrub hits healed in place: the dual checksum localized the
    /// corruption to one slot, the slot was rewritten algebraically, and
    /// both sums re-verified — no quarantine, no replica round-trip.
    /// This is what keeps an R=1 store serving through single-slot
    /// corruption instead of degrading.
    pub self_heals: AtomicU64,
}

/// What [`ShardStore::repair`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RepairOutcome {
    /// Copy installed, checksum-verified, replica re-admitted.
    Repaired,
    /// The replica was not quarantined (already healthy or mid-repair).
    NotQuarantined,
    /// No healthy, checksum-clean source replica exists (or the install
    /// failed verification); the replica stays quarantined.
    NoCleanSource,
}

struct RepairQueue {
    tickets: VecDeque<(usize, usize)>,
    shutdown: bool,
}

/// The replicated shard store. See module docs for the state machine.
pub struct ShardStore {
    pub plan: ShardPlan,
    shards: Vec<Shard>,
    /// Canonical per-table `C_T` checksums (global-table-id indexed);
    /// immutable ground truth for scrub and repair verification.
    checksums: Vec<EbChecksum>,
    /// Fault-event emission handle, inherited from the model the store
    /// was built from: scrub hits are journaled as `ScrubExact` events —
    /// `Recovered(CorrectInPlace)` when the self-heal lands, else
    /// escalating to the quarantine-and-repair rung.
    events: EventSink,
    /// Span profiler, inherited from the model like `events`: scrub
    /// scans calibrate the heal-cost EWMA, self-heals and repairs time
    /// their ladder rungs. Detached when the model's is.
    obs: ObsHandle,
    /// Policy handle for routing scrub detections into the victim
    /// table's `eb/<table>` site telemetry (so proactively-found
    /// corruption drives the escalation controller exactly like a
    /// serving-path flag). Set at build time when the model already has
    /// a policy, else post-hoc by `Engine::with_policy` — the engine
    /// builds the store before the control plane.
    policy: OnceLock<PolicyHandle>,
    pub stats: ShardStats,
    repair_q: Mutex<RepairQueue>,
    repair_cv: Condvar,
    scrub_stride: usize,
    /// Flat (shard, replica, slot) segment cursor for the budget-paced
    /// scrub ([`ShardStore::scrub_tick_budget`]) — carries deterministic
    /// progress across replicas between ticks.
    scrub_seg: Mutex<usize>,
}

impl ShardStore {
    /// Build the store from a model's tables: each shard's replicas are
    /// byte-identical copies (which is what makes sharded serving
    /// bit-identical to the unsharded path).
    pub fn from_model(model: &DlrmModel, plan: ShardPlan, scrub_stride: usize) -> Self {
        assert_eq!(
            plan.num_tables(),
            model.tables.len(),
            "plan table count must match the model"
        );
        assert!(scrub_stride > 0);
        let shards = (0..plan.num_shards)
            .map(|s| {
                let tables: Vec<usize> = plan.tables_of(s).to_vec();
                let replicas = (0..plan.replicas)
                    .map(|_| Replica {
                        data: RwLock::new(ReplicaTables {
                            tables: tables.iter().map(|&t| model.tables[t].clone()).collect(),
                            fused: tables.iter().map(|&t| model.fused[t].clone()).collect(),
                        }),
                        state: AtomicU8::new(HEALTHY),
                        scrub: Mutex::new(
                            tables.iter().map(|_| Scrubber::new(scrub_stride)).collect(),
                        ),
                    })
                    .collect();
                Shard { id: s, tables, replicas }
            })
            .collect();
        let policy = OnceLock::new();
        if model.policy.sites().is_some() {
            let _ = policy.set(model.policy.clone());
        }
        Self {
            plan,
            shards,
            checksums: model.checksums.clone(),
            events: model.events.clone(),
            obs: model.obs.clone(),
            policy,
            stats: ShardStats::default(),
            repair_q: Mutex::new(RepairQueue {
                tickets: VecDeque::new(),
                shutdown: false,
            }),
            repair_cv: Condvar::new(),
            scrub_stride,
            scrub_seg: Mutex::new(0),
        }
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Attach the policy handle after construction (idempotent; first
    /// wins). Called by `Engine::with_policy`, which necessarily runs
    /// after `with_shards` built this store from a then-detached model.
    pub fn attach_policy(&self, policy: PolicyHandle) {
        if policy.sites().is_some() {
            let _ = self.policy.set(policy);
        }
    }

    pub fn replica_state(&self, shard: usize, replica: usize) -> ReplicaState {
        ReplicaState::from_u8(self.shards[shard].replicas[replica].state.load(Ordering::Acquire))
    }

    /// First Healthy replica of `shard`, if any.
    pub fn healthy_replica(&self, shard: usize) -> Option<usize> {
        self.shards[shard]
            .replicas
            .iter()
            .position(|r| r.state.load(Ordering::Acquire) == HEALTHY)
    }

    /// Replica to serve from: the first healthy one, else replica 0
    /// (stale-serve — with R=1 there is nowhere to fail over to; the
    /// router reports such bags unrecovered).
    pub fn serving_replica(&self, shard: usize) -> usize {
        self.healthy_replica(shard).unwrap_or(0)
    }

    /// Shared read access to one replica's tables (the serving path).
    pub fn read_replica(&self, shard: usize, replica: usize) -> RwLockReadGuard<'_, ReplicaTables> {
        self.shards[shard].replicas[replica].data.read().unwrap()
    }

    /// Mark a replica quarantined (Healthy→Quarantined CAS) and enqueue a
    /// repair ticket. Returns false when the replica was not healthy
    /// (already quarantined or mid-repair) — no double ticket.
    pub fn quarantine(&self, shard: usize, replica: usize) -> bool {
        let rep = &self.shards[shard].replicas[replica];
        if rep
            .state
            .compare_exchange(HEALTHY, QUARANTINED, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        self.stats.quarantines.fetch_add(1, Ordering::Relaxed);
        self.enqueue_ticket(shard, replica);
        true
    }

    /// Re-enqueue a repair for an already-quarantined replica (operator
    /// hook after a failed repair; no-op counters-wise).
    pub fn request_repair(&self, shard: usize, replica: usize) {
        if self.replica_state(shard, replica) == ReplicaState::Quarantined {
            self.enqueue_ticket(shard, replica);
        }
    }

    fn enqueue_ticket(&self, shard: usize, replica: usize) {
        let mut q = self.repair_q.lock().unwrap();
        q.tickets.push_back((shard, replica));
        drop(q);
        self.repair_cv.notify_one();
    }

    /// Block until a repair ticket is available (the [`RepairWorker`]
    /// loop); `None` once [`ShardStore::shutdown_repairs`] was called.
    ///
    /// [`RepairWorker`]: crate::shard::RepairWorker
    pub fn wait_repair_ticket(&self) -> Option<(usize, usize)> {
        let mut q = self.repair_q.lock().unwrap();
        loop {
            if let Some(t) = q.tickets.pop_front() {
                return Some(t);
            }
            if q.shutdown {
                return None;
            }
            q = self.repair_cv.wait(q).unwrap();
        }
    }

    /// Unblock every ticket waiter permanently (worker shutdown).
    pub fn shutdown_repairs(&self) {
        self.repair_q.lock().unwrap().shutdown = true;
        self.repair_cv.notify_all();
    }

    /// Synchronously run every queued repair on the calling thread
    /// (deterministic tests / single-threaded operation). Returns the
    /// number of tickets processed.
    pub fn drain_repairs(&self) -> usize {
        let mut n = 0;
        loop {
            let ticket = self.repair_q.lock().unwrap().tickets.pop_front();
            match ticket {
                Some((s, r)) => {
                    self.repair(s, r);
                    n += 1;
                }
                None => return n,
            }
        }
    }

    /// Repair one quarantined replica from a healthy, checksum-clean
    /// sibling, verify the installed bytes against the canonical
    /// checksums, and re-admit. See module docs for the invariants.
    /// Never holds two replica locks at once (scan under the target's
    /// read lock, extract under the source's read lock, install under
    /// the target's write lock), so it cannot deadlock against the
    /// serving path.
    ///
    /// **Row-granular**: the target is first scanned against the
    /// canonical `C_T` per row, and only mismatching code rows are
    /// copied — on a multi-GB table with one flipped byte the write
    /// amounts to one row instead of the whole shard, shrinking the
    /// write-lock window to the verify pass. The replica's fused
    /// (α, β, C_T) serving meta is always refreshed from the clean
    /// source regardless (it is small relative to table data, is read
    /// by the serving bound-check, and its corruption is invisible to
    /// the code-sum scan). The whole-copy path is kept as the
    /// heavy-corruption fallback (> ¼ of the rows dirty — at that point
    /// a bulk copy is cheaper than row bookkeeping) and is what a
    /// quarantined-source retry ends up doing after the sibling sweep
    /// replaced wide corruption. Either way the **full** installed
    /// replica is re-verified before re-admission: rows the scan proved
    /// clean may have been hit between scan and install, and a repair
    /// must never re-admit dirty bytes.
    ///
    /// Detectability boundary: "dirty" means the row fails the dual
    /// exact check ([`EbChecksum::row_clean`] — plain `C_T` **or**
    /// index-weighted `C_W` mismatch). The §IV-C cancellation class
    /// (+δ on one code, −δ on another, which preserves the plain sum)
    /// was invisible to every detector before PR 6; the independent
    /// weight vector of `C_W` closes it, so row-granular repair now
    /// rewrites such rows too instead of silently skipping them.
    pub fn repair(&self, shard: usize, replica: usize) -> RepairOutcome {
        let sh = &self.shards[shard];
        let rep = &sh.replicas[replica];
        if rep
            .state
            .compare_exchange(QUARANTINED, REPAIRING, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return RepairOutcome::NotQuarantined;
        }
        // Ladder-rung span (recorded on successful re-admission below).
        let probe = self.obs.probe_rare();
        let t_repair = probe.map(|_| Instant::now());

        // 1. Scan the target: which rows actually mismatch C_T? (The
        //    replica is out of serving while Repairing, so this read
        //    lock is uncontended.) The scan bails out as soon as the
        //    whole-copy threshold is crossed — on a heavily-corrupted
        //    replica there is no point finishing a full code-sum pass
        //    whose result will be discarded.
        let (dirty, total_rows) = {
            let guard = rep.data.read().unwrap();
            let total_rows: usize = guard.tables.iter().map(|t| t.rows).sum();
            let mut dirty: Vec<(usize, usize)> = Vec::new(); // (slot, row)
            'scan: for (slot, &t) in sh.tables.iter().enumerate() {
                let table = &guard.tables[slot];
                for row in 0..table.rows {
                    if !self.checksums[t].row_clean(table, row) {
                        dirty.push((slot, row));
                        if dirty.len() * 4 > total_rows {
                            break 'scan; // whole-copy is already certain
                        }
                    }
                }
            }
            (dirty, total_rows)
        };
        let row_granular = dirty.len() * 4 <= total_rows;

        // 2. Find a proven-good source and extract the payload under the
        //    SAME read guard the proof ran under (no verify-to-copy
        //    race). A silently-corrupted candidate is itself quarantined
        //    and queued.
        enum Payload {
            /// Mismatching code rows plus a fresh copy of the fused
            /// (α, β, C_T) serving meta. The meta must be refreshed even
            /// when no code row is dirty: the per-replica meta is read
            /// by the serving bound-check, can itself take a soft error,
            /// and is invisible to the code-sum scan — leaving it in
            /// place would re-admit a replica that flags forever.
            Rows(Vec<(usize, usize, Vec<u8>)>, Vec<FusedEbAbft>),
            Whole(ReplicaTables),
        }
        let mut payload: Option<Payload> = None;
        for (r, src) in sh.replicas.iter().enumerate() {
            if r == replica || src.state.load(Ordering::Acquire) != HEALTHY {
                continue;
            }
            let guard = src.data.read().unwrap();
            if !self.replica_tables_clean(sh, &guard) {
                drop(guard);
                self.quarantine(shard, r);
                continue;
            }
            payload = Some(if row_granular {
                Payload::Rows(
                    dirty
                        .iter()
                        .map(|&(slot, row)| {
                            let table = &guard.tables[slot];
                            (slot, row, table.data[row * table.d..(row + 1) * table.d].to_vec())
                        })
                        .collect(),
                    guard.fused.clone(),
                )
            } else {
                Payload::Whole(guard.clone())
            });
            break;
        }

        let Some(payload) = payload else {
            rep.state.store(QUARANTINED, Ordering::Release);
            self.stats.failed_repairs.fetch_add(1, Ordering::Relaxed);
            return RepairOutcome::NoCleanSource;
        };

        {
            let mut guard = rep.data.write().unwrap();
            let rows_written = match payload {
                Payload::Rows(rows, fused) => {
                    let n = rows.len();
                    for (slot, row, bytes) in rows {
                        let d = guard.tables[slot].d;
                        guard.tables[slot].data[row * d..(row + 1) * d]
                            .copy_from_slice(&bytes);
                    }
                    guard.fused = fused;
                    n
                }
                Payload::Whole(fresh) => {
                    *guard = fresh;
                    total_rows
                }
            };
            // Re-verify the FULL installed replica before re-admission:
            // the copy crossed faultable memory, and rows outside the
            // scan may have been corrupted since.
            if !self.replica_tables_clean(sh, &guard) {
                drop(guard);
                rep.state.store(QUARANTINED, Ordering::Release);
                self.stats.failed_repairs.fetch_add(1, Ordering::Relaxed);
                return RepairOutcome::NoCleanSource;
            }
            self.stats.repaired_rows.fetch_add(rows_written as u64, Ordering::Relaxed);
        }
        // Fresh data ⇒ fresh scrub pass.
        *rep.scrub.lock().unwrap() =
            sh.tables.iter().map(|_| Scrubber::new(self.scrub_stride)).collect();
        rep.state.store(HEALTHY, Ordering::Release);
        self.stats.repairs.fetch_add(1, Ordering::Relaxed);
        if let (Some(p), Some(t0)) = (probe, t_repair) {
            p.span(Stage::QuarantineRepair, shard as u32, t0);
        }
        RepairOutcome::Repaired
    }

    /// Journal one scrub hit: `ScrubExact` detector, severity from the
    /// exact code-sum delta (Table-III significance split). Resolution
    /// is `Recovered(CorrectInPlace)` when the caller's self-heal
    /// rewrote the slot and re-verified, else
    /// `Escalated(QuarantineAndRepair)` — the quarantine is applied by
    /// the caller right after and the repair queue owns the rest, so
    /// the event never claims a repair that has not run yet (with no
    /// clean source it may never succeed; `failed_repairs` and the
    /// health block carry that outcome). Either way the hit is routed
    /// into the victim table's `eb/<table>` policy telemetry, so
    /// scrub-found corruption drives the escalation controller like a
    /// serving-path flag.
    fn emit_scrub_hit(
        &self,
        table: usize,
        replica: usize,
        row: usize,
        delta: i64,
        resolution: Resolution,
    ) {
        if let Some(policy) = self.policy.get() {
            if let Some(telem) = policy.eb_telem(table) {
                telem.note_flags(1);
            }
        }
        self.events.emit(
            SiteId::Eb(table as u32),
            UnitRef::ScrubSlot { replica: replica as u32, row: row as u32 },
            Detector::ScrubExact,
            Severity::from_code_delta(delta),
            resolution,
        );
    }

    /// Attempt the R=1 self-heal on one scrub-flagged row: localize the
    /// corruption to a single slot via the dual-checksum residual pair
    /// ([`EbChecksum::localize_slot`]), rewrite that slot algebraically
    /// under the replica's write lock, and re-verify **both** sums
    /// before declaring success. A failed re-verify reverts the byte —
    /// the caller falls down the ladder to quarantine-and-repair, and no
    /// half-corrected row is ever served. Returns whether the row
    /// healed.
    fn try_self_heal(&self, shard: usize, replica: usize, slot: usize, table: usize, row: usize) -> bool {
        // Fault-path span: rare enough to bypass the 1-in-n gate. A
        // landed heal also feeds the heal-cost EWMA the budget-paced
        // scrub charges from.
        let probe = self.obs.probe_rare();
        let t0 = probe.map(|_| Instant::now());
        let rep = &self.shards[shard].replicas[replica];
        let cs = &self.checksums[table];
        let mut guard = rep.data.write().unwrap();
        let t = &mut guard.tables[slot];
        let Some((j, original)) = cs.localize_slot(t, row) else {
            return false;
        };
        let prev = t.data[row * t.d + j];
        t.data[row * t.d + j] = original;
        if cs.row_clean(t, row) {
            if let (Some(p), Some(t0)) = (probe, t0) {
                let ns = t0.elapsed().as_nanos() as u64;
                p.span_ns(Stage::CorrectInPlace, table as u32, ns);
                self.obs.note_heal(ns);
            }
            true
        } else {
            t.data[row * t.d + j] = prev;
            false
        }
    }

    /// Full checksum pass over every slot of one replica's tables.
    fn replica_tables_clean(&self, sh: &Shard, data: &ReplicaTables) -> bool {
        sh.tables
            .iter()
            .enumerate()
            .all(|(slot, &t)| Scrubber::full_pass(&data.tables[slot], &self.checksums[t]).is_empty())
    }

    /// Advance every healthy replica's scrubbers by one strip. Each
    /// corrupted row first attempts the in-place self-heal
    /// ([`ShardStore::try_self_heal`]); rows that cannot be localized to
    /// one slot quarantine their replica (the proactive arm of
    /// detection-driven failover) and enqueue repairs. Returns the rows
    /// scanned by **this** tick (callers must not derive it from the
    /// shared cumulative stats — concurrent tickers would cross-count)
    /// and the `(shard, replica, global_table, row)` hits (healed rows
    /// included — they were real detections).
    pub fn scrub_tick(&self) -> (usize, Vec<(usize, usize, usize, usize)>) {
        let mut hits = Vec::new();
        let mut scanned = 0usize;
        for sh in &self.shards {
            for (r, rep) in sh.replicas.iter().enumerate() {
                if rep.state.load(Ordering::Acquire) != HEALTHY {
                    continue; // quarantined replicas are already pending repair
                }
                // Collect under the read lock, resolve after dropping it
                // (the self-heal needs the write lock).
                let mut found: Vec<(usize, usize, usize, i64)> = Vec::new();
                {
                    let data = rep.data.read().unwrap();
                    let mut scrub = rep.scrub.lock().unwrap();
                    for (slot, &t) in sh.tables.iter().enumerate() {
                        let report = scrub[slot].scrub_step(&data.tables[slot], &self.checksums[t]);
                        scanned += report.rows_scanned;
                        self.stats
                            .scrubbed_rows
                            .fetch_add(report.rows_scanned as u64, Ordering::Relaxed);
                        for row in report.corrupted_rows {
                            let delta = self.checksums[t].row_delta(&data.tables[slot], row);
                            found.push((slot, t, row, delta));
                        }
                    }
                }
                let mut dirty = false;
                for (slot, t, row, delta) in found {
                    self.stats.scrub_hits.fetch_add(1, Ordering::Relaxed);
                    let resolution = if self.try_self_heal(sh.id, r, slot, t, row) {
                        self.stats.self_heals.fetch_add(1, Ordering::Relaxed);
                        Resolution::Recovered(Recovery::CorrectInPlace)
                    } else {
                        dirty = true;
                        Resolution::Escalated(Recovery::QuarantineAndRepair)
                    };
                    self.emit_scrub_hit(t, r, row, delta, resolution);
                    hits.push((sh.id, r, t, row));
                }
                if dirty {
                    self.quarantine(sh.id, r);
                }
            }
        }
        (scanned, hits)
    }

    /// Budget-paced scrub: scan up to `budget` rows total this tick,
    /// resuming exactly where the previous tick stopped — a flat
    /// (shard, replica, slot) segment cursor carries progress **across
    /// replicas**, and each slot's [`Scrubber`] carries the intra-table
    /// row cursor, so `scrub_budget` pacing is exact: every tick scans
    /// `budget` rows (unless every segment is quarantined or empty) and
    /// consecutive ticks tile the whole healthy store without gaps or
    /// overlap. Segments on non-Healthy replicas are skipped (they are
    /// already queued for repair). Corrupted rows self-heal or
    /// quarantine their replica exactly like [`ShardStore::scrub_tick`]
    /// hits. Returns
    /// `(rows_scanned, hits)` with hits as `(shard, replica, table,
    /// row)`.
    ///
    /// # Heal-aware pacing
    ///
    /// A self-heal is not free: localize + rewrite + dual re-verify costs
    /// a measured multiple of one scan row (the profiler's heal-cost
    /// EWMA; [`crate::obs::DEFAULT_HEAL_COST_ROWS`] until measured). Each
    /// landed heal is **charged against the same budget**, so a tick that
    /// heals returns fewer scanned rows and the tick's total work — not
    /// just its scanning — is what the controller's `scrub_budget` paces.
    pub fn scrub_tick_budget(&self, budget: usize) -> (usize, Vec<(usize, usize, usize, usize)>) {
        let mut hits = Vec::new();
        let segs: usize = self
            .shards
            .iter()
            .map(|sh| sh.replicas.len() * sh.tables.len())
            .sum();
        if segs == 0 || budget == 0 {
            return (0, hits);
        }
        // `scanned` is what this tick actually scanned (returned);
        // `charged` additionally counts heal work in scan-row
        // equivalents and is what the budget caps.
        let mut scanned = 0usize;
        let mut charged = 0usize;
        let mut cursor = self.scrub_seg.lock().unwrap();
        let mut skipped = 0usize;
        while charged < budget && skipped < segs {
            let seg = *cursor % segs;
            let (s, r, slot) = self.seg_coords(seg);
            let rep = &self.shards[s].replicas[r];
            if rep.state.load(Ordering::Acquire) != HEALTHY {
                *cursor = (seg + 1) % segs;
                skipped += 1;
                continue;
            }
            let t = self.shards[s].tables[slot];
            let probe = self.obs.probe();
            let t_scan = probe.map(|_| Instant::now());
            let (report, deltas) = {
                let data = rep.data.read().unwrap();
                let mut scrub = rep.scrub.lock().unwrap();
                let report = scrub[slot].scrub_step_rows(
                    &data.tables[slot],
                    &self.checksums[t],
                    budget - charged,
                );
                let deltas: Vec<i64> = report
                    .corrupted_rows
                    .iter()
                    .map(|&row| self.checksums[t].row_delta(&data.tables[slot], row))
                    .collect();
                (report, deltas)
            };
            if report.rows_scanned == 0 {
                *cursor = (seg + 1) % segs;
                skipped += 1;
                continue;
            }
            // Scan-cost calibration for the heal charge denominator.
            if let (Some(_), Some(t0)) = (probe, t_scan) {
                self.obs
                    .note_scan(report.rows_scanned, t0.elapsed().as_nanos() as u64);
            }
            skipped = 0;
            scanned += report.rows_scanned;
            charged += report.rows_scanned;
            self.stats
                .scrubbed_rows
                .fetch_add(report.rows_scanned as u64, Ordering::Relaxed);
            let mut dirty = false;
            for (row, delta) in report.corrupted_rows.into_iter().zip(deltas) {
                self.stats.scrub_hits.fetch_add(1, Ordering::Relaxed);
                let resolution = if self.try_self_heal(s, r, slot, t, row) {
                    self.stats.self_heals.fetch_add(1, Ordering::Relaxed);
                    charged += self.obs.heal_rows_equiv();
                    Resolution::Recovered(Recovery::CorrectInPlace)
                } else {
                    dirty = true;
                    Resolution::Escalated(Recovery::QuarantineAndRepair)
                };
                self.emit_scrub_hit(t, r, row, delta, resolution);
                hits.push((s, r, t, row));
            }
            if dirty {
                self.quarantine(s, r);
            }
            if report.wrapped {
                *cursor = (seg + 1) % segs;
            }
        }
        (scanned, hits)
    }

    /// Map a flat scrub segment index to (shard, replica, slot).
    fn seg_coords(&self, mut seg: usize) -> (usize, usize, usize) {
        for (s, sh) in self.shards.iter().enumerate() {
            let n = sh.replicas.len() * sh.tables.len();
            if seg < n {
                return (s, seg / sh.tables.len(), seg % sh.tables.len());
            }
            seg -= n;
        }
        unreachable!("scrub segment out of range")
    }

    /// One full scrub pass over every healthy replica (campaigns /
    /// offline verification); corrupted rows self-heal or quarantine
    /// their replica exactly like [`ShardStore::scrub_tick`] hits.
    /// Returns the number of corrupted rows found (healed included).
    pub fn scrub_full(&self) -> usize {
        let mut found = 0;
        for sh in &self.shards {
            for (r, rep) in sh.replicas.iter().enumerate() {
                if rep.state.load(Ordering::Acquire) != HEALTHY {
                    continue;
                }
                let rows: Vec<(usize, usize, usize, i64)> = {
                    let data = rep.data.read().unwrap();
                    sh.tables
                        .iter()
                        .enumerate()
                        .flat_map(|(slot, &t)| {
                            Scrubber::full_pass(&data.tables[slot], &self.checksums[t])
                                .into_iter()
                                .map(move |row| (slot, t, row))
                                .collect::<Vec<_>>()
                        })
                        .map(|(slot, t, row)| {
                            (slot, t, row, self.checksums[t].row_delta(&data.tables[slot], row))
                        })
                        .collect()
                };
                let mut dirty = false;
                for (slot, t, row, delta) in rows {
                    found += 1;
                    self.stats.scrub_hits.fetch_add(1, Ordering::Relaxed);
                    let resolution = if self.try_self_heal(sh.id, r, slot, t, row) {
                        self.stats.self_heals.fetch_add(1, Ordering::Relaxed);
                        Resolution::Recovered(Recovery::CorrectInPlace)
                    } else {
                        dirty = true;
                        Resolution::Escalated(Recovery::QuarantineAndRepair)
                    };
                    self.emit_scrub_hit(t, r, row, delta, resolution);
                }
                if dirty {
                    self.quarantine(sh.id, r);
                }
            }
        }
        found
    }

    /// Fault-injection door (tests, campaigns, chaos drills): XOR `mask`
    /// into one stored code byte of `table` (global id) in one replica.
    /// Applying the same call twice restores the byte — but only when no
    /// repair ran in between; transient (restored) injections should use
    /// [`ShardStore::chaos_flip_table_byte`] /
    /// [`ShardStore::chaos_restore_table_byte`] instead. Returns the
    /// shard the table lives on.
    pub fn flip_table_byte(&self, table: usize, replica: usize, byte: usize, mask: u8) -> usize {
        let (shard, slot) = self.plan.slot_of(table);
        let mut guard = self.shards[shard].replicas[replica].data.write().unwrap();
        guard.tables[slot].data[byte] ^= mask;
        shard
    }

    /// Transient-chaos apply: XOR `mask` into a replica byte and return
    /// the previous value, for a later conditional restore.
    pub fn chaos_flip_table_byte(&self, table: usize, replica: usize, byte: usize, mask: u8) -> u8 {
        let (shard, slot) = self.plan.slot_of(table);
        let mut guard = self.shards[shard].replicas[replica].data.write().unwrap();
        let old = guard.tables[slot].data[byte];
        guard.tables[slot].data[byte] = old ^ mask;
        old
    }

    /// Transient-chaos undo: restore `original` **only if** the byte
    /// still holds the flipped value `original ^ mask`. A concurrent
    /// repair may already have rewritten the replica from a clean
    /// sibling — the corruption is gone and a blind XOR would
    /// RE-corrupt a replica that is marked Healthy. Returns whether the
    /// restore was applied.
    pub fn chaos_restore_table_byte(
        &self,
        table: usize,
        replica: usize,
        byte: usize,
        original: u8,
        mask: u8,
    ) -> bool {
        let (shard, slot) = self.plan.slot_of(table);
        let mut guard = self.shards[shard].replicas[replica].data.write().unwrap();
        let cell = &mut guard.tables[slot].data[byte];
        if *cell == original ^ mask {
            *cell = original;
            true
        } else {
            false
        }
    }

    /// Code bytes of one replica's copy of `table` (drill assertions).
    pub fn table_bytes(&self, table: usize, replica: usize) -> Vec<u8> {
        let (shard, slot) = self.plan.slot_of(table);
        self.read_replica(shard, replica).tables[slot].data.clone()
    }

    /// Replicas currently not Healthy (gauge for health reporting).
    pub fn quarantined_replicas(&self) -> usize {
        self.shards
            .iter()
            .flat_map(|sh| sh.replicas.iter())
            .filter(|r| r.state.load(Ordering::Acquire) != HEALTHY)
            .count()
    }

    /// Queued (not yet executed) repair tickets.
    pub fn pending_repairs(&self) -> usize {
        self.repair_q.lock().unwrap().tickets.len()
    }

    /// Health snapshot: per-shard replica states + lifetime counters.
    pub fn health_json(&self) -> Json {
        let shards = self
            .shards
            .iter()
            .map(|sh| {
                Json::obj(vec![
                    ("id", Json::Num(sh.id as f64)),
                    (
                        "tables",
                        Json::Arr(sh.tables.iter().map(|&t| Json::Num(t as f64)).collect()),
                    ),
                    (
                        "replicas",
                        Json::Arr(
                            sh.replicas
                                .iter()
                                .map(|r| {
                                    Json::Str(
                                        ReplicaState::from_u8(r.state.load(Ordering::Acquire))
                                            .as_str()
                                            .to_string(),
                                    )
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("num_shards", Json::Num(self.plan.num_shards as f64)),
            ("replicas_per_shard", Json::Num(self.plan.replicas as f64)),
            ("placement", Json::Str(self.plan.policy_name().to_string())),
            ("detections", n(&self.stats.detections)),
            ("quarantines", n(&self.stats.quarantines)),
            ("failovers", n(&self.stats.failovers)),
            ("repairs", n(&self.stats.repairs)),
            ("repaired_rows", n(&self.stats.repaired_rows)),
            ("failed_repairs", n(&self.stats.failed_repairs)),
            ("scrubbed_rows", n(&self.stats.scrubbed_rows)),
            ("scrub_hits", n(&self.stats.scrub_hits)),
            ("self_heals", n(&self.stats.self_heals)),
            (
                "quarantined_replicas",
                Json::Num(self.quarantined_replicas() as f64),
            ),
            ("pending_repairs", Json::Num(self.pending_repairs() as f64)),
            ("shards", Json::Arr(shards)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::{DlrmConfig, Protection, TableConfig};

    fn tiny_model() -> DlrmModel {
        DlrmModel::random(DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![
                TableConfig { rows: 60, pooling: 4 },
                TableConfig { rows: 40, pooling: 3 },
                TableConfig { rows: 30, pooling: 2 },
            ],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 0x5A,
        })
    }

    fn store(n: usize, r: usize) -> (DlrmModel, ShardStore) {
        let model = tiny_model();
        let plan = ShardPlan::hash_placement(model.tables.len(), n, r);
        let store = ShardStore::from_model(&model, plan, 16);
        (model, store)
    }

    #[test]
    fn replicas_start_healthy_and_byte_identical() {
        let (model, store) = store(2, 3);
        for t in 0..model.tables.len() {
            let (shard, _) = store.plan.slot_of(t);
            for r in 0..3 {
                assert_eq!(store.replica_state(shard, r), ReplicaState::Healthy);
                assert_eq!(store.table_bytes(t, r), model.tables[t].data);
            }
        }
        assert_eq!(store.quarantined_replicas(), 0);
    }

    #[test]
    fn quarantine_is_single_shot_and_enqueues() {
        let (_, store) = store(1, 2);
        assert!(store.quarantine(0, 1));
        assert!(!store.quarantine(0, 1), "second quarantine must be a no-op");
        assert_eq!(store.replica_state(0, 1), ReplicaState::Quarantined);
        assert_eq!(store.pending_repairs(), 1);
        assert_eq!(store.healthy_replica(0), Some(0));
        assert_eq!(store.stats.quarantines.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn repair_restores_from_clean_sibling() {
        let (model, store) = store(1, 2);
        let t = 0;
        store.flip_table_byte(t, 1, 5, 0x80);
        assert_ne!(store.table_bytes(t, 1), model.tables[t].data);
        assert!(store.quarantine(0, 1));
        assert_eq!(store.repair(0, 1), RepairOutcome::Repaired);
        assert_eq!(store.replica_state(0, 1), ReplicaState::Healthy);
        assert_eq!(store.table_bytes(t, 1), model.tables[t].data);
        assert_eq!(store.stats.repairs.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn row_granular_repair_copies_only_mismatching_rows() {
        let (model, store) = store(1, 2);
        let d = 8;
        // Two dirty rows (one high-bit, one low-bit flip) out of 130.
        store.flip_table_byte(0, 1, 0, 0x80);
        store.flip_table_byte(0, 1, 3 * d, 0x01);
        assert!(store.quarantine(0, 1));
        assert_eq!(store.repair(0, 1), RepairOutcome::Repaired);
        assert_eq!(store.replica_state(0, 1), ReplicaState::Healthy);
        assert_eq!(store.table_bytes(0, 1), model.tables[0].data);
        assert_eq!(
            store.stats.repaired_rows.load(Ordering::Relaxed),
            2,
            "only the C_T-mismatching rows are rewritten"
        );
    }

    #[test]
    fn heavy_corruption_falls_back_to_whole_copy() {
        let (model, store) = store(1, 2);
        let d = 8;
        // 60 of the shard's 130 rows dirty (> ¼): bulk copy wins.
        for row in 0..60 {
            store.flip_table_byte(0, 1, row * d, 0x80);
        }
        assert!(store.quarantine(0, 1));
        assert_eq!(store.repair(0, 1), RepairOutcome::Repaired);
        for t in 0..model.tables.len() {
            assert_eq!(store.table_bytes(t, 1), model.tables[t].data);
        }
        assert_eq!(
            store.stats.repaired_rows.load(Ordering::Relaxed),
            60 + 40 + 30,
            "whole-copy path rewrites the full shard"
        );
    }

    #[test]
    fn repair_without_clean_source_stays_quarantined() {
        let (_, store) = store(1, 1);
        store.flip_table_byte(0, 0, 3, 0x40);
        assert!(store.quarantine(0, 0));
        assert_eq!(store.repair(0, 0), RepairOutcome::NoCleanSource);
        assert_eq!(store.replica_state(0, 0), ReplicaState::Quarantined);
        assert_eq!(store.stats.failed_repairs.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn repair_rejects_corrupt_source_and_quarantines_it() {
        let (model, store) = store(1, 3);
        // Target (r0) and the first candidate source (r1) both corrupted;
        // only r2 is clean.
        store.flip_table_byte(0, 0, 1, 0x20);
        store.flip_table_byte(0, 1, 2, 0x10);
        assert!(store.quarantine(0, 0));
        assert_eq!(store.repair(0, 0), RepairOutcome::Repaired);
        assert_eq!(store.table_bytes(0, 0), model.tables[0].data);
        // The dirty source was itself quarantined + queued.
        assert_eq!(store.replica_state(0, 1), ReplicaState::Quarantined);
        assert!(store.pending_repairs() >= 1);
        assert!(store.drain_repairs() >= 1);
        assert_eq!(store.replica_state(0, 1), ReplicaState::Healthy);
        assert_eq!(store.table_bytes(0, 1), model.tables[0].data);
    }

    #[test]
    fn scrub_tick_self_heals_single_slot_corruption_in_place() {
        let (model, store) = store(2, 2);
        // Low-bit flip: invisible to float bounds, exact to the scrubber
        // — and single-slot, so the dual checksum localizes it and the
        // R-independent self-heal fixes it without quarantine.
        let shard = store.flip_table_byte(1, 1, 7, 0x01);
        let mut hits = Vec::new();
        for _ in 0..16 {
            let (rows, h) = store.scrub_tick();
            assert!(rows > 0, "healthy replicas must advance");
            hits.extend(h);
            if !hits.is_empty() {
                break;
            }
        }
        assert_eq!(hits.len(), 1);
        let (s, r, t, _row) = hits[0];
        assert_eq!((s, r, t), (shard, 1, 1));
        assert_eq!(store.replica_state(shard, 1), ReplicaState::Healthy, "healed, not quarantined");
        assert_eq!(store.table_bytes(1, 1), model.tables[1].data, "byte restored exactly");
        assert_eq!(store.stats.self_heals.load(Ordering::Relaxed), 1);
        assert_eq!(store.pending_repairs(), 0);
        assert_eq!(store.scrub_full(), 0, "nothing left to find");
    }

    #[test]
    fn scrub_tick_quarantines_unlocalizable_corruption() {
        let (model, store) = store(2, 2);
        // §IV-C cancellation corruption (+5/−5 in one row): detected by
        // the dual checksum but NOT single-slot, so the self-heal
        // declines and the ladder falls to quarantine-and-repair.
        let d = model.tables[1].d;
        let bytes = store.table_bytes(1, 1);
        let row = (0..model.tables[1].rows)
            .find(|&row| bytes[row * d + 1] <= 250 && bytes[row * d + 6] >= 5)
            .expect("some row admits a +5/-5 pair");
        let (a, b) = (bytes[row * d + 1], bytes[row * d + 6]);
        let shard = store.flip_table_byte(1, 1, row * d + 1, a ^ (a + 5));
        store.flip_table_byte(1, 1, row * d + 6, b ^ (b - 5));
        let mut hits = Vec::new();
        for _ in 0..16 {
            let (_, h) = store.scrub_tick();
            hits.extend(h);
            if !hits.is_empty() {
                break;
            }
        }
        assert_eq!(hits.len(), 1);
        assert_eq!(store.replica_state(shard, 1), ReplicaState::Quarantined);
        assert_eq!(store.stats.self_heals.load(Ordering::Relaxed), 0);
        assert_eq!(store.drain_repairs(), 1);
        assert_eq!(store.replica_state(shard, 1), ReplicaState::Healthy);
        assert_eq!(store.table_bytes(1, 1), model.tables[1].data);
    }

    #[test]
    fn r1_store_self_heals_where_repair_has_no_source() {
        // With R=1 there is no sibling to repair from — pre-PR-6 a scrub
        // hit meant quarantine forever (stale-serve). Single-slot
        // corruption now heals in place and the store keeps serving.
        let (model, store) = store(1, 1);
        store.flip_table_byte(0, 0, 3, 0x40);
        assert_eq!(store.scrub_full(), 1);
        assert_eq!(store.replica_state(0, 0), ReplicaState::Healthy);
        assert_eq!(store.table_bytes(0, 0), model.tables[0].data);
        assert_eq!(store.stats.self_heals.load(Ordering::Relaxed), 1);
        assert_eq!(store.stats.quarantines.load(Ordering::Relaxed), 0);
        assert_eq!(store.scrub_full(), 0);
    }

    #[test]
    fn budget_scrub_is_exactly_paced_and_covers_every_replica() {
        let (model, store) = store(2, 2);
        // Corrupt a low bit on one replica copy — only the exact scrub
        // sees it, and the self-heal fixes it in place.
        let shard = store.flip_table_byte(2, 1, 5, 0x01);
        // Total healthy rows: (60+40+30) tables × 2 replicas = 260.
        let total_rows = 2 * (60 + 40 + 30);
        let mut scanned = 0usize;
        let mut hits = Vec::new();
        let mut ticks = 0;
        while scanned < total_rows {
            let (rows, h) = store.scrub_tick_budget(25);
            assert!(rows <= 25);
            assert!(rows > 0, "healthy segments remain, budget must be spent");
            scanned += rows;
            ticks += 1;
            if !h.is_empty() {
                hits.extend(h);
                break;
            }
            // Exact pacing: every clean tick scans the full budget. (The
            // hit tick may come in under it — the self-heal is charged
            // against the same budget in scan-row equivalents.)
            assert_eq!(rows, 25);
        }
        assert!(scanned >= (ticks - 1) * 25);
        assert_eq!(hits.len(), 1);
        let (s, r, t, _row) = hits[0];
        assert_eq!((s, r, t), (shard, 1, 2));
        // Single-slot hit: healed in place, replica never left serving.
        assert_eq!(store.replica_state(shard, 1), ReplicaState::Healthy);
        assert_eq!(store.table_bytes(2, 1), model.tables[2].data);
        assert_eq!(store.stats.self_heals.load(Ordering::Relaxed), 1);
        // The budget keeps flowing afterwards, with nothing left to find.
        let (rows, h) = store.scrub_tick_budget(25);
        assert_eq!(rows, 25);
        assert!(h.is_empty());
        assert_eq!(store.quarantined_replicas(), 0);
    }

    #[test]
    fn self_heal_work_is_charged_against_the_scan_budget() {
        // One shard, one replica, segment order 60/40/30 rows. The flip
        // sits in table 0's first row, so a 70-row tick scans the whole
        // first segment (60, wrapping), heals — which charges
        // DEFAULT_HEAL_COST_ROWS against the remaining budget — and the
        // second segment then only gets what is left: the tick returns
        // 70 − heal_charge scanned rows.
        let (_, store) = store(1, 1);
        store.flip_table_byte(0, 0, 3, 0x01);
        let (rows, hits) = store.scrub_tick_budget(70);
        assert_eq!(hits.len(), 1);
        assert_eq!(store.stats.self_heals.load(Ordering::Relaxed), 1);
        assert_eq!(rows, 70 - crate::obs::DEFAULT_HEAL_COST_ROWS);
    }

    #[test]
    fn scrub_full_covers_everything_at_once() {
        let (model, store) = store(2, 2);
        store.flip_table_byte(2, 0, 0, 0x02);
        assert_eq!(store.scrub_full(), 1);
        // Healed in place (single slot), so no quarantine round-trip.
        let (shard, _) = store.plan.slot_of(2);
        assert_eq!(store.replica_state(shard, 0), ReplicaState::Healthy);
        assert_eq!(store.table_bytes(2, 0), model.tables[2].data);
        assert_eq!(store.quarantined_replicas(), 0);
        assert_eq!(store.scrub_full(), 0);
    }

    #[test]
    fn health_json_reports_states() {
        let (_, store) = store(2, 2);
        store.quarantine(0, 0);
        let j = store.health_json();
        assert_eq!(j.get("num_shards").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("quarantined_replicas").and_then(Json::as_usize), Some(1));
        assert!(j.get("shards").is_some());
    }
}
