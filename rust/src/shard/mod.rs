//! Sharded, replicated model store with detection-driven failover — the
//! serving layer that turns the paper's detectors into availability.
//!
//! The detectors (ABFT GEMM checksums, Eq-5 EmbeddingBag checksums,
//! background scrubbing) only pay off in production if a detection *does
//! something*. This subsystem gives them a target: embedding tables are
//! partitioned across N shards ([`ShardPlan`], hash-of-table-id, tables
//! placed whole so bags never split), each shard held as R byte-identical
//! replicas ([`ShardStore`]), with a [`ShardRouter`] in front that fans
//! bag traffic out per shard on the global thread pool and merges
//! bit-identically with the unsharded path.
//!
//! Control loop: a protected-EB flag that survives a same-replica retry,
//! or a scrubber hit, marks the replica **quarantined**; traffic fails
//! over to a healthy replica with zero downtime; a background
//! [`RepairWorker`] re-copies the shard from a clean replica
//! (checksum-verified against the store's canonical `C_T` columns) and
//! re-admits it. See `store.rs` for the state machine and repair
//! invariants, `router.rs` for the serving policy.

pub mod plan;
pub mod repair;
pub mod router;
pub mod store;

pub use plan::{HashPlacement, PlacementPolicy, RoundRobinPlacement, ShardPlan};
pub use repair::RepairWorker;
pub use router::ShardRouter;
pub use store::{RepairOutcome, ReplicaState, ReplicaTables, Shard, ShardStats, ShardStore};
