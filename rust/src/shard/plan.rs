//! Placement: which shard owns each embedding table.
//!
//! Tables are placed **whole** (hash-of-table-id, not row ranges): a bag
//! reads exactly one table, so whole-table placement keeps every bag's
//! gather inside a single shard and makes the sharded reduction trivially
//! bit-identical to the unsharded one — merging is a copy, never a
//! float re-association. Row-range sharding (the NUMA item on the
//! ROADMAP) would split a bag's sum across shards and force a float
//! merge order; it stays future work.

use crate::util::json::Json;
use crate::util::rng::splitmix64;

/// The shard topology: N shards × R replicas, plus the table→shard map.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    pub num_shards: usize,
    /// Replicas per shard (R = 1 means no failover target).
    pub replicas: usize,
    /// `assignment[t]` = shard owning global table `t`.
    assignment: Vec<usize>,
    /// `shard_tables[s]` = global table ids on shard `s`, ascending.
    shard_tables: Vec<Vec<usize>>,
    /// `slot[t]` = (shard, index of `t` within `shard_tables[shard]`).
    slot: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Hash-of-table-id placement over `num_shards` shards with
    /// `replicas` copies of each shard. Deterministic; shards may end up
    /// empty when `num_shards` exceeds the table count (legal — the
    /// router skips them).
    pub fn hash_placement(num_tables: usize, num_shards: usize, replicas: usize) -> Self {
        assert!(num_shards >= 1, "need at least one shard");
        assert!(replicas >= 1, "need at least one replica");
        let assignment: Vec<usize> = (0..num_tables)
            .map(|t| (splitmix64(t as u64) % num_shards as u64) as usize)
            .collect();
        let mut shard_tables = vec![Vec::new(); num_shards];
        let mut slot = vec![(0usize, 0usize); num_tables];
        for (t, &s) in assignment.iter().enumerate() {
            slot[t] = (s, shard_tables[s].len());
            shard_tables[s].push(t);
        }
        Self {
            num_shards,
            replicas,
            assignment,
            shard_tables,
            slot,
        }
    }

    pub fn num_tables(&self) -> usize {
        self.assignment.len()
    }

    /// Shard owning global table `t`.
    pub fn shard_of(&self, table: usize) -> usize {
        self.assignment[table]
    }

    /// (shard, local slot) of global table `t`.
    pub fn slot_of(&self, table: usize) -> (usize, usize) {
        self.slot[table]
    }

    /// Global table ids on shard `s`, ascending.
    pub fn tables_of(&self, shard: usize) -> &[usize] {
        &self.shard_tables[shard]
    }

    /// Shards that actually hold tables.
    pub fn occupied_shards(&self) -> usize {
        self.shard_tables.iter().filter(|t| !t.is_empty()).count()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_shards", Json::Num(self.num_shards as f64)),
            ("replicas", Json::Num(self.replicas as f64)),
            (
                "assignment",
                Json::Arr(self.assignment.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_total_and_consistent() {
        for shards in [1usize, 2, 3, 8] {
            let plan = ShardPlan::hash_placement(10, shards, 2);
            assert_eq!(plan.num_tables(), 10);
            let mut seen = vec![false; 10];
            for s in 0..shards {
                for &t in plan.tables_of(s) {
                    assert!(!seen[t], "table {t} placed twice");
                    seen[t] = true;
                    assert_eq!(plan.shard_of(t), s);
                    let (ps, slot) = plan.slot_of(t);
                    assert_eq!(ps, s);
                    assert_eq!(plan.tables_of(s)[slot], t);
                }
            }
            assert!(seen.iter().all(|&x| x), "placement must cover every table");
        }
    }

    #[test]
    fn placement_is_deterministic() {
        let a = ShardPlan::hash_placement(16, 4, 3);
        let b = ShardPlan::hash_placement(16, 4, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn single_shard_owns_everything() {
        let plan = ShardPlan::hash_placement(5, 1, 1);
        assert_eq!(plan.tables_of(0), &[0, 1, 2, 3, 4]);
        assert_eq!(plan.occupied_shards(), 1);
    }

    #[test]
    fn more_shards_than_tables_leaves_empties() {
        let plan = ShardPlan::hash_placement(2, 16, 2);
        assert!(plan.occupied_shards() <= 2);
        let total: usize = (0..16).map(|s| plan.tables_of(s).len()).sum();
        assert_eq!(total, 2);
    }
}
