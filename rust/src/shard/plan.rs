//! Placement: which shard owns each embedding table.
//!
//! Tables are placed **whole** (not row ranges): a bag reads exactly one
//! table, so whole-table placement keeps every bag's gather inside a
//! single shard and makes the sharded reduction trivially bit-identical
//! to the unsharded one — merging is a copy, never a float
//! re-association. Row-range sharding (the NUMA item on the ROADMAP)
//! would split a bag's sum across shards and force a float merge order;
//! it stays future work.
//!
//! *Which* shard owns a table is a [`PlacementPolicy`] (PR 8): the plan
//! builder takes any `table → shard` assignment strategy, while the plan
//! itself stays a frozen, validated lookup structure — router, store,
//! scrubber and repair never see the policy, only the materialized plan,
//! so a new policy (size-balanced, traffic-aware, NUMA-topology…) plugs
//! in without touching the serving path. [`HashPlacement`] is the
//! default and reproduces the original hash-of-table-id layout
//! byte-for-byte.

use crate::util::json::Json;
use crate::util::rng::splitmix64;

/// A table→shard assignment strategy. Implementations must be
/// deterministic (two calls with the same arguments return the same
/// assignment) — plan equality, repair re-derivation and test
/// reproducibility all lean on it.
pub trait PlacementPolicy {
    /// Return `assignment[t]` = owning shard for each of `num_tables`
    /// tables; every entry must be `< num_shards`.
    fn assign(&self, num_tables: usize, num_shards: usize) -> Vec<usize>;

    /// Stable identifier surfaced in shard health/metrics output.
    fn name(&self) -> &'static str;
}

/// Default policy: `shard(t) = splitmix64(t) mod num_shards`. Stateless
/// and uniform-ish for any table count; identical to the pre-trait
/// `hash_placement` layout.
pub struct HashPlacement;

impl PlacementPolicy for HashPlacement {
    fn assign(&self, num_tables: usize, num_shards: usize) -> Vec<usize> {
        (0..num_tables)
            .map(|t| (splitmix64(t as u64) % num_shards as u64) as usize)
            .collect()
    }

    fn name(&self) -> &'static str {
        "hash"
    }
}

/// Round-robin policy: `shard(t) = t mod num_shards`. Deliberately
/// boring — it exists to prove the seam is real (a second policy routes
/// traffic correctly with zero serving-path changes) and as the shape
/// a capacity-balanced policy would take.
pub struct RoundRobinPlacement;

impl PlacementPolicy for RoundRobinPlacement {
    fn assign(&self, num_tables: usize, num_shards: usize) -> Vec<usize> {
        (0..num_tables).map(|t| t % num_shards).collect()
    }

    fn name(&self) -> &'static str {
        "round_robin"
    }
}

/// The shard topology: N shards × R replicas, plus the table→shard map.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    pub num_shards: usize,
    /// Replicas per shard (R = 1 means no failover target).
    pub replicas: usize,
    /// `assignment[t]` = shard owning global table `t`.
    assignment: Vec<usize>,
    /// `shard_tables[s]` = global table ids on shard `s`, ascending.
    shard_tables: Vec<Vec<usize>>,
    /// `slot[t]` = (shard, index of `t` within `shard_tables[shard]`).
    slot: Vec<(usize, usize)>,
    /// Name of the policy that produced `assignment` (observability only
    /// — routing reads the materialized maps, never the policy).
    policy_name: &'static str,
}

impl ShardPlan {
    /// Materialize a plan from any [`PlacementPolicy`]: runs the policy
    /// once, validates its assignment, and freezes the derived lookup
    /// structures (per-shard table lists, table→slot map).
    pub fn from_policy(
        policy: &dyn PlacementPolicy,
        num_tables: usize,
        num_shards: usize,
        replicas: usize,
    ) -> Self {
        assert!(num_shards >= 1, "need at least one shard");
        assert!(replicas >= 1, "need at least one replica");
        let assignment = policy.assign(num_tables, num_shards);
        assert_eq!(
            assignment.len(),
            num_tables,
            "policy {} returned {} assignments for {num_tables} tables",
            policy.name(),
            assignment.len()
        );
        let mut shard_tables = vec![Vec::new(); num_shards];
        let mut slot = vec![(0usize, 0usize); num_tables];
        for (t, &s) in assignment.iter().enumerate() {
            assert!(
                s < num_shards,
                "policy {} placed table {t} on shard {s} of {num_shards}",
                policy.name()
            );
            slot[t] = (s, shard_tables[s].len());
            shard_tables[s].push(t);
        }
        Self {
            num_shards,
            replicas,
            assignment,
            shard_tables,
            slot,
            policy_name: policy.name(),
        }
    }

    /// Hash-of-table-id placement over `num_shards` shards with
    /// `replicas` copies of each shard — [`HashPlacement`] through
    /// [`ShardPlan::from_policy`]. Deterministic; shards may end up
    /// empty when `num_shards` exceeds the table count (legal — the
    /// router skips them).
    pub fn hash_placement(num_tables: usize, num_shards: usize, replicas: usize) -> Self {
        Self::from_policy(&HashPlacement, num_tables, num_shards, replicas)
    }

    pub fn num_tables(&self) -> usize {
        self.assignment.len()
    }

    /// Shard owning global table `t`.
    pub fn shard_of(&self, table: usize) -> usize {
        self.assignment[table]
    }

    /// (shard, local slot) of global table `t`.
    pub fn slot_of(&self, table: usize) -> (usize, usize) {
        self.slot[table]
    }

    /// Global table ids on shard `s`, ascending.
    pub fn tables_of(&self, shard: usize) -> &[usize] {
        &self.shard_tables[shard]
    }

    /// Shards that actually hold tables.
    pub fn occupied_shards(&self) -> usize {
        self.shard_tables.iter().filter(|t| !t.is_empty()).count()
    }

    /// Name of the policy that produced this plan.
    pub fn policy_name(&self) -> &'static str {
        self.policy_name
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("num_shards", Json::Num(self.num_shards as f64)),
            ("replicas", Json::Num(self.replicas as f64)),
            ("policy", Json::Str(self.policy_name.to_string())),
            (
                "assignment",
                Json::Arr(self.assignment.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_total_and_consistent() {
        for shards in [1usize, 2, 3, 8] {
            let plan = ShardPlan::hash_placement(10, shards, 2);
            assert_eq!(plan.num_tables(), 10);
            let mut seen = vec![false; 10];
            for s in 0..shards {
                for &t in plan.tables_of(s) {
                    assert!(!seen[t], "table {t} placed twice");
                    seen[t] = true;
                    assert_eq!(plan.shard_of(t), s);
                    let (ps, slot) = plan.slot_of(t);
                    assert_eq!(ps, s);
                    assert_eq!(plan.tables_of(s)[slot], t);
                }
            }
            assert!(seen.iter().all(|&x| x), "placement must cover every table");
        }
    }

    #[test]
    fn placement_is_deterministic() {
        let a = ShardPlan::hash_placement(16, 4, 3);
        let b = ShardPlan::hash_placement(16, 4, 3);
        assert_eq!(a, b);
    }

    #[test]
    fn hash_placement_layout_is_frozen() {
        // The trait refactor must not move a single table: the default
        // policy reproduces the original splitmix64 layout exactly.
        let plan = ShardPlan::hash_placement(12, 4, 1);
        for t in 0..12 {
            assert_eq!(plan.shard_of(t), (splitmix64(t as u64) % 4) as usize);
        }
        assert_eq!(plan.policy_name(), "hash");
    }

    #[test]
    fn single_shard_owns_everything() {
        let plan = ShardPlan::hash_placement(5, 1, 1);
        assert_eq!(plan.tables_of(0), &[0, 1, 2, 3, 4]);
        assert_eq!(plan.occupied_shards(), 1);
    }

    #[test]
    fn more_shards_than_tables_leaves_empties() {
        let plan = ShardPlan::hash_placement(2, 16, 2);
        assert!(plan.occupied_shards() <= 2);
        let total: usize = (0..16).map(|s| plan.tables_of(s).len()).sum();
        assert_eq!(total, 2);
    }

    #[test]
    fn alternate_policies_plug_in() {
        let plan = ShardPlan::from_policy(&RoundRobinPlacement, 10, 3, 2);
        assert_eq!(plan.policy_name(), "round_robin");
        for t in 0..10 {
            assert_eq!(plan.shard_of(t), t % 3);
        }
        // Derived structures hold for any legal policy.
        let mut seen = vec![false; 10];
        for s in 0..3 {
            for &t in plan.tables_of(s) {
                assert!(!seen[t]);
                seen[t] = true;
                assert_eq!(plan.slot_of(t).0, s);
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    #[should_panic(expected = "placed table")]
    fn out_of_range_assignment_is_rejected() {
        struct Broken;
        impl PlacementPolicy for Broken {
            fn assign(&self, num_tables: usize, num_shards: usize) -> Vec<usize> {
                vec![num_shards; num_tables] // one past the end
            }
            fn name(&self) -> &'static str {
                "broken"
            }
        }
        ShardPlan::from_policy(&Broken, 3, 2, 1);
    }
}
