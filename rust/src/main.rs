//! dlrm-abft launcher.
//!
//! Commands: serve / bench / campaign / artifacts / snapshot / trace-gen /
//! trace-replay / scrub / quickstart. Flags are `--key value` pairs (see
//! `util::cli`).

use anyhow::{bail, Context, Result};
use dlrm_abft::bench::figures;
use dlrm_abft::bench::harness::BenchConfig;
use dlrm_abft::bench::trace::{generate_trace, read_trace, write_trace, TraceGenConfig};
use dlrm_abft::coordinator::{BatchPolicy, ChaosConfig, Client, Engine, ScoreRequest, Server};
use dlrm_abft::dlrm::{DlrmConfig, DlrmModel, Protection};
use dlrm_abft::fault::campaign::{
    run_flightrec_campaign, EbCampaignConfig, FlightRecCampaignConfig, GemmCampaignConfig,
};
use dlrm_abft::runtime::PjrtEngine;
use dlrm_abft::util::cli::Cli;
use dlrm_abft::util::rng::Pcg32;
use dlrm_abft::util::stats::Summary;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = Cli::parse(&args)?;
    let result = match cli.command.as_str() {
        "serve" => serve(&cli),
        "bench" => bench(&cli),
        "campaign" => campaign(&cli),
        "artifacts" => artifacts(&cli),
        "snapshot" => snapshot(&cli),
        "trace-gen" => trace_gen(&cli),
        "score" => score(&cli),
        "trace-replay" => trace_replay(&cli),
        "scrub" => scrub(&cli),
        "quickstart" => quickstart(),
        "help" | "" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command {other:?}")
        }
    };
    if result.is_ok() {
        cli.reject_unknown()?;
    }
    result
}

fn print_help() {
    println!(
        "dlrm-abft — ABFT-protected low-precision DLRM serving\n\
         \n\
         USAGE: dlrm-abft <command> [--flag value ...]\n\
         \n\
         COMMANDS:\n\
           serve        --addr 127.0.0.1:7878 [--config cfg.json | --model-path m.dlrm]\n\
                        --max-batch 32 --max-wait-ms 2 --protection detect_recompute\n\
                        --async-io false  (epoll event loop front end; linux only)\n\
                        --max-conns 4096  (async connection ceiling; 0 = unlimited)\n\
                        --admit-queue 0  (admission queue bound; 0 = --max-queue)\n\
                        --slo-p99-ms 0  (p99 SLO; arms overload-adaptive detection)\n\
                        --chaos-weight-p 0 --chaos-table-p 0 --scrub-stride 0\n\
                        --policy-budget 0 --policy-tick-ms 50 --policy-bound-only false\n\
                        --policy-state policy.state  (controller warm-start file)\n\
                        --policy-pin-costs false  (pin static unit-cost priors)\n\
                        --obs-sample 0  (span profiler: 0 off, 1 all, n = 1-in-n)\n\
                        --flightrec false  (arm the fault flight recorder)\n\
                        --flightrec-severity significant|near_bound  (freeze floor)\n\
                        --flightrec-captures 8  (black-box pool slots)\n\
                        --flightrec-dump-dir DIR  (write blackbox_<id>.json; implies arm)\n\
           bench        --which fig5|fig6|table2|table3|analysis|ablations|eb-fused|all\n\
                        [--quick true] [--scale N] [--runs N] [--threads N]\n\
           campaign     --op gemm|eb|flightrec [--runs N] [--rows N] [--dim N]\n\
                        [--batches N] [--captures N] [--dump-dir DIR]  (flightrec)\n\
           artifacts    --dir artifacts     (load + compile PJRT artifacts)\n\
           snapshot     --out model.dlrm [--config cfg.json]  (build + save)\n\
           trace-gen    --out trace.jsonl [--requests N] [--rate R] [--zipf S]\n\
           score        --backend native|pjrt --input trace.jsonl [--out -]\n\
           trace-replay --trace trace.jsonl --addr HOST:PORT [--speed X]\n\
           scrub        --model-path m.dlrm  (offline full-table verification)\n\
           quickstart   (tiny protected model, end to end)"
    );
}

fn load_or_build_model(cli: &Cli, protection: Protection) -> Result<DlrmModel> {
    if let Some(path) = cli.get("model-path") {
        println!("loading snapshot {path}");
        return DlrmModel::load(path, protection);
    }
    let mut cfg = match cli.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            DlrmConfig::from_json_str(&text)?
        }
        None => DlrmConfig::default(),
    };
    cfg.protection = protection;
    println!(
        "building model: {} params, {} tables, protection {:?}",
        cfg.param_count(),
        cfg.tables.len(),
        cfg.protection
    );
    Ok(DlrmModel::random(cfg))
}

fn serve(cli: &Cli) -> Result<()> {
    let addr: String = cli.flag("addr", "127.0.0.1:7878".to_string())?;
    let protection = Protection::parse(&cli.flag("protection", "detect_recompute".to_string())?)?;
    let model = load_or_build_model(cli, protection)?;
    println!("model ready: {} MiB of weights", model.weight_bytes() / (1 << 20));
    let chaos_w: f64 = cli.flag("chaos-weight-p", 0.0)?;
    let chaos_t: f64 = cli.flag("chaos-table-p", 0.0)?;
    let mut engine = if chaos_w > 0.0 || chaos_t > 0.0 {
        Engine::with_chaos(
            model,
            ChaosConfig {
                p_weight_flip: chaos_w,
                p_table_flip: chaos_t,
                seed: cli.flag("chaos-seed", 0xC405u64)?,
            },
        )
    } else {
        Engine::new(model)
    };
    let scrub_stride: usize = cli.flag("scrub-stride", 0)?;
    if scrub_stride > 0 {
        engine = engine.with_scrubbing(scrub_stride);
        println!("background scrubbing: {scrub_stride} rows/table/batch");
    }
    // Adaptive detection control plane: a nonzero overhead budget
    // attaches per-site policies + the background escalation controller.
    let policy_budget: f64 = cli.flag("policy-budget", 0.0)?;
    let policy_tick_ms: u64 = cli.flag("policy-tick-ms", 50u64)?;
    let policy_bound_only: bool = cli.flag("policy-bound-only", false)?;
    // Controller warm-start file: loaded (if present) right after the
    // policy attaches, re-written periodically from the serve loop so
    // quiet sites aren't re-learned after every deploy.
    let policy_state_path = cli.get("policy-state").map(str::to_string);
    if policy_budget > 0.0 {
        let cfg = dlrm_abft::policy::PolicyConfig {
            overhead_budget: policy_budget,
            allow_bound_only: policy_bound_only,
            scrub_budget_base: cli.flag("policy-scrub-base", 256usize)?,
            tick: Duration::from_millis(policy_tick_ms.max(1)),
            // Pin the static UnitCosts priors (reproducible runs);
            // default is to let warm measured overheads replace them.
            pin_unit_costs: cli.flag("policy-pin-costs", false)?,
            ..Default::default()
        };
        if scrub_stride == 0 {
            // The controller's scrub_budget knob (raised under
            // persistent faults) needs scrubbers to pace; without this,
            // the policy's proactive arm would be a silent no-op.
            engine = engine.with_scrubbing(cfg.scrub_budget_base.max(1));
            println!(
                "background scrubbing auto-enabled (policy paces it at \
                 {} rows/tick)",
                cfg.scrub_budget_base
            );
        }
        println!(
            "adaptive detection: budget {policy_budget}, tick {policy_tick_ms}ms, \
             bound-only {policy_bound_only}"
        );
        engine = engine.with_policy(cfg);
        if let Some(path) = &policy_state_path {
            match std::fs::read_to_string(path) {
                Ok(text) => match engine.restore_policy_state(&text) {
                    Ok(()) => println!("policy state warm-started from {path}"),
                    Err(e) => println!("policy state {path} ignored ({e}); starting cold"),
                },
                Err(_) => println!("policy state {path} not found; starting cold"),
            }
        }
    } else if policy_state_path.is_some() {
        println!("--policy-state has no effect without --policy-budget > 0");
    }
    // PR 10 front-end knobs: async event loop, connection ceiling,
    // admission watermark, and the p99 SLO that arms the overload
    // controller (detection degrades toward its budget *before*
    // admission sheds a single request; see `policy::overload`).
    let async_io: bool = cli.flag("async-io", false)?;
    let max_conns: usize = cli.flag("max-conns", 4096usize)?;
    let admit_queue: usize = cli.flag("admit-queue", 0usize)?;
    let slo_p99_ms: u64 = cli.flag("slo-p99-ms", 0u64)?;
    if slo_p99_ms > 0 {
        engine = engine
            .with_overload(dlrm_abft::policy::OverloadConfig::for_slo_ms(slo_p99_ms));
        println!(
            "overload control armed: p99 SLO {slo_p99_ms}ms — detection degrades \
             before admission sheds"
        );
    }
    let max_queue: usize = cli.flag("max-queue", 4096usize)?;
    let policy = BatchPolicy {
        max_batch: cli.flag("max-batch", 32usize)?,
        max_wait: Duration::from_millis(cli.flag("max-wait-ms", 2u64)?),
        max_queue: if admit_queue > 0 { admit_queue } else { max_queue },
        // 0 = auto (min(4, cores)): connections hash across per-core
        // batch loops so the accept path doesn't funnel into one thread.
        loops: cli.flag("batch-loops", 0usize)?,
    };
    println!("batch loops: {}", policy.effective_loops());
    // Span profiler sampling: 0 = off (default; probes cost one relaxed
    // load), 1 = every pass, n = 1-in-n. Runtime-settable knob; the
    // `trace`/`prom` server ops expose what it captures.
    let obs_sample: u32 = cli.flag("obs-sample", 0u32)?;
    if obs_sample > 0 {
        engine.obs().set_sampling(obs_sample);
        println!("span profiler on: sampling 1-in-{obs_sample}");
    }
    // Fault flight recorder: freeze-on-fault black boxes, exposed via
    // {"op":"flightrec"} and optionally dumped from the serve loop.
    // A dump dir implies arming. Armed-but-idle costs nothing on the
    // clean path — the recorder is consulted only when a fault journals.
    let flightrec_on: bool = cli.flag("flightrec", false)?;
    let flightrec_dump = cli.get("flightrec-dump-dir").map(str::to_string);
    let flightrec_captures: usize =
        cli.flag("flightrec-captures", dlrm_abft::obs::DEFAULT_CAPTURES)?;
    let flightrec_sev: String = cli.flag("flightrec-severity", "significant".to_string())?;
    if flightrec_on || flightrec_dump.is_some() {
        let sev = dlrm_abft::detect::Severity::from_label(&flightrec_sev)
            .context("--flightrec-severity must be near_bound or significant")?;
        engine.arm_flightrec(flightrec_captures, sev);
        println!(
            "flight recorder armed: {flightrec_captures} capture slots, \
             severity >= {flightrec_sev}"
        );
        if let Some(dir) = &flightrec_dump {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating --flightrec-dump-dir {dir}"))?;
            println!("black boxes dump to {dir}");
        }
    }
    cli.reject_unknown()?;
    let engine = Arc::new(engine);
    #[cfg(target_os = "linux")]
    {
        if async_io {
            let server = dlrm_abft::coordinator::AsyncServer::start(
                &addr,
                Arc::clone(&engine),
                policy,
                dlrm_abft::coordinator::ReactorOptions { max_conns, ..Default::default() },
            )?;
            println!("serving on {} (epoll event loop, max {max_conns} conns)", server.addr);
            println!("protocol: newline-delimited JSON; try {{\"op\":\"ping\"}}");
            serve_housekeeping(&engine, policy_state_path.as_deref(), flightrec_dump.as_deref());
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        if async_io {
            println!(
                "--async-io needs linux epoll; using the threaded accept loop \
                 (--max-conns {max_conns} ignored)"
            );
        }
    }
    let server = Server::start(&addr, Arc::clone(&engine), policy)?;
    println!("serving on {}", server.addr);
    println!("protocol: newline-delimited JSON; try {{\"op\":\"ping\"}}");
    serve_housekeeping(&engine, policy_state_path.as_deref(), flightrec_dump.as_deref())
}

/// Serve-loop housekeeping (shared by the threaded and async front
/// ends): periodic best-effort policy-state persistence and
/// flight-recorder dumps (a hard kill loses at most a few seconds of
/// controller learning / undumped black boxes). Never returns.
fn serve_housekeeping(
    engine: &Engine,
    policy_state_path: Option<&str>,
    flightrec_dump: Option<&str>,
) -> ! {
    let persist_policy = policy_state_path.is_some() && engine.policy_sites().is_some();
    let tick = if persist_policy || flightrec_dump.is_some() {
        Duration::from_secs(5)
    } else {
        Duration::from_secs(3600)
    };
    loop {
        std::thread::sleep(tick);
        if persist_policy {
            if let (Some(path), Some(state)) = (policy_state_path, engine.policy_state()) {
                if let Err(e) = std::fs::write(path, state) {
                    println!("policy state write to {path} failed: {e}");
                }
            }
        }
        if let (Some(dir), Some(rec)) = (flightrec_dump, engine.flightrec()) {
            match rec.dump_new(std::path::Path::new(dir)) {
                Ok(0) => {}
                Ok(n) => println!("flight recorder: dumped {n} black box(es) to {dir}"),
                Err(e) => println!("flight recorder dump to {dir} failed: {e}"),
            }
        }
    }
}

fn bench(cli: &Cli) -> Result<()> {
    let which: String = cli.flag("which", "all".to_string())?;
    let quick: bool = cli.flag("quick", false)?;
    let threads: usize = cli.flag(
        "threads",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )?;
    let bench_cfg = if quick {
        BenchConfig { warmup_iters: 1, sample_iters: 5, inner_reps: 1 }
    } else {
        BenchConfig::default()
    };
    let scale: usize = cli.flag("scale", if quick { 40 } else { 1 })?;
    let runs: usize = cli.flag("runs", if quick { 10 } else { 100 })?;
    let rows: usize = cli.flag("rows", if quick { 100_000 } else { 4_000_000 })?;
    let dim: usize = cli.flag("dim", 64usize)?;
    let trials: usize = if quick { 200 } else { 2000 };
    let mut out = std::io::stdout();
    let run = |which: &str, out: &mut dyn std::io::Write| -> Result<()> {
        match which {
            "fig5" => {
                figures::run_fig5(&bench_cfg, out);
            }
            "fig6" => {
                figures::run_fig6(&bench_cfg, scale, out);
            }
            "table2" => {
                let cfg = GemmCampaignConfig { runs_per_shape: runs, ..Default::default() };
                figures::run_table2(&cfg, threads, out);
            }
            "table3" => {
                let cfg = EbCampaignConfig { table_rows: rows, dim, ..Default::default() };
                figures::run_table3(&cfg, if quick { 10 } else { 1 }, out);
            }
            "analysis" => figures::run_analysis(trials, out),
            "ablations" => figures::run_ablations(&bench_cfg, out),
            "eb-fused" => figures::run_eb_fused_perf(&bench_cfg, scale, out),
            other => bail!("unknown bench {other:?}"),
        }
        Ok(())
    };
    if which == "all" {
        for w in ["fig5", "fig6", "table2", "table3", "analysis", "ablations", "eb-fused"] {
            run(w, &mut out)?;
        }
    } else {
        run(&which, &mut out)?;
    }
    Ok(())
}

fn campaign(cli: &Cli) -> Result<()> {
    let op: String = cli.flag("op", "gemm".to_string())?;
    let mut out = std::io::stdout();
    match op.as_str() {
        "gemm" => {
            let cfg = GemmCampaignConfig {
                runs_per_shape: cli.flag("runs", 100usize)?,
                ..Default::default()
            };
            figures::run_table2(&cfg, cli.flag("threads", 1usize)?, &mut out);
        }
        "eb" => {
            let cfg = EbCampaignConfig {
                table_rows: cli.flag("rows", 4_000_000usize)?,
                dim: cli.flag("dim", 64usize)?,
                ..Default::default()
            };
            figures::run_table3(&cfg, 1, &mut out);
        }
        // Flight-recorder drill: persistent corruption under an armed
        // recorder; fails unless every black box is a complete
        // post-mortem. --dump-dir writes the blackbox_<id>.json artifacts.
        "flightrec" => {
            let cfg = FlightRecCampaignConfig {
                batches: cli.flag("batches", 32usize)?,
                captures: cli.flag("captures", 8usize)?,
                dump_dir: cli.get("dump-dir").map(str::to_string),
                ..Default::default()
            };
            let r = run_flightrec_campaign(&cfg);
            println!(
                "flightrec campaign: {} severe events, {} captures taken \
                 ({} resident, {} missed), complete post-mortems: {}, dumped {}",
                r.severe_events,
                r.captures_taken,
                r.resident,
                r.captures_missed,
                r.all_complete(),
                r.dumped
            );
            if !r.all_complete() {
                bail!("incomplete black boxes: {r:?}");
            }
        }
        other => bail!("unknown campaign {other:?}"),
    }
    Ok(())
}

fn artifacts(cli: &Cli) -> Result<()> {
    let dir: String = cli.flag("dir", "artifacts".to_string())?;
    let mut engine = PjrtEngine::cpu()?;
    let loaded = engine.load_artifact_dir(&dir)?;
    if loaded.is_empty() {
        bail!("no *.hlo.txt artifacts in {dir:?}; run `make artifacts` first");
    }
    println!("platform={} loaded={loaded:?}", engine.platform());
    for name in &loaded {
        println!("  {name}: compiled OK");
    }
    Ok(())
}

fn snapshot(cli: &Cli) -> Result<()> {
    let out: String = cli.flag("out", "model.dlrm".to_string())?;
    let model = load_or_build_model(cli, Protection::DetectRecompute)?;
    model.save(&out)?;
    let bytes = std::fs::metadata(&out)?.len();
    println!("wrote {out} ({} MiB)", bytes / (1 << 20));
    Ok(())
}

fn trace_gen(cli: &Cli) -> Result<()> {
    let out: String = cli.flag("out", "trace.jsonl".to_string())?;
    let model_cfg = match cli.get("config") {
        Some(path) => DlrmConfig::from_json_str(&std::fs::read_to_string(path)?)?,
        None => DlrmConfig::default(),
    };
    let gen = TraceGenConfig {
        rate: cli.flag("rate", 500.0)?,
        requests: cli.flag("requests", 1000usize)?,
        zipf_s: {
            let s: f64 = cli.flag("zipf", 1.05)?;
            (s > 0.0).then_some(s)
        },
        seed: cli.flag("seed", 0x7124CEu64)?,
    };
    let trace = generate_trace(&model_cfg, &gen);
    let mut f = std::io::BufWriter::new(std::fs::File::create(&out)?);
    write_trace(&mut f, &trace)?;
    println!("wrote {} requests to {out}", trace.len());
    Ok(())
}

/// Offline batch scoring: read a JSONL trace, score through the chosen
/// backend, emit JSONL results. The `pjrt` backend serves the jax/Pallas
/// artifacts — python stays off this path entirely.
fn score(cli: &Cli) -> Result<()> {
    use dlrm_abft::coordinator::{ArtifactShape, PjrtModelEngine};
    let input: String = cli.flag("input", "trace.jsonl".to_string())?;
    let backend: String = cli.flag("backend", "native".to_string())?;
    let out_path: String = cli.flag("out", "-".to_string())?;
    let trace = read_trace(std::io::BufReader::new(std::fs::File::open(&input)?))?;
    let mut out: Box<dyn std::io::Write> = if out_path == "-" {
        Box::new(std::io::stdout())
    } else {
        Box::new(std::io::BufWriter::new(std::fs::File::create(&out_path)?))
    };
    let to_reqs = |trace: &[dlrm_abft::bench::trace::TracedRequest]| -> Vec<ScoreRequest> {
        trace
            .iter()
            .enumerate()
            .map(|(i, t)| ScoreRequest { id: i as u64, dense: t.dense.clone(), sparse: t.sparse.clone() })
            .collect()
    };
    match backend.as_str() {
        "native" => {
            let model = load_or_build_model(cli, Protection::DetectRecompute)?;
            let engine = Engine::new(model);
            for chunk in to_reqs(&trace).chunks(16) {
                for resp in engine.process_batch(chunk.to_vec()) {
                    writeln!(out, "{}", resp.to_json())?;
                }
            }
            eprintln!("metrics: {}", engine.metrics.snapshot());
        }
        "pjrt" => {
            let dir: String = cli.flag("artifacts", "artifacts".to_string())?;
            let engine = PjrtModelEngine::load_dir(&dir, ArtifactShape::default())?;
            let max_b = *engine.batch_sizes().last().unwrap();
            for chunk in to_reqs(&trace).chunks(max_b) {
                for resp in engine.process_batch(chunk.to_vec())? {
                    writeln!(out, "{}", resp.to_json())?;
                }
            }
            eprintln!("metrics: {}", engine.metrics.snapshot());
        }
        other => bail!("unknown backend {other:?}"),
    }
    Ok(())
}

fn trace_replay(cli: &Cli) -> Result<()> {
    let path: String = cli.flag("trace", "trace.jsonl".to_string())?;
    let addr: String = cli.flag("addr", "127.0.0.1:7878".to_string())?;
    let speed: f64 = cli.flag("speed", 1.0)?;
    let trace = read_trace(std::io::BufReader::new(std::fs::File::open(&path)?))?;
    println!("replaying {} requests to {addr} at {speed}x", trace.len());
    let sock_addr: std::net::SocketAddr = addr.parse()?;
    let mut client = Client::connect(&sock_addr)?;
    let t0 = Instant::now();
    let mut latencies = Vec::with_capacity(trace.len());
    let mut detected = 0usize;
    for (i, req) in trace.iter().enumerate() {
        let due = Duration::from_micros((req.at_us as f64 / speed) as u64);
        if let Some(wait) = due.checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let score_req = ScoreRequest {
            id: i as u64,
            dense: req.dense.clone(),
            sparse: req.sparse.clone(),
        };
        let t = Instant::now();
        let resp = client.score(&score_req)?;
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
        detected += resp.detected as usize;
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::from(&latencies);
    println!(
        "done: {:.1} req/s, latency ms p50 {:.2} p95 {:.2} max {:.2}, detections {}",
        latencies.len() as f64 / wall,
        s.median,
        s.p95,
        s.max,
        detected
    );
    Ok(())
}

fn scrub(cli: &Cli) -> Result<()> {
    use dlrm_abft::abft::Scrubber;
    let path = cli
        .get("model-path")
        .context("scrub needs --model-path")?
        .to_string();
    let model = DlrmModel::load(&path, Protection::Detect)?;
    let t0 = Instant::now();
    let mut total_bad = 0usize;
    for (t, (table, checksum)) in model.tables.iter().zip(&model.checksums).enumerate() {
        let bad = Scrubber::full_pass(table, checksum);
        println!("table {t}: {} rows scanned, {} corrupted", table.rows, bad.len());
        total_bad += bad.len();
    }
    println!(
        "scrub complete in {:.2}s: {total_bad} corrupted rows",
        t0.elapsed().as_secs_f64()
    );
    if total_bad > 0 {
        bail!("{total_bad} corrupted rows found");
    }
    Ok(())
}

fn quickstart() -> Result<()> {
    use dlrm_abft::dlrm::TableConfig;
    println!("== dlrm-abft quickstart ==");
    let cfg = DlrmConfig {
        num_dense: 8,
        embedding_dim: 16,
        bottom_mlp: vec![32, 16],
        top_mlp: vec![32],
        tables: vec![TableConfig { rows: 10_000, pooling: 20 }; 4],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: 1,
    };
    let model = DlrmModel::random(cfg);
    let mut rng = Pcg32::new(2);
    let reqs = model.synth_requests(16, &mut rng);
    let (scores, report) = model.forward(&reqs);
    println!("scores[..4] = {:?}", &scores[..4]);
    println!("soft-error report: {report:?}");
    println!("quickstart OK");
    Ok(())
}
