//! Detection telemetry: the per-site counters the hot paths feed and the
//! controller's sliding windows read.
//!
//! Hot-path cost is bounded by design: when no policy is attached
//! ([`PolicyHandle`] is `None`) the only cost is an `Option` check; when
//! attached, each protected invocation pays one relaxed mode load plus a
//! handful of relaxed `fetch_add`s. All counters are **cumulative** —
//! the controller snapshots them per tick and differences consecutive
//! snapshots into its sliding window, so the hot path never touches a
//! ring buffer or a lock.

use crate::policy::mode::{DetectionMode, PolicyCell, MODE_SLOTS};
use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Number of per-site sampling-phase lanes. The rotating sample phase
/// used to be one `AtomicU64` per site — the last cache line every pool
/// worker contended on at high concurrency (PR 4 open item). Worker
/// threads are now spread round-robin over [`PHASE_LANES`]
/// cache-line-padded lanes, which removes the contention entirely for
/// up to 16 workers and divides it by the lane count beyond that (the
/// array is inline in [`SiteTelemetry`], so its size is a per-site
/// memory trade-off: 16 × 64 B). Coverage still rotates — each lane is
/// an independent 1-in-`n` phase stream — and `Sampled(1)` remains
/// exactly `Full` on every path (phase-independent; prop-tested in
/// `rust/tests/prop.rs`).
pub const PHASE_LANES: usize = 16;

/// One cache-line-padded phase counter.
#[derive(Debug, Default)]
#[repr(align(64))]
struct PhaseLane(AtomicU64);

/// Round-robin lane assignment for new threads.
static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static PHASE_LANE: Cell<usize> = const { Cell::new(usize::MAX) };
}

#[inline]
fn phase_lane() -> usize {
    PHASE_LANE.with(|l| {
        let mut lane = l.get();
        if lane == usize::MAX {
            lane = NEXT_LANE.fetch_add(1, Ordering::Relaxed) % PHASE_LANES;
            l.set(lane);
        }
        lane
    })
}

/// Cumulative counters of one protected site.
#[derive(Debug, Default)]
pub struct SiteTelemetry {
    /// Units (GEMM rows / EB bags) that flowed through the site.
    pub units: AtomicU64,
    /// Units actually verified (== `units` under `Full`).
    pub verified: AtomicU64,
    /// Detection flags raised at this site. Fed by the fault-event
    /// pipeline ([`crate::detect::EventSink::emit`]) — detection sites
    /// no longer bump this by hand.
    pub flags: AtomicU64,
    /// Sampling phase, sharded per worker thread (see [`PHASE_LANES`]):
    /// advances by the unit count of every invocation so `Sampled(n)`
    /// coverage rotates across rows/bags instead of pinning to fixed
    /// indices.
    sample_seq: [PhaseLane; PHASE_LANES],
}

impl SiteTelemetry {
    /// Reserve `count` units of sampling phase on the calling worker's
    /// lane; returns the old phase.
    #[inline]
    pub fn sample_phase(&self, count: u64) -> u64 {
        self.sample_seq[phase_lane()].0.fetch_add(count, Ordering::Relaxed)
    }

    /// Account one invocation's units / verified units.
    #[inline]
    pub fn record(&self, units: u64, verified: u64) {
        self.units.fetch_add(units, Ordering::Relaxed);
        if verified > 0 {
            self.verified.fetch_add(verified, Ordering::Relaxed);
        }
    }

    /// Raise `n` detection flags (the [`crate::detect::EventSink`] fan-out
    /// target; also used directly by controller tests to simulate
    /// traffic).
    #[inline]
    pub fn note_flags(&self, n: u64) {
        if n > 0 {
            self.flags.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Point-in-time copy of the cumulative counters.
    pub fn snapshot(&self) -> SiteSnapshot {
        SiteSnapshot {
            units: self.units.load(Ordering::Relaxed),
            verified: self.verified.load(Ordering::Relaxed),
            flags: self.flags.load(Ordering::Relaxed),
        }
    }
}

/// One snapshot of a site's cumulative counters (controller-side).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SiteSnapshot {
    pub units: u64,
    pub verified: u64,
    pub flags: u64,
}

impl SiteSnapshot {
    /// Per-tick delta `self - prev` (saturating; counters never reset).
    pub fn delta(&self, prev: &SiteSnapshot) -> SiteSnapshot {
        SiteSnapshot {
            units: self.units.saturating_sub(prev.units),
            verified: self.verified.saturating_sub(prev.verified),
            flags: self.flags.saturating_sub(prev.flags),
        }
    }
}

/// Which operator class a site protects (they have different calibrated
/// full-mode overheads and therefore different budget targets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    /// One MLP layer (bottom, top, or head — `Engine::layer_ref` order).
    Gemm,
    /// One embedding table.
    Eb,
}

/// One protected site: its mode cell plus its telemetry.
#[derive(Debug, Default)]
pub struct Site {
    pub cell: PolicyCell,
    pub telem: SiteTelemetry,
}

/// The control plane's shared state: one [`Site`] per protected operator
/// instance, the per-mode served-units counters, and the lifetime
/// escalation/decay tallies. Shared (`Arc`) between the model (hot-path
/// reads + telemetry writes), the controller (mode writes), and the
/// engine (metrics snapshots).
#[derive(Debug)]
pub struct PolicySites {
    /// GEMM sites in model layer order: bottom\[0..\], top\[0..\], head.
    pub gemm: Vec<Site>,
    /// EB sites, one per embedding table (global table id order).
    pub eb: Vec<Site>,
    /// Eq-5 bound relaxation factor applied under
    /// [`DetectionMode::BoundOnly`] on EB sites.
    pub bound_relax: f64,
    /// Cumulative units served per mode (indexed by
    /// [`DetectionMode::slot`]); the "per-mode served counters" in the
    /// metrics snapshot.
    pub served: [AtomicU64; MODE_SLOTS],
    /// Lifetime controller events (mirrored into the metrics snapshot).
    pub escalations: AtomicU64,
    pub decays: AtomicU64,
    pub scrub_boosts: AtomicU64,
    /// Rows the scrubber may scan per `Engine::scrub_tick` (the
    /// controller's `scrub_budget` knob; see `abft::scrub` for the exact
    /// pacing contract).
    pub scrub_budget: AtomicUsize,
}

impl PolicySites {
    /// Build with every site at `Full` (the safe default).
    pub fn new(gemm_sites: usize, eb_sites: usize, bound_relax: f64, scrub_budget: usize) -> Self {
        Self {
            gemm: (0..gemm_sites).map(|_| Site::default()).collect(),
            eb: (0..eb_sites).map(|_| Site::default()).collect(),
            bound_relax,
            served: Default::default(),
            escalations: AtomicU64::new(0),
            decays: AtomicU64::new(0),
            scrub_boosts: AtomicU64::new(0),
            scrub_budget: AtomicUsize::new(scrub_budget),
        }
    }

    /// Total site count (flat index space: gemm sites then eb sites —
    /// the controller's neighbor map uses this space).
    pub fn len(&self) -> usize {
        self.gemm.len() + self.eb.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat-index access (gemm sites first, then eb).
    pub fn site(&self, flat: usize) -> &Site {
        if flat < self.gemm.len() {
            &self.gemm[flat]
        } else {
            &self.eb[flat - self.gemm.len()]
        }
    }

    /// Flat index and kind of every site, for the controller.
    pub fn kind(&self, flat: usize) -> SiteKind {
        if flat < self.gemm.len() {
            SiteKind::Gemm
        } else {
            SiteKind::Eb
        }
    }

    /// Flat index of EB site `t` (global table id).
    pub fn eb_flat(&self, t: usize) -> usize {
        self.gemm.len() + t
    }

    /// Bump the per-mode served-units counter.
    #[inline]
    pub fn note_served(&self, mode: DetectionMode, units: u64) {
        self.served[mode.slot()].fetch_add(units, Ordering::Relaxed);
    }

    /// Force every site to `mode` (benches / drills).
    pub fn set_all(&self, mode: DetectionMode) {
        for s in self.gemm.iter().chain(&self.eb) {
            s.cell.store(mode);
        }
    }
}

/// The model's (optional) attachment to a policy table. `Default` is
/// detached: every mode query answers `Full` and no telemetry is
/// recorded — byte-for-byte the pre-policy behavior.
#[derive(Clone, Debug, Default)]
pub struct PolicyHandle(Option<Arc<PolicySites>>);

impl PolicyHandle {
    pub fn attached(sites: Arc<PolicySites>) -> Self {
        Self(Some(sites))
    }

    #[inline]
    pub fn sites(&self) -> Option<&Arc<PolicySites>> {
        self.0.as_ref()
    }

    /// Mode of GEMM site `i` (model layer order); `Full` when detached.
    #[inline]
    pub fn gemm_mode(&self, i: usize) -> DetectionMode {
        match &self.0 {
            Some(s) => s.gemm[i].cell.load(),
            None => DetectionMode::Full,
        }
    }

    /// Telemetry of GEMM site `i`; `None` when detached.
    #[inline]
    pub fn gemm_telem(&self, i: usize) -> Option<&SiteTelemetry> {
        self.0.as_ref().map(|s| &s.gemm[i].telem)
    }

    /// Mode of EB site `t` (global table id); `Full` when detached.
    #[inline]
    pub fn eb_mode(&self, t: usize) -> DetectionMode {
        match &self.0 {
            Some(s) => s.eb[t].cell.load(),
            None => DetectionMode::Full,
        }
    }

    #[inline]
    pub fn eb_telem(&self, t: usize) -> Option<&SiteTelemetry> {
        self.0.as_ref().map(|s| &s.eb[t].telem)
    }

    /// The EB bound-relaxation factor (1.0 when detached — never used on
    /// the detached path, but a sane value regardless).
    #[inline]
    pub fn bound_relax(&self) -> f64 {
        self.0.as_ref().map_or(1.0, |s| s.bound_relax)
    }

    /// One bag's policy decision at EB site `t` — the single dispatch
    /// both the local EB stage and the shard router call, so the
    /// sampled/bound semantics (and the Sampled(1) ≡ Full invariant)
    /// cannot drift between serving topologies. Loads the mode, counts
    /// the served unit, advances the sampling phase when sampling, and
    /// returns `(site telemetry, run-the-checked-kernel, Eq-5 bound
    /// scale)`. Detached: `(None, check, 1.0)` — the Full behavior.
    #[inline]
    pub fn eb_bag_policy(&self, t: usize) -> (Option<&SiteTelemetry>, bool, f64) {
        let Some(sites) = self.sites() else {
            return (None, true, 1.0);
        };
        let mode = sites.eb[t].cell.load();
        sites.note_served(mode, 1);
        let telem = &sites.eb[t].telem;
        let (check, scale) = match mode {
            DetectionMode::Full => (true, 1.0),
            DetectionMode::Sampled(n) => (telem.sample_phase(1) % n.max(1) as u64 == 0, 1.0),
            DetectionMode::BoundOnly => (true, sites.bound_relax),
            DetectionMode::Off => (false, 1.0),
        };
        (Some(telem), check, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detached_handle_is_full_everywhere() {
        let h = PolicyHandle::default();
        assert_eq!(h.gemm_mode(0), DetectionMode::Full);
        assert_eq!(h.eb_mode(7), DetectionMode::Full);
        assert!(h.gemm_telem(0).is_none());
        assert!(h.sites().is_none());
    }

    #[test]
    fn attached_handle_reads_cells() {
        let sites = Arc::new(PolicySites::new(3, 2, 1e3, 256));
        sites.gemm[1].cell.store(DetectionMode::Sampled(4));
        sites.eb[0].cell.store(DetectionMode::Off);
        let h = PolicyHandle::attached(Arc::clone(&sites));
        assert_eq!(h.gemm_mode(0), DetectionMode::Full);
        assert_eq!(h.gemm_mode(1), DetectionMode::Sampled(4));
        assert_eq!(h.eb_mode(0), DetectionMode::Off);
        assert_eq!(h.eb_mode(1), DetectionMode::Full);
    }

    #[test]
    fn snapshots_difference_into_deltas() {
        let t = SiteTelemetry::default();
        t.record(10, 5);
        let a = t.snapshot();
        t.record(6, 3);
        t.note_flags(2);
        let b = t.snapshot();
        let d = b.delta(&a);
        assert_eq!(d, SiteSnapshot { units: 6, verified: 3, flags: 2 });
    }

    #[test]
    fn sample_phase_advances_by_count() {
        let t = SiteTelemetry::default();
        assert_eq!(t.sample_phase(8), 0);
        assert_eq!(t.sample_phase(3), 8);
        assert_eq!(t.sample_phase(1), 11);
    }

    #[test]
    fn sample_phase_lanes_are_per_thread_streams() {
        // Each thread draws from its own lane: a sibling thread's draws
        // never perturb this thread's phase stream.
        let t = Arc::new(SiteTelemetry::default());
        assert_eq!(t.sample_phase(4), 0);
        let t2 = Arc::clone(&t);
        let other = std::thread::spawn(move || {
            // A fresh thread starts its own lane at phase 0 (lane
            // assignment is round-robin, and even on lane collision the
            // stream only advances by this thread's own draws).
            let first = t2.sample_phase(100);
            (first, t2.sample_phase(1))
        });
        let (first, second) = other.join().unwrap();
        assert_eq!(second, first + 100, "the sibling's lane advances by its own draws");
        // Lane assignment is a global round-robin, so the sibling lands
        // on its own lane (this thread's stream unperturbed) or, rarely,
        // collides with ours — either way every draw is accounted.
        let last = t.sample_phase(1);
        assert!(
            (first == 0 && last == 4) || (first == 4 && last == first + 101),
            "unexpected phase interleaving: first={first} last={last}"
        );
    }

    #[test]
    fn flat_index_space_covers_both_classes() {
        let sites = PolicySites::new(2, 3, 1e3, 128);
        assert_eq!(sites.len(), 5);
        assert_eq!(sites.kind(1), SiteKind::Gemm);
        assert_eq!(sites.kind(2), SiteKind::Eb);
        assert_eq!(sites.eb_flat(2), 4);
        sites.set_all(DetectionMode::Sampled(2));
        assert_eq!(sites.site(4).cell.load(), DetectionMode::Sampled(2));
    }
}
