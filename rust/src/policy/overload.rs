//! Overload-adaptive detection (PR 10): the serve-side pressure input
//! to the policy lattice.
//!
//! The paper's budgets (<20% GEMM, <26% EmbeddingBag) frame detection
//! as *overhead* — so under SLO pressure, detection overhead is the
//! first thing the server trades, and shedding load is the last. The
//! [`OverloadCtl`] watches the measured serving p99 (a windowed view of
//! the cumulative latency histogram — [`crate::obs::HistWindow`] —
//! because a cumulative p99 never comes back down after a burst) plus
//! batch-queue depth against a `--slo-p99-ms` target, and walks a
//! three-level floor with hysteresis in both directions:
//!
//! ```text
//!            sustained over-SLO (enter_ticks)          more pressure
//!   Normal ───────────────────────────────► Degrading ─────────────► Shedding
//!   floor: none          floor: Sampled(n*) → BoundOnly         admission rejects
//!     ▲                                                              │
//!     └────────── sustained under clear line (clear_ticks each) ◄────┘
//! ```
//!
//! The floor is *applied* by
//! [`PolicyController::apply_overload_floor`](super::PolicyController::apply_overload_floor),
//! which exempts every site holding an escalation cooldown — a fault
//! still snaps its site to `Full` within one controller tick while the
//! front end is degraded, and detected corruption is never served. Only
//! after the floor is fully pressed (`Sampled(n*)`, then `BoundOnly`
//! when opted in) and pressure persists does the state reach
//! `Shedding`, where admission starts refusing requests — so detection
//! degrades strictly before the first shed.
//!
//! The hot-path surface is two relaxed atomic loads
//! ([`OverloadCtl::should_shed`]); the state machine itself runs only
//! on the (per-tick) control path.

use crate::obs::{HistWindow, LogLinHist};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;

/// Serve-side overload state, coarsest first.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadState {
    /// Under SLO: no floor, no shedding.
    Normal,
    /// Sustained pressure: detection floor pressed down, still admitting.
    Degrading,
    /// Floor exhausted and pressure persists: admission sheds.
    Shedding,
}

impl OverloadState {
    /// Stable numeric code (strings are skipped by the Prometheus
    /// walker, so the snapshot carries both).
    pub fn code(self) -> u32 {
        match self {
            OverloadState::Normal => 0,
            OverloadState::Degrading => 1,
            OverloadState::Shedding => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            OverloadState::Normal => "normal",
            OverloadState::Degrading => "degrading",
            OverloadState::Shedding => "shedding",
        }
    }

    fn from_code(c: u32) -> Self {
        match c {
            0 => OverloadState::Normal,
            1 => OverloadState::Degrading,
            _ => OverloadState::Shedding,
        }
    }
}

/// Detection floor the overload controller presses sites toward.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadFloor {
    /// No floor: sites follow the normal escalate/decay walk.
    None,
    /// Press straight to the budgeted target (`Sampled(n*)` per site) —
    /// where quiet decay would eventually land, minus the patience.
    Budgeted,
    /// Press below budget to `BoundOnly` (one aggregate check per
    /// invocation) — the deepest the dial goes before shedding.
    BoundOnly,
}

impl OverloadFloor {
    pub fn level(self) -> u32 {
        match self {
            OverloadFloor::None => 0,
            OverloadFloor::Budgeted => 1,
            OverloadFloor::BoundOnly => 2,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            OverloadFloor::None => "none",
            OverloadFloor::Budgeted => "budgeted",
            OverloadFloor::BoundOnly => "bound_only",
        }
    }

    fn from_level(l: u32) -> Self {
        match l {
            0 => OverloadFloor::None,
            1 => OverloadFloor::Budgeted,
            _ => OverloadFloor::BoundOnly,
        }
    }
}

/// Overload-controller tuning. Defaults favor stability: two sustained
/// over-SLO ticks per degradation step, four clear ticks per restore
/// step, and a dead band between the SLO and `clear_frac · SLO` where
/// nothing moves.
#[derive(Clone, Copy, Debug)]
pub struct OverloadConfig {
    /// The p99 target, microseconds (`--slo-p99-ms` × 1000).
    pub slo_p99_us: u64,
    /// Pressure clears only below `clear_frac · slo` (restore
    /// hysteresis; must be ≤ 1).
    pub clear_frac: f64,
    /// Consecutive over-pressure ticks per step down (floor deeper, or
    /// Degrading → Shedding once the floor is exhausted).
    pub enter_ticks: u32,
    /// Consecutive clear ticks per step back up (Shedding → Degrading,
    /// then one floor level at a time).
    pub clear_ticks: u32,
    /// Queue depth ≥ `queue_frac · bound` counts as pressure even while
    /// the windowed p99 looks healthy (the queue is tomorrow's p99).
    pub queue_frac: f64,
    /// Whether the floor may press below the budgeted `Sampled(n*)` to
    /// `BoundOnly`.
    pub allow_bound_only: bool,
}

impl OverloadConfig {
    /// Config for a p99 SLO given in milliseconds.
    pub fn for_slo_ms(ms: u64) -> Self {
        Self {
            slo_p99_us: ms.saturating_mul(1000),
            clear_frac: 0.75,
            enter_ticks: 2,
            clear_ticks: 4,
            queue_frac: 0.5,
            allow_bound_only: true,
        }
    }
}

struct Inner {
    window: HistWindow,
    over_streak: u32,
    under_streak: u32,
    floor: u32,
    shedding: bool,
}

/// The overload controller. `tick` runs the state machine (control
/// path, one short mutex); everything admission or a metrics snapshot
/// reads is a relaxed atomic.
pub struct OverloadCtl {
    cfg: OverloadConfig,
    state: AtomicU32,
    floor: AtomicU32,
    last_p99_us: AtomicU64,
    degrade_steps: AtomicU64,
    restore_steps: AtomicU64,
    pressed_sites: AtomicU64,
    inner: Mutex<Inner>,
}

impl OverloadCtl {
    pub fn new(cfg: OverloadConfig) -> Self {
        Self {
            cfg,
            state: AtomicU32::new(OverloadState::Normal.code()),
            floor: AtomicU32::new(0),
            last_p99_us: AtomicU64::new(0),
            degrade_steps: AtomicU64::new(0),
            restore_steps: AtomicU64::new(0),
            pressed_sites: AtomicU64::new(0),
            inner: Mutex::new(Inner {
                window: HistWindow::new(),
                over_streak: 0,
                under_streak: 0,
                floor: 0,
                shedding: false,
            }),
        }
    }

    /// One control tick: roll the latency window, classify pressure,
    /// advance the state machine, and return the floor the policy
    /// controller should apply this tick.
    pub fn tick(&self, hist: &LogLinHist, queue_depth: usize, queue_bound: usize) -> OverloadFloor {
        let mut g = self.inner.lock().unwrap();
        let p99 = g.window.roll_quantile(hist, 0.99);
        if let Some(p) = p99 {
            self.last_p99_us.store(p, Ordering::Relaxed);
        }
        let q_over =
            queue_bound > 0 && (queue_depth as f64) >= self.cfg.queue_frac * queue_bound as f64;
        let lat_over = p99.is_some_and(|p| p > self.cfg.slo_p99_us);
        // No new samples reads as clear: either traffic stopped or
        // everything was shed — both mean pressure is draining.
        let lat_clear =
            p99.is_none_or(|p| (p as f64) <= self.cfg.slo_p99_us as f64 * self.cfg.clear_frac);
        if lat_over || q_over {
            g.over_streak += 1;
            g.under_streak = 0;
        } else if lat_clear {
            g.under_streak += 1;
            g.over_streak = 0;
        } else {
            // Dead band between clear line and SLO: hold position.
            g.over_streak = 0;
            g.under_streak = 0;
        }
        let max_floor = if self.cfg.allow_bound_only { 2 } else { 1 };
        if g.over_streak >= self.cfg.enter_ticks.max(1) {
            g.over_streak = 0;
            if g.floor < max_floor {
                g.floor += 1;
                self.degrade_steps.fetch_add(1, Ordering::Relaxed);
            } else if !g.shedding {
                g.shedding = true;
            }
        }
        if g.under_streak >= self.cfg.clear_ticks.max(1) {
            g.under_streak = 0;
            if g.shedding {
                g.shedding = false;
            } else if g.floor > 0 {
                g.floor -= 1;
                self.restore_steps.fetch_add(1, Ordering::Relaxed);
            }
        }
        let state = if g.shedding {
            OverloadState::Shedding
        } else if g.floor > 0 {
            OverloadState::Degrading
        } else {
            OverloadState::Normal
        };
        self.state.store(state.code(), Ordering::Relaxed);
        self.floor.store(g.floor, Ordering::Relaxed);
        OverloadFloor::from_level(g.floor)
    }

    /// Admission check — two relaxed loads, no locks. Sheds only in
    /// `Shedding` state, and then only while the queue sits above half
    /// its bound, so a shedding server keeps serving at reduced rate
    /// instead of blackholing (and the latency window keeps getting
    /// samples to recover on).
    #[inline]
    pub fn should_shed(&self, queue_depth: usize, queue_bound: usize) -> bool {
        if self.state.load(Ordering::Relaxed) != OverloadState::Shedding.code() {
            return false;
        }
        queue_bound == 0 || queue_depth.saturating_mul(2) >= queue_bound
    }

    /// Record how many sites the policy controller changed when applying
    /// this tick's floor.
    pub fn note_pressed(&self, sites: usize) {
        if sites > 0 {
            self.pressed_sites.fetch_add(sites as u64, Ordering::Relaxed);
        }
    }

    pub fn state(&self) -> OverloadState {
        OverloadState::from_code(self.state.load(Ordering::Relaxed))
    }

    pub fn floor(&self) -> OverloadFloor {
        OverloadFloor::from_level(self.floor.load(Ordering::Relaxed))
    }

    /// Windowed p99 as of the last tick that saw samples, microseconds.
    pub fn last_p99_us(&self) -> u64 {
        self.last_p99_us.load(Ordering::Relaxed)
    }

    pub fn degrade_steps(&self) -> u64 {
        self.degrade_steps.load(Ordering::Relaxed)
    }

    pub fn restore_steps(&self) -> u64 {
        self.restore_steps.load(Ordering::Relaxed)
    }

    pub fn pressed_sites(&self) -> u64 {
        self.pressed_sites.load(Ordering::Relaxed)
    }

    pub fn config(&self) -> &OverloadConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> OverloadConfig {
        OverloadConfig {
            slo_p99_us: 1000,
            clear_frac: 0.75,
            enter_ticks: 2,
            clear_ticks: 2,
            queue_frac: 0.5,
            allow_bound_only: true,
        }
    }

    fn feed(h: &LogLinHist, us: u64, n: usize) {
        for _ in 0..n {
            h.record(us);
        }
    }

    #[test]
    fn degrades_through_both_floor_levels_before_shedding() {
        let ctl = OverloadCtl::new(quick_cfg());
        let h = LogLinHist::new();
        let mut saw_budgeted = false;
        let mut saw_bound = false;
        for _ in 0..12 {
            feed(&h, 5000, 50);
            let floor = ctl.tick(&h, 0, 1000);
            match ctl.state() {
                OverloadState::Normal => assert_eq!(floor, OverloadFloor::None),
                OverloadState::Degrading => {
                    saw_budgeted |= floor == OverloadFloor::Budgeted;
                    saw_bound |= floor == OverloadFloor::BoundOnly;
                }
                OverloadState::Shedding => {
                    // Shedding is only reachable with the floor fully
                    // pressed: detection degraded strictly first.
                    assert!(saw_budgeted && saw_bound);
                    assert_eq!(floor, OverloadFloor::BoundOnly);
                    return;
                }
            }
        }
        panic!("never reached Shedding under sustained 5x-SLO pressure");
    }

    #[test]
    fn recovers_with_hysteresis_when_pressure_clears() {
        let ctl = OverloadCtl::new(quick_cfg());
        let h = LogLinHist::new();
        while ctl.state() != OverloadState::Shedding {
            feed(&h, 5000, 50);
            ctl.tick(&h, 0, 1000);
        }
        // Clear traffic: the ladder unwinds one step per clear_ticks —
        // Shedding → floor 2 → floor 1 → Normal, never all at once.
        let mut states = Vec::new();
        for _ in 0..12 {
            feed(&h, 100, 50);
            ctl.tick(&h, 0, 1000);
            states.push((ctl.state(), ctl.floor().level()));
        }
        assert_eq!(
            states.last().copied(),
            Some((OverloadState::Normal, 0)),
            "states: {states:?}"
        );
        // Degrading with the full floor must appear on the way down.
        assert!(states.contains(&(OverloadState::Degrading, 2)), "states: {states:?}");
        assert!(states.contains(&(OverloadState::Degrading, 1)), "states: {states:?}");
        assert!(ctl.restore_steps() >= 2);
    }

    #[test]
    fn dead_band_holds_position() {
        let ctl = OverloadCtl::new(quick_cfg());
        let h = LogLinHist::new();
        for _ in 0..4 {
            feed(&h, 5000, 50);
            ctl.tick(&h, 0, 1000);
        }
        let floor = ctl.floor().level();
        assert!(floor >= 1);
        // Between clear line (750) and SLO (1000): neither streak grows.
        for _ in 0..10 {
            feed(&h, 900, 50);
            ctl.tick(&h, 0, 1000);
        }
        assert_eq!(ctl.floor().level(), floor, "dead band moved the floor");
    }

    #[test]
    fn queue_depth_alone_is_pressure() {
        let ctl = OverloadCtl::new(quick_cfg());
        let h = LogLinHist::new();
        for _ in 0..2 {
            feed(&h, 100, 10); // latency healthy
            ctl.tick(&h, 600, 1000); // queue at 60% of bound
        }
        assert_eq!(ctl.state(), OverloadState::Degrading);
        assert!(ctl.degrade_steps() >= 1);
    }

    #[test]
    fn shed_gate_needs_shedding_state_and_deep_queue() {
        let ctl = OverloadCtl::new(quick_cfg());
        assert!(!ctl.should_shed(1000, 1000), "Normal never sheds");
        let h = LogLinHist::new();
        while ctl.state() != OverloadState::Shedding {
            feed(&h, 5000, 50);
            ctl.tick(&h, 900, 1000);
        }
        assert!(ctl.should_shed(600, 1000));
        assert!(!ctl.should_shed(100, 1000), "shallow queue serves even while Shedding");
    }
}
