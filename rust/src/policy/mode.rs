//! Detection modes and the lock-free per-site policy cell.
//!
//! A **site** is one protected operator instance: an MLP layer (GEMM
//! ABFT, Eq 3b) or an embedding table (EB ABFT, Eq 5). Each site carries
//! a [`PolicyCell`] that the hot path reads with **one relaxed atomic
//! load** per invocation; the background controller is the only writer.
//!
//! # Mode lattice (detection intensity, descending)
//!
//! ```text
//!   Full  >  Sampled(2)  >  Sampled(4)  >  …  >  BoundOnly  >  Off
//! ```
//!
//! * [`DetectionMode::Full`] — every row / bag verified. Bit-identical to
//!   the pre-policy behavior and the default (a zeroed cell decodes to
//!   `Full`, so an un-attached model is always fully protected).
//! * [`DetectionMode::Sampled`]`(n)` — 1-in-`n` units verified, phase
//!   carried by a per-site counter so coverage rotates across rows/bags
//!   rather than pinning to the same indices. `Sampled(1)` is exactly
//!   `Full` (property-tested in `rust/tests/prop.rs`).
//! * [`DetectionMode::BoundOnly`] — the weakest still-on check: GEMM
//!   collapses the per-row congruences into one batch-aggregate residue
//!   (a single mod test; opposing-sign multi-fault deltas can cancel),
//!   EB keeps the Eq-5 check but with the bound relaxed by the policy's
//!   `bound_relax` factor (only gross corruption flags; low-significance
//!   faults are left to the scrubber's exact integer compare).
//! * [`DetectionMode::Off`] — no verification (the unchecked kernels).
//!
//! **Invariant**: on clean data every mode produces **bit-identical
//! outputs** — verification only reads the accumulator / bag result, it
//! never changes them — so mode changes can never move a served score.
//! Modes trade *coverage* (detection probability and latency) against
//! *overhead*, nothing else.

use std::sync::atomic::{AtomicU32, Ordering};

/// Detection intensity of one protected site. See the module docs for
/// the lattice and semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectionMode {
    /// Verify every unit (default; bit-identical to pre-policy behavior).
    Full,
    /// Verify 1-in-`n` units (`n >= 1`; `Sampled(1)` ≡ `Full`).
    Sampled(u32),
    /// Single aggregate / relaxed-bound check per invocation.
    BoundOnly,
    /// No verification.
    Off,
}

/// Per-mode index used by the served-units counters (array of 4).
pub const MODE_SLOTS: usize = 4;

const TAG_FULL: u32 = 0;
const TAG_SAMPLED: u32 = 1;
const TAG_BOUND: u32 = 2;
const TAG_OFF: u32 = 3;
/// Sample rates are stored in the low 24 bits of the cell.
const RATE_MASK: u32 = (1 << 24) - 1;

impl DetectionMode {
    /// Encode into the cell's u32. `Full` encodes to 0 so a zeroed cell
    /// is the fully-protected default.
    fn encode(self) -> u32 {
        match self {
            DetectionMode::Full => 0,
            DetectionMode::Sampled(n) => (TAG_SAMPLED << 24) | (n.max(1) & RATE_MASK),
            DetectionMode::BoundOnly => TAG_BOUND << 24,
            DetectionMode::Off => TAG_OFF << 24,
        }
    }

    fn decode(v: u32) -> Self {
        match v >> 24 {
            TAG_FULL => DetectionMode::Full,
            TAG_SAMPLED => DetectionMode::Sampled((v & RATE_MASK).max(1)),
            TAG_BOUND => DetectionMode::BoundOnly,
            _ => DetectionMode::Off,
        }
    }

    /// Slot in the per-mode served-units counters.
    pub fn slot(self) -> usize {
        match self {
            DetectionMode::Full => 0,
            DetectionMode::Sampled(_) => 1,
            DetectionMode::BoundOnly => 2,
            DetectionMode::Off => 3,
        }
    }

    /// Human/JSON name of the mode (rate elided).
    pub fn as_str(self) -> &'static str {
        match self {
            DetectionMode::Full => "full",
            DetectionMode::Sampled(_) => "sampled",
            DetectionMode::BoundOnly => "bound_only",
            DetectionMode::Off => "off",
        }
    }

    /// Estimated detection overhead of this mode relative to `Full`
    /// (the controller's budget math): `Full` = 1, `Sampled(n)` = 1/n,
    /// `BoundOnly` = the documented aggregate-check coefficient, `Off` =
    /// 0. Multiply by the site class's calibrated full-mode overhead
    /// fraction to estimate the site's current overhead.
    pub fn relative_cost(self) -> f64 {
        match self {
            DetectionMode::Full => 1.0,
            DetectionMode::Sampled(n) => 1.0 / n.max(1) as f64,
            // One fused residue/relaxed-bound pass: reads every unit but
            // drops the per-unit reduction + branch work.
            DetectionMode::BoundOnly => 0.5,
            DetectionMode::Off => 0.0,
        }
    }
}

/// Lock-free per-site mode cell: one relaxed load on the hot path, one
/// relaxed store from the controller. Relaxed is sufficient — the mode
/// only gates *whether* a check runs; it orders nothing.
#[derive(Debug, Default)]
pub struct PolicyCell(AtomicU32);

impl PolicyCell {
    pub fn new(mode: DetectionMode) -> Self {
        Self(AtomicU32::new(mode.encode()))
    }

    #[inline]
    pub fn load(&self) -> DetectionMode {
        DetectionMode::decode(self.0.load(Ordering::Relaxed))
    }

    pub fn store(&self, mode: DetectionMode) {
        self.0.store(mode.encode(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_roundtrip() {
        for mode in [
            DetectionMode::Full,
            DetectionMode::Sampled(1),
            DetectionMode::Sampled(2),
            DetectionMode::Sampled(1000),
            DetectionMode::BoundOnly,
            DetectionMode::Off,
        ] {
            assert_eq!(DetectionMode::decode(mode.encode()), mode);
        }
    }

    #[test]
    fn zeroed_cell_is_full() {
        let cell = PolicyCell::default();
        assert_eq!(cell.load(), DetectionMode::Full);
    }

    #[test]
    fn cell_store_load() {
        let cell = PolicyCell::new(DetectionMode::Full);
        cell.store(DetectionMode::Sampled(8));
        assert_eq!(cell.load(), DetectionMode::Sampled(8));
        cell.store(DetectionMode::BoundOnly);
        assert_eq!(cell.load(), DetectionMode::BoundOnly);
    }

    #[test]
    fn relative_costs_are_monotone_down_the_lattice() {
        let full = DetectionMode::Full.relative_cost();
        let s4 = DetectionMode::Sampled(4).relative_cost();
        let off = DetectionMode::Off.relative_cost();
        assert!(full > s4 && s4 > off);
        assert_eq!(DetectionMode::Sampled(1).relative_cost(), full);
    }
}
