//! The escalation controller: closes the loop from telemetry to
//! detection intensity.
//!
//! # State machine (per site, evaluated once per tick)
//!
//! ```text
//!            any flag in the tick's window delta
//!   (any mode) ─────────────────────────────────► Full  [cooldown := C]
//!                                                   │ + neighbors → Full
//!        quiet tick: cooldown -= 1 … then           │
//!        after P consecutive quiet ticks            ▼
//!   Full → Sampled(2) → Sampled(4) → … → Sampled(n*) [→ BoundOnly [→ Off]]
//!                 (one lattice step per P quiet ticks — never skips)
//! ```
//!
//! * **Escalation is instant and contagious**: one flag snaps the site —
//!   and its neighbors (adjacent MLP layers; co-sharded tables) — to
//!   `Full` in the same tick, because real memory faults cluster
//!   spatially (Ma et al., PAPERS.md) and a site that just flagged says
//!   nothing about whether its neighbor's corruption sits below a
//!   sampled check's coverage.
//! * **Decay is slow and stepwise** (hysteresis): a site must be quiet
//!   for `cooldown_ticks`, then each further `decay_patience` quiet
//!   ticks buys exactly one lattice step down, stopping at the budget
//!   target `n*`. A single flag resets the whole descent, so modes
//!   cannot flap.
//! * **Budget math**: the target sample rate is the smallest `n` with
//!   `full_overhead / n ≤ overhead_budget`, i.e.
//!   `n* = ceil(full_overhead / overhead_budget)` (clamped to
//!   `max_sample`), per site class — the paper's <20% GEMM / <26% EB
//!   ceilings become a steady-state dial instead of a compile-time
//!   property.
//! * **Per-site fault-rate priors** ([`SitePriors`]): when a deployment
//!   knows its fault history (Ma et al.'s hardware-error study shows
//!   DLRM fault rates are highly non-uniform across layers and tables),
//!   each site's decay target is seeded from its prior instead of the
//!   one class-wide budget: the class budget is redistributed in
//!   proportion to the site's normalized prior, so fault-prone sites
//!   settle at a denser sampling rate and historically-quiet sites pay
//!   less — at the same class-wide overhead total. `n*_i =
//!   ceil(full_overhead / (budget · p_i / p̄))`, clamped to
//!   `[1, max_sample]`.
//! * **Persistent flags boost scrubbing**: a site flagging for
//!   `persist_ticks` consecutive ticks means reactive detection keeps
//!   hitting the same bad memory — the controller multiplies the
//!   `scrub_budget` knob (rows per [`Engine::scrub_tick`]) by
//!   `scrub_boost`, and restores the base rate once every site has been
//!   quiet for a full window.
//!
//! [`Engine::scrub_tick`]: crate::coordinator::Engine::scrub_tick

use crate::obs::MeasuredUnitCosts;
use crate::policy::mode::DetectionMode;
use crate::policy::overload::OverloadFloor;
use crate::policy::telemetry::{PolicySites, SiteKind, SiteSnapshot};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Control-plane configuration. `Default` is conservative: 5% overhead
/// budget, decay only as far as sampling (no `BoundOnly`/`Off`), and a
/// manual tick (tests and the campaign drive [`PolicyController::step`]
/// directly; the server passes a real interval).
#[derive(Clone, Debug)]
pub struct PolicyConfig {
    /// Target per-site detection overhead fraction in quiet steady state.
    pub overhead_budget: f64,
    /// Calibrated overhead fraction of `Full`-mode detection per site
    /// class (see [`UnitCosts`]; defaults follow the paper's measured
    /// ranges). With a profiler attached these are only the cold-start
    /// prior — live per-site measurements override them once warm.
    pub unit_costs: UnitCosts,
    /// Pin the budget math to the static `unit_costs` prior even when
    /// live measured overheads are available (reproducible runs: the
    /// controller's decisions stop depending on machine timing).
    pub pin_unit_costs: bool,
    /// Ticks a site must stay at `Full` after a flag before decay may
    /// begin.
    pub cooldown_ticks: u32,
    /// Consecutive quiet ticks per single decay step (hysteresis).
    pub decay_patience: u32,
    /// Consecutive flagged ticks that trigger a scrub-budget boost.
    pub persist_ticks: u32,
    /// Multiplier applied to `scrub_budget_base` while faults persist.
    pub scrub_boost: usize,
    /// Baseline rows per `Engine::scrub_tick`.
    pub scrub_budget_base: usize,
    /// Sliding-window length in ticks (window stats in the snapshot).
    pub window_ticks: usize,
    /// Hard cap on the sampled rate (coverage floor: at least one unit
    /// in `max_sample` is always verified while sampling).
    pub max_sample: u32,
    /// Allow decay past `Sampled(n*)` into `BoundOnly`.
    pub allow_bound_only: bool,
    /// Allow decay past `BoundOnly` into `Off` (requires
    /// `allow_bound_only`).
    pub allow_off: bool,
    /// Eq-5 bound relaxation under `BoundOnly` on EB sites.
    pub bound_relax: f64,
    /// Per-site fault-rate priors seeding each site's decay target (see
    /// module docs). Empty (the default) means every site of a class
    /// shares the class-wide budget unchanged.
    pub site_priors: SitePriors,
    /// Controller tick interval; `Duration::ZERO` = manual ticking via
    /// [`crate::coordinator::Engine::policy_tick`].
    pub tick: Duration,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            overhead_budget: 0.05,
            unit_costs: UnitCosts::default(),
            pin_unit_costs: false,
            cooldown_ticks: 4,
            decay_patience: 2,
            persist_ticks: 3,
            scrub_boost: 4,
            scrub_budget_base: 256,
            window_ticks: 8,
            max_sample: 64,
            allow_bound_only: false,
            allow_off: false,
            bound_relax: 1e3,
            site_priors: SitePriors::default(),
            tick: Duration::ZERO,
        }
    }
}

/// Per-site relative fault-rate priors (e.g. from a hardware-error
/// history à la Ma et al.): `gemm[i]` / `eb[t]` are non-negative rates
/// in any consistent unit — only the ratio to the class mean matters.
/// An empty class vector disables priors for that class (weight 1.0
/// everywhere); a missing or zero entry means "no faults ever observed
/// here" and decays the site to the least checking the lattice allows
/// (`Sampled(max_sample)`, still a coverage floor — never `Off` without
/// its own opt-in).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SitePriors {
    pub gemm: Vec<f64>,
    pub eb: Vec<f64>,
}

impl SitePriors {
    /// The budget weight of site `idx` within its class:
    /// `p_i / mean(p)`, or 1.0 when the class has no priors (or a
    /// degenerate all-zero vector).
    pub fn weight(&self, kind: SiteKind, idx: usize) -> f64 {
        let v = match kind {
            SiteKind::Gemm => &self.gemm,
            SiteKind::Eb => &self.eb,
        };
        if v.is_empty() {
            return 1.0;
        }
        let mean = v.iter().map(|x| x.max(0.0)).sum::<f64>() / v.len() as f64;
        if mean <= 0.0 {
            return 1.0;
        }
        v.get(idx).copied().unwrap_or(0.0).max(0.0) / mean
    }
}

/// Calibrated full-mode detection overhead fractions per site class —
/// the unit costs the budget math runs on. Defaults sit mid-range of the
/// paper's measurements (§IV/§V: up to 20% GEMM, 4–26% EB depending on
/// shape); operators calibrate them for a deployment from the
/// `perf_policy` bench's Full-vs-Off mode rows and pass the measured
/// ratios in their [`PolicyConfig`].
#[derive(Clone, Copy, Debug)]
pub struct UnitCosts {
    /// verify-cost / gemm-cost for a `Full` protected GEMM.
    pub gemm_full_overhead: f64,
    /// checked-bag cost / plain-bag cost − 1 for a `Full` protected EB.
    pub eb_full_overhead: f64,
}

impl Default for UnitCosts {
    fn default() -> Self {
        Self {
            gemm_full_overhead: 0.12,
            eb_full_overhead: 0.20,
        }
    }
}

impl UnitCosts {
    fn class_overhead(&self, kind: SiteKind) -> f64 {
        match kind {
            SiteKind::Gemm => self.gemm_full_overhead,
            SiteKind::Eb => self.eb_full_overhead,
        }
    }
}

/// What one controller tick did (folded into the serving metrics).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepReport {
    /// Sites snapped to `Full` this tick (site itself + neighbors).
    pub escalations: usize,
    /// Single lattice steps down taken this tick.
    pub decays: usize,
    /// Scrub-budget boosts applied this tick.
    pub scrub_boosts: usize,
}

/// Per-site controller state (controller-private; the hot path never
/// sees this).
#[derive(Debug, Default)]
struct SiteCtl {
    prev: SiteSnapshot,
    window: VecDeque<SiteSnapshot>,
    cooldown: u32,
    quiet_streak: u32,
    flagged_streak: u32,
}

/// The escalation controller. Owns the per-site window state; shares the
/// [`PolicySites`] cells/counters with the hot path.
pub struct PolicyController {
    sites: Arc<PolicySites>,
    /// Flat-index neighbor lists (gemm sites first, then eb) — escalation
    /// fan-out targets.
    neighbors: Vec<Vec<usize>>,
    cfg: PolicyConfig,
    ctl: Vec<SiteCtl>,
    scrub_boosted: bool,
    ticks: u64,
    /// Live measured per-site overheads from the span profiler; `None`
    /// (or `pin_unit_costs`) keeps the static `unit_costs` prior.
    measured: Option<Arc<MeasuredUnitCosts>>,
}

impl PolicyController {
    pub fn new(sites: Arc<PolicySites>, neighbors: Vec<Vec<usize>>, cfg: PolicyConfig) -> Self {
        assert_eq!(neighbors.len(), sites.len(), "one neighbor list per site");
        let n = sites.len();
        Self {
            sites,
            neighbors,
            cfg,
            ctl: (0..n).map(|_| SiteCtl::default()).collect(),
            scrub_boosted: false,
            ticks: 0,
            measured: None,
        }
    }

    /// Attach the profiler's measured-cost accumulators: once a site is
    /// warm, its budget math (`n*`, `overhead_est`) runs on the live
    /// measured full-detection overhead instead of the static prior,
    /// unless `cfg.pin_unit_costs` pins the prior.
    pub fn attach_measured(&mut self, measured: Arc<MeasuredUnitCosts>) {
        self.measured = Some(measured);
    }

    /// The live measured full-detection overhead of one flat site, when
    /// the profiler has warmed it (reported in the policy block even
    /// when `pin_unit_costs` keeps it out of the budget math, so drift
    /// between prior and reality stays visible).
    pub fn measured_overhead(&self, flat: usize) -> Option<f64> {
        self.measured.as_ref()?.site_overhead(flat)
    }

    /// Full-detection overhead the budget math runs on for one flat
    /// site: the measured value when available and not pinned, else the
    /// calibrated class prior.
    fn site_full_overhead(&self, flat: usize) -> f64 {
        if !self.cfg.pin_unit_costs {
            if let Some(m) = self.measured_overhead(flat) {
                return m;
            }
        }
        self.cfg.unit_costs.class_overhead(self.sites.kind(flat))
    }

    pub fn config(&self) -> &PolicyConfig {
        &self.cfg
    }

    /// Ticks executed so far (escalation-latency reporting).
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Budget-target sample rate for a site class (prior weight 1.0):
    /// `n* = ceil(full_overhead / budget)`, clamped to `[1, max_sample]`.
    pub fn target_rate(&self, kind: SiteKind) -> u32 {
        target_rate_weighted(&self.cfg, kind, 1.0)
    }

    /// Budget-target sample rate of one flat site, with its
    /// [`SitePriors`] weight folded into the budget share:
    /// `n*_i = ceil(full_overhead / (budget · p_i / p̄))` — where
    /// `full_overhead` is the live measured value once the profiler has
    /// warmed the site (unless pinned), else the class prior.
    pub fn target_rate_site(&self, flat: usize) -> u32 {
        let kind = self.sites.kind(flat);
        let idx = if flat < self.sites.gemm.len() {
            flat
        } else {
            flat - self.sites.gemm.len()
        };
        target_rate_for(
            &self.cfg,
            self.site_full_overhead(flat),
            self.cfg.site_priors.weight(kind, idx),
        )
    }

    /// The mode decay lands on for a site class once fully quiet (prior
    /// weight 1.0; see [`PolicyController::target_mode_site`]).
    pub fn target_mode(&self, kind: SiteKind) -> DetectionMode {
        target_mode_for(&self.cfg, self.target_rate(kind))
    }

    /// The mode one flat site decays to once fully quiet, priors
    /// included.
    pub fn target_mode_site(&self, flat: usize) -> DetectionMode {
        target_mode_for(&self.cfg, self.target_rate_site(flat))
    }

    /// Run one control tick: snapshot every site, difference into window
    /// deltas, escalate / cool down / decay, and retune the scrub
    /// budget. Deterministic given the telemetry stream — tests and the
    /// adaptive campaign call this directly.
    pub fn step(&mut self) -> StepReport {
        self.ticks += 1;
        let mut report = StepReport::default();
        let n = self.sites.len();
        let mut flagged = vec![false; n];

        // Phase 1: collect this tick's deltas.
        for i in 0..n {
            let snap = self.sites.site(i).telem.snapshot();
            let delta = snap.delta(&self.ctl[i].prev);
            self.ctl[i].prev = snap;
            self.ctl[i].window.push_back(delta);
            while self.ctl[i].window.len() > self.cfg.window_ticks.max(1) {
                self.ctl[i].window.pop_front();
            }
            flagged[i] = delta.flags > 0;
        }

        // Phase 2: escalation fan-out. A flag snaps the site and its
        // neighbors to Full; every target gets the full cooldown.
        let mut escalate = vec![false; n];
        for i in 0..n {
            if flagged[i] {
                escalate[i] = true;
                for &j in &self.neighbors[i] {
                    escalate[j] = true;
                }
            }
        }

        // Phase 3: apply transitions. (Modes are read/written through the
        // shared `sites` Arc; per-site controller state through `ctl` —
        // field-disjoint borrows, no `&self` method calls in the loop.)
        for i in 0..n {
            let target_n = self.target_rate_site(i);
            let mode = self.sites.site(i).cell.load();
            let next = next_down(&self.cfg, mode, target_n);
            let ctl = &mut self.ctl[i];
            if escalate[i] {
                ctl.cooldown = self.cfg.cooldown_ticks;
                ctl.quiet_streak = 0;
                ctl.flagged_streak = if flagged[i] { ctl.flagged_streak + 1 } else { 0 };
                if mode != DetectionMode::Full {
                    self.sites.site(i).cell.store(DetectionMode::Full);
                    report.escalations += 1;
                }
                continue;
            }
            ctl.flagged_streak = 0;
            if ctl.cooldown > 0 {
                ctl.cooldown -= 1;
                ctl.quiet_streak = 0;
                continue;
            }
            ctl.quiet_streak += 1;
            if ctl.quiet_streak >= self.cfg.decay_patience.max(1) {
                if let Some(next) = next {
                    self.sites.site(i).cell.store(next);
                    report.decays += 1;
                }
                ctl.quiet_streak = 0;
            }
        }

        // Phase 4: scrub pacing. Persistent flags anywhere → boost; a
        // full window of silence everywhere → back to base.
        let persist = self
            .ctl
            .iter()
            .any(|c| c.flagged_streak >= self.cfg.persist_ticks.max(1));
        if persist && !self.scrub_boosted {
            self.sites.scrub_budget.store(
                self.cfg.scrub_budget_base * self.cfg.scrub_boost.max(1),
                Ordering::Relaxed,
            );
            self.scrub_boosted = true;
            report.scrub_boosts += 1;
            self.sites.scrub_boosts.fetch_add(1, Ordering::Relaxed);
        } else if self.scrub_boosted {
            let all_quiet = self
                .ctl
                .iter()
                .all(|c| c.window.iter().all(|d| d.flags == 0));
            if all_quiet {
                self.sites
                    .scrub_budget
                    .store(self.cfg.scrub_budget_base, Ordering::Relaxed);
                self.scrub_boosted = false;
            }
        }

        if report.escalations > 0 {
            self.sites
                .escalations
                .fetch_add(report.escalations as u64, Ordering::Relaxed);
        }
        if report.decays > 0 {
            self.sites
                .decays
                .fetch_add(report.decays as u64, Ordering::Relaxed);
        }
        report
    }

    /// Apply the serve-side overload floor (PR 10) to every site *not*
    /// held by an escalation cooldown. The floor walks the same lattice
    /// direction as quiet decay, minus the patience: `Budgeted` presses
    /// sites sampling denser than their budget target straight to
    /// `Sampled(n*)`, `BoundOnly` presses anything stronger down to the
    /// single aggregate check — the overload dial is an explicit
    /// operator opt-in (`--slo-p99-ms`), so it may go below what
    /// `allow_bound_only` lets quiet decay reach. Cooldown sites are
    /// exempt: an injected fault still snaps its site to `Full` within
    /// one [`PolicyController::step`] even while the front end is
    /// degraded. Lifting the floor raises only modes the policy itself
    /// could never have chosen (`BoundOnly` without `allow_bound_only`,
    /// `Off` without `allow_off`) back to the budgeted target;
    /// policy-legal modes are left to the normal escalate/decay walk.
    /// Returns the number of sites changed.
    pub fn apply_overload_floor(&mut self, floor: OverloadFloor) -> usize {
        let mut changed = 0;
        for i in 0..self.sites.len() {
            if self.ctl[i].cooldown > 0 {
                continue;
            }
            let mode = self.sites.site(i).cell.load();
            let policy_legal = match mode {
                DetectionMode::BoundOnly => self.cfg.allow_bound_only,
                DetectionMode::Off => self.cfg.allow_off,
                _ => true,
            };
            let n = self.target_rate_site(i);
            let budgeted = if n <= 1 {
                DetectionMode::Full
            } else {
                DetectionMode::Sampled(n)
            };
            let target = match floor {
                OverloadFloor::None => {
                    if policy_legal {
                        continue;
                    }
                    budgeted
                }
                OverloadFloor::Budgeted => match mode {
                    DetectionMode::Full => budgeted,
                    DetectionMode::Sampled(cur) if cur < n => budgeted,
                    DetectionMode::BoundOnly | DetectionMode::Off if !policy_legal => budgeted,
                    _ => continue,
                },
                OverloadFloor::BoundOnly => match mode {
                    DetectionMode::Full | DetectionMode::Sampled(_) => DetectionMode::BoundOnly,
                    _ => continue,
                },
            };
            if target != mode {
                self.sites.site(i).cell.store(target);
                changed += 1;
            }
        }
        changed
    }

    /// Window stats of one flat site (summed deltas), for the metrics
    /// snapshot.
    pub fn window_stats(&self, flat: usize) -> SiteSnapshot {
        let mut acc = SiteSnapshot::default();
        for d in &self.ctl[flat].window {
            acc.units += d.units;
            acc.verified += d.verified;
            acc.flags += d.flags;
        }
        acc
    }

    /// Estimated current detection-overhead fraction of one site: the
    /// mode's relative cost × the site's full-mode overhead (measured
    /// when warm and not pinned, else the calibrated class prior).
    pub fn overhead_estimate(&self, flat: usize) -> f64 {
        let mode = self.sites.site(flat).cell.load();
        mode.relative_cost() * self.site_full_overhead(flat)
    }

    /// Serialize the controller's warm-start state — per-site mode,
    /// streaks and window deltas, plus the tick counter — as a
    /// [`PolicyState`]. The serve CLI persists it to `--policy-state` so
    /// a redeploy does not re-learn which sites are quiet.
    pub fn snapshot(&self) -> PolicyState {
        PolicyState {
            gemm_sites: self.sites.gemm.len(),
            eb_sites: self.sites.eb.len(),
            ticks: self.ticks,
            sites: self
                .ctl
                .iter()
                .enumerate()
                .map(|(i, ctl)| SiteState {
                    mode: self.sites.site(i).cell.load(),
                    cooldown: ctl.cooldown,
                    quiet_streak: ctl.quiet_streak,
                    flagged_streak: ctl.flagged_streak,
                    window: ctl.window.iter().copied().collect(),
                })
                .collect(),
        }
    }

    /// Restore a [`PolicyController::snapshot`]: site modes, streaks and
    /// windows resume where the previous process left them. The telemetry
    /// delta baseline is re-anchored at the **live** counters (they
    /// restart with the process), so the first tick after restore sees
    /// only new activity rather than a bogus giant delta. Rejected — with
    /// the controller untouched — when the state's site shape does not
    /// match this model.
    pub fn restore(&mut self, state: &PolicyState) -> Result<(), String> {
        if state.gemm_sites != self.sites.gemm.len() || state.eb_sites != self.sites.eb.len() {
            return Err(format!(
                "policy-state shape {}+{} sites does not match model {}+{}",
                state.gemm_sites,
                state.eb_sites,
                self.sites.gemm.len(),
                self.sites.eb.len()
            ));
        }
        self.ticks = state.ticks;
        for (i, s) in state.sites.iter().enumerate() {
            self.sites.site(i).cell.store(s.mode);
            let ctl = &mut self.ctl[i];
            ctl.cooldown = s.cooldown;
            ctl.quiet_streak = s.quiet_streak;
            ctl.flagged_streak = s.flagged_streak;
            ctl.window = s.window.iter().copied().collect();
            ctl.prev = self.sites.site(i).telem.snapshot();
        }
        Ok(())
    }
}

/// Versioned, human-readable serialization of the controller's
/// warm-start state (see [`PolicyController::snapshot`]). The wire form
/// is line-oriented text with a `dlrm-abft-policy-state v1` header —
/// trivially diffable in a deploy artifact, no external codec:
///
/// ```text
/// dlrm-abft-policy-state v1
/// sites <gemm> <eb>
/// ticks <n>
/// site <flat> <mode> <cooldown> <quiet> <flagged> <u/v/f,...|->
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicyState {
    pub gemm_sites: usize,
    pub eb_sites: usize,
    pub ticks: u64,
    /// Flat site order: gemm sites first, then eb — the same order the
    /// controller's `ctl` vector uses.
    pub sites: Vec<SiteState>,
}

/// One site's persisted controller state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SiteState {
    pub mode: DetectionMode,
    pub cooldown: u32,
    pub quiet_streak: u32,
    pub flagged_streak: u32,
    /// Sliding-window per-tick deltas, oldest first.
    pub window: Vec<SiteSnapshot>,
}

impl PolicyState {
    pub const MAGIC: &'static str = "dlrm-abft-policy-state";
    pub const VERSION: u32 = 1;

    pub fn encode(&self) -> String {
        use std::fmt::Write;
        let mut out = format!("{} v{}\n", Self::MAGIC, Self::VERSION);
        let _ = writeln!(out, "sites {} {}", self.gemm_sites, self.eb_sites);
        let _ = writeln!(out, "ticks {}", self.ticks);
        for (i, s) in self.sites.iter().enumerate() {
            let window = if s.window.is_empty() {
                "-".to_string()
            } else {
                s.window
                    .iter()
                    .map(|d| format!("{}/{}/{}", d.units, d.verified, d.flags))
                    .collect::<Vec<_>>()
                    .join(",")
            };
            let _ = writeln!(
                out,
                "site {} {} {} {} {} {}",
                i,
                mode_state_str(s.mode),
                s.cooldown,
                s.quiet_streak,
                s.flagged_streak,
                window
            );
        }
        out
    }

    pub fn parse(text: &str) -> Result<Self, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().ok_or("empty policy state")?;
        let expect = format!("{} v{}", Self::MAGIC, Self::VERSION);
        if header.trim() != expect {
            return Err(format!("bad policy-state header {header:?} (want {expect:?})"));
        }
        let (mut shape, mut ticks, mut sites) = (None, 0u64, Vec::new());
        for line in lines {
            let mut f = line.split_whitespace();
            match f.next() {
                Some("sites") => {
                    shape = Some((field(f.next())?, field(f.next())?));
                }
                Some("ticks") => ticks = field(f.next())?,
                Some("site") => {
                    let idx: usize = field(f.next())?;
                    if idx != sites.len() {
                        return Err(format!("site line {idx} out of order"));
                    }
                    sites.push(SiteState {
                        mode: parse_mode(f.next().ok_or("missing mode")?)?,
                        cooldown: field(f.next())?,
                        quiet_streak: field(f.next())?,
                        flagged_streak: field(f.next())?,
                        window: parse_window(f.next().unwrap_or("-"))?,
                    });
                }
                Some(other) => return Err(format!("unknown policy-state record {other:?}")),
                None => {}
            }
        }
        let (gemm_sites, eb_sites) = shape.ok_or("missing sites line")?;
        if sites.len() != gemm_sites + eb_sites {
            return Err(format!(
                "{} site lines, expected {}",
                sites.len(),
                gemm_sites + eb_sites
            ));
        }
        Ok(Self { gemm_sites, eb_sites, ticks, sites })
    }
}

fn mode_state_str(mode: DetectionMode) -> String {
    match mode {
        DetectionMode::Full => "full".into(),
        DetectionMode::Sampled(n) => format!("sampled:{n}"),
        DetectionMode::BoundOnly => "bound_only".into(),
        DetectionMode::Off => "off".into(),
    }
}

fn parse_mode(s: &str) -> Result<DetectionMode, String> {
    match s {
        "full" => Ok(DetectionMode::Full),
        "bound_only" => Ok(DetectionMode::BoundOnly),
        "off" => Ok(DetectionMode::Off),
        _ => s
            .strip_prefix("sampled:")
            .and_then(|n| n.parse().ok())
            .map(DetectionMode::Sampled)
            .ok_or_else(|| format!("bad mode {s:?}")),
    }
}

fn parse_window(s: &str) -> Result<Vec<SiteSnapshot>, String> {
    if s == "-" {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|d| {
            let mut p = d.split('/');
            Ok(SiteSnapshot {
                units: field(p.next())?,
                verified: field(p.next())?,
                flags: field(p.next())?,
            })
        })
        .collect()
}

fn field<T: std::str::FromStr>(s: Option<&str>) -> Result<T, String> {
    s.ok_or("truncated policy-state line")?
        .parse()
        .map_err(|_| format!("bad policy-state field {:?}", s.unwrap_or("")))
}

/// Budget-target sample rate: smallest `n` with `full_overhead/n ≤
/// budget · weight`, i.e. `ceil(full_overhead / (budget · weight))`,
/// clamped to `[1, max_sample]`. `weight` is the site's normalized
/// fault-rate prior ([`SitePriors::weight`]; 1.0 without priors); a
/// zero weight (no faults ever recorded at the site) decays to the
/// least checking the lattice allows, `Sampled(max_sample)` — still a
/// 1-in-`max_sample` coverage floor.
fn target_rate_weighted(cfg: &PolicyConfig, kind: SiteKind, weight: f64) -> u32 {
    target_rate_for(cfg, cfg.unit_costs.class_overhead(kind), weight)
}

/// [`target_rate_weighted`] with the full-mode overhead supplied by the
/// caller — the class prior for class-level queries, the live measured
/// value for per-site queries when the profiler has warmed the site.
fn target_rate_for(cfg: &PolicyConfig, full_overhead: f64, weight: f64) -> u32 {
    if cfg.overhead_budget <= 0.0 {
        return 1;
    }
    let budget = cfg.overhead_budget * weight.max(0.0);
    if budget <= 0.0 {
        return cfg.max_sample.max(1);
    }
    let n = (full_overhead / budget).ceil() as u32;
    n.clamp(1, cfg.max_sample)
}

/// The mode a fully-quiet site settles at for a given target rate.
fn target_mode_for(cfg: &PolicyConfig, n: u32) -> DetectionMode {
    if cfg.allow_bound_only {
        if cfg.allow_off {
            DetectionMode::Off
        } else {
            DetectionMode::BoundOnly
        }
    } else if n <= 1 {
        // Budget already satisfied at Full; nothing lower is opted in.
        DetectionMode::Full
    } else {
        DetectionMode::Sampled(n)
    }
}

/// One lattice step down from `mode` toward the site's target rate, or
/// `None` when already there. Never skips a level: Full → Sampled(2) →
/// doubling → Sampled(n*) → [BoundOnly] → [Off], the latter two gated
/// on opt-in.
fn next_down(cfg: &PolicyConfig, mode: DetectionMode, target_n: u32) -> Option<DetectionMode> {
    match mode {
        DetectionMode::Full if target_n >= 2 => Some(DetectionMode::Sampled(2.min(target_n))),
        DetectionMode::Full if cfg.allow_bound_only => Some(DetectionMode::BoundOnly),
        DetectionMode::Full => None,
        DetectionMode::Sampled(n) if n < target_n => {
            Some(DetectionMode::Sampled((n * 2).min(target_n)))
        }
        DetectionMode::Sampled(_) if cfg.allow_bound_only => Some(DetectionMode::BoundOnly),
        DetectionMode::Sampled(_) => None,
        DetectionMode::BoundOnly if cfg.allow_off => Some(DetectionMode::Off),
        DetectionMode::BoundOnly => None,
        DetectionMode::Off => None,
    }
}

/// Adjacency used for escalation fan-out: MLP layers neighbor the layers
/// directly before/after them (a fault domain usually spans adjacent
/// panels of one weight blob); embedding tables neighbor the tables
/// co-located on the same shard when a placement is given (they share
/// replica memory), else the adjacent table ids.
pub fn build_neighbors(
    gemm_sites: usize,
    eb_sites: usize,
    eb_groups: Option<&[Vec<usize>]>,
) -> Vec<Vec<usize>> {
    let mut out: Vec<Vec<usize>> = Vec::with_capacity(gemm_sites + eb_sites);
    for i in 0..gemm_sites {
        let mut nb = Vec::new();
        if i > 0 {
            nb.push(i - 1);
        }
        if i + 1 < gemm_sites {
            nb.push(i + 1);
        }
        out.push(nb);
    }
    match eb_groups {
        Some(groups) => {
            // Table t's neighbors: the other tables of its group.
            let mut by_table: Vec<Vec<usize>> = vec![Vec::new(); eb_sites];
            for group in groups {
                for &t in group {
                    for &u in group {
                        if u != t && t < eb_sites && u < eb_sites {
                            by_table[t].push(gemm_sites + u);
                        }
                    }
                }
            }
            out.extend(by_table);
        }
        None => {
            for t in 0..eb_sites {
                let mut nb = Vec::new();
                if t > 0 {
                    nb.push(gemm_sites + t - 1);
                }
                if t + 1 < eb_sites {
                    nb.push(gemm_sites + t + 1);
                }
                out.push(nb);
            }
        }
    }
    out
}

/// Background controller thread: ticks at `cfg.tick` until dropped.
/// The engine holds the controller in an `Arc<Mutex<_>>` so manual
/// [`crate::coordinator::Engine::policy_tick`] calls and the thread
/// serialize on the same state.
pub struct ControllerThread {
    shutdown: Arc<AtomicBool>,
    handle: Option<thread::JoinHandle<()>>,
}

impl ControllerThread {
    pub fn spawn(controller: Arc<Mutex<PolicyController>>, tick: Duration) -> Self {
        Self::spawn_with(controller, tick, |_| {})
    }

    /// [`ControllerThread::spawn`] with a per-tick observer, called with
    /// the controller's tick counter after each background step while the
    /// lock is already released — the engine uses it to stamp the
    /// fault-event sink's `ctl_tick` so journal events correlate with
    /// controller epochs in both ticking modes.
    pub fn spawn_with(
        controller: Arc<Mutex<PolicyController>>,
        tick: Duration,
        on_tick: impl Fn(u64) + Send + 'static,
    ) -> Self {
        assert!(tick > Duration::ZERO, "spawn needs a real tick interval");
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = Arc::clone(&shutdown);
        let handle = thread::Builder::new()
            .name("policy-controller".into())
            .spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    thread::sleep(tick);
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let t = {
                        let mut c = controller.lock().unwrap();
                        c.step();
                        c.ticks()
                    };
                    on_tick(t);
                }
            })
            .expect("spawn policy controller");
        Self {
            shutdown,
            handle: Some(handle),
        }
    }
}

impl Drop for ControllerThread {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sites(gemm: usize, eb: usize) -> Arc<PolicySites> {
        Arc::new(PolicySites::new(gemm, eb, 1e3, 256))
    }

    fn controller(s: &Arc<PolicySites>, cfg: PolicyConfig) -> PolicyController {
        let nb = build_neighbors(s.gemm.len(), s.eb.len(), None);
        PolicyController::new(Arc::clone(s), nb, cfg)
    }

    fn quick_cfg() -> PolicyConfig {
        PolicyConfig {
            overhead_budget: 0.05,
            unit_costs: UnitCosts { gemm_full_overhead: 0.12, eb_full_overhead: 0.20 },
            cooldown_ticks: 2,
            decay_patience: 1,
            persist_ticks: 2,
            ..PolicyConfig::default()
        }
    }

    #[test]
    fn budget_math_targets() {
        let s = sites(1, 1);
        let c = controller(&s, quick_cfg());
        // ceil(0.12/0.05)=3, ceil(0.20/0.05)=4
        assert_eq!(c.target_rate(SiteKind::Gemm), 3);
        assert_eq!(c.target_rate(SiteKind::Eb), 4);
        assert_eq!(c.target_mode(SiteKind::Eb), DetectionMode::Sampled(4));
    }

    #[test]
    fn measured_overhead_overrides_prior_unless_pinned() {
        use crate::obs::MIN_SAMPLES;
        let s = sites(1, 1);
        let mut c = controller(&s, quick_cfg());
        let m = Arc::new(MeasuredUnitCosts::new(1, 1));
        c.attach_measured(Arc::clone(&m));
        // Cold accumulators: everything still runs on the prior.
        assert_eq!(c.measured_overhead(0), None);
        assert_eq!(c.target_rate_site(0), 3); // ceil(0.12/0.05)
        // Warm the GEMM site at a measured 0.30 overhead (2.5× prior).
        for _ in 0..MIN_SAMPLES {
            m.note_gemm(0, 1000, 300, 8, 8);
        }
        assert!((c.measured_overhead(0).unwrap() - 0.30).abs() < 1e-9);
        assert_eq!(c.target_rate_site(0), 6, "ceil(0.30/0.05) from measured");
        assert!((c.overhead_estimate(0) - 0.30).abs() < 1e-9, "Full mode estimate");
        // Pinning restores the prior for budget math but keeps the
        // measured value visible.
        let mut pinned_cfg = quick_cfg();
        pinned_cfg.pin_unit_costs = true;
        let mut cp = controller(&s, pinned_cfg);
        cp.attach_measured(m);
        assert_eq!(cp.target_rate_site(0), 3);
        assert!((cp.overhead_estimate(0) - 0.12).abs() < 1e-9);
        assert!(cp.measured_overhead(0).is_some());
    }

    #[test]
    fn site_priors_skew_per_site_targets() {
        // Two EB sites, priors 4 : 0.25 → weights p/p̄ with p̄ = 2.125:
        // site 0 gets a 1.882× budget share (denser sampling), site 1 a
        // 0.118× share (sparser), both clamped to [1, max_sample].
        let s = sites(1, 2);
        let mut cfg = quick_cfg();
        cfg.site_priors = SitePriors { gemm: vec![], eb: vec![4.0, 0.25] };
        let c = controller(&s, cfg);
        // eb flat indices are 1 and 2 (one gemm site first).
        // ceil(0.20 / (0.05 · 4/2.125)) = ceil(2.125) = 3
        assert_eq!(c.target_rate_site(1), 3);
        // ceil(0.20 / (0.05 · 0.25/2.125)) = ceil(34) = 34
        assert_eq!(c.target_rate_site(2), 34);
        assert_eq!(c.target_mode_site(1), DetectionMode::Sampled(3));
        assert_eq!(c.target_mode_site(2), DetectionMode::Sampled(34));
        // The gemm class has no priors: class-wide target unchanged.
        assert_eq!(c.target_rate_site(0), c.target_rate(SiteKind::Gemm));
    }

    #[test]
    fn zero_prior_decays_to_the_coverage_floor_not_off() {
        let s = sites(0, 2);
        let mut cfg = quick_cfg();
        cfg.max_sample = 16;
        cfg.site_priors = SitePriors { gemm: vec![], eb: vec![1.0, 0.0] };
        let mut c = controller(&s, cfg);
        assert_eq!(c.target_rate_site(1), 16, "zero prior → max_sample, never Off");
        for _ in 0..16 {
            c.step();
        }
        assert_eq!(s.eb[1].cell.load(), DetectionMode::Sampled(16));
        assert!(matches!(s.eb[0].cell.load(), DetectionMode::Sampled(_)));
    }

    #[test]
    fn priors_decay_walk_stops_at_each_sites_own_target() {
        // Same class, different priors → the decay walk parts ways at
        // each site's own n* (never skipping a lattice level).
        let s = sites(0, 2);
        let mut cfg = quick_cfg();
        cfg.site_priors = SitePriors { gemm: vec![], eb: vec![4.0, 0.25] };
        let mut c = controller(&s, cfg);
        for _ in 0..16 {
            c.step();
        }
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Sampled(3));
        assert_eq!(s.eb[1].cell.load(), DetectionMode::Sampled(34));
    }

    #[test]
    fn overload_floor_presses_skips_cooldown_sites_and_restores() {
        let s = sites(2, 1);
        let mut c = controller(&s, quick_cfg()); // gemm n*=3, eb n*=4
        // Flag gemm/0 and step: the site (and neighbors) hold Full under
        // cooldown — the floor must not touch them.
        s.gemm[0].telem.note_flags(1);
        c.step();
        assert_eq!(s.gemm[0].cell.load(), DetectionMode::Full);
        let changed = c.apply_overload_floor(OverloadFloor::Budgeted);
        // gemm/0 + neighbor gemm/1 are cooling down; only eb/0 presses.
        assert_eq!(changed, 1);
        assert_eq!(s.gemm[0].cell.load(), DetectionMode::Full);
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Sampled(4));
        // Deeper floor presses below budget (quick_cfg leaves
        // allow_bound_only off — overload is the explicit opt-in).
        for _ in 0..8 {
            c.step(); // drain cooldowns quietly
        }
        let changed = c.apply_overload_floor(OverloadFloor::BoundOnly);
        assert!(changed >= 1);
        assert_eq!(s.eb[0].cell.load(), DetectionMode::BoundOnly);
        // A fault while degraded still escalates within one tick.
        s.eb[0].telem.note_flags(1);
        let r = c.step();
        assert!(r.escalations >= 1);
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Full);
        // Lifting the floor restores modes the policy could never have
        // chosen back to the budgeted target.
        for _ in 0..8 {
            c.step();
        }
        c.apply_overload_floor(OverloadFloor::BoundOnly);
        assert_eq!(s.gemm[1].cell.load(), DetectionMode::BoundOnly);
        let changed = c.apply_overload_floor(OverloadFloor::None);
        assert!(changed >= 1);
        assert_eq!(s.gemm[1].cell.load(), DetectionMode::Sampled(3));
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Sampled(4));
    }

    /// Table-driven decay: quiet ticks walk the lattice one step per
    /// patience period, doubling the rate, capping at the target.
    #[test]
    fn quiet_decay_walks_lattice_without_skipping() {
        let s = sites(0, 1);
        let mut c = controller(&s, quick_cfg());
        let mut seen = vec![s.eb[0].cell.load()];
        for _ in 0..6 {
            c.step();
            seen.push(s.eb[0].cell.load());
        }
        use DetectionMode::*;
        assert_eq!(
            seen,
            vec![Full, Sampled(2), Sampled(4), Sampled(4), Sampled(4), Sampled(4), Sampled(4)],
            "decay must step Full→S2→S4 and hold at the target"
        );
        assert_eq!(s.decays.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn flag_escalates_site_and_neighbors_immediately() {
        let s = sites(3, 0);
        let mut c = controller(&s, quick_cfg());
        // Decay everything to the target first.
        for _ in 0..8 {
            c.step();
        }
        assert_ne!(s.gemm[1].cell.load(), DetectionMode::Full);
        // One flag on the middle site.
        s.gemm[1].telem.record(10, 5);
        s.gemm[1].telem.note_flags(1);
        let rep = c.step();
        assert_eq!(rep.escalations, 3, "site + both neighbors escalate");
        for g in &s.gemm {
            assert_eq!(g.cell.load(), DetectionMode::Full);
        }
        assert_eq!(s.escalations.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn cooldown_and_patience_gate_redecay() {
        let cfg = quick_cfg(); // cooldown 2, patience 1
        let s = sites(0, 1);
        let mut c = controller(&s, cfg);
        s.eb[0].telem.record(4, 4);
        s.eb[0].telem.note_flags(1);
        c.step(); // escalation tick (already Full → no mode change, cooldown set)
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Full);
        c.step(); // cooldown 2→1
        c.step(); // cooldown 1→0
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Full, "still cooling");
        c.step(); // first quiet tick past cooldown → one decay step
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Sampled(2));
    }

    #[test]
    fn flapping_flags_pin_the_site_at_full() {
        let s = sites(0, 1);
        let mut c = controller(&s, quick_cfg());
        for tick in 0..10 {
            if tick % 2 == 0 {
                s.eb[0].telem.record(4, 4);
                s.eb[0].telem.note_flags(1);
            }
            c.step();
            assert_eq!(
                s.eb[0].cell.load(),
                DetectionMode::Full,
                "alternating flags must never let the mode decay (tick {tick})"
            );
        }
    }

    #[test]
    fn persistent_flags_boost_scrub_budget_then_quiet_restores() {
        let cfg = quick_cfg(); // persist 2, boost 4, base 256
        let s = sites(0, 1);
        let mut c = controller(&s, cfg.clone());
        assert_eq!(s.scrub_budget.load(Ordering::Relaxed), 256);
        s.eb[0].telem.record(4, 4);
        s.eb[0].telem.note_flags(1);
        c.step();
        assert_eq!(s.scrub_budget.load(Ordering::Relaxed), 256, "one tick is not persistent");
        s.eb[0].telem.record(4, 4);
        s.eb[0].telem.note_flags(1);
        let rep = c.step();
        assert_eq!(rep.scrub_boosts, 1);
        assert_eq!(s.scrub_budget.load(Ordering::Relaxed), 256 * 4);
        // Quiet until the whole window is silent → budget restored.
        for _ in 0..cfg.window_ticks + 1 {
            c.step();
        }
        assert_eq!(s.scrub_budget.load(Ordering::Relaxed), 256);
        assert_eq!(s.scrub_boosts.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn bound_only_requires_opt_in() {
        let mut cfg = quick_cfg();
        cfg.allow_bound_only = true;
        let s = sites(0, 1);
        let mut c = controller(&s, cfg);
        for _ in 0..10 {
            c.step();
        }
        assert_eq!(s.eb[0].cell.load(), DetectionMode::BoundOnly);
        // And never Off without its own opt-in.
        for _ in 0..5 {
            c.step();
        }
        assert_eq!(s.eb[0].cell.load(), DetectionMode::BoundOnly);
    }

    #[test]
    fn shard_grouped_neighbors() {
        let nb = build_neighbors(2, 4, Some(&[vec![0, 2], vec![1, 3]]));
        assert_eq!(nb.len(), 6);
        assert_eq!(nb[2], vec![2 + 2]); // table 0 ↔ table 2
        assert_eq!(nb[3], vec![2 + 3]); // table 1 ↔ table 3
        assert_eq!(nb[0], vec![1]); // layer adjacency untouched
    }

    #[test]
    fn policy_state_roundtrips_through_text() {
        let s = sites(1, 2);
        let mut c = controller(&s, quick_cfg());
        s.eb[0].telem.record(10, 5);
        s.eb[0].telem.note_flags(1);
        for _ in 0..5 {
            c.step();
        }
        let state = c.snapshot();
        assert_eq!(PolicyState::parse(&state.encode()).unwrap(), state);
    }

    #[test]
    fn restore_resumes_modes_and_streaks_in_a_fresh_process() {
        let s = sites(0, 1);
        let mut c = controller(&s, quick_cfg());
        for _ in 0..2 {
            c.step();
        }
        assert_eq!(s.eb[0].cell.load(), DetectionMode::Sampled(4), "decayed to target");
        let state = c.snapshot();
        // A fresh process: new site table (cells default to Full) and a
        // new controller — restore must not re-learn the quiet sites.
        let s2 = sites(0, 1);
        let mut c2 = controller(&s2, quick_cfg());
        assert_eq!(s2.eb[0].cell.load(), DetectionMode::Full);
        c2.restore(&state).unwrap();
        assert_eq!(s2.eb[0].cell.load(), DetectionMode::Sampled(4));
        assert_eq!(c2.ticks(), 2);
        // The re-anchored telemetry baseline keeps the first post-restore
        // tick quiet (no bogus counter delta → no spurious escalation).
        let rep = c2.step();
        assert_eq!(rep.escalations, 0);
        assert_eq!(s2.eb[0].cell.load(), DetectionMode::Sampled(4));
    }

    #[test]
    fn restore_rejects_shape_mismatch_and_bad_text() {
        let s = sites(1, 1);
        let state = controller(&s, quick_cfg()).snapshot();
        let s2 = sites(2, 1);
        let mut c2 = controller(&s2, quick_cfg());
        assert!(c2.restore(&state).is_err(), "site-shape mismatch must be rejected");
        assert!(PolicyState::parse("bogus v9\n").is_err());
        let mut text = state.encode();
        text.push_str("wat 1\n");
        assert!(PolicyState::parse(&text).is_err());
    }

    #[test]
    fn window_stats_sum_recent_deltas() {
        let s = sites(0, 1);
        let mut c = controller(&s, quick_cfg());
        s.eb[0].telem.record(10, 5);
        c.step();
        s.eb[0].telem.record(6, 3);
        s.eb[0].telem.note_flags(1);
        c.step();
        let w = c.window_stats(0);
        assert_eq!(w.units, 16);
        assert_eq!(w.verified, 8);
        assert_eq!(w.flags, 1);
    }
}
