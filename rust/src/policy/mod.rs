//! Adaptive detection control plane: SLO-aware per-operator detection
//! policies with telemetry-driven escalation.
//!
//! The paper's detectors carry hard overhead ceilings (<20% GEMM, <26%
//! EmbeddingBag) but run at a *compile-time fixed* intensity: every GEMM
//! row and every bag is always fully verified regardless of the observed
//! fault rate. This subsystem closes the loop from runtime telemetry to
//! detection intensity, spending the overhead budget where faults
//! actually appear (V-ABFT's adaptive-threshold insight + Ma et al.'s
//! observation that DLRM fault impact is highly non-uniform across
//! layers and tables — see PAPERS.md):
//!
//! * [`mode`] — the per-site [`DetectionMode`] lattice
//!   (`Full > Sampled(n) > BoundOnly > Off`) and the lock-free
//!   [`PolicyCell`] the hot path reads with one relaxed atomic load.
//! * [`telemetry`] — per-site cumulative counters (units, verified
//!   units, flags) fed by `AbftLinear`, the fused EB path, and the shard
//!   router; the controller differences them into sliding windows.
//! * [`controller`] — the background escalation state machine: quiet
//!   sites decay stepwise toward the configured overhead budget; any
//!   flag snaps the site and its neighbors back to `Full` for a
//!   cooldown; persistent flags raise the shard/table scrub pacing via
//!   the `scrub_budget` knob. Hysteresis everywhere — modes never flap.
//! * [`overload`] — the serve-side pressure input (PR 10): an
//!   [`OverloadCtl`] watches the measured p99 against `--slo-p99-ms`
//!   and, under sustained pressure, presses non-escalated sites down
//!   the lattice (`Sampled(n*)`, then `BoundOnly`) *before* admission
//!   sheds a single request, restoring with hysteresis when pressure
//!   clears.
//!
//! Safety invariant (tested in `rust/tests/prop.rs` and the
//! `fused_epilogue`/`shard_integration` grids): **modes never change
//! served values on clean data** — verification only observes
//! accumulators and bag sums. `Full` is the default (a detached model is
//! byte-for-byte the pre-policy engine), and `Sampled(1)` is exactly
//! `Full` on every dispatch path.

pub mod controller;
pub mod mode;
pub mod overload;
pub mod telemetry;

pub use controller::{
    build_neighbors, ControllerThread, PolicyConfig, PolicyController, PolicyState, SitePriors,
    SiteState, StepReport, UnitCosts,
};
pub use mode::{DetectionMode, PolicyCell};
pub use overload::{OverloadConfig, OverloadCtl, OverloadFloor, OverloadState};
pub use telemetry::{PolicyHandle, PolicySites, Site, SiteKind, SiteSnapshot, SiteTelemetry};
