//! Lock-free log-linear histogram: geometric octaves split into
//! [`SUB_BUCKETS`] linear sub-buckets, with interpolated quantiles.
//!
//! The PR 3 latency histogram used pure log2 buckets and reported the
//! bucket *upper bound* as the quantile — at the top of the serving
//! range that makes p99 wrong by up to 2× (a 1.1 ms p99 reports as
//! 2048 µs). Four linear sub-buckets per octave bound the bucket width
//! to 25% of the value, and linear interpolation inside the winning
//! bucket removes the systematic upper-bound bias, so the same
//! fixed-size atomic array now resolves quantiles to a few percent.
//!
//! The histogram is unit-agnostic: the serving latency histogram records
//! microseconds, the span profiler's per-stage histograms record
//! nanoseconds. Recording is one `fetch_add` on the bucket plus two on
//! the sum/count — the same lock-free discipline as every other serving
//! counter.

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-buckets per power-of-two octave.
pub const SUB_BUCKETS: usize = 4;

/// Highest octave with its own sub-buckets: values up to `2^40 - 1`
/// (~18 minutes in nanoseconds, ~12 days in microseconds) resolve
/// normally; anything larger clamps into the last bucket.
const MAX_OCTAVE: usize = 39;

/// Total bucket count: exact buckets for 0..4, then `SUB_BUCKETS` per
/// octave for octaves 2..=[`MAX_OCTAVE`].
pub const NUM_BUCKETS: usize = SUB_BUCKETS * MAX_OCTAVE;

/// Bucket index for a value: exact below 4, else
/// `4·(octave−1) + (v − 2^octave) / 2^(octave−2)`.
#[inline]
fn bucket_index(v: u64) -> usize {
    if v < SUB_BUCKETS as u64 {
        return v as usize;
    }
    let octave = 63 - v.leading_zeros() as usize;
    if octave > MAX_OCTAVE {
        return NUM_BUCKETS - 1;
    }
    SUB_BUCKETS * (octave - 1) + ((v - (1u64 << octave)) >> (octave - 2)) as usize
}

/// `[lo, hi)` value bounds of one bucket (inverse of [`bucket_index`]).
fn bucket_bounds(idx: usize) -> (u64, u64) {
    if idx < SUB_BUCKETS {
        return (idx as u64, idx as u64 + 1);
    }
    let octave = idx / SUB_BUCKETS + 1;
    let width = 1u64 << (octave - 2);
    let lo = (1u64 << octave) + (idx % SUB_BUCKETS) as u64 * width;
    (lo, lo + width)
}

/// Log-linear histogram over `u64` samples. See the module docs.
pub struct LogLinHist {
    buckets: [AtomicU64; NUM_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl LogLinHist {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one sample (whatever unit the owner chose).
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples (same unit as the samples).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum() as f64 / c as f64
        }
    }

    /// Interpolated quantile: find the bucket holding the `q`-th sample
    /// and interpolate linearly by rank inside it, rather than reporting
    /// the bucket's upper bound.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            if seen + n >= target {
                let (lo, hi) = bucket_bounds(i);
                let frac = (target - seen) as f64 / n as f64;
                return lo + ((hi - lo) as f64 * frac).round() as u64;
            }
            seen += n;
        }
        // Unreachable with a consistent count, but racing recorders can
        // momentarily disagree; report the largest resolvable value.
        bucket_bounds(NUM_BUCKETS - 1).1
    }
}

impl Default for LogLinHist {
    fn default() -> Self {
        Self::new()
    }
}

/// Windowed quantiles over a cumulative [`LogLinHist`]: remembers the
/// per-bucket counts seen at the last roll and resolves quantiles over
/// only the samples recorded since. A cumulative p99 never comes back
/// down after a burst, so anything reacting to *current* pressure (the
/// overload controller) needs the delta view; one fixed array, no
/// allocation after construction.
pub struct HistWindow {
    last: [u64; NUM_BUCKETS],
}

impl HistWindow {
    pub fn new() -> Self {
        Self {
            last: [0; NUM_BUCKETS],
        }
    }

    /// Quantile over the samples recorded since the previous roll, then
    /// advance the window. `None` when no new samples arrived (racing
    /// recorders may make individual buckets transiently regress; those
    /// deltas clamp to 0).
    pub fn roll_quantile(&mut self, hist: &LogLinHist, q: f64) -> Option<u64> {
        let mut delta = [0u64; NUM_BUCKETS];
        let mut total = 0u64;
        for (i, b) in hist.buckets.iter().enumerate() {
            let now = b.load(Ordering::Relaxed);
            delta[i] = now.saturating_sub(self.last[i]);
            self.last[i] = now;
            total += delta[i];
        }
        if total == 0 {
            return None;
        }
        let target = ((total as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in delta.iter().enumerate() {
            if n == 0 {
                continue;
            }
            if seen + n >= target {
                let (lo, hi) = bucket_bounds(i);
                let frac = (target - seen) as f64 / n as f64;
                return Some(lo + ((hi - lo) as f64 * frac).round() as u64);
            }
            seen += n;
        }
        Some(bucket_bounds(NUM_BUCKETS - 1).1)
    }
}

impl Default for HistWindow {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_bounds_are_inverse() {
        for v in (0..4096u64).chain([1u64 << 20, (1 << 30) + 12345, (1 << 39) + 7]) {
            let idx = bucket_index(v);
            let (lo, hi) = bucket_bounds(idx);
            assert!(lo <= v && v < hi, "v={v} idx={idx} bounds=({lo},{hi})");
        }
        // Oversized values clamp into the last bucket.
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn bucket_width_is_at_most_a_quarter_of_the_value() {
        for idx in SUB_BUCKETS..NUM_BUCKETS {
            let (lo, hi) = bucket_bounds(idx);
            assert!(
                (hi - lo) * 4 <= lo.max(1) * 2,
                "bucket {idx} too wide: ({lo},{hi})"
            );
        }
    }

    #[test]
    fn interpolated_quantiles_beat_log2_upper_bounds() {
        let h = LogLinHist::new();
        // 1000 samples uniform in [1000, 2000): a pure log2 histogram
        // puts them all in [1024, 2048) and reports p99 = 2048. The
        // log-linear + interpolated estimate must land within 15%.
        for i in 0..1000u64 {
            h.record(1000 + i);
        }
        let p99 = h.quantile(0.99) as f64;
        assert!((p99 - 1990.0).abs() / 1990.0 < 0.15, "p99 = {p99}");
        let p50 = h.quantile(0.5) as f64;
        assert!((p50 - 1500.0).abs() / 1500.0 < 0.15, "p50 = {p50}");
    }

    #[test]
    fn empty_and_zero_are_safe() {
        let h = LogLinHist::new();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        h.record(0);
        assert_eq!(h.count(), 1);
        assert!(h.quantile(1.0) <= 1);
    }

    #[test]
    fn window_quantile_tracks_recent_samples_only() {
        let h = LogLinHist::new();
        let mut w = HistWindow::new();
        assert_eq!(w.roll_quantile(&h, 0.99), None);
        for _ in 0..100 {
            h.record(100_000);
        }
        let burst = w.roll_quantile(&h, 0.99).unwrap();
        assert!(burst >= 90_000, "burst window p99 = {burst}");
        // The cumulative p99 never recovers from the burst; the window
        // resolves the calm that followed.
        for _ in 0..100 {
            h.record(100);
        }
        assert!(h.quantile(0.99) >= 90_000);
        let calm = w.roll_quantile(&h, 0.99).unwrap();
        assert!(calm < 200, "calm window p99 = {calm}");
        assert_eq!(w.roll_quantile(&h, 0.99), None);
    }

    #[test]
    fn sum_and_mean_track_samples() {
        let h = LogLinHist::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.sum(), 60);
        assert_eq!(h.mean(), 20.0);
    }
}
