//! Fault flight recorder: freeze-on-fault black box with causal
//! request timelines and post-mortem export.
//!
//! The detectors say *that* a soft error fired; triage needs to know
//! *what the system was doing when it fired*. The span rings
//! ([`super::profiler`]) hold exactly that context — but they are
//! scrape-only and get silently overwritten within milliseconds. The
//! recorder closes the loop: when the [`crate::detect::EventSink`]
//! journals a [`FaultEvent`] at or above a configured
//! [`Severity`] floor, it calls [`FlightRecorder::freeze`], which
//! snapshots
//!
//! * the per-lane span rings (the recent-past timeline, with per-lane
//!   recorded/fill/overwritten watermarks so sampling loss is explicit),
//! * the policy plane (per-site `DetectionMode`, budgeted `n*`, measured
//!   overheads — via a closure the engine wires in),
//! * shard health (replica states, self-heal/repair counters — same),
//! * kernel dispatch state (last-stamped tier per gemm site),
//!
//! into one slot of a bounded pool of immutable `BlackBox` captures.
//!
//! # Hot-path contract
//!
//! *Armed but idle is free.* The recorder is only ever consulted from
//! the sink's `emit` fan-out, which runs **exclusively on faults** —
//! the probe path never sees it, so the disarmed/armed-idle cost at a
//! probe point stays exactly one relaxed load (the profiler's sampling
//! knob). Ring-copy buffers are preallocated at arm time, so freezing
//! reuses them; the JSON snapshot closures allocate, but only on the
//! (rare) fault path. `freeze` takes a slot via `try_lock` — if a
//! reader is serializing that capture concurrently, the freeze is
//! counted as missed rather than ever blocking the serving thread.
//!
//! # Eviction
//!
//! Captures are identified by a monotone id (1, 2, …). The pool holds
//! the newest `captures` of them; slot `(id − 1) % captures` is simply
//! overwritten, so pool exhaustion evicts the oldest capture and never
//! stalls. [`FlightRecorder::dump_new`] keeps a cursor of ids already
//! written to disk, so exporting is decoupled from freezing (the serve
//! loop / campaign calls it off the fault path).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::detect::{FaultEvent, Severity};
use crate::util::json::Json;

use super::profiler::{
    unpack_record, ObsCore, Stage, OBS_LANES, RING_PER_LANE, TIER_UNKNOWN,
};

/// Default capture-pool size.
pub const DEFAULT_CAPTURES: usize = 8;

/// A snapshot closure the engine wires in (policy plane, shard health).
pub type SnapshotFn = Box<dyn Fn() -> Json + Send + Sync>;

/// One reusable capture slot. `id == 0` means never filled.
struct CaptureSlot {
    id: u64,
    event: Option<FaultEvent>,
    /// Lifetime head per lane at freeze time.
    heads: Box<[u64]>,
    /// Lane-major ring copy (`OBS_LANES * RING_PER_LANE` words).
    rings: Box<[u64]>,
    /// Kernel tier code per gemm site at freeze time.
    tiers: Box<[u8]>,
    sample_1_in: u32,
    policy: Json,
    shards: Json,
}

impl CaptureSlot {
    fn new(gemm_sites: usize) -> Self {
        Self {
            id: 0,
            event: None,
            heads: vec![0u64; OBS_LANES].into_boxed_slice(),
            rings: vec![0u64; OBS_LANES * RING_PER_LANE].into_boxed_slice(),
            tiers: vec![TIER_UNKNOWN; gemm_sites.max(1)].into_boxed_slice(),
            sample_1_in: 0,
            policy: Json::Null,
            shards: Json::Null,
        }
    }
}

/// The recorder. Constructed and armed by the engine; triggered by the
/// sink; read by the `{"op":"flightrec"}` server op and the dump loop.
pub struct FlightRecorder {
    min_severity: Severity,
    slots: Box<[Mutex<CaptureSlot>]>,
    /// Next capture id − 1 (ids are 1-based so 0 can mean "empty").
    seq: AtomicU64,
    /// Freezes skipped because the target slot was locked by a reader.
    missed: AtomicU64,
    /// Capture ids `<= dumped_through` have been written to disk.
    dumped_through: AtomicU64,
    obs: OnceLock<Arc<ObsCore>>,
    policy_snap: OnceLock<SnapshotFn>,
    shard_snap: OnceLock<SnapshotFn>,
}

impl FlightRecorder {
    /// Preallocates every capture buffer; nothing on the freeze path
    /// grows them.
    pub fn new(captures: usize, min_severity: Severity, gemm_sites: usize) -> Self {
        let captures = captures.max(1);
        Self {
            min_severity,
            slots: (0..captures)
                .map(|_| Mutex::new(CaptureSlot::new(gemm_sites)))
                .collect(),
            seq: AtomicU64::new(0),
            missed: AtomicU64::new(0),
            dumped_through: AtomicU64::new(0),
            obs: OnceLock::new(),
            policy_snap: OnceLock::new(),
            shard_snap: OnceLock::new(),
        }
    }

    pub fn min_severity(&self) -> Severity {
        self.min_severity
    }

    pub fn pool_size(&self) -> usize {
        self.slots.len()
    }

    /// Lifetime captures taken.
    pub fn captures_taken(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Wire the profiler core whose rings get snapshotted (once).
    pub fn attach_obs(&self, core: Arc<ObsCore>) {
        let _ = self.obs.set(core);
    }

    /// Wire the policy-plane snapshot closure (once).
    pub fn attach_policy_snapshot(&self, f: SnapshotFn) {
        let _ = self.policy_snap.set(f);
    }

    /// Wire the shard-health snapshot closure (once).
    pub fn attach_shard_snapshot(&self, f: SnapshotFn) {
        let _ = self.shard_snap.set(f);
    }

    /// Severity-gated trigger, called by the sink for every journaled
    /// event. Below the floor: one comparison. At/above: take the next
    /// pool slot (evicting its previous capture) and snapshot into it.
    /// Never blocks — a slot busy under a reader just counts `missed`.
    pub fn maybe_freeze(&self, ev: &FaultEvent) {
        if ev.severity >= self.min_severity {
            self.freeze(ev);
        }
    }

    /// Unconditional freeze (the severity gate lives in
    /// [`Self::maybe_freeze`]).
    pub fn freeze(&self, ev: &FaultEvent) {
        let id = self.seq.fetch_add(1, Ordering::Relaxed) + 1;
        let idx = ((id - 1) % self.slots.len() as u64) as usize;
        let Ok(mut slot) = self.slots[idx].try_lock() else {
            self.missed.fetch_add(1, Ordering::Relaxed);
            return;
        };
        slot.id = id;
        slot.event = Some(*ev);
        if let Some(core) = self.obs.get() {
            core.snapshot_rings(&mut slot.heads, &mut slot.rings);
            slot.sample_1_in = core.sample_n_relaxed();
            for (site, t) in slot.tiers.iter_mut().enumerate() {
                *t = core.gemm_tier_code(site);
            }
        } else {
            slot.heads.fill(0);
            slot.sample_1_in = 0;
        }
        slot.policy = match self.policy_snap.get() {
            Some(f) => f(),
            None => Json::Null,
        };
        slot.shards = match self.shard_snap.get() {
            Some(f) => f(),
            None => Json::Null,
        };
    }

    /// Status block for `metrics_snapshot()`: armed config + counters.
    pub fn status_json(&self) -> Json {
        Json::obj(vec![
            ("pool", Json::Num(self.pool_size() as f64)),
            ("captures", Json::Num(self.captures_taken() as f64)),
            (
                "resident",
                Json::Num(self.resident_ids().len() as f64),
            ),
            ("missed", Json::Num(self.missed.load(Ordering::Relaxed) as f64)),
            (
                "dumped_through",
                Json::Num(self.dumped_through.load(Ordering::Relaxed) as f64),
            ),
            (
                "min_severity",
                Json::Str(self.min_severity.as_str().to_string()),
            ),
        ])
    }

    /// Ids of the captures currently resident, oldest first.
    fn resident_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self
            .slots
            .iter()
            .filter_map(|s| {
                let slot = s.lock().unwrap();
                (slot.id != 0).then_some(slot.id)
            })
            .collect();
        ids.sort_unstable();
        ids
    }

    /// The `flightrec` list payload: status + one summary row per
    /// resident capture.
    pub fn list_json(&self) -> Json {
        let mut rows = Vec::new();
        for id in self.resident_ids() {
            let idx = ((id - 1) % self.slots.len() as u64) as usize;
            let slot = self.slots[idx].lock().unwrap();
            if slot.id != id {
                continue; // evicted between listing and locking
            }
            let ev = match &slot.event {
                Some(ev) => ev,
                None => continue,
            };
            rows.push(Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("tick", Json::Num(ev.tick as f64)),
                ("flow", Json::Num(ev.flow as f64)),
                ("site", Json::Str(ev.site.label())),
                ("severity", Json::Str(ev.severity.as_str().into())),
                (
                    "dumped",
                    Json::Bool(id <= self.dumped_through.load(Ordering::Relaxed)),
                ),
            ]));
        }
        Json::obj(vec![
            ("status", self.status_json()),
            ("captures", Json::Arr(rows)),
        ])
    }

    /// One full `BlackBox` capture as self-contained JSON, or `None` if
    /// `id` was never taken or has been evicted.
    pub fn capture_json(&self, id: u64) -> Option<Json> {
        if id == 0 {
            return None;
        }
        let idx = ((id - 1) % self.slots.len() as u64) as usize;
        let slot = self.slots[idx].lock().unwrap();
        if slot.id != id {
            return None;
        }
        Some(Self::blackbox_json(&slot))
    }

    /// Build the export document from a filled slot: the triggering
    /// event, the full recent-past span timeline, the causal per-flow
    /// timeline (spans whose flow tag matches the event's flow), lane
    /// watermarks, kernel tiers, and the policy/shard snapshots.
    fn blackbox_json(slot: &CaptureSlot) -> Json {
        let ev = slot.event.as_ref().expect("filled slot has an event");
        let want_tag = super::flow::tag(ev.flow);
        let mut spans = Vec::new();
        let mut flow_timeline = Vec::new();
        let mut lanes = Vec::new();
        for li in 0..OBS_LANES {
            let head = slot.heads[li];
            if head == 0 {
                continue;
            }
            let fill = head.min(RING_PER_LANE as u64);
            lanes.push(Json::obj(vec![
                ("id", Json::Num(li as f64)),
                ("recorded", Json::Num(head as f64)),
                ("fill", Json::Num(fill as f64)),
                ("overwritten", Json::Num((head - fill) as f64)),
            ]));
            let base = li * RING_PER_LANE;
            // Oldest resident record first within the lane — per-lane
            // order is exact, so a single-threaded flow's spans come out
            // causally ordered.
            for i in 0..fill {
                let pos = ((head - fill + i) % RING_PER_LANE as u64) as usize;
                let Some((stage, site, flow_tag, dur_ns)) =
                    unpack_record(slot.rings[base + pos])
                else {
                    continue;
                };
                let mut fields = vec![
                    ("lane", Json::Num(li as f64)),
                    ("stage", Json::Str(stage.as_str().to_string())),
                    ("site", Json::Num(site as f64)),
                    ("dur_us", Json::Num(dur_ns as f64 / 1e3)),
                ];
                if flow_tag != 0 {
                    fields.push(("flow", Json::Num(flow_tag as f64)));
                }
                if matches!(
                    stage,
                    Stage::MlpLayer
                        | Stage::Verify
                        | Stage::CorrectInPlace
                        | Stage::RecomputeUnit
                ) {
                    if let Some(tier) = slot
                        .tiers
                        .get(site as usize)
                        .copied()
                        .filter(|&c| c != TIER_UNKNOWN)
                        .and_then(crate::gemm::KernelTier::from_code)
                    {
                        fields.push(("tier", Json::Str(tier.as_str().to_string())));
                    }
                }
                let row = Json::obj(fields);
                if want_tag != 0 && flow_tag == want_tag {
                    flow_timeline.push(row.clone());
                }
                spans.push(row);
            }
        }
        let tiers = slot
            .tiers
            .iter()
            .enumerate()
            .filter(|(_, &c)| c != TIER_UNKNOWN)
            .filter_map(|(site, &c)| {
                crate::gemm::KernelTier::from_code(c).map(|t| {
                    Json::obj(vec![
                        ("site", Json::Num(site as f64)),
                        ("tier", Json::Str(t.as_str().to_string())),
                    ])
                })
            })
            .collect();
        Json::obj(vec![
            ("id", Json::Num(slot.id as f64)),
            ("event", ev.to_json()),
            ("flow", Json::Num(ev.flow as f64)),
            ("flow_tag", Json::Num(want_tag as f64)),
            ("sample_1_in", Json::Num(slot.sample_1_in as f64)),
            ("flow_timeline", Json::Arr(flow_timeline)),
            ("spans", Json::Arr(spans)),
            ("lanes", Json::Arr(lanes)),
            ("kernel_tiers", Json::Arr(tiers)),
            ("policy", slot.policy.clone()),
            ("shards", slot.shards.clone()),
        ])
    }

    /// Drop every resident capture (the `clear` sub-op). Ids stay
    /// monotone; the dump cursor advances past everything cleared so a
    /// later dump doesn't resurrect them.
    pub fn clear(&self) {
        for s in self.slots.iter() {
            let mut slot = s.lock().unwrap();
            slot.id = 0;
            slot.event = None;
            slot.policy = Json::Null;
            slot.shards = Json::Null;
        }
        let taken = self.captures_taken();
        self.dumped_through.fetch_max(taken, Ordering::Relaxed);
    }

    /// Write every not-yet-dumped resident capture to
    /// `dir/blackbox_<id>.json` and advance the dump cursor. Returns the
    /// number written. Runs off the fault path (serve loop / campaign
    /// epilogue), so file I/O and allocation are fine here.
    pub fn dump_new(&self, dir: &Path) -> std::io::Result<usize> {
        let through = self.dumped_through.load(Ordering::Relaxed);
        let mut written = 0usize;
        let mut max_id = through;
        for id in self.resident_ids() {
            if id <= through {
                continue;
            }
            if let Some(doc) = self.capture_json(id) {
                std::fs::create_dir_all(dir)?;
                std::fs::write(dir.join(format!("blackbox_{id}.json")), format!("{doc}"))?;
                written += 1;
                max_id = max_id.max(id);
            }
        }
        self.dumped_through.fetch_max(max_id, Ordering::Relaxed);
        Ok(written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detect::{Detector, Resolution, SiteId, UnitRef};
    use crate::obs::ObsHandle;

    fn ev(flow: u64, severity: Severity) -> FaultEvent {
        FaultEvent {
            tick: 9,
            ctl_tick: 2,
            flow,
            site: SiteId::Gemm(0),
            unit: UnitRef::GemmRow { row: 4 },
            detector: Detector::GemmChecksum,
            severity,
            resolution: Resolution::Recovered(crate::detect::Recovery::RecomputeUnit),
        }
    }

    #[test]
    fn severity_floor_gates_freezing() {
        let rec = FlightRecorder::new(4, Severity::Significant, 2);
        rec.maybe_freeze(&ev(1, Severity::NearBound));
        assert_eq!(rec.captures_taken(), 0);
        rec.maybe_freeze(&ev(1, Severity::Significant));
        assert_eq!(rec.captures_taken(), 1);
        // Floor at NearBound records everything.
        let all = FlightRecorder::new(4, Severity::NearBound, 2);
        all.maybe_freeze(&ev(1, Severity::NearBound));
        assert_eq!(all.captures_taken(), 1);
    }

    #[test]
    fn capture_reconstructs_the_flow_timeline() {
        let h = ObsHandle::attached(2, 1, 1);
        let flow_id = crate::obs::flow::mint();
        let p = h.probe().unwrap();
        p.span_ns(Stage::Parse, 0, 1_000); // pre-flow noise
        {
            let _g = crate::obs::flow::FlowGuard::enter(flow_id);
            p.span_ns(Stage::EbGather, 0, 2_000);
            p.span_ns(Stage::MlpLayer, 1, 3_000);
            p.span_ns(Stage::Verify, 1, 400);
        }
        h.note_gemm_tier(1, crate::gemm::KernelTier::Avx2.code());

        let rec = FlightRecorder::new(2, Severity::Significant, 2);
        rec.attach_obs(Arc::clone(h.core_arc().unwrap()));
        rec.attach_policy_snapshot(Box::new(|| {
            Json::obj(vec![("sites", Json::Arr(vec![]))])
        }));
        rec.maybe_freeze(&ev(flow_id, Severity::Significant));

        let doc = rec.capture_json(1).expect("capture 1 resident");
        assert_eq!(doc.path(&["event", "site"]).and_then(Json::as_str), Some("gemm/0"));
        assert_eq!(doc.get("flow").and_then(Json::as_usize), Some(flow_id as usize));
        let tl = doc.get("flow_timeline").and_then(Json::as_arr).unwrap();
        let stages: Vec<_> = tl
            .iter()
            .map(|s| s.get("stage").and_then(Json::as_str).unwrap().to_string())
            .collect();
        assert_eq!(stages, ["eb_gather", "mlp_layer", "verify"], "causal order, flow-filtered");
        let mlp = &tl[1];
        assert_eq!(mlp.get("tier").and_then(Json::as_str), Some("avx2"));
        let spans = doc.get("spans").and_then(Json::as_arr).unwrap();
        assert_eq!(spans.len(), 4, "full timeline keeps unattributed spans");
        assert!(doc.path(&["policy", "sites"]).is_some());
        assert_eq!(doc.get("shards"), Some(&Json::Null));
    }

    #[test]
    fn pool_evicts_oldest_and_ids_stay_monotone() {
        let rec = FlightRecorder::new(2, Severity::Significant, 1);
        for f in 1..=5u64 {
            rec.freeze(&ev(f, Severity::Significant));
        }
        assert_eq!(rec.captures_taken(), 5);
        assert!(rec.capture_json(3).is_none(), "evicted");
        assert!(rec.capture_json(4).is_some());
        assert!(rec.capture_json(5).is_some());
        let list = rec.list_json();
        let rows = list.get("captures").and_then(Json::as_arr).unwrap();
        let ids: Vec<_> = rows
            .iter()
            .map(|r| r.get("id").and_then(Json::as_usize).unwrap())
            .collect();
        assert_eq!(ids, [4, 5], "oldest first, newest retained");
    }

    #[test]
    fn busy_slot_is_skipped_never_blocked_on() {
        let rec = FlightRecorder::new(1, Severity::Significant, 1);
        let guard = rec.slots[0].lock().unwrap();
        rec.freeze(&ev(1, Severity::Significant));
        drop(guard);
        assert_eq!(rec.captures_taken(), 1, "id was still consumed");
        assert_eq!(rec.missed.load(Ordering::Relaxed), 1);
        assert!(rec.capture_json(1).is_none(), "missed capture holds no data");
    }

    #[test]
    fn dump_writes_each_capture_once_and_clear_resets() {
        let dir = std::env::temp_dir().join(format!(
            "flightrec_test_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let rec = FlightRecorder::new(4, Severity::Significant, 1);
        rec.freeze(&ev(1, Severity::Significant));
        rec.freeze(&ev(2, Severity::Significant));
        assert_eq!(rec.dump_new(&dir).unwrap(), 2);
        assert!(dir.join("blackbox_1.json").is_file());
        assert!(dir.join("blackbox_2.json").is_file());
        // Nothing new → nothing written.
        assert_eq!(rec.dump_new(&dir).unwrap(), 0);
        rec.freeze(&ev(3, Severity::Significant));
        assert_eq!(rec.dump_new(&dir).unwrap(), 1);
        // The artifact is self-contained JSON with the trigger inside.
        let text = std::fs::read_to_string(dir.join("blackbox_3.json")).unwrap();
        let doc = Json::parse(&text).expect("artifact parses");
        assert_eq!(doc.path(&["event", "severity"]).and_then(Json::as_str), Some("significant"));
        rec.clear();
        assert!(rec.capture_json(3).is_none());
        assert_eq!(
            rec.list_json().get("captures").and_then(Json::as_arr).unwrap().len(),
            0
        );
        assert_eq!(rec.dump_new(&dir).unwrap(), 0, "clear advances the dump cursor");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
