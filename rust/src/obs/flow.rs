//! Request/batch flow-ID minting and thread-local propagation.
//!
//! A *flow* is one causal unit of work moving through the pipeline —
//! one scored batch inside the engine, or one client request on a
//! server connection. Flow IDs are minted from a process-global
//! counter and carried in a thread-local, so span records and fault
//! events get stamped without widening any hot-path signature: the
//! scorer enters a [`FlowGuard`] once per batch and every probe fired
//! under it inherits the ID. Spans pack the flow as a 14-bit rolling
//! tag ([`tag`]); fault events carry the full 64-bit ID, which is what
//! lets a flight-recorder capture match an event to its spans.
//!
//! Flows survive both thread handoffs in the pipeline: `Batcher::submit`
//! records the submitter's flow with the queued item and re-enters it
//! when the queue-wait span is cut, and `Scope::spawn` captures the
//! spawning thread's flow into the job so pool workers (row-block GEMM,
//! EB bag fan-out) record their batch's flow instead of 0 — per-request
//! timelines attribute across the batcher boundary and the fan-out.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Bits of flow identity carried inside a packed span record.
pub const FLOW_TAG_BITS: u32 = 14;

/// Largest span flow tag; full IDs fold onto `1..=FLOW_TAG_MAX`.
pub const FLOW_TAG_MAX: u64 = (1 << FLOW_TAG_BITS) - 1;

static NEXT_FLOW: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static CURRENT_FLOW: Cell<u64> = const { Cell::new(0) };
}

/// Mint a fresh process-unique flow ID (never 0).
#[inline]
pub fn mint() -> u64 {
    NEXT_FLOW.fetch_add(1, Ordering::Relaxed)
}

/// The flow the current thread is working under; 0 = unattributed.
#[inline]
pub fn current() -> u64 {
    CURRENT_FLOW.with(Cell::get)
}

/// Fold a full flow ID onto its span tag. 0 stays 0 (unattributed);
/// real IDs land on `1..=FLOW_TAG_MAX`, so a tag only collides with a
/// flow `FLOW_TAG_MAX` mints away — far wider than any span ring.
#[inline]
pub fn tag(id: u64) -> u64 {
    if id == 0 {
        0
    } else {
        (id - 1) % FLOW_TAG_MAX + 1
    }
}

/// Scope guard: sets the current thread's flow for its lifetime and
/// restores the previous flow on drop, so nested scopes (a request
/// guard around a batch guard) unwind correctly.
pub struct FlowGuard {
    prev: u64,
}

impl FlowGuard {
    #[inline]
    pub fn enter(id: u64) -> FlowGuard {
        let prev = CURRENT_FLOW.with(|c| c.replace(id));
        FlowGuard { prev }
    }
}

impl Drop for FlowGuard {
    fn drop(&mut self) {
        CURRENT_FLOW.with(|c| c.set(self.prev));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_is_monotonic_and_never_zero() {
        let a = mint();
        let b = mint();
        assert!(a > 0);
        assert!(b > a);
    }

    #[test]
    fn guard_sets_and_restores_nested_flows() {
        assert_eq!(current(), 0);
        {
            let _outer = FlowGuard::enter(7);
            assert_eq!(current(), 7);
            {
                let _inner = FlowGuard::enter(9);
                assert_eq!(current(), 9);
            }
            assert_eq!(current(), 7);
        }
        assert_eq!(current(), 0);
    }

    #[test]
    fn tag_folds_ids_onto_nonzero_range() {
        assert_eq!(tag(0), 0);
        assert_eq!(tag(1), 1);
        assert_eq!(tag(FLOW_TAG_MAX), FLOW_TAG_MAX);
        assert_eq!(tag(FLOW_TAG_MAX + 1), 1);
        for id in 1..200u64 {
            let t = tag(id);
            assert!((1..=FLOW_TAG_MAX).contains(&t));
        }
    }
}
