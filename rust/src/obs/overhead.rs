//! Live detection-overhead accounting.
//!
//! The paper's headline claims are overhead claims — GEMM detection
//! below 20%, EmbeddingBag below 26% — but until now the policy
//! controller budgeted `n*` from *static* `UnitCosts` constants copied
//! out of the paper. This module turns overhead into a measured,
//! per-site, live quantity:
//!
//! - [`MeasuredUnitCosts`] holds one lock-free EWMA cell per detection
//!   site. GEMM sites record `verify_ns / op_ns` (normalized to
//!   full-detection cost when only a sampled subset of rows was
//!   verified). EB sites record checked and unchecked bag-gather costs
//!   separately — under `Full` every served bag is checked, so the
//!   profiler occasionally gathers one *extra* unchecked bag purely for
//!   calibration — and the overhead is derived as `checked/unchecked − 1`.
//! - [`HealCost`] compares the scrubber's self-heal write path against a
//!   scan-only slot so budgeted scrub ticks can charge healed slots at
//!   their real cost (the carried PR 6 item).
//!
//! The `PolicyController` consumes `MeasuredUnitCosts` in place of the
//! static defaults once a site has [`MIN_SAMPLES`] observations; the
//! calibrated defaults remain the cold-start prior, and
//! `PolicyConfig::pin_unit_costs` pins them for reproducible runs.

use std::sync::atomic::{AtomicU64, Ordering};

/// EWMA smoothing factor for measured costs.
pub const MEASURE_ALPHA: f64 = 0.1;

/// Observations required before a measured value overrides the prior.
pub const MIN_SAMPLES: u64 = 4;

/// Measured overheads are clamped to this many multiples of the
/// operator cost — a wild outlier (scheduler preemption mid-span) must
/// not poison the EWMA.
pub const MAX_OVERHEAD: f64 = 10.0;

/// Default budget charge for one self-healed slot, in scan-row
/// equivalents, used until the heal path has been measured.
pub const DEFAULT_HEAL_COST_ROWS: usize = 4;

/// Upper clamp on the measured heal charge (budget units per heal).
pub const MAX_HEAL_COST_ROWS: usize = 1024;

/// Lock-free EWMA cell: value as f64 bits plus an observation count.
/// Concurrent `note` calls may drop an update; that is acceptable for
/// telemetry and keeps the hot path at two relaxed atomics.
struct Ewma {
    bits: AtomicU64,
    count: AtomicU64,
}

impl Ewma {
    fn new() -> Self {
        Self {
            bits: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn note(&self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let n = self.count.load(Ordering::Relaxed);
        let next = if n == 0 {
            x
        } else {
            let old = f64::from_bits(self.bits.load(Ordering::Relaxed));
            old + MEASURE_ALPHA * (x - old)
        };
        self.bits.store(next.to_bits(), Ordering::Relaxed);
        self.count.store(n + 1, Ordering::Relaxed);
    }

    /// Smoothed value once warm (`count >= MIN_SAMPLES`), else `None`.
    fn value(&self) -> Option<f64> {
        if self.count.load(Ordering::Relaxed) >= MIN_SAMPLES {
            Some(f64::from_bits(self.bits.load(Ordering::Relaxed)))
        } else {
            None
        }
    }

    fn samples(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }
}

/// Per-site measured full-detection overhead fractions, flat-indexed
/// like `PolicySites`: GEMM sites first, then EB table sites.
pub struct MeasuredUnitCosts {
    gemm_sites: usize,
    /// GEMM sites: EWMA of `verify/op` normalized to full detection.
    gemm_overhead: Vec<Ewma>,
    /// EB sites: EWMA of checked / unchecked bag-gather nanoseconds,
    /// kept separately so the ratio uses matched smoothing.
    eb_checked_ns: Vec<Ewma>,
    eb_unchecked_ns: Vec<Ewma>,
}

impl MeasuredUnitCosts {
    pub fn new(gemm_sites: usize, eb_sites: usize) -> Self {
        Self {
            gemm_sites,
            gemm_overhead: (0..gemm_sites).map(|_| Ewma::new()).collect(),
            eb_checked_ns: (0..eb_sites).map(|_| Ewma::new()).collect(),
            eb_unchecked_ns: (0..eb_sites).map(|_| Ewma::new()).collect(),
        }
    }

    pub fn gemm_sites(&self) -> usize {
        self.gemm_sites
    }

    pub fn total_sites(&self) -> usize {
        self.gemm_sites + self.eb_checked_ns.len()
    }

    /// Record one measured GEMM layer pass: operator time, verify time,
    /// total row count, and how many rows the verify actually covered
    /// (sampled modes verify a subset; the ratio is scaled back up to
    /// the full-detection cost the controller budgets against).
    pub fn note_gemm(&self, site: usize, op_ns: u64, verify_ns: u64, units: u64, verified: u64) {
        if site >= self.gemm_sites || op_ns == 0 || verified == 0 || units == 0 {
            return;
        }
        let full =
            (verify_ns as f64 / op_ns as f64) * (units as f64 / verified as f64);
        self.gemm_overhead[site].note(full.clamp(0.0, MAX_OVERHEAD));
    }

    /// Record one checked (fused gather+verify) bag-gather duration.
    pub fn note_eb_checked(&self, table: usize, ns: u64) {
        if let Some(cell) = self.eb_checked_ns.get(table) {
            cell.note(ns as f64);
        }
    }

    /// Record one unchecked (plain gather) bag-gather duration.
    pub fn note_eb_unchecked(&self, table: usize, ns: u64) {
        if let Some(cell) = self.eb_unchecked_ns.get(table) {
            cell.note(ns as f64);
        }
    }

    /// Measured full-detection overhead fraction for a flat site index,
    /// or `None` until the site is warm.
    pub fn site_overhead(&self, flat: usize) -> Option<f64> {
        if flat < self.gemm_sites {
            return self.gemm_overhead[flat].value();
        }
        let t = flat - self.gemm_sites;
        let checked = self.eb_checked_ns.get(t)?.value()?;
        let unchecked = self.eb_unchecked_ns.get(t)?.value()?;
        if unchecked <= 0.0 {
            return None;
        }
        Some(((checked / unchecked) - 1.0).clamp(0.0, MAX_OVERHEAD))
    }

    /// Observation count for a flat site (min of the two EB cells).
    pub fn site_samples(&self, flat: usize) -> u64 {
        if flat < self.gemm_sites {
            return self.gemm_overhead[flat].samples();
        }
        let t = flat - self.gemm_sites;
        match (self.eb_checked_ns.get(t), self.eb_unchecked_ns.get(t)) {
            (Some(c), Some(u)) => c.samples().min(u.samples()),
            _ => 0,
        }
    }
}

/// Measured cost of the scrubber's self-heal write path relative to a
/// scan-only slot, so budgeted scrub ticks charge heals at their real
/// multiple instead of pretending a heal is free.
pub struct HealCost {
    heal_ns: Ewma,
    scan_row_ns: Ewma,
}

impl HealCost {
    pub fn new() -> Self {
        Self {
            heal_ns: Ewma::new(),
            scan_row_ns: Ewma::new(),
        }
    }

    /// Record a scan segment: `rows` scanned in `ns` total.
    pub fn note_scan(&self, rows: usize, ns: u64) {
        if rows > 0 {
            self.scan_row_ns.note(ns as f64 / rows as f64);
        }
    }

    /// Record one self-heal attempt (localize + rewrite + re-verify).
    pub fn note_heal(&self, ns: u64) {
        self.heal_ns.note(ns as f64);
    }

    /// Budget charge for one heal, in scan-row equivalents. Falls back
    /// to [`DEFAULT_HEAL_COST_ROWS`] until both paths are warm.
    pub fn rows_equiv(&self) -> usize {
        match (self.heal_ns.value(), self.scan_row_ns.value()) {
            (Some(h), Some(s)) if s > 0.0 => {
                ((h / s).round() as usize).clamp(1, MAX_HEAL_COST_ROWS)
            }
            _ => DEFAULT_HEAL_COST_ROWS,
        }
    }
}

impl Default for HealCost {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_overhead_warms_after_min_samples() {
        let m = MeasuredUnitCosts::new(2, 1);
        for _ in 0..MIN_SAMPLES - 1 {
            m.note_gemm(0, 1000, 150, 8, 8);
        }
        assert_eq!(m.site_overhead(0), None, "cold site must defer to prior");
        m.note_gemm(0, 1000, 150, 8, 8);
        let ovh = m.site_overhead(0).unwrap();
        assert!((ovh - 0.15).abs() < 1e-9, "ovh = {ovh}");
        assert_eq!(m.site_overhead(1), None);
    }

    #[test]
    fn gemm_sampled_verify_is_normalized_to_full_cost() {
        let m = MeasuredUnitCosts::new(1, 0);
        // Verify covered 2 of 8 rows at 50ns against a 1000ns operator:
        // full-detection cost is 50*4/1000 = 0.20.
        for _ in 0..MIN_SAMPLES {
            m.note_gemm(0, 1000, 50, 8, 2);
        }
        let ovh = m.site_overhead(0).unwrap();
        assert!((ovh - 0.20).abs() < 1e-9, "ovh = {ovh}");
    }

    #[test]
    fn eb_overhead_is_checked_over_unchecked_minus_one() {
        let m = MeasuredUnitCosts::new(1, 2);
        for _ in 0..MIN_SAMPLES {
            m.note_eb_checked(0, 1250);
            m.note_eb_unchecked(0, 1000);
        }
        let ovh = m.site_overhead(1).unwrap();
        assert!((ovh - 0.25).abs() < 1e-9, "ovh = {ovh}");
        // Checked faster than unchecked (noise) clamps to zero.
        let m2 = MeasuredUnitCosts::new(0, 1);
        for _ in 0..MIN_SAMPLES {
            m2.note_eb_checked(0, 900);
            m2.note_eb_unchecked(0, 1000);
        }
        assert_eq!(m2.site_overhead(0), Some(0.0));
    }

    #[test]
    fn degenerate_inputs_are_ignored() {
        let m = MeasuredUnitCosts::new(1, 1);
        m.note_gemm(0, 0, 100, 8, 8); // zero op time
        m.note_gemm(0, 1000, 100, 8, 0); // nothing verified
        m.note_gemm(7, 1000, 100, 8, 8); // out of range
        assert_eq!(m.site_samples(0), 0);
        m.note_eb_checked(9, 1); // out of range: no panic
    }

    #[test]
    fn heal_cost_defaults_then_tracks_measured_ratio() {
        let h = HealCost::new();
        assert_eq!(h.rows_equiv(), DEFAULT_HEAL_COST_ROWS);
        for _ in 0..MIN_SAMPLES {
            h.note_scan(100, 10_000); // 100 ns per row
            h.note_heal(700); // one heal = 7 scan rows
        }
        assert_eq!(h.rows_equiv(), 7);
    }
}
