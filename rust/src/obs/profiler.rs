//! Hot-path span profiler: thread-local, zero-steady-state-alloc span
//! timers over every pipeline stage.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled is a branch.** Every instrumentation point starts with
//!    [`ObsHandle::probe`]: detached handles return `None` on one
//!    branch; attached handles do one relaxed load of the sampling
//!    knob. No `Instant::now()` is taken unless the probe fired.
//! 2. **Zero steady-state allocation.** Span records are single packed
//!    `u64`s written with relaxed stores into pre-sized per-lane rings
//!    ([`OBS_LANES`] cache-line-padded lanes, threads assigned
//!    round-robin like the policy telemetry); per-stage histograms are
//!    fixed atomic arrays. Nothing on the record path touches the heap.
//! 3. **1-in-n sampling.** `sample_n == 0` disables capture, `1`
//!    captures everything, `n` captures exactly every n-th probe per
//!    lane (a per-lane counter, so single-threaded capture is exact —
//!    tested). Rare fault-path spans (recovery rungs, repairs) use
//!    [`ObsHandle::probe_rare`], which bypasses the 1-in-n gate — a
//!    once-per-outage event would otherwise almost never be sampled.
//!
//! A span record packs `stage (6 bits) | site (12 bits) | flow tag
//! (14 bits) | dur_ns (32 bits)` into one `u64` (stage stored +1 so an
//! empty slot is 0), so readers never see a torn record — no seqlock
//! needed. The flow tag is the rolling fold of the thread's current
//! flow ID (see [`super::flow`]); durations saturate at ~4.3 s, far
//! above any span this profiler times. Ring overwrite is *not* silent:
//! each lane's lifetime head doubles as its drop counter, surfaced per
//! lane in the metrics snapshot (and therefore in `{"op":"prom"}`).

use std::cell::Cell;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::util::json::Json;

use super::hist::LogLinHist;
use super::overhead::{HealCost, MeasuredUnitCosts};

/// Pipeline stages a span can cover. One histogram per stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Stage {
    /// Request line → `ScoreRequest` (server fast path).
    Parse = 0,
    /// Time a request sat in the batcher queue before being drained.
    QueueWait,
    /// Whole EmbeddingBag stage for one batch (local or sharded).
    EbGather,
    /// One fused checked bag gather (detection cost calibration).
    EbBagChecked,
    /// Pairwise feature interaction.
    Interaction,
    /// One MLP layer's GEMM + requantize epilogue (site = layer).
    MlpLayer,
    /// Detection verify, distinct from the operator it protects.
    Verify,
    /// Top-input standardize + requantize between EB and top MLP.
    Requantize,
    /// Recovery ladder rung: algebraic in-place correction.
    CorrectInPlace,
    /// Recovery ladder rung: recompute one unit.
    RecomputeUnit,
    /// Recovery ladder rung: retry a batch through detection.
    RetryBatch,
    /// Recovery ladder rung: shard-batch failover re-serve lap.
    FailoverReplica,
    /// Recovery ladder rung: background quarantine + verified repair.
    QuarantineRepair,
}

pub const STAGE_COUNT: usize = 13;

pub const STAGES: [Stage; STAGE_COUNT] = [
    Stage::Parse,
    Stage::QueueWait,
    Stage::EbGather,
    Stage::EbBagChecked,
    Stage::Interaction,
    Stage::MlpLayer,
    Stage::Verify,
    Stage::Requantize,
    Stage::CorrectInPlace,
    Stage::RecomputeUnit,
    Stage::RetryBatch,
    Stage::FailoverReplica,
    Stage::QuarantineRepair,
];

impl Stage {
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::QueueWait => "queue_wait",
            Stage::EbGather => "eb_gather",
            Stage::EbBagChecked => "eb_bag_checked",
            Stage::Interaction => "interaction",
            Stage::MlpLayer => "mlp_layer",
            Stage::Verify => "verify",
            Stage::Requantize => "requantize",
            Stage::CorrectInPlace => "correct_in_place",
            Stage::RecomputeUnit => "recompute_unit",
            Stage::RetryBatch => "retry_batch",
            Stage::FailoverReplica => "failover_replica",
            Stage::QuarantineRepair => "quarantine_repair",
        }
    }

    fn from_index(i: usize) -> Option<Stage> {
        STAGES.get(i).copied()
    }
}

/// Worker lanes for ring capture (same shape as the policy telemetry).
pub const OBS_LANES: usize = 16;

/// Span records retained per lane.
pub const RING_PER_LANE: usize = 256;

const STAGE_BITS: u32 = 6;
const SITE_BITS: u32 = 12;
const SITE_MASK: u64 = (1 << SITE_BITS) - 1;
const FLOW_BITS: u32 = super::flow::FLOW_TAG_BITS;
const FLOW_SHIFT: u32 = STAGE_BITS + SITE_BITS;
const DUR_SHIFT: u32 = STAGE_BITS + SITE_BITS + FLOW_BITS;
const DUR_MASK: u64 = (1 << (64 - DUR_SHIFT)) - 1;

#[inline]
fn pack(stage: Stage, site: u32, flow_tag: u64, dur_ns: u64) -> u64 {
    (stage as u64 + 1)
        | ((site as u64).min(SITE_MASK) << STAGE_BITS)
        | (flow_tag << FLOW_SHIFT)
        | (dur_ns.min(DUR_MASK) << DUR_SHIFT)
}

/// Decode one packed span record: `(stage, site, flow_tag, dur_ns)`.
/// `None` for an empty (never-written) ring slot. Public so the flight
/// recorder can rebuild timelines from a ring snapshot.
pub fn unpack_record(rec: u64) -> Option<(Stage, u32, u64, u64)> {
    let tag = rec & ((1 << STAGE_BITS) - 1);
    if tag == 0 {
        return None;
    }
    let stage = Stage::from_index(tag as usize - 1)?;
    let site = ((rec >> STAGE_BITS) & SITE_MASK) as u32;
    let flow_tag = (rec >> FLOW_SHIFT) & super::flow::FLOW_TAG_MAX;
    let dur_ns = rec >> DUR_SHIFT;
    Some((stage, site, flow_tag, dur_ns))
}

/// One worker lane: a head counter, the 1-in-n sampling phase, and a
/// ring of packed span records. Cache-line aligned so lanes don't
/// false-share.
#[repr(align(64))]
struct Lane {
    head: AtomicU64,
    phase: AtomicU64,
    ring: [AtomicU64; RING_PER_LANE],
}

impl Lane {
    fn new() -> Self {
        Self {
            head: AtomicU64::new(0),
            phase: AtomicU64::new(0),
            ring: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

static NEXT_LANE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static OBS_LANE: Cell<usize> = const { Cell::new(usize::MAX) };
}

#[inline]
fn lane_id() -> usize {
    OBS_LANE.with(|c| {
        let v = c.get();
        if v != usize::MAX {
            return v;
        }
        let v = NEXT_LANE.fetch_add(1, Ordering::Relaxed) % OBS_LANES;
        c.set(v);
        v
    })
}

/// Shared profiler state: sampling knob, per-stage histograms, capture
/// rings, and the measured-cost accumulators.
pub struct ObsCore {
    sample_n: AtomicU32,
    stages: [LogLinHist; STAGE_COUNT],
    lanes: Box<[Lane]>,
    measured: Arc<MeasuredUnitCosts>,
    heal: HealCost,
    /// Last-dispatched GEMM kernel tier per gemm site
    /// (`gemm::KernelTier::code()`, [`TIER_UNKNOWN`] until stamped) —
    /// lets traces and the metrics snapshot say *which* kernel the
    /// sampled spans were measuring.
    gemm_tiers: Box<[AtomicU8]>,
}

/// Sentinel for a gemm site whose kernel tier has not been stamped yet.
pub const TIER_UNKNOWN: u8 = u8::MAX;

impl ObsCore {
    pub fn new(gemm_sites: usize, eb_sites: usize, sample_n: u32) -> Self {
        Self {
            sample_n: AtomicU32::new(sample_n),
            stages: std::array::from_fn(|_| LogLinHist::new()),
            lanes: (0..OBS_LANES).map(|_| Lane::new()).collect(),
            measured: Arc::new(MeasuredUnitCosts::new(gemm_sites, eb_sites)),
            heal: HealCost::new(),
            gemm_tiers: (0..gemm_sites.max(1)).map(|_| AtomicU8::new(TIER_UNKNOWN)).collect(),
        }
    }

    #[inline]
    fn record(&self, stage: Stage, site: u32, dur_ns: u64) {
        self.stages[stage as usize].record(dur_ns);
        let flow_tag = super::flow::tag(super::flow::current());
        let lane = &self.lanes[lane_id()];
        let h = lane.head.fetch_add(1, Ordering::Relaxed);
        lane.ring[(h % RING_PER_LANE as u64) as usize]
            .store(pack(stage, site, flow_tag, dur_ns), Ordering::Relaxed);
    }

    /// 1-in-n gate; `None` when this probe is not sampled.
    #[inline]
    fn gate(&self) -> Option<Probe<'_>> {
        let n = self.sample_n.load(Ordering::Relaxed);
        if n == 0 {
            return None;
        }
        if n > 1 {
            let lane = &self.lanes[lane_id()];
            let prev = lane.phase.fetch_add(1, Ordering::Relaxed);
            if prev % n as u64 != 0 {
                return None;
            }
        }
        Some(Probe { core: self })
    }

    pub fn per_stage_hist(&self, stage: Stage) -> &LogLinHist {
        &self.stages[stage as usize]
    }

    /// Copy every lane's lifetime head into `heads` (length
    /// [`OBS_LANES`]) and every lane's ring into `rings` (lane-major,
    /// `OBS_LANES * RING_PER_LANE` words). Records are single words, so
    /// relaxed loads can't tear them; the copy is a consistent-enough
    /// recent-past snapshot for post-mortem timelines (a lane written
    /// concurrently may be off by the in-flight record). Writes only
    /// into caller-owned buffers — the flight recorder preallocates
    /// them so freezing allocates nothing.
    pub fn snapshot_rings(&self, heads: &mut [u64], rings: &mut [u64]) {
        debug_assert!(heads.len() >= self.lanes.len());
        debug_assert!(rings.len() >= self.lanes.len() * RING_PER_LANE);
        for (li, lane) in self.lanes.iter().enumerate() {
            heads[li] = lane.head.load(Ordering::Relaxed);
            let base = li * RING_PER_LANE;
            for (si, slot) in lane.ring.iter().enumerate() {
                rings[base + si] = slot.load(Ordering::Relaxed);
            }
        }
    }

    /// Current sampling knob (one relaxed load).
    pub fn sample_n_relaxed(&self) -> u32 {
        self.sample_n.load(Ordering::Relaxed)
    }

    /// Number of gemm sites the tier registry was sized for.
    pub fn num_gemm_sites(&self) -> usize {
        self.gemm_tiers.len()
    }

    /// Last-stamped kernel tier code at a gemm site
    /// ([`TIER_UNKNOWN`] until stamped or out of range).
    pub fn gemm_tier_code(&self, site: usize) -> u8 {
        self.gemm_tiers
            .get(site)
            .map_or(TIER_UNKNOWN, |s| s.load(Ordering::Relaxed))
    }
}

/// An armed sampling decision. Holding one means "this pass is being
/// profiled" — take timestamps and report spans through it.
#[derive(Clone, Copy)]
pub struct Probe<'a> {
    core: &'a ObsCore,
}

impl Probe<'_> {
    /// Record a span that started at `t0` and ends now.
    #[inline]
    pub fn span(&self, stage: Stage, site: u32, t0: Instant) {
        self.span_ns(stage, site, t0.elapsed().as_nanos() as u64);
    }

    /// Record a span with an already-measured duration.
    #[inline]
    pub fn span_ns(&self, stage: Stage, site: u32, dur_ns: u64) {
        self.core.record(stage, site, dur_ns);
    }

    /// The measured-cost accumulators, for feeding overhead EWMAs from
    /// the same timings the spans captured.
    #[inline]
    pub fn measured(&self) -> &MeasuredUnitCosts {
        &self.core.measured
    }
}

/// Cloneable handle to the profiler; `detached()` is a permanent no-op
/// whose probe path is a single branch. Mirrors `EventSink`.
#[derive(Clone)]
pub struct ObsHandle(Option<Arc<ObsCore>>);

static DETACHED_OBS: ObsHandle = ObsHandle::detached();

impl ObsHandle {
    pub const fn detached() -> Self {
        ObsHandle(None)
    }

    /// A `&'static` detached handle for contexts that hold a borrow.
    pub fn detached_ref() -> &'static ObsHandle {
        &DETACHED_OBS
    }

    /// Create an attached profiler sized for the model's detection
    /// sites. `sample_n = 0` starts disabled (capture off, zero cost
    /// beyond one relaxed load per probe point).
    pub fn attached(gemm_sites: usize, eb_sites: usize, sample_n: u32) -> Self {
        ObsHandle(Some(Arc::new(ObsCore::new(gemm_sites, eb_sites, sample_n))))
    }

    pub fn is_attached(&self) -> bool {
        self.0.is_some()
    }

    pub fn core(&self) -> Option<&ObsCore> {
        self.0.as_deref()
    }

    /// The shared core itself, for components that hold their own
    /// reference (the flight recorder snapshots its rings).
    pub fn core_arc(&self) -> Option<&Arc<ObsCore>> {
        self.0.as_ref()
    }

    /// Set the sampling knob: 0 = off, 1 = every pass, n = 1-in-n.
    pub fn set_sampling(&self, n: u32) {
        if let Some(core) = &self.0 {
            core.sample_n.store(n, Ordering::Relaxed);
        }
    }

    pub fn sampling(&self) -> u32 {
        self.0
            .as_ref()
            .map_or(0, |c| c.sample_n.load(Ordering::Relaxed))
    }

    /// Sampled probe for steady-state stages. `None` = not profiling
    /// this pass; the caller takes no timestamps.
    #[inline]
    pub fn probe(&self) -> Option<Probe<'_>> {
        match &self.0 {
            Some(core) => core.gate(),
            None => None,
        }
    }

    /// Probe for rare fault-path spans (recovery rungs, repairs):
    /// bypasses the 1-in-n gate but still respects off (`sample_n == 0`).
    #[inline]
    pub fn probe_rare(&self) -> Option<Probe<'_>> {
        match &self.0 {
            Some(core) if core.sample_n.load(Ordering::Relaxed) != 0 => {
                Some(Probe { core })
            }
            _ => None,
        }
    }

    /// Measured-cost accumulators (shared with the policy controller).
    pub fn measured(&self) -> Option<Arc<MeasuredUnitCosts>> {
        self.0.as_ref().map(|c| Arc::clone(&c.measured))
    }

    /// Stamp the kernel tier dispatched at a gemm site (out-of-range
    /// sites and detached handles are no-ops). One relaxed store.
    #[inline]
    pub fn note_gemm_tier(&self, site: u32, code: u8) {
        if let Some(core) = &self.0 {
            if let Some(slot) = core.gemm_tiers.get(site as usize) {
                slot.store(code, Ordering::Relaxed);
            }
        }
    }

    /// Last-stamped kernel tier code for a gemm site; `None` when
    /// detached, out of range, or never stamped.
    pub fn gemm_tier(&self, site: u32) -> Option<u8> {
        self.0
            .as_ref()
            .and_then(|c| c.gemm_tiers.get(site as usize))
            .map(|s| s.load(Ordering::Relaxed))
            .filter(|&c| c != TIER_UNKNOWN)
    }

    /// Record a scrub scan segment for heal-cost calibration.
    pub fn note_scan(&self, rows: usize, ns: u64) {
        if let Some(core) = &self.0 {
            core.heal.note_scan(rows, ns);
        }
    }

    /// Record one self-heal duration for heal-cost calibration.
    pub fn note_heal(&self, ns: u64) {
        if let Some(core) = &self.0 {
            core.heal.note_heal(ns);
        }
    }

    /// Budget charge for one self-healed slot, in scan-row equivalents
    /// (default constant until measured; see [`HealCost`]).
    pub fn heal_rows_equiv(&self) -> usize {
        match &self.0 {
            Some(core) => core.heal.rows_equiv(),
            None => super::overhead::DEFAULT_HEAL_COST_ROWS,
        }
    }

    /// Per-stage histogram block for the metrics snapshot: count,
    /// total, and interpolated p50/p99 per stage (µs), plus the
    /// per-lane ring watermarks ([`lanes_json`](Self::lanes_json)) so
    /// span loss is visible wherever the snapshot is scraped.
    pub fn stages_json(&self) -> Json {
        let mut arr = Vec::new();
        if let Some(core) = &self.0 {
            for stage in STAGES {
                let h = core.per_stage_hist(stage);
                let count = h.count();
                if count == 0 {
                    continue;
                }
                arr.push(Json::obj(vec![
                    ("stage", Json::Str(stage.as_str().to_string())),
                    ("count", Json::Num(count as f64)),
                    ("total_us", Json::Num(h.sum() as f64 / 1e3)),
                    ("p50_us", Json::Num(h.quantile(0.5) as f64 / 1e3)),
                    ("p99_us", Json::Num(h.quantile(0.99) as f64 / 1e3)),
                ]));
            }
        }
        Json::obj(vec![
            ("sample_1_in", Json::Num(self.sampling() as f64)),
            ("stages", Json::Arr(arr)),
            ("rings", self.lanes_json()),
        ])
    }

    /// Per-lane span-ring watermarks: lifetime `recorded` (the lane
    /// head), `fill` high-watermark (resident records — rings never
    /// shrink, so resident *is* the watermark), and `overwritten`
    /// (records lost to ring wrap — the previously-silent drop
    /// counter). Lanes that never recorded are elided; `id` labels the
    /// lane in Prometheus output.
    pub fn lanes_json(&self) -> Json {
        let mut lanes = Vec::new();
        let mut overwritten_total = 0u64;
        if let Some(core) = &self.0 {
            for (li, lane) in core.lanes.iter().enumerate() {
                let head = lane.head.load(Ordering::Relaxed);
                if head == 0 {
                    continue;
                }
                let fill = head.min(RING_PER_LANE as u64);
                let overwritten = head - fill;
                overwritten_total += overwritten;
                lanes.push(Json::obj(vec![
                    ("id", Json::Num(li as f64)),
                    ("recorded", Json::Num(head as f64)),
                    ("fill", Json::Num(fill as f64)),
                    ("overwritten", Json::Num(overwritten as f64)),
                ]));
            }
        }
        Json::obj(vec![
            ("per_lane_capacity", Json::Num(RING_PER_LANE as f64)),
            ("overwritten_total", Json::Num(overwritten_total as f64)),
            ("lanes", Json::Arr(lanes)),
        ])
    }

    /// Recent sampled spans (newest-ish; per-lane order is exact, lane
    /// interleaving is not) plus the per-stage quantile block — the
    /// payload of the server's `{"op":"trace"}`.
    pub fn trace_json(&self, max: usize) -> Json {
        let mut spans = Vec::new();
        if let Some(core) = &self.0 {
            'outer: for lane in core.lanes.iter() {
                let head = lane.head.load(Ordering::Relaxed);
                let resident = head.min(RING_PER_LANE as u64);
                // Oldest resident record first within the lane.
                for i in 0..resident {
                    let slot = ((head - resident + i) % RING_PER_LANE as u64) as usize;
                    let rec = lane.ring[slot].load(Ordering::Relaxed);
                    if let Some((stage, site, flow_tag, dur_ns)) = unpack_record(rec) {
                        let mut fields = vec![
                            ("stage", Json::Str(stage.as_str().to_string())),
                            ("site", Json::Num(site as f64)),
                            ("dur_us", Json::Num(dur_ns as f64 / 1e3)),
                        ];
                        if flow_tag != 0 {
                            fields.push(("flow", Json::Num(flow_tag as f64)));
                        }
                        // GEMM-backed spans carry the dispatched kernel
                        // tier, so a trace says which kernel the span
                        // actually timed.
                        if matches!(
                            stage,
                            Stage::MlpLayer
                                | Stage::Verify
                                | Stage::CorrectInPlace
                                | Stage::RecomputeUnit
                        ) {
                            if let Some(tier) = core
                                .gemm_tiers
                                .get(site as usize)
                                .map(|s| s.load(Ordering::Relaxed))
                                .filter(|&c| c != TIER_UNKNOWN)
                                .and_then(crate::gemm::KernelTier::from_code)
                            {
                                fields.push(("tier", Json::Str(tier.as_str().to_string())));
                            }
                        }
                        spans.push(Json::obj(fields));
                        if spans.len() >= max {
                            break 'outer;
                        }
                    }
                }
            }
        }
        Json::obj(vec![
            ("spans", Json::Arr(spans)),
            ("stages", self.stages_json()),
        ])
    }
}

impl Default for ObsHandle {
    fn default() -> Self {
        Self::detached()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detached_probe_is_none_and_all_ops_are_noops() {
        let h = ObsHandle::detached();
        assert!(h.probe().is_none());
        assert!(h.probe_rare().is_none());
        h.set_sampling(1);
        assert_eq!(h.sampling(), 0);
        h.note_heal(100);
        assert_eq!(
            h.heal_rows_equiv(),
            super::super::overhead::DEFAULT_HEAL_COST_ROWS
        );
        assert!(h.measured().is_none());
    }

    #[test]
    fn pack_unpack_round_trips_and_zero_is_empty() {
        assert!(unpack_record(0).is_none());
        for (stage, site, flow, ns) in [
            (Stage::Parse, 0u32, 0u64, 0u64),
            (Stage::Verify, 5, 77, 123_456),
            (
                Stage::QuarantineRepair,
                SITE_MASK as u32,
                crate::obs::flow::FLOW_TAG_MAX,
                (1 << 32) - 1,
            ),
        ] {
            let (s2, site2, flow2, ns2) = unpack_record(pack(stage, site, flow, ns)).unwrap();
            assert_eq!(s2, stage);
            assert_eq!(site2, site);
            assert_eq!(flow2, flow);
            assert_eq!(ns2, ns);
        }
        // Oversized sites clamp instead of corrupting neighbors.
        let (_, site, flow, _) = unpack_record(pack(Stage::Verify, 16_000, 3, 9)).unwrap();
        assert_eq!(site, SITE_MASK as u32);
        assert_eq!(flow, 3);
        // Durations saturate rather than corrupt the stage tag.
        let (s, _, _, ns) = unpack_record(pack(Stage::Parse, 1, 0, u64::MAX)).unwrap();
        assert_eq!(s, Stage::Parse);
        assert_eq!(ns, DUR_MASK);
    }

    #[test]
    fn spans_inherit_the_threads_current_flow() {
        let h = ObsHandle::attached(1, 1, 1);
        let p = h.probe().unwrap();
        p.span_ns(Stage::Parse, 0, 100);
        let flow_id = crate::obs::flow::mint();
        {
            let _g = crate::obs::flow::FlowGuard::enter(flow_id);
            p.span_ns(Stage::Verify, 0, 200);
        }
        p.span_ns(Stage::Requantize, 0, 300);
        let doc = h.trace_json(16);
        let spans = doc.get("spans").and_then(Json::as_arr).unwrap();
        let flow_of = |stage: &str| {
            spans
                .iter()
                .find(|s| s.get("stage").and_then(Json::as_str) == Some(stage))
                .unwrap()
                .get("flow")
                .and_then(Json::as_f64)
        };
        assert_eq!(flow_of("parse"), None, "pre-guard span is unattributed");
        assert_eq!(
            flow_of("verify"),
            Some(crate::obs::flow::tag(flow_id) as f64),
            "guarded span carries the flow tag"
        );
        assert_eq!(flow_of("requantize"), None, "guard restored on drop");
    }

    #[test]
    fn lane_watermarks_expose_overwrites() {
        let h = ObsHandle::attached(1, 1, 1);
        let p = h.probe().unwrap();
        for i in 0..(RING_PER_LANE as u64 + 30) {
            p.span_ns(Stage::Parse, 0, i);
        }
        let rings = h.lanes_json();
        assert_eq!(
            rings.get("overwritten_total").and_then(Json::as_f64),
            Some(30.0)
        );
        let lanes = rings.get("lanes").and_then(Json::as_arr).unwrap();
        let lane = lanes
            .iter()
            .find(|l| l.get("overwritten").and_then(Json::as_f64) == Some(30.0))
            .expect("the hot lane reports its overwrites");
        assert_eq!(
            lane.get("fill").and_then(Json::as_f64),
            Some(RING_PER_LANE as f64)
        );
        assert_eq!(
            lane.get("recorded").and_then(Json::as_f64),
            Some(RING_PER_LANE as f64 + 30.0)
        );
        // The snapshot block embeds the same rows.
        let obs = h.stages_json();
        assert!(obs.path(&["rings", "overwritten_total"]).is_some());
    }

    #[test]
    fn ring_snapshot_copies_heads_and_records() {
        let h = ObsHandle::attached(1, 1, 1);
        let p = h.probe().unwrap();
        p.span_ns(Stage::Verify, 2, 4_000);
        let core = h.core().unwrap();
        let mut heads = vec![0u64; OBS_LANES];
        let mut rings = vec![0u64; OBS_LANES * RING_PER_LANE];
        core.snapshot_rings(&mut heads, &mut rings);
        assert_eq!(heads.iter().sum::<u64>(), 1);
        let decoded: Vec<_> = rings.iter().filter_map(|&r| unpack_record(r)).collect();
        assert_eq!(decoded, vec![(Stage::Verify, 2, 0, 4_000)]);
    }

    #[test]
    fn sampled_capture_is_exactly_one_in_n_per_lane() {
        let core = ObsCore::new(4, 2, 4);
        let mut fired = 0;
        for _ in 0..64 {
            if let Some(p) = core.gate() {
                p.span_ns(Stage::MlpLayer, 0, 1000);
                fired += 1;
            }
        }
        assert_eq!(fired, 16, "1-in-4 over 64 probes must fire exactly 16");
        assert_eq!(core.per_stage_hist(Stage::MlpLayer).count(), 16);
    }

    #[test]
    fn sampling_zero_disables_and_one_captures_all() {
        let h = ObsHandle::attached(2, 1, 0);
        assert!(h.probe().is_none());
        assert!(h.probe_rare().is_none());
        h.set_sampling(1);
        for _ in 0..10 {
            let p = h.probe().expect("always-on probe");
            p.span_ns(Stage::Parse, 0, 500);
        }
        assert!(h.probe_rare().is_some());
        let core = h.core().unwrap();
        assert_eq!(core.per_stage_hist(Stage::Parse).count(), 10);
    }

    #[test]
    fn trace_json_surfaces_recent_spans_and_stage_quantiles() {
        let h = ObsHandle::attached(2, 1, 1);
        let p = h.probe().unwrap();
        p.span_ns(Stage::Verify, 3, 2_000);
        p.span_ns(Stage::MlpLayer, 3, 10_000);
        let doc = h.trace_json(100);
        let spans = doc.get("spans").and_then(Json::as_arr).unwrap();
        assert_eq!(spans.len(), 2);
        let stages = doc
            .path(&["stages", "stages"])
            .and_then(Json::as_arr)
            .unwrap();
        assert!(stages
            .iter()
            .any(|s| s.get("stage").and_then(Json::as_str) == Some("verify")));
        // max truncates.
        let doc2 = h.trace_json(1);
        assert_eq!(doc2.get("spans").and_then(Json::as_arr).unwrap().len(), 1);
    }

    #[test]
    fn gemm_tier_registry_stamps_and_labels_traces() {
        let h = ObsHandle::attached(3, 1, 1);
        assert_eq!(h.gemm_tier(0), None, "unstamped site has no tier");
        h.note_gemm_tier(0, crate::gemm::KernelTier::Avx2.code());
        assert_eq!(h.gemm_tier(0), Some(crate::gemm::KernelTier::Avx2.code()));
        h.note_gemm_tier(99, 1); // out of range: no-op, no panic
        assert_eq!(h.gemm_tier(99), None);
        let p = h.probe().unwrap();
        p.span_ns(Stage::MlpLayer, 0, 5_000);
        p.span_ns(Stage::Parse, 0, 1_000);
        let spans = h.trace_json(10);
        let spans = spans.get("spans").and_then(Json::as_arr).unwrap();
        let mlp = spans
            .iter()
            .find(|s| s.get("stage").and_then(Json::as_str) == Some("mlp_layer"))
            .unwrap();
        assert_eq!(mlp.get("tier").and_then(Json::as_str), Some("avx2"));
        let parse = spans
            .iter()
            .find(|s| s.get("stage").and_then(Json::as_str) == Some("parse"))
            .unwrap();
        assert!(parse.get("tier").is_none(), "non-GEMM spans carry no tier");

        // Detached: all tier ops are no-ops.
        let d = ObsHandle::detached();
        d.note_gemm_tier(0, 1);
        assert_eq!(d.gemm_tier(0), None);
    }

    #[test]
    fn ring_overwrites_but_histograms_keep_lifetime_counts() {
        let h = ObsHandle::attached(1, 1, 1);
        let p = h.probe().unwrap();
        for i in 0..(RING_PER_LANE as u64 + 50) {
            p.span_ns(Stage::Parse, 0, i);
        }
        let resident = h
            .trace_json(usize::MAX)
            .get("spans")
            .and_then(Json::as_arr)
            .unwrap()
            .len();
        assert!(resident <= OBS_LANES * RING_PER_LANE);
        assert_eq!(
            h.core().unwrap().per_stage_hist(Stage::Parse).count(),
            RING_PER_LANE as u64 + 50
        );
    }
}
