//! Prometheus text-format rendering of the metrics snapshot.
//!
//! One generic walker over the snapshot [`Json`] tree, so every
//! existing counter, histogram quantile, policy site, shard health
//! block, journal aggregate — and anything a future PR adds to the
//! snapshot — shows up in a scrape without a hand-maintained mapping:
//!
//! - object keys extend the metric name (`policy.scrub_budget` →
//!   `dlrm_policy_scrub_budget`);
//! - array elements become labels: an element object is labeled by its
//!   `site`/`stage`/`id`/`op` field when present, else by index, and
//!   nested arrays accumulate labels;
//! - numbers and booleans (0/1) emit sample lines; strings and nulls
//!   are identifiers, not samples, and are skipped.

use crate::util::json::Json;

/// Metric-name prefix for every emitted sample.
pub const PROM_PREFIX: &str = "dlrm";

/// Keys that identify an array element and become its label instead of
/// a bare index.
const LABEL_KEYS: [&str; 4] = ["site", "stage", "id", "op"];

/// Render a snapshot document as Prometheus text format.
pub fn render_prometheus(root: &Json) -> String {
    let mut out = String::new();
    walk(&mut out, &mut String::from(PROM_PREFIX), &mut Vec::new(), root);
    out
}

fn walk(out: &mut String, name: &mut String, labels: &mut Vec<(String, String)>, j: &Json) {
    match j {
        Json::Num(x) => emit(out, name, labels, *x),
        Json::Bool(b) => emit(out, name, labels, if *b { 1.0 } else { 0.0 }),
        Json::Obj(map) => {
            for (k, v) in map {
                let len = name.len();
                name.push('_');
                push_sanitized(name, k);
                walk(out, name, labels, v);
                name.truncate(len);
            }
        }
        Json::Arr(arr) => {
            for (i, el) in arr.iter().enumerate() {
                let label = element_label(el, i);
                labels.push(label);
                walk(out, name, labels, el);
                labels.pop();
            }
        }
        Json::Str(_) | Json::Null => {}
    }
}

/// Label for one array element: its identifying field when it has one,
/// else its index.
fn element_label(el: &Json, index: usize) -> (String, String) {
    if let Json::Obj(map) = el {
        for key in LABEL_KEYS {
            match map.get(key) {
                Some(Json::Str(s)) => return (key.to_string(), s.clone()),
                Some(Json::Num(x)) => return (key.to_string(), fmt_num(*x)),
                _ => {}
            }
        }
    }
    ("idx".to_string(), index.to_string())
}

fn emit(out: &mut String, name: &str, labels: &[(String, String)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            for c in v.chars() {
                match c {
                    '"' | '\\' => {
                        out.push('\\');
                        out.push(c);
                    }
                    '\n' => out.push_str("\\n"),
                    _ => out.push(c),
                }
            }
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    out.push_str(&fmt_num(value));
    out.push('\n');
}

fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

/// Append `key` with any character outside `[a-zA-Z0-9_:]` replaced by
/// an underscore (Prometheus metric-name charset).
fn push_sanitized(name: &mut String, key: &str) {
    for c in key.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            name.push(c);
        } else {
            name.push('_');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_nested_objects_and_name_sanitizing() {
        let doc = Json::obj(vec![
            ("requests", Json::Num(42.0)),
            ("ratio", Json::Num(0.25)),
            ("enabled", Json::Bool(true)),
            ("label", Json::Str("skipped".to_string())),
            (
                "policy",
                Json::obj(vec![("scrub-budget", Json::Num(128.0))]),
            ),
        ]);
        let text = render_prometheus(&doc);
        assert!(text.contains("dlrm_requests 42\n"), "{text}");
        assert!(text.contains("dlrm_ratio 0.25\n"), "{text}");
        assert!(text.contains("dlrm_enabled 1\n"), "{text}");
        assert!(text.contains("dlrm_policy_scrub_budget 128\n"), "{text}");
        assert!(!text.contains("skipped"), "{text}");
    }

    #[test]
    fn arrays_label_by_site_key_or_index() {
        let doc = Json::obj(vec![(
            "sites",
            Json::Arr(vec![
                Json::obj(vec![
                    ("site", Json::Str("gemm/0".to_string())),
                    ("overhead", Json::Num(0.12)),
                ]),
                Json::obj(vec![("overhead", Json::Num(0.2))]),
            ]),
        )]);
        let text = render_prometheus(&doc);
        assert!(
            text.contains("dlrm_sites_overhead{site=\"gemm/0\"} 0.12\n"),
            "{text}"
        );
        assert!(
            text.contains("dlrm_sites_overhead{idx=\"1\"} 0.2\n"),
            "{text}"
        );
    }

    #[test]
    fn nested_arrays_accumulate_labels_and_numeric_ids_work() {
        let doc = Json::obj(vec![(
            "shards",
            Json::Arr(vec![Json::obj(vec![
                ("id", Json::Num(3.0)),
                ("tables", Json::Arr(vec![Json::Num(7.0)])),
            ])]),
        )]);
        let text = render_prometheus(&doc);
        assert!(
            text.contains("dlrm_shards_tables{id=\"3\",idx=\"0\"} 7\n"),
            "{text}"
        );
    }

    #[test]
    fn label_values_are_escaped() {
        let doc = Json::Arr(vec![Json::obj(vec![
            ("site", Json::Str("a\"b\\c".to_string())),
            ("v", Json::Num(1.0)),
        ])]);
        let text = render_prometheus(&doc);
        assert!(text.contains("site=\"a\\\"b\\\\c\""), "{text}");
    }
}
