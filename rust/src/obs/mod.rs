//! Observability plane: hot-path span profiler, live detection-overhead
//! accounting, and Prometheus exposition.
//!
//! The paper's claims are *overhead* claims (GEMM detection < 20%,
//! EmbeddingBag < 26%); this module is how the system measures its own
//! detection cost instead of assuming it:
//!
//! - [`profiler`] — thread-local, zero-steady-state-alloc span timers
//!   over every pipeline stage (parse, queue-wait, EB gather,
//!   interaction, per-layer GEMM, *verify as its own span*, requantize,
//!   and each recovery-ladder rung), 1-in-n sampled, aggregated into
//!   lock-free per-stage log-linear histograms.
//! - [`overhead`] — per-site EWMAs of measured verify-cost ÷
//!   operator-cost ([`MeasuredUnitCosts`]) consumed by the policy
//!   controller in place of the static `UnitCosts` prior, plus the
//!   scrubber's measured self-heal cost ([`HealCost`]).
//! - [`hist`] — the shared log-linear histogram (4 linear sub-buckets
//!   per octave, interpolated quantiles) that also fixes the serving
//!   latency histogram's log2 p99 coarseness.
//! - [`prom`] — Prometheus text rendering of the whole metrics
//!   snapshot for the server's `{"op":"prom"}`.
//! - [`flow`] — request/batch flow IDs minted per unit of causal work
//!   and carried in a thread-local, stamped into span records (14-bit
//!   rolling tag) and fault events (full ID) so a capture reconstructs
//!   the per-request timeline.
//! - [`flightrec`] — the fault flight recorder: a severity-gated,
//!   bounded pool of immutable `BlackBox` captures (span rings + policy
//!   plane + shard health + kernel tiers) frozen by the event sink at
//!   fault time, exported via `{"op":"flightrec"}` and
//!   `--flightrec-dump-dir`.

pub mod flightrec;
pub mod flow;
pub mod hist;
pub mod overhead;
pub mod profiler;
pub mod prom;

pub use flightrec::{FlightRecorder, SnapshotFn, DEFAULT_CAPTURES};
pub use flow::{FlowGuard, FLOW_TAG_BITS, FLOW_TAG_MAX};
pub use hist::{HistWindow, LogLinHist, NUM_BUCKETS, SUB_BUCKETS};
pub use overhead::{HealCost, MeasuredUnitCosts, DEFAULT_HEAL_COST_ROWS, MIN_SAMPLES};
pub use profiler::{ObsCore, ObsHandle, Probe, Stage, STAGES, STAGE_COUNT};
pub use prom::render_prometheus;
