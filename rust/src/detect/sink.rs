//! The event sink: the single handle every detection site emits through.
//!
//! One emission call fans a [`FaultEvent`] out to the three consumers
//! that were previously fed by hand at each of the five detection
//! sites:
//!
//! 1. the [`Journal`] (always — the auditable record),
//! 2. `policy::telemetry` — the flagged site's `flags` counter, which
//!    drives the escalation controller. This leg rides the
//!    [`SiteCtx::emit`] wrapper (or the site's own telemetry handle at
//!    the EB sites), **not** a sink-side registry: the site already
//!    holds its `&SiteTelemetry`, so escalation keeps working even for
//!    a standalone model whose sink is detached. The scrubber is not a
//!    policy site and feeds no flags.
//! 3. `coordinator::metrics` — the `detections` / `shard_detections` /
//!    `scrub_hits` counter families, routed by detector and unit.
//!
//! The handle is cheap and cloneable (`Option<Arc>` like
//! [`PolicyHandle`]); a **detached** sink journals nothing, so
//! standalone models (tools, unit tests) pay one `Option` check. The
//! engine attaches one sink at construction and threads it into the
//! model (and from there into the shard store), wiring metrics
//! immediately.
//!
//! Emission happens **only on faults** — the clean path never calls
//! `emit` — so everything here is off the latency path and the
//! steady-state zero-allocation invariant is untouched.
//!
//! [`PolicyHandle`]: crate::policy::PolicyHandle

use crate::coordinator::metrics::Metrics;
use crate::detect::event::{Detector, FaultEvent, Resolution, Severity, SiteId, UnitRef};
use crate::detect::journal::{Journal, DEFAULT_JOURNAL_CAPACITY};
use crate::detect::LOCAL_REPLICA;
use crate::obs::{FlightRecorder, ObsHandle};
use crate::policy::SiteTelemetry;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

/// Shared core of an attached sink.
pub struct SinkCore {
    journal: Journal,
    /// Journal timestamp: the engine advances it once per scored batch.
    tick: AtomicU64,
    /// Controller timestamp: the engine stamps the policy controller's
    /// step counter here on every `policy_tick`, so emitted events
    /// correlate with the controller decision window that saw them
    /// (stays 0 when no controller runs).
    ctl_tick: AtomicU64,
    /// Wired by the engine at construction.
    metrics: OnceLock<Arc<Metrics>>,
    /// Armed flight recorder, wired by the engine when `--flightrec` is
    /// on. Consulted only here — emission runs exclusively on faults, so
    /// the probe/clean path never touches it.
    recorder: OnceLock<Arc<FlightRecorder>>,
}

/// The emit handle. `Default`/[`EventSink::detached`] is a no-op.
#[derive(Clone, Default)]
pub struct EventSink(Option<Arc<SinkCore>>);

/// The process-wide detached sink, for call sites that need a
/// `&'static EventSink` (e.g. [`SiteCtx::bare`]).
static DETACHED: EventSink = EventSink::detached();

impl EventSink {
    /// A no-op sink (`const`, so it can back statics).
    pub const fn detached() -> Self {
        Self(None)
    }

    /// An attached sink with a journal of `capacity` events.
    pub fn with_capacity(capacity: usize) -> Self {
        Self(Some(Arc::new(SinkCore {
            journal: Journal::with_capacity(capacity),
            tick: AtomicU64::new(0),
            ctl_tick: AtomicU64::new(0),
            metrics: OnceLock::new(),
            recorder: OnceLock::new(),
        })))
    }

    /// An attached sink at the default capacity
    /// ([`DEFAULT_JOURNAL_CAPACITY`]).
    pub fn attached() -> Self {
        Self::with_capacity(DEFAULT_JOURNAL_CAPACITY)
    }

    pub fn is_attached(&self) -> bool {
        self.0.is_some()
    }

    /// The journal, when attached.
    pub fn journal(&self) -> Option<&Journal> {
        self.0.as_deref().map(|c| &c.journal)
    }

    /// Wire the metrics counters (idempotent; first wins).
    pub fn attach_metrics(&self, metrics: Arc<Metrics>) {
        if let Some(core) = &self.0 {
            let _ = core.metrics.set(metrics);
        }
    }

    /// Arm a flight recorder: every journaled event at or above its
    /// severity floor freezes a `BlackBox` capture (idempotent; first
    /// wins).
    pub fn attach_recorder(&self, recorder: Arc<FlightRecorder>) {
        if let Some(core) = &self.0 {
            let _ = core.recorder.set(recorder);
        }
    }

    /// The armed recorder, when attached.
    pub fn recorder(&self) -> Option<&Arc<FlightRecorder>> {
        self.0.as_deref().and_then(|c| c.recorder.get())
    }

    /// Advance the journal timestamp (the engine: once per batch).
    pub fn advance_tick(&self) {
        if let Some(core) = &self.0 {
            core.tick.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current journal tick (0 when detached).
    pub fn tick(&self) -> u64 {
        self.0.as_deref().map_or(0, |c| c.tick.load(Ordering::Relaxed))
    }

    /// Record the policy controller's step counter (the engine: on every
    /// `policy_tick`); emitted events carry it as their `ctl_tick`.
    pub fn set_ctl_tick(&self, ctl_tick: u64) {
        if let Some(core) = &self.0 {
            core.ctl_tick.store(ctl_tick, Ordering::Relaxed);
        }
    }

    /// Current controller tick (0 when detached or controller-less).
    pub fn ctl_tick(&self) -> u64 {
        self.0.as_deref().map_or(0, |c| c.ctl_tick.load(Ordering::Relaxed))
    }

    /// Emit one detection event: journal it and route the matching
    /// metrics counter. No-op when detached. Policy-site flags are fed
    /// by the caller's telemetry handle (see [`SiteCtx::emit`] and the
    /// module docs) — not here — so escalation does not depend on sink
    /// wiring.
    pub fn emit(
        &self,
        site: SiteId,
        unit: UnitRef,
        detector: Detector,
        severity: Severity,
        resolution: Resolution,
    ) {
        let Some(core) = &self.0 else { return };
        let ev = FaultEvent {
            tick: core.tick.load(Ordering::Relaxed),
            ctl_tick: core.ctl_tick.load(Ordering::Relaxed),
            // The emitting thread's flow (0 off-request, e.g. background
            // scrub) — the capture/journal correlation key.
            flow: crate::obs::flow::current(),
            site,
            unit,
            detector,
            severity,
            resolution,
        };
        core.journal.record(&ev);
        // Freeze-on-fault: the recorder sees every journaled event and
        // applies its own severity floor. Fault path only — never the
        // clean path.
        if let Some(rec) = core.recorder.get() {
            rec.maybe_freeze(&ev);
        }
        // Metrics routing: one detection family per detector/unit.
        if let Some(m) = core.metrics.get() {
            match (detector, unit) {
                (Detector::ScrubExact, _) => {
                    m.scrub_hits.fetch_add(1, Ordering::Relaxed);
                }
                (Detector::EbBound, UnitRef::Bag { replica, .. })
                    if replica != LOCAL_REPLICA =>
                {
                    m.shard_detections.fetch_add(1, Ordering::Relaxed);
                }
                _ => {
                    m.detections.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

/// One detection site's emission context: the sink, the site's identity,
/// its (optional) policy telemetry, and the span profiler — bundled so
/// hot-path signatures carry one argument instead of four. Constructed
/// per layer/table invocation by the model; [`SiteCtx::bare`] gives
/// standalone callers (layer unit tests, baselines) a detached context.
#[derive(Clone, Copy)]
pub struct SiteCtx<'a> {
    pub sink: &'a EventSink,
    pub site: SiteId,
    pub telem: Option<&'a SiteTelemetry>,
    /// Span profiler handle; defaults to the detached no-op so existing
    /// constructors stay two/three-argument. The model threads its own
    /// handle in via [`SiteCtx::with_obs`].
    pub obs: &'a ObsHandle,
}

impl<'a> SiteCtx<'a> {
    pub fn new(sink: &'a EventSink, site: SiteId, telem: Option<&'a SiteTelemetry>) -> Self {
        Self { sink, site, telem, obs: ObsHandle::detached_ref() }
    }

    /// Detached-sink context (site id is a placeholder — nothing is
    /// emitted through a detached sink).
    pub fn bare(telem: Option<&'a SiteTelemetry>) -> Self {
        Self { sink: &DETACHED, site: SiteId::Gemm(0), telem, obs: ObsHandle::detached_ref() }
    }

    /// Thread a profiler handle into the context (builder-style, so the
    /// existing constructors keep their signatures).
    pub fn with_obs(mut self, obs: &'a ObsHandle) -> Self {
        self.obs = obs;
        self
    }

    /// Emit at this site: raise the site's telemetry flag (the
    /// escalation controller's signal — works even with a detached
    /// sink) and fan the event to journal + metrics.
    #[inline]
    pub fn emit(
        &self,
        unit: UnitRef,
        detector: Detector,
        severity: Severity,
        resolution: Resolution,
    ) {
        if let Some(t) = self.telem {
            t.note_flags(1);
        }
        self.sink.emit(self.site, unit, detector, severity, resolution);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detect::Recovery;

    #[test]
    fn detached_sink_is_a_noop() {
        let s = EventSink::detached();
        assert!(!s.is_attached());
        assert!(s.journal().is_none());
        s.advance_tick();
        assert_eq!(s.tick(), 0);
        s.emit(
            SiteId::Gemm(0),
            UnitRef::BatchAggregate,
            Detector::GemmAggregate,
            Severity::NearBound,
            Resolution::Degraded,
        );
    }

    #[test]
    fn emit_journals_with_tick() {
        let s = EventSink::with_capacity(8);
        s.advance_tick();
        s.advance_tick();
        s.emit(
            SiteId::Eb(1),
            UnitRef::Bag { request: 4, replica: 0 },
            Detector::EbBound,
            Severity::Significant,
            Resolution::Recovered(Recovery::FailoverReplica),
        );
        let j = s.journal().unwrap();
        assert_eq!(j.total(), 1);
        let ev = j.recent(1)[0];
        assert_eq!(ev.tick, 2);
        assert_eq!(ev.ctl_tick, 0, "no controller stamped yet");
        assert_eq!(ev.site, SiteId::Eb(1));

        // Once the engine stamps the controller step, events carry it.
        s.set_ctl_tick(9);
        s.emit(
            SiteId::Eb(1),
            UnitRef::Bag { request: 5, replica: 0 },
            Detector::EbBound,
            Severity::Significant,
            Resolution::Recovered(Recovery::FailoverReplica),
        );
        assert_eq!(j.recent(1)[0].ctl_tick, 9);
    }

    #[test]
    fn site_ctx_emit_raises_flags_even_with_a_detached_sink() {
        // The escalation signal must not depend on sink wiring: a
        // standalone model with a hand-attached policy still counts
        // flags through its telemetry handle.
        let telem = SiteTelemetry::default();
        let ctx = SiteCtx::bare(Some(&telem));
        ctx.emit(
            UnitRef::GemmRow { row: 0 },
            Detector::GemmChecksum,
            Severity::Significant,
            Resolution::DetectedOnly,
        );
        assert_eq!(telem.flags.load(Ordering::Relaxed), 1);
        // And through an attached sink, the journal records too.
        let s = EventSink::with_capacity(4);
        let ctx = SiteCtx::new(&s, SiteId::Gemm(3), Some(&telem));
        ctx.emit(
            UnitRef::GemmRow { row: 1 },
            Detector::GemmChecksum,
            Severity::NearBound,
            Resolution::Recovered(Recovery::RecomputeUnit),
        );
        assert_eq!(telem.flags.load(Ordering::Relaxed), 2);
        assert_eq!(s.journal().unwrap().total(), 1);
    }

    #[test]
    fn emit_routes_metrics_families() {
        let s = EventSink::with_capacity(8);
        let m = Arc::new(Metrics::new());
        s.attach_metrics(Arc::clone(&m));
        s.emit(
            SiteId::Gemm(0),
            UnitRef::GemmRow { row: 1 },
            Detector::GemmChecksum,
            Severity::Significant,
            Resolution::Recovered(Recovery::RecomputeUnit),
        );
        s.emit(
            SiteId::Eb(0),
            UnitRef::Bag { request: 0, replica: LOCAL_REPLICA },
            Detector::EbBound,
            Severity::Significant,
            Resolution::Escalated(Recovery::RetryBatch),
        );
        s.emit(
            SiteId::Eb(0),
            UnitRef::Bag { request: 0, replica: 1 },
            Detector::EbBound,
            Severity::Significant,
            Resolution::Recovered(Recovery::FailoverReplica),
        );
        s.emit(
            SiteId::Eb(0),
            UnitRef::ScrubSlot { replica: 1, row: 3 },
            Detector::ScrubExact,
            Severity::NearBound,
            Resolution::Escalated(Recovery::QuarantineAndRepair),
        );
        assert_eq!(m.detections.load(Ordering::Relaxed), 2, "gemm row + local bag");
        assert_eq!(m.shard_detections.load(Ordering::Relaxed), 1);
        assert_eq!(m.scrub_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn emit_stamps_the_current_flow_and_triggers_the_recorder() {
        let s = EventSink::with_capacity(8);
        let rec = Arc::new(crate::obs::FlightRecorder::new(
            2,
            Severity::Significant,
            1,
        ));
        s.attach_recorder(Arc::clone(&rec));
        let flow_id = crate::obs::flow::mint();
        {
            let _g = crate::obs::flow::FlowGuard::enter(flow_id);
            s.emit(
                SiteId::Gemm(0),
                UnitRef::GemmRow { row: 2 },
                Detector::GemmChecksum,
                Severity::Significant,
                Resolution::Recovered(Recovery::RecomputeUnit),
            );
        }
        let ev = s.journal().unwrap().recent(1)[0];
        assert_eq!(ev.flow, flow_id, "journaled event carries the flow");
        assert_eq!(rec.captures_taken(), 1, "Severe event froze a capture");
        let cap = rec.capture_json(1).unwrap();
        assert_eq!(
            cap.path(&["event", "flow"]).and_then(crate::util::json::Json::as_usize),
            Some(flow_id as usize)
        );
        // Below the floor: journaled but not frozen; off-flow: flow 0.
        s.emit(
            SiteId::Gemm(0),
            UnitRef::GemmRow { row: 3 },
            Detector::GemmChecksum,
            Severity::NearBound,
            Resolution::DetectedOnly,
        );
        assert_eq!(s.journal().unwrap().recent(1)[0].flow, 0);
        assert_eq!(rec.captures_taken(), 1);
    }
}
