//! Unified fault-event pipeline (PR 5): typed detection verdicts, a
//! severity-ranked recovery ladder, and an auditable event journal.
//!
//! The paper's two detectors (Eq-3b GEMM checksums, Eq-5 EmbeddingBag
//! bounds) fire from five sites that grew up independently — GEMM row
//! verify, the fused EB path, the shard router's retry/failover loop,
//! the scrubber, and the BoundOnly batch aggregate. This subsystem makes
//! a detection a **first-class event** with one vocabulary and one
//! emission path:
//!
//! * [`event`] — [`FaultEvent`]: site ([`SiteId`]), implicated unit
//!   ([`UnitRef`]), detector, [`Severity`] (classified significant-bit
//!   vs near-bound from the detector's own margin), and [`Resolution`]
//!   (the terminal state of the recovery walk).
//! * [`recovery`] — the single ordered ladder `CorrectInPlace →
//!   RecomputeUnit → RetryBatch → FailoverReplica → QuarantineAndRepair
//!   → Degrade` with per-site-class applicability; every site consults
//!   it instead of hand-rolling its own flow. `CorrectInPlace` (PR 6)
//!   is the algebraic rung: where partial checksums localize the fault
//!   to one unit slot, it is rewritten in place and re-verified — the
//!   only rung cheaper than the unit's original computation.
//! * [`journal`] — a lock-free fixed-capacity ring recording every
//!   event with its resolution and tick; queryable via the `events`
//!   server op, summarized in `metrics_snapshot()`, and the substrate
//!   `fault::campaign` assertions are expressed over ("an injected
//!   fault produces a matching event", "detected corruption is never
//!   served").
//! * [`sink`] — the one [`EventSink`] handle sites emit through; the
//!   emission path fans each event to the journal, the flagged policy
//!   site's telemetry (via [`SiteCtx`] / the site's own handle, so
//!   escalation never depends on sink wiring), and the serving metrics
//!   counters.
//!
//! # Contracts
//!
//! * **Clean path untouched** — emission happens only on flags; served
//!   bytes are bit-identical to the pre-PR-5 engine on clean data, and
//!   the steady-state zero-allocation invariant
//!   (`rust/tests/zero_alloc.rs`) holds with the journal attached (it
//!   is pre-sized at attach and records into fixed atomics).
//! * **Every detection is journaled** — all five sites emit through the
//!   sink; `rust/tests/detect_integration.rs` injects one fault per
//!   site class and checks the single matching event.
//! * **Resolutions are honest** — `Recovered(step)` is only recorded
//!   when the step's re-check passed; a served-but-corrupt unit is
//!   `Degraded`, never silent.

pub mod event;
pub mod journal;
pub mod recovery;
pub mod sink;

pub use event::{
    Detector, FaultEvent, Resolution, Severity, SiteId, UnitRef, EB_SIGNIFICANT_MARGIN,
    GEMM_SIGNIFICANT_DELTA, LOCAL_REPLICA, SCRUB_SIGNIFICANT_DELTA,
};
pub use journal::{Journal, DEFAULT_JOURNAL_CAPACITY};
pub use recovery::{first_step, ladder, next_step, Recovery, SiteClass};
pub use sink::{EventSink, SiteCtx};
