//! The event journal: a lock-free fixed-capacity ring of
//! [`FaultEvent`]s plus cheap aggregate counters.
//!
//! # Design constraints (and how they are met)
//!
//! * **Zero steady-state allocation** — the slot array is sized once at
//!   attach ([`Journal::with_capacity`]); recording touches only
//!   pre-existing atomics, consistent with `rust/tests/zero_alloc.rs`
//!   (the engine attaches a journal by default, and the zero-alloc
//!   steady-state test runs with it attached).
//! * **Lock-free** — recording is one `fetch_add` to claim a sequence
//!   number plus atomic stores into the claimed slot behind a seqlock
//!   generation stamp; queries validate the stamp before and after
//!   reading, so a reader never blocks a writer and a torn slot is
//!   skipped, not mis-reported. The payload words are themselves
//!   atomics, so concurrent access is race-free by construction. Two
//!   writers collide on one slot only when their sequences are exactly
//!   `capacity` events apart (one writer stalled across a full ring
//!   wrap); the stamp doubles as a per-slot claim ([`BUSY`]) so their
//!   payloads can never interleave — the loser briefly spins, and if
//!   the older write lands last its stamp simply hides the newer event
//!   from ring queries (the aggregates already counted it). Readers are
//!   always wait-free.
//! * **Bounded** — when more than `capacity` events have ever been
//!   recorded, the oldest are overwritten; [`Journal::total`] keeps the
//!   lifetime count and the aggregate counters never lose events, so
//!   "how many" queries stay exact even after wrap. (All counters are
//!   independently monotone; a reader racing an in-flight `record` may
//!   transiently see `total` ahead of the `by_*` sums by at most the
//!   number of concurrent writers — they converge as soon as those
//!   writes retire.)

use crate::detect::event::{
    FaultEvent, DETECTOR_SLOTS, RESOLUTION_KIND_NAMES, RESOLUTION_SLOTS,
};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Default ring capacity the engine attaches with.
pub const DEFAULT_JOURNAL_CAPACITY: usize = 1024;

/// Stamp value marking a slot mid-write (no valid `seq + 1` ever equals
/// it — sequences are bounded far below `u64::MAX`).
const BUSY: u64 = u64::MAX;

struct Slot {
    /// Generation stamp: `0` = empty, [`BUSY`] = mid-write, else
    /// `seq + 1` of the event held.
    stamp: AtomicU64,
    meta: AtomicU64,
    aux: AtomicU64,
    tick: AtomicU64,
    /// Full 64-bit flow ID — the `(meta, aux)` pair is fully packed, so
    /// the request correlation rides its own word (see
    /// [`FaultEvent::flow`]).
    flow: AtomicU64,
}

impl Slot {
    fn new() -> Self {
        Self {
            stamp: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            aux: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            flow: AtomicU64::new(0),
        }
    }
}

/// The ring journal. See module docs for the concurrency contract.
pub struct Journal {
    slots: Box<[Slot]>,
    /// Next sequence number == lifetime event count.
    head: AtomicU64,
    by_severity: [AtomicU64; 2],
    by_detector: [AtomicU64; DETECTOR_SLOTS],
    by_resolution: [AtomicU64; RESOLUTION_SLOTS],
}

impl Journal {
    /// Pre-size the ring; this is the only allocation the journal ever
    /// performs.
    pub fn with_capacity(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            slots: (0..capacity).map(|_| Slot::new()).collect(),
            head: AtomicU64::new(0),
            by_severity: Default::default(),
            by_detector: Default::default(),
            by_resolution: Default::default(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Lifetime events recorded (monotone; survives wrap).
    pub fn total(&self) -> u64 {
        self.head.load(Ordering::Acquire)
    }

    /// Events currently resident in the ring.
    pub fn len(&self) -> usize {
        (self.total() as usize).min(self.capacity())
    }

    pub fn is_empty(&self) -> bool {
        self.total() == 0
    }

    /// Events overwritten by wrap (lifetime − resident).
    pub fn dropped(&self) -> u64 {
        self.total().saturating_sub(self.len() as u64)
    }

    /// Record one event. Allocation-free; see the module docs for the
    /// (only) writer-collision case that spins.
    pub fn record(&self, ev: &FaultEvent) {
        let (meta, aux) = ev.encode();
        let seq = self.head.fetch_add(1, Ordering::AcqRel);
        let slot = &self.slots[(seq % self.capacity() as u64) as usize];
        // Claim the slot (stamp → BUSY): without this, a writer stalled
        // for a full ring wrap could interleave its payload words with a
        // later writer's and publish a stamp over a *mixed* payload —
        // the one torn state a seqlock reader cannot detect.
        loop {
            let cur = slot.stamp.load(Ordering::Acquire);
            if cur != BUSY
                && slot
                    .stamp
                    .compare_exchange_weak(cur, BUSY, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                break;
            }
            std::hint::spin_loop();
        }
        slot.meta.store(meta, Ordering::Relaxed);
        slot.aux.store(aux, Ordering::Relaxed);
        slot.tick.store(ev.tick, Ordering::Relaxed);
        slot.flow.store(ev.flow, Ordering::Relaxed);
        // Release publishes the payload to stamp-acquiring readers.
        slot.stamp.store(seq + 1, Ordering::Release);
        self.by_severity[ev.severity as usize].fetch_add(1, Ordering::Relaxed);
        self.by_detector[ev.detector as usize].fetch_add(1, Ordering::Relaxed);
        self.by_resolution[ev.resolution.slot()].fetch_add(1, Ordering::Relaxed);
    }

    /// Read the event at lifetime sequence `seq`, if it is still
    /// resident and not mid-overwrite.
    fn read_seq(&self, seq: u64) -> Option<FaultEvent> {
        let slot = &self.slots[(seq % self.capacity() as u64) as usize];
        let want = seq + 1;
        if slot.stamp.load(Ordering::Acquire) != want {
            return None;
        }
        let meta = slot.meta.load(Ordering::Relaxed);
        let aux = slot.aux.load(Ordering::Relaxed);
        let tick = slot.tick.load(Ordering::Relaxed);
        let flow = slot.flow.load(Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::Acquire);
        if slot.stamp.load(Ordering::Relaxed) != want {
            return None; // overwritten while reading — skip, never tear
        }
        Some(FaultEvent::decode(meta, aux, tick, flow))
    }

    /// Events with lifetime sequence `>= mark`, oldest first. `mark` is
    /// a prior [`Journal::total`] value; events that wrapped out of the
    /// ring since then are absent (use `total() - mark` for the exact
    /// count). This is the campaign / test query primitive.
    pub fn since(&self, mark: u64) -> Vec<FaultEvent> {
        let total = self.total();
        let start = mark.max(total.saturating_sub(self.capacity() as u64));
        (start..total).filter_map(|s| self.read_seq(s)).collect()
    }

    /// The newest `max` resident events, oldest first.
    pub fn recent(&self, max: usize) -> Vec<FaultEvent> {
        let total = self.total();
        self.since(total.saturating_sub(max.min(self.capacity()) as u64))
    }

    /// Aggregate counters block for `metrics_snapshot()` — exact across
    /// wrap (counters are fed at record time, not derived from the
    /// ring).
    pub fn counts_json(&self) -> Json {
        let n = |c: &AtomicU64| Json::Num(c.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("total", Json::Num(self.total() as f64)),
            ("resident", Json::Num(self.len() as f64)),
            ("capacity", Json::Num(self.capacity() as f64)),
            ("dropped", Json::Num(self.dropped() as f64)),
            (
                "by_severity",
                Json::obj(vec![
                    ("near_bound", n(&self.by_severity[0])),
                    ("significant", n(&self.by_severity[1])),
                ]),
            ),
            (
                "by_detector",
                Json::obj(vec![
                    ("gemm_checksum", n(&self.by_detector[0])),
                    ("gemm_aggregate", n(&self.by_detector[1])),
                    ("eb_bound", n(&self.by_detector[2])),
                    ("scrub_exact", n(&self.by_detector[3])),
                ]),
            ),
            (
                "by_resolution",
                Json::obj(
                    RESOLUTION_KIND_NAMES
                        .iter()
                        .zip(&self.by_resolution)
                        .map(|(&k, c)| (k, n(c)))
                        .collect(),
                ),
            ),
        ])
    }

    /// The `events` server-op payload: counts plus the newest `max`
    /// event rows, and the cursor (`next_cursor`) a poller passes back
    /// as `since_tick` to read only what's new next time.
    pub fn events_json(&self, max: usize) -> Json {
        Json::obj(vec![
            ("counts", self.counts_json()),
            ("next_cursor", Json::Num(self.total() as f64)),
            (
                "events",
                Json::Arr(self.recent(max).iter().map(FaultEvent::to_json).collect()),
            ),
        ])
    }

    /// Events that can no longer be served to a cursor at `since`
    /// because the ring wrapped past it: the count of lost events a
    /// poller would otherwise silently skip.
    pub fn gap_since(&self, since: u64) -> u64 {
        let oldest_resident = self.total().saturating_sub(self.capacity() as u64);
        oldest_resident.saturating_sub(since)
    }

    /// The cursored `events` payload: only events with lifetime sequence
    /// `>= since` (a prior `next_cursor`), newest `max` of them. Pollers
    /// stop re-reading the whole ring every scrape. When the ring has
    /// wrapped past the cursor, `gap` reports exactly how many events
    /// between the cursor and the oldest resident row were lost —
    /// resuming is explicit, never silent (`gap` is 0 when nothing was
    /// missed; rows trimmed by `max` are still resident, so they are
    /// pageable, not gapped).
    pub fn events_json_since(&self, since: u64, max: usize) -> Json {
        let mut rows = self.since(since);
        if rows.len() > max {
            rows.drain(..rows.len() - max);
        }
        Json::obj(vec![
            ("counts", self.counts_json()),
            ("next_cursor", Json::Num(self.total() as f64)),
            ("gap", Json::Num(self.gap_since(since) as f64)),
            (
                "events",
                Json::Arr(rows.iter().map(FaultEvent::to_json).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detect::event::{Detector, Resolution, Severity, SiteId, UnitRef};
    use crate::detect::Recovery;

    fn ev(i: u32) -> FaultEvent {
        FaultEvent {
            tick: i as u64,
            ctl_tick: (i / 4) as u64,
            flow: (i as u64) * 3,
            site: SiteId::Eb(i % 3),
            unit: UnitRef::GemmRow { row: i },
            detector: Detector::GemmChecksum,
            severity: if i % 2 == 0 { Severity::NearBound } else { Severity::Significant },
            resolution: Resolution::Recovered(Recovery::RecomputeUnit),
        }
    }

    #[test]
    fn records_and_reads_back_in_order() {
        let j = Journal::with_capacity(16);
        for i in 0..5 {
            j.record(&ev(i));
        }
        assert_eq!(j.total(), 5);
        assert_eq!(j.len(), 5);
        assert_eq!(j.dropped(), 0);
        let got = j.since(0);
        assert_eq!(got.len(), 5);
        for (i, e) in got.iter().enumerate() {
            assert_eq!(*e, ev(i as u32));
        }
        assert_eq!(j.since(3).len(), 2);
        assert_eq!(j.recent(2), j.since(3));
    }

    #[test]
    fn wrap_keeps_newest_and_exact_totals() {
        let j = Journal::with_capacity(8);
        for i in 0..20 {
            j.record(&ev(i));
        }
        assert_eq!(j.total(), 20);
        assert_eq!(j.len(), 8);
        assert_eq!(j.dropped(), 12);
        let got = j.since(0);
        assert_eq!(got.len(), 8, "only the resident tail survives wrap");
        for (k, e) in got.iter().enumerate() {
            assert_eq!(*e, ev(12 + k as u32), "oldest-first tail");
        }
        // Aggregates never lose wrapped events.
        let c = j.counts_json();
        assert_eq!(c.path(&["by_severity", "near_bound"]).and_then(Json::as_usize), Some(10));
        assert_eq!(c.path(&["by_severity", "significant"]).and_then(Json::as_usize), Some(10));
        assert_eq!(c.get("dropped").and_then(Json::as_usize), Some(12));
    }

    #[test]
    fn concurrent_writers_never_tear_readers() {
        use std::sync::Arc;
        let j = Arc::new(Journal::with_capacity(32));
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let j = Arc::clone(&j);
                std::thread::spawn(move || {
                    for i in 0..500u32 {
                        j.record(&ev(w * 1000 + i));
                    }
                })
            })
            .collect();
        // Reader races the writers; every event it sees must decode to a
        // value some writer actually wrote (tick == row field by
        // construction of `ev`).
        for _ in 0..200 {
            for e in j.recent(32) {
                if let UnitRef::GemmRow { row } = e.unit {
                    assert_eq!(e.tick, row as u64, "torn slot surfaced");
                } else {
                    panic!("impossible unit decoded: {e:?}");
                }
            }
        }
        for w in writers {
            w.join().unwrap();
        }
        assert_eq!(j.total(), 2000);
    }

    #[test]
    fn events_json_shape() {
        let j = Journal::with_capacity(4);
        j.record(&ev(1));
        let doc = j.events_json(8);
        assert_eq!(doc.path(&["counts", "total"]).and_then(Json::as_usize), Some(1));
        assert!(matches!(doc.get("events"), Some(Json::Arr(a)) if a.len() == 1));
        assert_eq!(doc.get("next_cursor").and_then(Json::as_usize), Some(1));
    }

    #[test]
    fn cursored_events_return_only_whats_new() {
        let j = Journal::with_capacity(16);
        for i in 0..5 {
            j.record(&ev(i));
        }
        let first = j.events_json_since(0, 100);
        assert_eq!(first.get("events").and_then(Json::as_arr).unwrap().len(), 5);
        let cursor = first.get("next_cursor").and_then(Json::as_usize).unwrap() as u64;
        assert_eq!(cursor, 5);
        // Nothing new → empty page, cursor unchanged.
        let empty = j.events_json_since(cursor, 100);
        assert!(empty.get("events").and_then(Json::as_arr).unwrap().is_empty());
        assert_eq!(empty.get("next_cursor").and_then(Json::as_usize), Some(5));
        // Two more events → exactly those two.
        j.record(&ev(5));
        j.record(&ev(6));
        let page = j.events_json_since(cursor, 100);
        let rows = page.get("events").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        // `max` keeps the newest rows of the page.
        let capped = j.events_json_since(0, 2);
        assert_eq!(capped.get("events").and_then(Json::as_arr).unwrap().len(), 2);
        // Nothing wrapped in any of these queries.
        assert_eq!(first.get("gap").and_then(Json::as_usize), Some(0));
        assert_eq!(capped.get("gap").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn wrapped_cursor_reports_an_explicit_gap() {
        let j = Journal::with_capacity(8);
        for i in 0..3 {
            j.record(&ev(i));
        }
        let cursor = j.total(); // 3
        // 13 more events: ring holds seqs 8..16, so 8 − 3 = 5 events the
        // cursor can never see.
        for i in 3..16 {
            j.record(&ev(i));
        }
        let page = j.events_json_since(cursor, 100);
        assert_eq!(page.get("gap").and_then(Json::as_usize), Some(5));
        let rows = page.get("events").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 8, "resident tail still served");
        assert_eq!(
            rows[0].get("tick").and_then(Json::as_usize),
            Some(8),
            "page resumes at the oldest resident event"
        );
        // A fresh cursor at total sees no gap.
        let fresh = j.events_json_since(j.total(), 100);
        assert_eq!(fresh.get("gap").and_then(Json::as_usize), Some(0));
        // Flow IDs survive the journal round trip.
        assert_eq!(rows[0].get("flow").and_then(Json::as_usize), Some(24));
    }
}
