//! The recovery ladder: one ordered menu of recovery actions, with
//! per-site-class applicability.
//!
//! Before PR 5 every detection site hand-rolled its own recovery —
//! `abft/gemm.rs` recomputed a row and re-requantized it, the shard
//! router retried on the same replica then failed the shard-batch over,
//! the engine retried a whole batch for the BoundOnly aggregate, the
//! scrubber quarantined on a hit. Those are all rungs of **one** ladder,
//! ordered cheapest-first:
//!
//! ```text
//!   CorrectInPlace → RecomputeUnit → RetryBatch → FailoverReplica
//!                  → QuarantineAndRepair → Degrade
//! ```
//!
//! PR 6 added `CorrectInPlace` at the top: where the detector layout can
//! *localize* the fault (GEMM group partial checksums naming the corrupt
//! accumulator entry, the dual EB checksum resolving a corrupt store row
//! to one slot), the fix is algebraic and in place — no recompute, no
//! failover — and is always re-verified before anything is served. A
//! failed re-verify (multi-fault) falls to the next rung like any other.
//!
//! A site class walks only the rungs that make sense for it
//! ([`ladder`]): a local GEMM row cannot fail over (there is no replica
//! of the engine's weights), a sharded bag does not batch-retry (the
//! router's failover re-serves the shard-batch from a sibling, which
//! dominates it), and a scrub hit goes straight to quarantine (the row
//! was not being served, so there is nothing to recompute). The walk's
//! terminal state is what a [`crate::detect::Resolution`] records:
//! `Recovered(step)` when a rung's re-check passed, `Escalated(step)`
//! when the next rung belongs to an outer layer (the engine owns
//! `RetryBatch`), `Degraded` when the ladder is exhausted.
//!
//! Keeping the order and applicability *here* — and making every site
//! consult [`next_step`] — is what lets a new scenario (a new detector,
//! a new recovery rung) be added in one place instead of five.

use crate::abft::AbftGemm;
use crate::quant::{requantize_cols_into, RequantEpilogue};

/// One rung of the recovery ladder, ordered cheapest-first. The
/// discriminants are the wire encoding ([`crate::detect::FaultEvent`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Recovery {
    /// Fix the localized fault algebraically in place (GEMM: rewrite the
    /// one corrupt i32 accumulator entry named by the group partial
    /// checksums; EB store: rewrite the one corrupt row slot the dual
    /// checksum resolves) and re-verify. The only rung that costs less
    /// than the unit's original computation.
    CorrectInPlace = 0,
    /// Recompute the single implicated unit (GEMM row + re-requantize;
    /// EB bag re-gather on the same replica). Clears transient
    /// compute/bus faults.
    RecomputeUnit = 1,
    /// Re-run the whole batch's forward pass (the engine's rung — the
    /// only recovery that can follow a non-localizing aggregate flag).
    RetryBatch = 2,
    /// Re-serve the whole shard-batch from a healthy sibling replica
    /// (sharded EB only; everything the corrupt replica computed is
    /// suspect).
    FailoverReplica = 3,
    /// Quarantine the corrupted replica and queue a checksum-verified
    /// repair (sharded stores; pairs with [`Recovery::FailoverReplica`]
    /// on the serving path, stands alone for scrub hits).
    QuarantineAndRepair = 4,
    /// Serve the value anyway and mark the batch degraded — the ladder's
    /// explicit floor, never silent.
    Degrade = 5,
}

/// Number of [`Recovery`] rungs (aggregate-counter sizing).
pub const RECOVERY_STEPS: usize = 6;

impl Recovery {
    pub fn as_str(self) -> &'static str {
        match self {
            Recovery::CorrectInPlace => "correct_in_place",
            Recovery::RecomputeUnit => "recompute_unit",
            Recovery::RetryBatch => "retry_batch",
            Recovery::FailoverReplica => "failover_replica",
            Recovery::QuarantineAndRepair => "quarantine_and_repair",
            Recovery::Degrade => "degrade",
        }
    }

    /// Inverse of the `repr(u8)` discriminant (wire decode).
    pub fn from_index(i: usize) -> Self {
        match i {
            0 => Recovery::CorrectInPlace,
            1 => Recovery::RecomputeUnit,
            2 => Recovery::RetryBatch,
            3 => Recovery::FailoverReplica,
            4 => Recovery::QuarantineAndRepair,
            _ => Recovery::Degrade,
        }
    }
}

/// The detection-site classes the ladder is filtered by.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteClass {
    /// Local (engine-owned) GEMM row verification.
    GemmRow,
    /// The BoundOnly batch-aggregate GEMM check — cannot localize, so
    /// no per-unit rung applies.
    GemmAggregate,
    /// Local (unsharded) EmbeddingBag verification.
    EbLocal,
    /// Shard-router EmbeddingBag verification over replicas.
    EbSharded,
    /// Scrubber hit on a shard replica.
    ScrubSharded,
    /// Scrubber hit on the engine's own tables — repair is an operator
    /// action (see `resilience_integration.rs`), nothing automatic.
    ScrubLocal,
}

/// The rungs applicable to one site class, in ladder order.
pub fn ladder(class: SiteClass) -> &'static [Recovery] {
    use Recovery::*;
    match class {
        SiteClass::GemmRow => &[CorrectInPlace, RecomputeUnit, RetryBatch, Degrade],
        SiteClass::GemmAggregate => &[RetryBatch, Degrade],
        SiteClass::EbLocal => &[RecomputeUnit, RetryBatch, Degrade],
        SiteClass::EbSharded => &[RecomputeUnit, FailoverReplica, QuarantineAndRepair, Degrade],
        SiteClass::ScrubSharded => &[CorrectInPlace, QuarantineAndRepair],
        SiteClass::ScrubLocal => &[],
    }
}

/// The first rung of a class's ladder, if any (an empty ladder means the
/// event resolves [`crate::detect::Resolution::DetectedOnly`]).
pub fn first_step(class: SiteClass) -> Option<Recovery> {
    ladder(class).first().copied()
}

/// The rung after `after` in `class`'s ladder, or `None` when `after` is
/// the class's last (or not applicable at all — a misuse that resolves
/// to "nothing further").
pub fn next_step(class: SiteClass, after: Recovery) -> Option<Recovery> {
    let steps = ladder(class);
    steps
        .iter()
        .position(|&s| s == after)
        .and_then(|i| steps.get(i + 1).copied())
}

/// The `RecomputeUnit` rung for a flagged GEMM row, shared by every
/// caller that used to hand-roll it: recompute the row's `C_temp` from A
/// and the packed (encoded) B through the production kernel, re-verify
/// Eq 3b on the repaired accumulator, and re-requantize the row so the
/// output equals the two-pass requantize-after-recompute flow
/// bit-for-bit. Returns whether the row verifies clean afterwards
/// (`false` ⇒ the operand itself is corrupt; the caller escalates to the
/// next applicable rung).
pub fn recompute_gemm_row(
    abft: &AbftGemm,
    x: &[u8],
    row: usize,
    m: usize,
    epi: &RequantEpilogue<'_>,
    c_temp: &mut [i32],
    out: &mut [u8],
) -> bool {
    let n = abft.n;
    let nt = abft.n_total();
    abft.recompute_row(x, row, c_temp, m);
    requantize_cols_into(
        &c_temp[row * nt..(row + 1) * nt],
        1,
        nt,
        0..n,
        &epi.a_row_sums[row..row + 1],
        epi.b_col_sums,
        &epi.spec,
        epi.relu_floor,
        &mut out[row * n..(row + 1) * n],
    );
    crate::abft::gemm::row_ok(&c_temp[row * nt..(row + 1) * nt], n, abft.modulus)
}

/// The `CorrectInPlace` rung for a flagged GEMM row: algebraic
/// localization + single-entry fix ([`AbftGemm::correct_row`]), then —
/// only when the fix re-verified clean — re-requantize the row so the
/// served bytes equal the recompute flow bit-for-bit. Returns the
/// [`RowCorrection`] so the caller can emit the delta as severity
/// evidence; on any decline `out` is untouched and the caller falls to
/// [`recompute_gemm_row`].
pub fn correct_gemm_row(
    abft: &AbftGemm,
    x: &[u8],
    row: usize,
    m: usize,
    epi: &RequantEpilogue<'_>,
    c_temp: &mut [i32],
    out: &mut [u8],
) -> crate::abft::RowCorrection {
    let n = abft.n;
    let nt = abft.n_total();
    let got = abft.correct_row(x, row, c_temp, m);
    if got.corrected() {
        requantize_cols_into(
            &c_temp[row * nt..(row + 1) * nt],
            1,
            nt,
            0..n,
            &epi.a_row_sums[row..row + 1],
            epi.b_col_sums,
            &epi.spec,
            epi.relu_floor,
            &mut out[row * n..(row + 1) * n],
        );
    }
    got
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_order_is_global_and_monotone() {
        // Every class's ladder is a subsequence of the one global order.
        for class in [
            SiteClass::GemmRow,
            SiteClass::GemmAggregate,
            SiteClass::EbLocal,
            SiteClass::EbSharded,
            SiteClass::ScrubSharded,
            SiteClass::ScrubLocal,
        ] {
            let steps = ladder(class);
            for w in steps.windows(2) {
                assert!(w[0] < w[1], "{class:?}: {steps:?} out of ladder order");
            }
        }
    }

    #[test]
    fn per_class_applicability() {
        // Local sites have no replica to fail over to.
        assert!(!ladder(SiteClass::GemmRow).contains(&Recovery::FailoverReplica));
        assert!(!ladder(SiteClass::EbLocal).contains(&Recovery::FailoverReplica));
        // The aggregate cannot name a row, so no per-unit recompute.
        assert_eq!(first_step(SiteClass::GemmAggregate), Some(Recovery::RetryBatch));
        // Sharded bags escalate recompute → failover (not batch retry).
        assert_eq!(
            next_step(SiteClass::EbSharded, Recovery::RecomputeUnit),
            Some(Recovery::FailoverReplica)
        );
        assert_eq!(
            next_step(SiteClass::EbSharded, Recovery::QuarantineAndRepair),
            Some(Recovery::Degrade)
        );
        // Scrub hits try the algebraic self-heal first (sharded), then
        // quarantine; local scrub reports only.
        assert_eq!(first_step(SiteClass::ScrubSharded), Some(Recovery::CorrectInPlace));
        assert_eq!(
            next_step(SiteClass::ScrubSharded, Recovery::CorrectInPlace),
            Some(Recovery::QuarantineAndRepair)
        );
        assert_eq!(first_step(SiteClass::ScrubLocal), None);
        // Flagged GEMM rows try the in-place fix before recomputing.
        assert_eq!(first_step(SiteClass::GemmRow), Some(Recovery::CorrectInPlace));
        assert_eq!(
            next_step(SiteClass::GemmRow, Recovery::CorrectInPlace),
            Some(Recovery::RecomputeUnit)
        );
        // Last rungs terminate.
        assert_eq!(next_step(SiteClass::GemmRow, Recovery::Degrade), None);
        assert_eq!(next_step(SiteClass::ScrubSharded, Recovery::QuarantineAndRepair), None);
    }

    #[test]
    fn from_index_roundtrip() {
        for i in 0..RECOVERY_STEPS {
            assert_eq!(Recovery::from_index(i) as usize, i);
        }
    }
}
