//! Typed fault events: the single vocabulary every detection site speaks.
//!
//! Before PR 5 a detection was a loose boolean / counter bump whose
//! meaning depended on which of five sites raised it (GEMM row verify,
//! the fused EB bag check, the shard router's per-bag loop, the
//! scrubber, the BoundOnly batch aggregate). A [`FaultEvent`] makes the
//! detection first-class: *where* it fired ([`SiteId`]), *what* unit was
//! implicated ([`UnitRef`]), *which* detector tripped ([`Detector`]),
//! *how bad* it looks ([`Severity`]), and *what the pipeline did about
//! it* ([`Resolution`]). Every event is journaled
//! ([`crate::detect::Journal`]) with the tick it occurred on, so fault
//! attribution is a query instead of archaeology across counter
//! families.
//!
//! # Severity classification
//!
//! The paper's Table III splits EB faults by bit significance; PR 5
//! generalizes that split to every detector:
//!
//! * **EB (Eq 5)** — by the margin ratio `excess / threshold` of the
//!   relative-bound check: a flag within [`EB_SIGNIFICANT_MARGIN`]× of
//!   the bound is [`Severity::NearBound`] (plausibly a low-significance
//!   bit riding the round-off edge); anything further out is
//!   [`Severity::Significant`].
//! * **GEMM (Eq 3b)** — by the **recompute-referenced delta**: the Eq-3b
//!   residual is only meaningful mod 127 on its own, but the
//!   `RecomputeUnit` rung yields a clean reference, and the residual
//!   shift across it is exactly the injected corruption. Deltas below
//!   [`GEMM_SIGNIFICANT_DELTA`] are smaller than one requantization step
//!   at production shapes — they usually cannot move the served u8 code
//!   ([`Severity::NearBound`]); larger deltas, and every flag without a
//!   reference (persistent operand corruption, detect-only modes, the
//!   aggregate), classify worst-case as [`Severity::Significant`].
//! * **Scrub (exact `C_T` compare)** — by the integer code-sum delta:
//!   [`SCRUB_SIGNIFICANT_DELTA`] (= 16) reproduces Table III's
//!   high-4-bits / low-4-bits significance split.

use crate::util::json::Json;

/// Which protected operator instance raised the event. Indices follow
/// the policy site spaces: GEMM sites in model layer order (bottom
/// layers, top layers, head), EB sites by global table id.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteId {
    /// MLP layer `i` (flat model layer order).
    Gemm(u32),
    /// Embedding table `t` (global table id).
    Eb(u32),
}

impl SiteId {
    /// Stable human/JSON label, e.g. `gemm/2`, `eb/0`.
    pub fn label(self) -> String {
        match self {
            SiteId::Gemm(i) => format!("gemm/{i}"),
            SiteId::Eb(t) => format!("eb/{t}"),
        }
    }
}

/// Replica index standing for "the engine's own (unsharded) copy".
pub const LOCAL_REPLICA: u32 = u32::MAX;

/// The unit of work the detector implicated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnitRef {
    /// One row of the protected GEMM's output tile.
    GemmRow { row: u32 },
    /// One pooled bag of one request. `replica` is the shard replica the
    /// bag was computed on, or [`LOCAL_REPLICA`] for the unsharded path.
    Bag { request: u32, replica: u32 },
    /// One table row found by the background scrubber. `replica` as for
    /// [`UnitRef::Bag`].
    ScrubSlot { replica: u32, row: u32 },
    /// The whole batch tile (the `BoundOnly` aggregate cannot name a
    /// row).
    BatchAggregate,
}

/// Which check tripped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Detector {
    /// Eq-3b per-row GEMM checksum (`Full` / `Sampled` modes).
    GemmChecksum,
    /// Eq-3b batch-aggregate congruence (`BoundOnly` mode).
    GemmAggregate,
    /// Eq-5 EmbeddingBag relative float bound (fused serving check).
    EbBound,
    /// Exact integer `C_T` compare (the scrubber).
    ScrubExact,
}

impl Detector {
    pub fn as_str(self) -> &'static str {
        match self {
            Detector::GemmChecksum => "gemm_checksum",
            Detector::GemmAggregate => "gemm_aggregate",
            Detector::EbBound => "eb_bound",
            Detector::ScrubExact => "scrub_exact",
        }
    }
}

/// GEMM residual magnitude at or above which a flag is
/// [`Severity::Significant`]: at production shapes a smaller delta is
/// below one requantization step, so it usually cannot move the served
/// byte.
pub const GEMM_SIGNIFICANT_DELTA: i64 = 1 << 12;

/// Eq-5 `excess / threshold` ratio at or above which an EB flag is
/// [`Severity::Significant`].
pub const EB_SIGNIFICANT_MARGIN: f64 = 32.0;

/// Scrub code-sum delta at or above which a hit is
/// [`Severity::Significant`] — a flip in the upper 4 bits of a u8 code
/// moves the row sum by ≥ 16 (the paper's Table-III significance
/// split).
pub const SCRUB_SIGNIFICANT_DELTA: i64 = 16;

/// How far past its detection threshold the flag landed. Ordered:
/// `NearBound < Significant`, so severity floors (e.g. the flight
/// recorder's freeze threshold) are plain comparisons.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Barely past the threshold — plausibly a low-significance bit.
    NearBound,
    /// Clearly past the threshold — a significant-bit corruption.
    Significant,
}

impl Severity {
    /// Classify a GEMM row/aggregate residual (`Σ C − checksum`, i64).
    pub fn from_gemm_delta(delta: i64) -> Self {
        if delta.unsigned_abs() >= GEMM_SIGNIFICANT_DELTA as u64 {
            Severity::Significant
        } else {
            Severity::NearBound
        }
    }

    /// Classify an Eq-5 flag by its margin ratio. `threshold` is the
    /// bound side (`rel_bound · bound_scale · scale`); callers only
    /// invoke this on flagged bags, where `excess > threshold`.
    pub fn from_eb_margin(excess: f64, threshold: f64) -> Self {
        if excess >= EB_SIGNIFICANT_MARGIN * threshold.max(f64::MIN_POSITIVE) {
            Severity::Significant
        } else {
            Severity::NearBound
        }
    }

    /// Classify a scrub hit by its exact code-sum delta.
    pub fn from_code_delta(delta: i64) -> Self {
        if delta.unsigned_abs() >= SCRUB_SIGNIFICANT_DELTA as u64 {
            Severity::Significant
        } else {
            Severity::NearBound
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Severity::NearBound => "near_bound",
            Severity::Significant => "significant",
        }
    }

    /// Inverse of [`Severity::as_str`] (CLI / config parsing).
    pub fn from_label(s: &str) -> Option<Severity> {
        match s {
            "near_bound" => Some(Severity::NearBound),
            "significant" => Some(Severity::Significant),
            _ => None,
        }
    }
}

pub use crate::detect::recovery::Recovery;

/// What the pipeline did about the detection — the terminal state of the
/// unit's walk down the recovery ladder (see [`crate::detect::recovery`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Resolution {
    /// Detect-only protection (or the unsharded scrubber): reported, no
    /// automatic recovery — the value was served / left as-is.
    DetectedOnly,
    /// The named ladder step recovered the unit (its re-check passed or
    /// a clean replica took over).
    Recovered(Recovery),
    /// Local steps exhausted; the named next ladder step is owned by an
    /// outer layer (e.g. the engine's batch retry) and will run after
    /// this event is recorded.
    Escalated(Recovery),
    /// The ladder is exhausted — the corrupted unit was served and the
    /// batch marked degraded.
    Degraded,
}

impl Resolution {
    /// The terminal state of a failed local rung: `Escalated(step)` when
    /// the ladder names a next rung (owned by an outer layer), else the
    /// explicit `Degraded` floor. The one place the escalate-or-degrade
    /// decision lives — sites pass `recovery::next_step(..)` /
    /// `recovery::first_step(..)` straight in.
    pub fn escalated_or_degraded(step: Option<Recovery>) -> Self {
        match step {
            Some(step) => Resolution::Escalated(step),
            None => Resolution::Degraded,
        }
    }

    /// Human/JSON label, e.g. `recovered:failover_replica`.
    pub fn label(self) -> String {
        match self {
            Resolution::DetectedOnly => "detected_only".to_string(),
            Resolution::Recovered(r) => format!("recovered:{}", r.as_str()),
            Resolution::Escalated(r) => format!("escalated:{}", r.as_str()),
            Resolution::Degraded => "degraded".to_string(),
        }
    }

    /// Aggregate-counter slot ([`RESOLUTION_SLOTS`]): the four terminal
    /// kinds, step elided.
    pub fn slot(self) -> usize {
        match self {
            Resolution::DetectedOnly => 0,
            Resolution::Recovered(_) => 1,
            Resolution::Escalated(_) => 2,
            Resolution::Degraded => 3,
        }
    }

    pub fn kind_str(self) -> &'static str {
        RESOLUTION_KIND_NAMES[self.slot()]
    }
}

/// Number of [`Resolution::slot`] values.
pub const RESOLUTION_SLOTS: usize = 4;
pub const RESOLUTION_KIND_NAMES: [&str; RESOLUTION_SLOTS] =
    ["detected_only", "recovered", "escalated", "degraded"];

/// Number of [`Detector`] variants (aggregate-counter sizing).
pub const DETECTOR_SLOTS: usize = 4;

/// One first-class detection event. Produced at the detection site,
/// fanned out by [`crate::detect::EventSink`], persisted in the
/// [`crate::detect::Journal`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Journal tick the event was recorded on (the engine advances the
    /// tick once per scored batch; standalone emitters leave it at 0).
    pub tick: u64,
    /// Controller tick the event was recorded under — the policy
    /// controller's step counter at emit time, stamped by the sink so a
    /// journal row correlates directly with the controller decision
    /// window that saw it (0 when no controller is attached). Truncated
    /// to [`CTL_TICK_MASK`] on the wire.
    pub ctl_tick: u64,
    /// Flow (request/batch) ID the emitting thread was working under
    /// ([`crate::obs::flow`]), stamped by the sink at emit time; 0 when
    /// unattributed (background scrubbers, standalone emitters). This is
    /// what correlates an event with its request's span timeline in a
    /// flight-recorder capture. Carried in its own journal word — the
    /// `(meta, aux)` pair is fully packed.
    pub flow: u64,
    pub site: SiteId,
    pub unit: UnitRef,
    pub detector: Detector,
    pub severity: Severity,
    pub resolution: Resolution,
}

// ---- packed wire format (journal slots are plain AtomicU64s) ----------
//
// meta word layout (low → high):
//   bit  0      site kind (0 = Gemm, 1 = Eb)
//   bits 1..25  site index (24 bits)
//   bits 25..27 unit kind  (0 GemmRow, 1 Bag, 2 ScrubSlot, 3 Aggregate)
//   bits 27..29 detector
//   bit  29     severity   (0 NearBound, 1 Significant)
//   bits 30..32 resolution kind
//   bits 32..35 resolution step (Recovery)
//   bits 35..64 controller tick (29 bits, truncated)
// aux word: unit payload — low u32 = row / request, high u32 = replica.
// The flow ID does not fit here; journal slots carry it in a dedicated
// word, threaded back through `decode`'s `flow` parameter.

const SITE_IDX_MASK: u64 = (1 << 24) - 1;

/// Controller-tick wire width: 29 bits. At one controller step per
/// policy interval this wraps after ~537M steps — far beyond any serve
/// lifetime; correlation queries only care about recency anyway.
pub const CTL_TICK_MASK: u64 = (1 << 29) - 1;

impl FaultEvent {
    /// Pack into the journal's `(meta, aux)` words. Lossless for site
    /// indices < 2^24 and unit coordinates < 2^32 (both far above any
    /// real deployment; asserted in debug builds).
    pub fn encode(&self) -> (u64, u64) {
        let (site_kind, site_idx) = match self.site {
            SiteId::Gemm(i) => (0u64, i as u64),
            SiteId::Eb(t) => (1u64, t as u64),
        };
        debug_assert!(site_idx <= SITE_IDX_MASK, "site index overflows packing");
        let (unit_kind, lo, hi) = match self.unit {
            UnitRef::GemmRow { row } => (0u64, row, 0),
            UnitRef::Bag { request, replica } => (1, request, replica),
            UnitRef::ScrubSlot { replica, row } => (2, row, replica),
            UnitRef::BatchAggregate => (3, 0, 0),
        };
        let det = self.detector as u64;
        let sev = match self.severity {
            Severity::NearBound => 0u64,
            Severity::Significant => 1,
        };
        let (res_kind, res_step) = match self.resolution {
            Resolution::DetectedOnly => (0u64, 0u64),
            Resolution::Recovered(r) => (1, r as u64),
            Resolution::Escalated(r) => (2, r as u64),
            Resolution::Degraded => (3, 0),
        };
        debug_assert!(res_step <= 0b111, "resolution step overflows packing");
        let meta = site_kind
            | (site_idx & SITE_IDX_MASK) << 1
            | unit_kind << 25
            | det << 27
            | sev << 29
            | res_kind << 30
            | res_step << 32
            | (self.ctl_tick & CTL_TICK_MASK) << 35;
        (meta, lo as u64 | (hi as u64) << 32)
    }

    /// Inverse of [`FaultEvent::encode`]; `tick` and `flow` ride their
    /// own journal words.
    pub fn decode(meta: u64, aux: u64, tick: u64, flow: u64) -> Self {
        let site_idx = ((meta >> 1) & SITE_IDX_MASK) as u32;
        let site = if meta & 1 == 0 {
            SiteId::Gemm(site_idx)
        } else {
            SiteId::Eb(site_idx)
        };
        let lo = aux as u32;
        let hi = (aux >> 32) as u32;
        let unit = match (meta >> 25) & 0b11 {
            0 => UnitRef::GemmRow { row: lo },
            1 => UnitRef::Bag { request: lo, replica: hi },
            2 => UnitRef::ScrubSlot { replica: hi, row: lo },
            _ => UnitRef::BatchAggregate,
        };
        let detector = match (meta >> 27) & 0b11 {
            0 => Detector::GemmChecksum,
            1 => Detector::GemmAggregate,
            2 => Detector::EbBound,
            _ => Detector::ScrubExact,
        };
        let severity = if (meta >> 29) & 1 == 0 {
            Severity::NearBound
        } else {
            Severity::Significant
        };
        let step = Recovery::from_index(((meta >> 32) & 0b111) as usize);
        let resolution = match (meta >> 30) & 0b11 {
            0 => Resolution::DetectedOnly,
            1 => Resolution::Recovered(step),
            2 => Resolution::Escalated(step),
            _ => Resolution::Degraded,
        };
        let ctl_tick = meta >> 35;
        Self { tick, ctl_tick, flow, site, unit, detector, severity, resolution }
    }

    /// JSON row for the `events` server op.
    pub fn to_json(&self) -> Json {
        let unit = match self.unit {
            UnitRef::GemmRow { row } => Json::obj(vec![
                ("kind", Json::Str("gemm_row".into())),
                ("row", Json::Num(row as f64)),
            ]),
            UnitRef::Bag { request, replica } => Json::obj(vec![
                ("kind", Json::Str("bag".into())),
                ("request", Json::Num(request as f64)),
                (
                    "replica",
                    if replica == LOCAL_REPLICA {
                        Json::Str("local".into())
                    } else {
                        Json::Num(replica as f64)
                    },
                ),
            ]),
            UnitRef::ScrubSlot { replica, row } => Json::obj(vec![
                ("kind", Json::Str("scrub_slot".into())),
                ("row", Json::Num(row as f64)),
                (
                    "replica",
                    if replica == LOCAL_REPLICA {
                        Json::Str("local".into())
                    } else {
                        Json::Num(replica as f64)
                    },
                ),
            ]),
            UnitRef::BatchAggregate => {
                Json::obj(vec![("kind", Json::Str("batch_aggregate".into()))])
            }
        };
        Json::obj(vec![
            ("tick", Json::Num(self.tick as f64)),
            ("ctl_tick", Json::Num(self.ctl_tick as f64)),
            ("flow", Json::Num(self.flow as f64)),
            ("site", Json::Str(self.site.label())),
            ("unit", unit),
            ("detector", Json::Str(self.detector.as_str().into())),
            ("severity", Json::Str(self.severity.as_str().into())),
            ("resolution", Json::Str(self.resolution.label())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<FaultEvent> {
        vec![
            FaultEvent {
                tick: 0,
                ctl_tick: 0,
                flow: 11,
                site: SiteId::Gemm(0),
                unit: UnitRef::GemmRow { row: 7 },
                detector: Detector::GemmChecksum,
                severity: Severity::Significant,
                resolution: Resolution::Recovered(Recovery::CorrectInPlace),
            },
            FaultEvent {
                tick: 42,
                ctl_tick: 17,
                flow: 12,
                site: SiteId::Eb(3),
                unit: UnitRef::Bag { request: 5, replica: 1 },
                detector: Detector::EbBound,
                severity: Severity::NearBound,
                resolution: Resolution::Recovered(Recovery::FailoverReplica),
            },
            FaultEvent {
                tick: u32::MAX as u64 + 9,
                ctl_tick: CTL_TICK_MASK,
                flow: 0,
                site: SiteId::Eb(2),
                unit: UnitRef::ScrubSlot { replica: LOCAL_REPLICA, row: 3_999_999 },
                detector: Detector::ScrubExact,
                severity: Severity::Significant,
                resolution: Resolution::DetectedOnly,
            },
            FaultEvent {
                tick: 1,
                ctl_tick: 3,
                flow: 13,
                site: SiteId::Gemm(6),
                unit: UnitRef::BatchAggregate,
                detector: Detector::GemmAggregate,
                severity: Severity::NearBound,
                resolution: Resolution::Escalated(Recovery::RetryBatch),
            },
            FaultEvent {
                tick: 2,
                ctl_tick: 0,
                flow: 14,
                site: SiteId::Eb(0),
                unit: UnitRef::Bag { request: 0, replica: LOCAL_REPLICA },
                detector: Detector::EbBound,
                severity: Severity::Significant,
                resolution: Resolution::Degraded,
            },
        ]
    }

    #[test]
    fn encode_roundtrips_every_variant() {
        for ev in sample_events() {
            let (meta, aux) = ev.encode();
            assert_eq!(FaultEvent::decode(meta, aux, ev.tick, ev.flow), ev);
        }
    }

    #[test]
    fn severity_thresholds_split_significance() {
        assert_eq!(Severity::from_gemm_delta(5), Severity::NearBound);
        assert_eq!(Severity::from_gemm_delta(-(1 << 12)), Severity::Significant);
        assert_eq!(Severity::from_gemm_delta(1 << 20), Severity::Significant);
        // Table-III split: upper-nibble code flips move the sum by ≥ 16.
        assert_eq!(Severity::from_code_delta(1), Severity::NearBound);
        assert_eq!(Severity::from_code_delta(-128), Severity::Significant);
        assert_eq!(Severity::from_code_delta(15), Severity::NearBound);
        assert_eq!(Severity::from_code_delta(16), Severity::Significant);
        // EB margin ratio.
        assert_eq!(Severity::from_eb_margin(1.5, 1.0), Severity::NearBound);
        assert_eq!(Severity::from_eb_margin(64.0, 1.0), Severity::Significant);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(SiteId::Gemm(2).label(), "gemm/2");
        assert_eq!(SiteId::Eb(0).label(), "eb/0");
        assert_eq!(
            Resolution::Recovered(Recovery::QuarantineAndRepair).label(),
            "recovered:quarantine_and_repair"
        );
        assert_eq!(
            Resolution::Recovered(Recovery::CorrectInPlace).label(),
            "recovered:correct_in_place"
        );
        assert_eq!(Resolution::Escalated(Recovery::RetryBatch).label(), "escalated:retry_batch");
        assert_eq!(Resolution::DetectedOnly.label(), "detected_only");
        assert_eq!(Resolution::Degraded.label(), "degraded");
    }

    #[test]
    fn json_rows_carry_every_field() {
        let ev = &sample_events()[1];
        let j = ev.to_json();
        assert_eq!(j.get("ctl_tick").and_then(Json::as_usize), Some(17));
        assert_eq!(j.get("flow").and_then(Json::as_usize), Some(12));
        assert_eq!(j.get("site").and_then(Json::as_str), Some("eb/3"));
        assert_eq!(j.get("detector").and_then(Json::as_str), Some("eb_bound"));
        assert_eq!(j.get("severity").and_then(Json::as_str), Some("near_bound"));
        assert_eq!(
            j.get("resolution").and_then(Json::as_str),
            Some("recovered:failover_replica")
        );
        assert_eq!(j.path(&["unit", "request"]).and_then(Json::as_usize), Some(5));
    }
}
