//! Requantization: combine the 32-bit integer product `C_temp = A_I·B_I`
//! with the rank-1 correction terms of Eq 1 and emit the quantized output
//! tuple `(C_I, α_C, β_C)` (paper Fig 1).
//!
//! `AB ≈ α_A α_B A_I B_I
//!      + α_A β_B (A_I e_k) e_nᵀ      (row sums of A_I)
//!      + α_B β_A e_m (e_kᵀ B_I)      (column sums of B_I)
//!      + k β_A β_B e_m e_nᵀ`
//!
//! The paper's ABFT checksum column lives in `C_temp` and is *excluded*
//! from requantization (§IV-A3); `requantize_exclude_last_col` implements
//! exactly that.

use super::QParams;
use std::sync::Arc;

/// Everything the requantization step needs besides `C_temp`.
#[derive(Clone, Debug)]
pub struct RequantParams {
    pub a: QParams,
    pub b: QParams,
    /// Output lattice.
    pub c: QParams,
    /// Row sums of `A_I` (length m).
    pub a_row_sums: Vec<i32>,
    /// Column sums of `B_I` (length n). `Arc`-shared with the owning
    /// layer's pack-time cache: B is the long-lived operand, so its
    /// column sums are computed once and every forward's params borrow
    /// them instead of cloning O(n) ints per call (ROADMAP open item).
    pub b_col_sums: Arc<[i32]>,
    /// Inner dimension k.
    pub k: usize,
}

impl RequantParams {
    /// Compute row sums of A (m×k u8) and column sums of B (k×n i8).
    pub fn prepare(
        a_mat: &[u8],
        b_mat: &[i8],
        m: usize,
        k: usize,
        n: usize,
        a: QParams,
        b: QParams,
        c: QParams,
    ) -> Self {
        assert_eq!(a_mat.len(), m * k);
        assert_eq!(b_mat.len(), k * n);
        let mut a_row_sums = vec![0i32; m];
        for i in 0..m {
            let mut s = 0i32;
            for p in 0..k {
                s += a_mat[i * k + p] as i32;
            }
            a_row_sums[i] = s;
        }
        let mut b_col_sums = vec![0i32; n];
        for p in 0..k {
            let row = &b_mat[p * n..(p + 1) * n];
            for (j, &v) in row.iter().enumerate() {
                b_col_sums[j] += v as i32;
            }
        }
        Self {
            a,
            b,
            c,
            a_row_sums,
            b_col_sums: b_col_sums.into(),
            k,
        }
    }

    /// Real-valued output entry before final quantization.
    #[inline]
    pub fn real_value(&self, c_temp_ij: i32, i: usize, j: usize) -> f32 {
        self.a.alpha * self.b.alpha * c_temp_ij as f32
            + self.a.alpha * self.b.beta * self.a_row_sums[i] as f32
            + self.b.alpha * self.a.beta * self.b_col_sums[j] as f32
            + self.k as f32 * self.a.beta * self.b.beta
    }
}

/// Requantize an m×n `C_temp` (row-major, stride n) to u8.
pub fn requantize(c_temp: &[i32], m: usize, n: usize, p: &RequantParams) -> Vec<u8> {
    assert_eq!(c_temp.len(), m * n);
    let mut out = vec![0u8; m * n];
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] = p.c.quantize_u8(p.real_value(c_temp[i * n + j], i, j));
        }
    }
    out
}

/// Requantize an m×(n+1) `C_temp` whose last column is the ABFT checksum:
/// the checksum column is skipped, output is m×n (paper §IV-A3: "modify the
/// requantization procedure to let it exclude the last column").
pub fn requantize_exclude_last_col(
    c_temp: &[i32],
    m: usize,
    n_plus_1: usize,
    p: &RequantParams,
) -> Vec<u8> {
    assert!(n_plus_1 >= 1);
    let n = n_plus_1 - 1;
    assert_eq!(c_temp.len(), m * n_plus_1);
    let mut out = vec![0u8; m * n];
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] = p.c.quantize_u8(p.real_value(c_temp[i * n_plus_1 + j], i, j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_slice_i8, quantize_slice_u8, QParams};
    use crate::util::rng::Pcg32;

    /// Float reference: dequantize inputs, real matmul.
    fn float_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
        c
    }

    fn int_matmul(a: &[u8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p] as i32;
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j] as i32;
                }
            }
        }
        c
    }

    #[test]
    fn quantized_matmul_tracks_float_matmul() {
        let (m, k, n) = (8, 32, 16);
        let mut rng = Pcg32::new(99);
        let af: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 2.0).collect();
        let bf: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
        let (aq, apar) = quantize_slice_u8(&af);
        let (bq, bpar) = quantize_slice_i8(&bf);
        let cf = float_matmul(&af, &bf, m, k, n);
        let (lo, hi) = (
            cf.iter().cloned().fold(f32::INFINITY, f32::min),
            cf.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        );
        let cpar = QParams::fit_u8(lo, hi);
        let p = RequantParams::prepare(&aq, &bq, m, k, n, apar, bpar, cpar);
        let c_temp = int_matmul(&aq, &bq, m, k, n);
        let cq = requantize(&c_temp, m, n, &p);
        // Dequantized output should match the float matmul to quantization noise.
        let tol = cpar.alpha * 2.0 + 0.05 * (hi - lo);
        for (idx, &q) in cq.iter().enumerate() {
            let approx = cpar.dequantize_u8(q);
            assert!(
                (approx - cf[idx]).abs() < tol,
                "idx={idx} approx={approx} exact={}",
                cf[idx]
            );
        }
    }

    #[test]
    fn exclude_last_col_drops_checksum() {
        let (m, k, n) = (3, 4, 5);
        let mut rng = Pcg32::new(7);
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let qp = QParams { alpha: 1.0, beta: 0.0 };
        let p = RequantParams::prepare(&a, &b, m, k, n, qp, qp, QParams::fit_u8(-500.0, 500.0));
        let c = int_matmul(&a, &b, m, k, n);
        // Build m×(n+1) with junk checksum column.
        let mut c_aug = vec![0i32; m * (n + 1)];
        for i in 0..m {
            c_aug[i * (n + 1)..i * (n + 1) + n].copy_from_slice(&c[i * n..(i + 1) * n]);
            c_aug[i * (n + 1) + n] = 0x5A5A5A;
        }
        let plain = requantize(&c, m, n, &p);
        let excl = requantize_exclude_last_col(&c_aug, m, n + 1, &p);
        assert_eq!(plain, excl);
    }

    #[test]
    fn real_value_matches_eq1_identity() {
        // With alpha=1, beta=0 on both sides, real_value == c_temp.
        let qp = QParams { alpha: 1.0, beta: 0.0 };
        let p = RequantParams {
            a: qp,
            b: qp,
            c: qp,
            a_row_sums: vec![10],
            b_col_sums: vec![20].into(),
            k: 4,
        };
        assert_eq!(p.real_value(42, 0, 0), 42.0);
    }
}
