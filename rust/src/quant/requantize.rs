//! Requantization: combine the 32-bit integer product `C_temp = A_I·B_I`
//! with the rank-1 correction terms of Eq 1 and emit the quantized output
//! tuple `(C_I, α_C, β_C)` (paper Fig 1).
//!
//! `AB ≈ α_A α_B A_I B_I
//!      + α_A β_B (A_I e_k) e_nᵀ      (row sums of A_I)
//!      + α_B β_A e_m (e_kᵀ B_I)      (column sums of B_I)
//!      + k β_A β_B e_m e_nᵀ`
//!
//! There is **one** rounding implementation, [`requantize_cols_into`],
//! parameterized by the output column range. Its three callers:
//! [`requantize`] (all columns), [`requantize_exclude_last_col`] (the
//! paper's §IV-A3 "modify the requantization procedure to let it exclude
//! the last column" — the ABFT checksum column lives in `C_temp` and is
//! never requantized), and the fused GEMM epilogue
//! (`gemm::gemm_requant_exec_into`), which runs the same arithmetic on
//! the accumulator tile while it is still in registers and falls back to
//! this scalar core for ragged/boundary panels — which is exactly why the
//! fused path is bit-identical to the two-pass one.

use super::QParams;
use std::ops::Range;
use std::sync::Arc;

/// Everything the requantization step needs besides `C_temp`.
#[derive(Clone, Debug)]
pub struct RequantParams {
    pub a: QParams,
    pub b: QParams,
    /// Output lattice.
    pub c: QParams,
    /// Row sums of `A_I` (length m).
    pub a_row_sums: Vec<i32>,
    /// Column sums of `B_I` (length n). `Arc`-shared with the owning
    /// layer's pack-time cache: B is the long-lived operand, so its
    /// column sums are computed once and every forward's params borrow
    /// them instead of cloning O(n) ints per call (ROADMAP open item).
    pub b_col_sums: Arc<[i32]>,
    /// Inner dimension k.
    pub k: usize,
}

/// The scalar coefficients of Eq 1's affine map from an accumulator entry
/// (plus its row/column sums) to a real value, pre-multiplied once per
/// forward. `Copy`, so kernels can carry it by value; the operation order
/// in [`RequantSpec::real`] is the bit-exactness contract every
/// requantization path (scalar core, fused AVX2 epilogue) must follow.
#[derive(Clone, Copy, Debug)]
pub struct RequantSpec {
    /// `α_A · α_B` (scales `C_temp`).
    pub s_prod: f32,
    /// `α_A · β_B` (scales the A row sum).
    pub s_arow: f32,
    /// `α_B · β_A` (scales the B column sum).
    pub s_bcol: f32,
    /// `k · β_A · β_B`.
    pub s_const: f32,
    /// Output lattice.
    pub c: QParams,
}

impl RequantSpec {
    pub fn new(a: QParams, b: QParams, c: QParams, k: usize) -> Self {
        Self {
            s_prod: a.alpha * b.alpha,
            s_arow: a.alpha * b.beta,
            s_bcol: b.alpha * a.beta,
            s_const: k as f32 * a.beta * b.beta,
            c,
        }
    }

    /// Real-valued output for one accumulator entry. The sum order
    /// `((t1 + t2) + t3) + t4` is deliberate and load-bearing: the fused
    /// SIMD epilogue replays exactly this sequence of f32 operations.
    #[inline]
    pub fn real(&self, c_temp_ij: i32, a_row_sum: i32, b_col_sum: i32) -> f32 {
        self.s_prod * c_temp_ij as f32
            + self.s_arow * a_row_sum as f32
            + self.s_bcol * b_col_sum as f32
            + self.s_const
    }

    /// One output code: quantize the real value, then apply the quantized
    /// ReLU floor (`0` disables it — `max(q, 0)` is the identity on u8).
    #[inline]
    pub fn quantize(&self, c_temp_ij: i32, a_row_sum: i32, b_col_sum: i32, relu_floor: u8) -> u8 {
        self.c
            .quantize_u8(self.real(c_temp_ij, a_row_sum, b_col_sum))
            .max(relu_floor)
    }
}

/// Borrowed binding of a [`RequantSpec`] to one GEMM's sum vectors — what
/// the fused GEMM epilogue carries into the kernel. `n_out` is the
/// payload width: columns `n_out..n_total` of the accumulator (the ABFT
/// checksum column, when present) are skipped exactly as
/// [`requantize_exclude_last_col`] skips them.
#[derive(Clone, Copy)]
pub struct RequantEpilogue<'a> {
    pub spec: RequantSpec,
    /// Row sums of the A block being multiplied (length = block rows).
    pub a_row_sums: &'a [i32],
    /// Column sums of B's payload (length ≥ `n_out`).
    pub b_col_sums: &'a [i32],
    /// Output (payload) column count; `≤ packed.n_total()`.
    pub n_out: usize,
    /// Quantized-ReLU floor; `0` means no ReLU.
    pub relu_floor: u8,
}

impl RequantParams {
    /// Compute row sums of A (m×k u8) and column sums of B (k×n i8).
    pub fn prepare(
        a_mat: &[u8],
        b_mat: &[i8],
        m: usize,
        k: usize,
        n: usize,
        a: QParams,
        b: QParams,
        c: QParams,
    ) -> Self {
        assert_eq!(a_mat.len(), m * k);
        assert_eq!(b_mat.len(), k * n);
        let mut a_row_sums = vec![0i32; m];
        for (i, s) in a_row_sums.iter_mut().enumerate() {
            *s = a_mat[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum();
        }
        let mut b_col_sums = vec![0i32; n];
        for p in 0..k {
            let row = &b_mat[p * n..(p + 1) * n];
            for (j, &v) in row.iter().enumerate() {
                b_col_sums[j] += v as i32;
            }
        }
        Self {
            a,
            b,
            c,
            a_row_sums,
            b_col_sums: b_col_sums.into(),
            k,
        }
    }

    /// The `Copy` coefficient bundle for this params set.
    pub fn spec(&self) -> RequantSpec {
        RequantSpec::new(self.a, self.b, self.c, self.k)
    }

    /// Real-valued output entry before final quantization.
    #[inline]
    pub fn real_value(&self, c_temp_ij: i32, i: usize, j: usize) -> f32 {
        self.spec()
            .real(c_temp_ij, self.a_row_sums[i], self.b_col_sums[j])
    }
}

/// The single requantization implementation: quantize columns `cols` of a
/// `rows × stride` `C_temp` block into a dense `rows × cols.len()` u8
/// output, applying the quantized-ReLU floor. `a_row_sums` is indexed by
/// block-local row (callers slice it when processing a row block);
/// `b_col_sums` is indexed by absolute column.
pub fn requantize_cols_into(
    c_temp: &[i32],
    rows: usize,
    stride: usize,
    cols: Range<usize>,
    a_row_sums: &[i32],
    b_col_sums: &[i32],
    spec: &RequantSpec,
    relu_floor: u8,
    out: &mut [u8],
) {
    assert!(cols.end <= stride, "column range exceeds stride");
    assert!(cols.end <= b_col_sums.len(), "missing B column sums");
    assert_eq!(c_temp.len(), rows * stride, "C_temp shape");
    assert_eq!(a_row_sums.len(), rows, "A row sums");
    let w = cols.end - cols.start;
    assert_eq!(out.len(), rows * w, "output shape");
    for i in 0..rows {
        let crow = &c_temp[i * stride + cols.start..i * stride + cols.end];
        let orow = &mut out[i * w..(i + 1) * w];
        let ar = a_row_sums[i];
        for (x, (o, &bc)) in crow
            .iter()
            .zip(orow.iter_mut().zip(&b_col_sums[cols.clone()]))
        {
            *o = spec.quantize(*x, ar, bc, relu_floor);
        }
    }
}

/// Requantize an m×n `C_temp` (row-major, stride n) to u8.
pub fn requantize(c_temp: &[i32], m: usize, n: usize, p: &RequantParams) -> Vec<u8> {
    let mut out = vec![0u8; m * n];
    requantize_cols_into(
        c_temp,
        m,
        n,
        0..n,
        &p.a_row_sums,
        &p.b_col_sums,
        &p.spec(),
        0,
        &mut out,
    );
    out
}

/// Requantize an m×(n+1) `C_temp` whose last column is the ABFT checksum:
/// the checksum column is skipped, output is m×n (paper §IV-A3: "modify the
/// requantization procedure to let it exclude the last column").
pub fn requantize_exclude_last_col(
    c_temp: &[i32],
    m: usize,
    n_plus_1: usize,
    p: &RequantParams,
) -> Vec<u8> {
    assert!(n_plus_1 >= 1);
    let n = n_plus_1 - 1;
    let mut out = vec![0u8; m * n];
    requantize_cols_into(
        c_temp,
        m,
        n_plus_1,
        0..n,
        &p.a_row_sums,
        &p.b_col_sums,
        &p.spec(),
        0,
        &mut out,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_slice_i8, quantize_slice_u8, QParams};
    use crate::util::rng::Pcg32;

    /// Float reference: dequantize inputs, real matmul.
    fn float_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
        c
    }

    fn int_matmul(a: &[u8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
        let mut c = vec![0i32; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p] as i32;
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j] as i32;
                }
            }
        }
        c
    }

    #[test]
    fn quantized_matmul_tracks_float_matmul() {
        let (m, k, n) = (8, 32, 16);
        let mut rng = Pcg32::new(99);
        let af: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 2.0).collect();
        let bf: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
        let (aq, apar) = quantize_slice_u8(&af);
        let (bq, bpar) = quantize_slice_i8(&bf);
        let cf = float_matmul(&af, &bf, m, k, n);
        let (lo, hi) = (
            cf.iter().cloned().fold(f32::INFINITY, f32::min),
            cf.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
        );
        let cpar = QParams::fit_u8(lo, hi);
        let p = RequantParams::prepare(&aq, &bq, m, k, n, apar, bpar, cpar);
        let c_temp = int_matmul(&aq, &bq, m, k, n);
        let cq = requantize(&c_temp, m, n, &p);
        // Dequantized output should match the float matmul to quantization noise.
        let tol = cpar.alpha * 2.0 + 0.05 * (hi - lo);
        for (idx, &q) in cq.iter().enumerate() {
            let approx = cpar.dequantize_u8(q);
            assert!(
                (approx - cf[idx]).abs() < tol,
                "idx={idx} approx={approx} exact={}",
                cf[idx]
            );
        }
    }

    #[test]
    fn exclude_last_col_drops_checksum() {
        let (m, k, n) = (3, 4, 5);
        let mut rng = Pcg32::new(7);
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let qp = QParams { alpha: 1.0, beta: 0.0 };
        let p = RequantParams::prepare(&a, &b, m, k, n, qp, qp, QParams::fit_u8(-500.0, 500.0));
        let c = int_matmul(&a, &b, m, k, n);
        // Build m×(n+1) with junk checksum column.
        let mut c_aug = vec![0i32; m * (n + 1)];
        for i in 0..m {
            c_aug[i * (n + 1)..i * (n + 1) + n].copy_from_slice(&c[i * n..(i + 1) * n]);
            c_aug[i * (n + 1) + n] = 0x5A5A5A;
        }
        let plain = requantize(&c, m, n, &p);
        let excl = requantize_exclude_last_col(&c_aug, m, n + 1, &p);
        assert_eq!(plain, excl);
    }

    #[test]
    fn real_value_matches_eq1_identity() {
        // With alpha=1, beta=0 on both sides, real_value == c_temp.
        let qp = QParams { alpha: 1.0, beta: 0.0 };
        let p = RequantParams {
            a: qp,
            b: qp,
            c: qp,
            a_row_sums: vec![10],
            b_col_sums: vec![20].into(),
            k: 4,
        };
        assert_eq!(p.real_value(42, 0, 0), 42.0);
    }

    #[test]
    fn cols_range_matches_full_requantize_columnwise() {
        // The range-parameterized core must agree with the full-width
        // wrapper on any sub-range, including ReLU flooring.
        let (m, k, n) = (5, 24, 13);
        let mut rng = Pcg32::new(21);
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let (_, apar) = quantize_slice_u8(&[0.0, 3.0]);
        let (_, bpar) = quantize_slice_i8(&[-0.5, 0.5]);
        let p = RequantParams::prepare(&a, &b, m, k, n, apar, bpar, QParams::fit_u8(-40.0, 44.0));
        let c = int_matmul(&a, &b, m, k, n);
        let full = requantize(&c, m, n, &p);
        for (start, end) in [(0usize, n), (0, 4), (3, 11), (n - 1, n), (6, 6)] {
            for floor in [0u8, p.c.quantize_u8(0.0)] {
                let w = end - start;
                let mut part = vec![0u8; m * w];
                requantize_cols_into(
                    &c,
                    m,
                    n,
                    start..end,
                    &p.a_row_sums,
                    &p.b_col_sums,
                    &p.spec(),
                    floor,
                    &mut part,
                );
                for i in 0..m {
                    for j in 0..w {
                        assert_eq!(
                            part[i * w + j],
                            full[i * n + start + j].max(floor),
                            "({start}..{end}) floor={floor} ({i},{j})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn spec_real_is_bitwise_real_value() {
        let mut rng = Pcg32::new(33);
        let a = QParams { alpha: 0.013, beta: -1.7 };
        let b = QParams { alpha: 0.0041, beta: 0.33 };
        let c = QParams::fit_u8(-3.0, 9.0);
        let p = RequantParams {
            a,
            b,
            c,
            a_row_sums: (0..7).map(|_| rng.gen_range(0, 50_000) as i32).collect(),
            b_col_sums: (0..9)
                .map(|_| rng.gen_range(0, 30_000) as i32 - 15_000)
                .collect::<Vec<_>>()
                .into(),
            k: 321,
        };
        let spec = p.spec();
        for i in 0..7 {
            for j in 0..9 {
                let ct = rng.gen_range(0, 1 << 20) as i32 - (1 << 19);
                assert_eq!(
                    p.real_value(ct, i, j).to_bits(),
                    spec.real(ct, p.a_row_sums[i], p.b_col_sums[j]).to_bits()
                );
            }
        }
    }
}
