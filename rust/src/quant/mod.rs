//! Quantized-arithmetic substrate (paper §III-A, Fig 1).
//!
//! Real values are represented as `x ≈ α·x_I + β` with `x_I` a short
//! integer: `u8` for activations (matrix A), `i8` for weights (matrix B),
//! following the paper's convention (and PyTorch/FBGEMM's).
//!
//! A quantized GEMM (Eq 1) decomposes into the integer product
//! `C_temp = A_I · B_I` plus rank-1 correction terms, followed by a
//! *requantization* step producing the 8-bit output tuple `(C_I, α_C, β_C)`.

pub mod acc16;
pub mod requantize;

pub use acc16::{acc16_saturation_proof, Acc16Proof, ACC16_MAX_SPILL_PAIRS, ACC16_SHORT_K_MAX};
pub use requantize::{
    requantize, requantize_cols_into, requantize_exclude_last_col, RequantEpilogue, RequantParams,
    RequantSpec,
};

/// Affine quantization parameters: `x ≈ alpha * x_int + beta`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    pub alpha: f32,
    pub beta: f32,
}

impl QParams {
    /// Fit `[x_min, x_max]` onto the `u8` lattice `[0, 255]`.
    pub fn fit_u8(x_min: f32, x_max: f32) -> Self {
        let (lo, hi) = sanitize_range(x_min, x_max);
        let alpha = (hi - lo) / 255.0;
        Self { alpha, beta: lo }
    }

    /// Fit `[x_min, x_max]` onto the `i8` lattice `[-128, 127]`.
    pub fn fit_i8(x_min: f32, x_max: f32) -> Self {
        let (lo, hi) = sanitize_range(x_min, x_max);
        let alpha = (hi - lo) / 255.0;
        Self {
            alpha,
            beta: lo + 128.0 * alpha,
        }
    }

    /// Quantize one value to u8: round((x - beta)/alpha) clamped to [0,255].
    #[inline]
    pub fn quantize_u8(&self, x: f32) -> u8 {
        let q = ((x - self.beta) / self.alpha).round();
        q.clamp(0.0, 255.0) as u8
    }

    /// Quantize one value to i8.
    #[inline]
    pub fn quantize_i8(&self, x: f32) -> i8 {
        let q = ((x - self.beta) / self.alpha).round();
        q.clamp(-128.0, 127.0) as i8
    }

    #[inline]
    pub fn dequantize_u8(&self, q: u8) -> f32 {
        self.alpha * q as f32 + self.beta
    }

    #[inline]
    pub fn dequantize_i8(&self, q: i8) -> f32 {
        self.alpha * q as f32 + self.beta
    }
}

fn sanitize_range(x_min: f32, x_max: f32) -> (f32, f32) {
    assert!(x_min.is_finite() && x_max.is_finite() && x_min <= x_max);
    // Degenerate ranges still need a nonzero alpha.
    if x_max - x_min < f32::EPSILON {
        (x_min - 0.5, x_min + 0.5)
    } else {
        (x_min, x_max)
    }
}

/// Quantize an f32 slice to u8 with range fitted from the data.
pub fn quantize_slice_u8(xs: &[f32]) -> (Vec<u8>, QParams) {
    let (lo, hi) = min_max(xs);
    let qp = QParams::fit_u8(lo, hi);
    (xs.iter().map(|&x| qp.quantize_u8(x)).collect(), qp)
}

/// Quantize an f32 slice to i8 with range fitted from the data.
pub fn quantize_slice_i8(xs: &[f32]) -> (Vec<i8>, QParams) {
    let (lo, hi) = min_max(xs);
    let qp = QParams::fit_i8(lo, hi);
    (xs.iter().map(|&x| qp.quantize_i8(x)).collect(), qp)
}

pub fn dequantize_slice_u8(qs: &[u8], qp: QParams) -> Vec<f32> {
    qs.iter().map(|&q| qp.dequantize_u8(q)).collect()
}

pub fn dequantize_slice_i8(qs: &[i8], qp: QParams) -> Vec<f32> {
    qs.iter().map(|&q| qp.dequantize_i8(q)).collect()
}

fn min_max(xs: &[f32]) -> (f32, f32) {
    assert!(!xs.is_empty());
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    (lo, hi)
}

/// 4-bit quantization parameters for embedding rows (paper cites
/// post-training 4-bit quantization of embedding tables [24]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams4 {
    pub alpha: f32,
    pub beta: f32,
}

impl QParams4 {
    pub fn fit(x_min: f32, x_max: f32) -> Self {
        let (lo, hi) = sanitize_range(x_min, x_max);
        Self {
            alpha: (hi - lo) / 15.0,
            beta: lo,
        }
    }

    #[inline]
    pub fn quantize(&self, x: f32) -> u8 {
        (((x - self.beta) / self.alpha).round()).clamp(0.0, 15.0) as u8
    }

    #[inline]
    pub fn dequantize(&self, q: u8) -> f32 {
        debug_assert!(q < 16);
        self.alpha * q as f32 + self.beta
    }
}

/// Pack a slice of 4-bit codes (values < 16) two-per-byte, low nibble first.
pub fn pack_nibbles(codes: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; (codes.len() + 1) / 2];
    for (i, &c) in codes.iter().enumerate() {
        debug_assert!(c < 16);
        if i % 2 == 0 {
            out[i / 2] |= c;
        } else {
            out[i / 2] |= c << 4;
        }
    }
    out
}

/// Read the i-th 4-bit code from a nibble-packed buffer.
#[inline]
pub fn get_nibble(packed: &[u8], i: usize) -> u8 {
    let b = packed[i / 2];
    if i % 2 == 0 {
        b & 0x0f
    } else {
        b >> 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn u8_roundtrip_error_within_half_step() {
        let qp = QParams::fit_u8(-3.0, 5.0);
        for i in 0..=1000 {
            let x = -3.0 + 8.0 * i as f32 / 1000.0;
            let err = (qp.dequantize_u8(qp.quantize_u8(x)) - x).abs();
            assert!(err <= qp.alpha * 0.5 + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn i8_roundtrip_error_within_half_step() {
        let qp = QParams::fit_i8(-1.0, 1.0);
        for i in 0..=1000 {
            let x = -1.0 + 2.0 * i as f32 / 1000.0;
            let err = (qp.dequantize_i8(qp.quantize_i8(x)) - x).abs();
            assert!(err <= qp.alpha * 0.5 + 1e-6);
        }
    }

    #[test]
    fn endpoints_map_to_lattice_ends() {
        let qp = QParams::fit_u8(-2.0, 2.0);
        assert_eq!(qp.quantize_u8(-2.0), 0);
        assert_eq!(qp.quantize_u8(2.0), 255);
        let qi = QParams::fit_i8(-2.0, 2.0);
        assert_eq!(qi.quantize_i8(-2.0), -128);
        assert_eq!(qi.quantize_i8(2.0), 127);
    }

    #[test]
    fn out_of_range_clamps() {
        let qp = QParams::fit_u8(0.0, 1.0);
        assert_eq!(qp.quantize_u8(-100.0), 0);
        assert_eq!(qp.quantize_u8(100.0), 255);
    }

    #[test]
    fn degenerate_range_ok() {
        let qp = QParams::fit_u8(1.0, 1.0);
        let q = qp.quantize_u8(1.0);
        assert!((qp.dequantize_u8(q) - 1.0).abs() < 0.01);
    }

    #[test]
    fn slice_roundtrip_random() {
        let mut rng = Pcg32::new(1234);
        let xs: Vec<f32> = (0..4096).map(|_| rng.next_f32() * 10.0 - 5.0).collect();
        let (qs, qp) = quantize_slice_u8(&xs);
        let back = dequantize_slice_u8(&qs, qp);
        for (x, b) in xs.iter().zip(&back) {
            assert!((x - b).abs() <= qp.alpha * 0.5 + 1e-5);
        }
    }

    #[test]
    fn nibble_pack_roundtrip() {
        let mut rng = Pcg32::new(5);
        for len in [0usize, 1, 2, 7, 64, 129] {
            let codes: Vec<u8> = (0..len).map(|_| rng.next_u8() & 0x0f).collect();
            let packed = pack_nibbles(&codes);
            assert_eq!(packed.len(), (len + 1) / 2);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(get_nibble(&packed, i), c);
            }
        }
    }

    #[test]
    fn four_bit_roundtrip() {
        let qp = QParams4::fit(-1.0, 1.0);
        for i in 0..16 {
            let x = qp.dequantize(i);
            assert_eq!(qp.quantize(x), i);
        }
    }
}
