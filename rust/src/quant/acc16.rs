//! Static i16-saturation proof for the int16-accumulation GEMM tier.
//!
//! The acc16 kernel (`gemm::acc16`) computes `maddubs`-style pair sums —
//! `a_even·b_even + a_odd·b_odd` with `a ∈ u8`, `b ∈ i8` — and keeps
//! accumulating them in **i16 lanes**, spilling (sign-extending and
//! adding) into the i32 accumulators only every `spill_pairs` pair
//! blocks. That is twice the madd throughput of the i32 AVX2 path, but
//! it is only *bit-identical* to the scalar kernel if neither the
//! `maddubs` pair sum nor any in-window i16 partial sum can leave
//! `[-32768, 32767]`.
//!
//! Weights are the long-lived operand and known at pack time, while
//! activations are only bounded (`a ≤ 255`), so we prove saturation
//! freedom **statically per pack**: for every column `j` and every
//! aligned window of `spill_pairs` consecutive pair blocks,
//!
//! ```text
//!   Σ_window 255 · (|b[2pp][j]| + |b[2pp+1][j]|)  ≤  32767  (i16::MAX)
//! ```
//!
//! Since each pair term `t_pp = a₀·b₀ + a₁·b₁` satisfies
//! `|t_pp| ≤ 255·(|b₀| + |b₁|)`, the bound implies (a) every single
//! pair sum fits i16, so `maddubs`' saturating add never saturates, and
//! (b) every partial sum inside a window has magnitude at most the
//! window's term-magnitude total, so the i16 accumulation never wraps —
//! for **any** u8 activation values. The odd trailing k-row (when k is
//! odd) is excluded: the kernel folds it in exact i32 arithmetic.
//!
//! The proof is per-column over *all* stored columns, so the ABFT Eq-3b
//! checksum and group-checksum columns are covered by the same argument
//! and keep riding the same panels (protected GEMM stays one kernel
//! call on every tier).

/// Largest spill window the prover will certify (pair blocks between
/// i16→i32 spills). Beyond this the spill cost is already amortized to
/// noise, and larger windows only make eligibility rarer.
pub const ACC16_MAX_SPILL_PAIRS: usize = 16;

/// The acc16 tier only pays off on short-k GEMMs (the spill and the
/// extra i32 adds are per-panel-pass overhead); above this depth the
/// dispatcher prefers the plain AVX2 i32 path.
pub const ACC16_SHORT_K_MAX: usize = 256;

/// A pack-time certificate that the int16-accumulation kernel is exact
/// for this operand: accumulating `spill_pairs` consecutive `maddubs`
/// pair sums in i16 cannot saturate or wrap, for any u8 activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Acc16Proof {
    /// Certified spill cadence: pair blocks accumulated in i16 between
    /// i16→i32 spills. Always ≥ 1 and ≤ [`ACC16_MAX_SPILL_PAIRS`].
    pub spill_pairs: u8,
}

/// Try to certify a `k × nt` operand for int16 accumulation, reading
/// elements through `at(row, col)` (any layout). Returns the proof with
/// the **largest** certifiable spill window from `{16, 8, 4, 2, 1}`
/// (fewest spills wins), or `None` when even window 1 — i.e. a single
/// `maddubs` pair sum — can exceed `i16::MAX` in magnitude, in which
/// case the dispatcher must fall back to the exact i32 tiers.
pub fn acc16_saturation_proof(
    k: usize,
    nt: usize,
    at: impl Fn(usize, usize) -> i8,
) -> Option<Acc16Proof> {
    let pairs = k / 2;
    if pairs == 0 || nt == 0 {
        // No pair blocks: nothing for an i16 accumulator to do.
        return None;
    }
    // Per (pair block, column) worst-case term magnitude over u8
    // activations: 255·(|b_even| + |b_odd|). Computed once, reused for
    // every candidate window.
    let mut term = vec![0u32; pairs * nt];
    for pp in 0..pairs {
        for j in 0..nt {
            let b0 = (at(2 * pp, j) as i32).unsigned_abs();
            let b1 = (at(2 * pp + 1, j) as i32).unsigned_abs();
            term[pp * nt + j] = 255 * (b0 + b1);
        }
    }
    let cap = pairs.min(ACC16_MAX_SPILL_PAIRS);
    let mut candidates = [0usize; 5];
    let mut nc = 0;
    candidates[nc] = cap;
    nc += 1;
    for w in [8usize, 4, 2, 1] {
        if w < cap {
            candidates[nc] = w;
            nc += 1;
        }
    }
    'cand: for &w in &candidates[..nc] {
        // Aligned windows (the kernel spills every w pair blocks from
        // pair 0), including the final partial window.
        for j in 0..nt {
            let mut pp = 0;
            while pp < pairs {
                let end = (pp + w).min(pairs);
                let sum: u64 = (pp..end).map(|q| term[q * nt + j] as u64).sum();
                if sum > i16::MAX as u64 {
                    continue 'cand;
                }
                pp = end;
            }
        }
        return Some(Acc16Proof {
            spill_pairs: w as u8,
        });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_magnitude_pair_is_window_one() {
        // |b0|+|b1| = 128 ⇒ 255·128 = 32640 ≤ 32767: certifiable, but
        // only with a window of a single pair.
        let proof = acc16_saturation_proof(64, 8, |p, _| if p % 2 == 0 { 64 } else { -64 });
        assert_eq!(proof, Some(Acc16Proof { spill_pairs: 1 }));
    }

    #[test]
    fn one_over_the_line_is_rejected() {
        // |b0|+|b1| = 129 ⇒ 255·129 = 32895 > 32767: a single maddubs
        // pair sum can saturate, so no proof exists.
        let proof = acc16_saturation_proof(64, 8, |p, _| if p % 2 == 0 { 65 } else { -64 });
        assert_eq!(proof, None);
        // ...even if only ONE column is hot.
        let proof = acc16_saturation_proof(64, 8, |p, j| {
            if j == 7 && p < 2 {
                if p == 0 {
                    65
                } else {
                    64
                }
            } else {
                1
            }
        });
        assert_eq!(proof, None);
    }

    #[test]
    fn small_weights_earn_wide_windows() {
        // |b0|+|b1| = 4 ⇒ per-pair term 1020; 16 pairs sum to 16320,
        // well under 32767 ⇒ the full 16-pair window certifies.
        let proof = acc16_saturation_proof(200, 33, |_, _| 2);
        assert_eq!(proof, Some(Acc16Proof { spill_pairs: 16 }));
        // |b0|+|b1| = 16 ⇒ per-pair 4080; ×8 = 32640 ok, ×16 = 65280
        // over ⇒ window 8.
        let proof = acc16_saturation_proof(200, 33, |_, _| 8);
        assert_eq!(proof, Some(Acc16Proof { spill_pairs: 8 }));
    }

    #[test]
    fn odd_tail_row_is_not_part_of_the_proof() {
        // k = 3: one pair block + the odd tail row. The tail row holds a
        // huge value but the kernel folds it in i32, so only the pair
        // block must certify.
        let proof = acc16_saturation_proof(3, 4, |p, _| if p == 2 { -128 } else { 1 });
        assert_eq!(proof, Some(Acc16Proof { spill_pairs: 1 }));
    }

    #[test]
    fn degenerate_shapes_decline() {
        assert_eq!(acc16_saturation_proof(1, 8, |_, _| 1), None);
        assert_eq!(acc16_saturation_proof(0, 8, |_, _| 1), None);
        assert_eq!(acc16_saturation_proof(8, 0, |_, _| 1), None);
    }

    #[test]
    fn partial_final_window_is_checked() {
        // pairs = 5, cap window 5: columns are tiny except the last
        // pair, which alone exceeds the bound ⇒ every candidate window
        // fails on its final (partial or aligned) window.
        let proof = acc16_saturation_proof(10, 2, |p, _| if p >= 8 { 127 } else { 0 });
        assert_eq!(proof, None);
    }
}
