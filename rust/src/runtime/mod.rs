//! PJRT runtime: loads the HLO-text artifacts produced by the python
//! compile path (`python/compile/aot.py`, jax + Pallas) and executes them
//! from the rust hot path — python is never on the request path.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The actual PJRT execution depends on the `xla` crate (xla_extension
//! bindings), which is not available in the offline build environment.
//! It is therefore gated behind the custom `pjrt_runtime` cfg (add the
//! `xla` dependency and build with `RUSTFLAGS="--cfg pjrt_runtime"`); without it an
//! API-compatible stub compiles in whose constructor reports that PJRT
//! support is disabled. Everything downstream (`PjrtModelEngine`, the
//! `artifacts`/`score --backend pjrt` CLI paths) degrades to a clean
//! runtime error instead of a missing symbol.

use std::path::Path;

/// A typed host tensor crossing the rust↔PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    U8(Vec<u8>, Vec<usize>),
    I8(Vec<i8>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::U8(_, d) | Tensor::I8(_, d) | Tensor::I32(_, d) | Tensor::F32(_, d) => d,
        }
    }

    pub fn element_count(&self) -> usize {
        self.dims().iter().product()
    }
}

#[cfg(pjrt_runtime)]
mod backend {
    use super::Tensor;
    use anyhow::{anyhow, Context, Result};
    use std::collections::HashMap;
    use std::path::Path;

    impl Tensor {
        fn to_literal(&self) -> Result<xla::Literal> {
            let (ty, bytes, dims): (xla::ElementType, Vec<u8>, &[usize]) = match self {
                Tensor::U8(v, d) => (xla::ElementType::U8, v.clone(), d),
                Tensor::I8(v, d) => (
                    xla::ElementType::S8,
                    v.iter().map(|&x| x as u8).collect(),
                    d,
                ),
                Tensor::I32(v, d) => (
                    xla::ElementType::S32,
                    v.iter().flat_map(|x| x.to_le_bytes()).collect(),
                    d,
                ),
                Tensor::F32(v, d) => (
                    xla::ElementType::F32,
                    v.iter().flat_map(|x| x.to_le_bytes()).collect(),
                    d,
                ),
            };
            xla::Literal::create_from_shape_and_untyped_data(ty, dims, &bytes)
                .map_err(|e| anyhow!("literal creation failed: {e:?}"))
        }

        fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
            let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let ty = shape.ty();
            let t = match ty {
                xla::ElementType::U8 => {
                    Tensor::U8(lit.to_vec::<u8>().map_err(|e| anyhow!("{e:?}"))?, dims)
                }
                xla::ElementType::S8 => {
                    Tensor::I8(lit.to_vec::<i8>().map_err(|e| anyhow!("{e:?}"))?, dims)
                }
                xla::ElementType::S32 => {
                    Tensor::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?, dims)
                }
                xla::ElementType::F32 => {
                    Tensor::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?, dims)
                }
                other => return Err(anyhow!("unsupported output element type {other:?}")),
            };
            Ok(t)
        }
    }

    /// Compiled-executable cache over a PJRT CPU client.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        executables: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl PjrtEngine {
        pub fn cpu() -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
            Ok(Self {
                client,
                executables: HashMap::new(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load and compile one HLO-text artifact under `name`.
        pub fn load_hlo_text<P: AsRef<Path>>(&mut self, name: &str, path: P) -> Result<()> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            self.executables.insert(name.to_string(), exe);
            Ok(())
        }

        /// Load every `*.hlo.txt` in a directory; names are file stems.
        pub fn load_artifact_dir<P: AsRef<Path>>(&mut self, dir: P) -> Result<Vec<String>> {
            let mut loaded = Vec::new();
            for entry in std::fs::read_dir(dir.as_ref())
                .with_context(|| format!("reading {}", dir.as_ref().display()))?
            {
                let path = entry?.path();
                let fname = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
                if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                    self.load_hlo_text(stem, &path)?;
                    loaded.push(stem.to_string());
                }
            }
            loaded.sort();
            Ok(loaded)
        }

        pub fn has(&self, name: &str) -> bool {
            self.executables.contains_key(name)
        }

        pub fn names(&self) -> Vec<&str> {
            let mut v: Vec<&str> = self.executables.keys().map(|s| s.as_str()).collect();
            v.sort();
            v
        }

        /// Execute `name` with the given inputs. The artifact must have been
        /// lowered with `return_tuple=True`; all tuple elements are returned.
        pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let exe = self
                .executables
                .get(name)
                .ok_or_else(|| anyhow!("no executable named {name:?}"))?;
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(Tensor::to_literal)
                .collect::<Result<_>>()?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch result: {e:?}"))?;
            let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
            parts.iter().map(Tensor::from_literal).collect()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn tensor_roundtrip_literal() {
            // The only code converting Tensor ↔ xla::Literal (including
            // the i8→u8 byte reinterpretation) — keep it unit-covered in
            // pjrt builds.
            let cases = vec![
                Tensor::U8(vec![1, 2, 3, 4], vec![2, 2]),
                Tensor::I8(vec![-1, 2, -3, 4, 5, -6], vec![2, 3]),
                Tensor::I32(vec![i32::MIN, 0, i32::MAX], vec![3]),
                Tensor::F32(vec![1.5, -2.5], vec![2]),
            ];
            for t in cases {
                let lit = t.to_literal().unwrap();
                let back = Tensor::from_literal(&lit).unwrap();
                assert_eq!(t, back);
            }
        }
    }
}

#[cfg(not(pjrt_runtime))]
mod backend {
    use super::Tensor;
    use anyhow::{bail, Result};
    use std::path::Path;

    const DISABLED: &str =
        "PJRT support is not compiled in (add the xla dependency and build with --cfg pjrt_runtime)";

    /// API-compatible stub: constructing it reports that PJRT is disabled,
    /// so every downstream path (CLI `artifacts`, `score --backend pjrt`,
    /// the hybrid example) fails with a clear message instead of at link
    /// time. No instance can exist, so the other methods are unreachable.
    pub struct PjrtEngine {
        never: std::convert::Infallible,
    }

    impl PjrtEngine {
        pub fn cpu() -> Result<Self> {
            bail!("{DISABLED}");
        }

        pub fn platform(&self) -> String {
            match self.never {}
        }

        pub fn load_hlo_text<P: AsRef<Path>>(&mut self, _name: &str, _path: P) -> Result<()> {
            match self.never {}
        }

        pub fn load_artifact_dir<P: AsRef<Path>>(&mut self, _dir: P) -> Result<Vec<String>> {
            match self.never {}
        }

        pub fn has(&self, _name: &str) -> bool {
            match self.never {}
        }

        pub fn names(&self) -> Vec<&str> {
            match self.never {}
        }

        pub fn execute(&self, _name: &str, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            match self.never {}
        }
    }
}

pub use backend::PjrtEngine;

/// True when PJRT execution was compiled in.
pub fn pjrt_enabled() -> bool {
    cfg!(pjrt_runtime)
}

/// Convenience used by tests and the CLI to check for artifacts on disk.
pub fn artifact_exists<P: AsRef<Path>>(dir: P, name: &str) -> bool {
    dir.as_ref().join(format!("{name}.hlo.txt")).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_dims_and_count() {
        let t = Tensor::I32(vec![1, 2, 3, 4, 5, 6], vec![2, 3]);
        assert_eq!(t.dims(), &[2, 3]);
        assert_eq!(t.element_count(), 6);
    }

    // These need a PJRT-enabled build AND a lowered artifact; the reference
    // one from /opt/xla-example (f32 2x2 matmul + 2.0) is regenerated on
    // demand by the python side. Integration tests against our own
    // artifacts live in rust/tests/runtime_integration.rs.
    #[cfg(pjrt_runtime)]
    #[test]
    fn engine_boots_cpu() {
        let engine = PjrtEngine::cpu().unwrap();
        assert_eq!(engine.platform(), "cpu");
        assert!(engine.names().is_empty());
    }

    #[cfg(pjrt_runtime)]
    #[test]
    fn missing_executable_is_error() {
        let engine = PjrtEngine::cpu().unwrap();
        let r = engine.execute("nope", &[]);
        assert!(r.is_err());
    }

    #[cfg(not(pjrt_runtime))]
    #[test]
    fn stub_reports_disabled() {
        assert!(!pjrt_enabled());
        let err = PjrtEngine::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("PJRT"), "{err}");
    }
}
