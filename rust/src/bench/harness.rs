//! From-scratch measurement harness (no criterion offline): warmup,
//! repeated timed runs, robust summaries, and overhead-ratio reporting —
//! the shape every paper figure needs (protected vs unprotected time).

use crate::util::stats::Summary;
use std::time::Instant;

/// Measurement settings.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub sample_iters: usize,
    /// Inner repetitions per timed sample (amortizes clock overhead for
    /// microsecond-scale bodies).
    pub inner_reps: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            sample_iters: 15,
            inner_reps: 1,
        }
    }
}

/// Timed samples (seconds per single body execution).
#[derive(Clone, Debug)]
pub struct Measurement {
    pub samples: Vec<f64>,
}

impl Measurement {
    pub fn summary(&self) -> Summary {
        Summary::from(&self.samples)
    }

    pub fn median(&self) -> f64 {
        self.summary().median
    }
}

/// Measure a closure. A `prep` hook runs before each sample, outside the
/// timed region (cache flushes live there).
pub fn measure<F: FnMut(), P: FnMut()>(cfg: &BenchConfig, mut prep: P, mut body: F) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        body();
    }
    let mut samples = Vec::with_capacity(cfg.sample_iters);
    for _ in 0..cfg.sample_iters {
        prep();
        let t0 = Instant::now();
        for _ in 0..cfg.inner_reps {
            body();
        }
        samples.push(t0.elapsed().as_secs_f64() / cfg.inner_reps as f64);
    }
    Measurement { samples }
}

/// Measure two closures with interleaved samples (A,B,A,B,…) so slow
/// drift (frequency scaling, noisy neighbours on a shared core) cancels
/// out of the A/B ratio — the fair way to measure protection overhead.
pub fn measure_pair<A: FnMut(), B: FnMut(), P: FnMut()>(
    cfg: &BenchConfig,
    mut prep: P,
    mut body_a: A,
    mut body_b: B,
) -> (Measurement, Measurement) {
    for _ in 0..cfg.warmup_iters {
        body_a();
        body_b();
    }
    let mut samples_a = Vec::with_capacity(cfg.sample_iters);
    let mut samples_b = Vec::with_capacity(cfg.sample_iters);
    for _ in 0..cfg.sample_iters {
        prep();
        let t0 = Instant::now();
        for _ in 0..cfg.inner_reps {
            body_a();
        }
        samples_a.push(t0.elapsed().as_secs_f64() / cfg.inner_reps as f64);
        prep();
        let t1 = Instant::now();
        for _ in 0..cfg.inner_reps {
            body_b();
        }
        samples_b.push(t1.elapsed().as_secs_f64() / cfg.inner_reps as f64);
    }
    (Measurement { samples: samples_a }, Measurement { samples: samples_b })
}

/// Overhead of `protected` relative to `baseline`, from medians:
/// `(t_p - t_b) / t_b`. Matches the paper's Fig 5 / Fig 6 y-axis.
pub fn overhead_pct(baseline: &Measurement, protected: &Measurement) -> f64 {
    let b = baseline.median();
    let p = protected.median();
    (p - b) / b * 100.0
}

/// Render one figure-style row: name, baseline, protected, overhead.
pub fn format_row(name: &str, baseline: &Measurement, protected: &Measurement) -> String {
    format!(
        "{:<24} base={:>9.3}us prot={:>9.3}us overhead={:>6.2}%",
        name,
        baseline.median() * 1e6,
        protected.median() * 1e6,
        overhead_pct(baseline, protected)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            sample_iters: 5,
            inner_reps: 10,
        };
        let mut acc = 0u64;
        let m = measure(&cfg, || {}, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        std::hint::black_box(acc);
        assert_eq!(m.samples.len(), 5);
        assert!(m.median() > 0.0);
    }

    #[test]
    fn overhead_of_double_work_positive() {
        let cfg = BenchConfig {
            warmup_iters: 2,
            sample_iters: 9,
            inner_reps: 50,
        };
        let work = |n: u64| {
            let mut acc = 0u64;
            for i in 0..n {
                acc = acc.wrapping_add(std::hint::black_box(i).wrapping_mul(i));
            }
            std::hint::black_box(acc);
        };
        let base = measure(&cfg, || {}, || work(20_000));
        let double = measure(&cfg, || {}, || work(40_000));
        let oh = overhead_pct(&base, &double);
        assert!(oh > 40.0 && oh < 200.0, "overhead={oh}");
    }
}
