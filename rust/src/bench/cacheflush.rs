//! Cache flushing for memory-bound benchmarks (paper §VI-A2: "We flush the
//! cache since the embedding table is too large to be held in the cache in
//! real world scenarios").

/// A buffer larger than any realistic LLC; sweeping it evicts the
/// benchmark's working set.
pub struct CacheFlusher {
    buf: Vec<u8>,
    sink: u64,
}

/// 256 MiB — comfortably past typical LLC (CLFLUSH would be exact but
/// needs per-line loops over gigabyte tables; a sweep is what FBGEMM's own
/// benchmarks do).
pub const DEFAULT_FLUSH_BYTES: usize = 256 << 20;

impl CacheFlusher {
    pub fn new() -> Self {
        Self::with_bytes(DEFAULT_FLUSH_BYTES)
    }

    pub fn with_bytes(bytes: usize) -> Self {
        Self {
            buf: vec![1u8; bytes],
            sink: 0,
        }
    }

    /// Read+write sweep; the data dependency on `sink` stops dead-code
    /// elimination.
    pub fn flush(&mut self) {
        let mut acc = self.sink;
        for chunk in self.buf.chunks_mut(64) {
            acc = acc.wrapping_add(chunk[0] as u64);
            chunk[0] = chunk[0].wrapping_add(1);
        }
        self.sink = acc;
    }

    pub fn sink(&self) -> u64 {
        self.sink
    }
}

impl Default for CacheFlusher {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flush_touches_every_line() {
        let mut f = CacheFlusher::with_bytes(1 << 20);
        let s0 = f.sink();
        f.flush();
        assert_ne!(f.sink(), s0);
        // Second flush sees the incremented bytes.
        let s1 = f.sink();
        f.flush();
        assert_ne!(f.sink(), s1);
    }
}
