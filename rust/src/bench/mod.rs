//! Benchmark infrastructure: the measurement harness (criterion is not in
//! the offline crate set), cache flushing for memory-bound runs, and the
//! paper's workload generators.

pub mod cacheflush;
pub mod figures;
pub mod trace;
pub mod harness;
pub mod roofline;
pub mod workload;

pub use cacheflush::CacheFlusher;
pub use harness::{measure, overhead_pct, BenchConfig, Measurement};
pub use workload::{gen_eb_batch, table1_settings, EbSetting, IndexDist};
