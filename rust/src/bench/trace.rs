//! Request traces: a JSONL format for recording, generating, and
//! replaying serving workloads — the paper evaluates on synthetic uniform
//! traffic (Table I); production CTR traffic is zipfian and bursty, so
//! the trace layer lets every bench run against either, or against a
//! captured trace file.
//!
//! One JSON object per line:
//! `{"at_us": 1234, "dense": [...], "sparse": [[...], ...]}`

use crate::dlrm::DlrmConfig;
use crate::util::json::Json;
use crate::util::rng::{Pcg32, Zipf};
use anyhow::{anyhow, Result};
use std::io::{BufRead, Write};

/// One traced request: arrival offset + model inputs.
#[derive(Clone, Debug, PartialEq)]
pub struct TracedRequest {
    /// Arrival time offset from trace start, microseconds.
    pub at_us: u64,
    pub dense: Vec<f32>,
    pub sparse: Vec<Vec<usize>>,
}

impl TracedRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_us", Json::Num(self.at_us as f64)),
            (
                "dense",
                Json::Arr(self.dense.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
            (
                "sparse",
                Json::Arr(
                    self.sparse
                        .iter()
                        .map(|t| Json::Arr(t.iter().map(|&i| Json::Num(i as f64)).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            at_us: j
                .get("at_us")
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("missing at_us"))? as u64,
            dense: j
                .get("dense")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing dense"))?
                .iter()
                .map(|x| x.as_f64().map(|v| v as f32).ok_or_else(|| anyhow!("bad dense")))
                .collect::<Result<_>>()?,
            sparse: j
                .get("sparse")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing sparse"))?
                .iter()
                .map(|t| {
                    t.as_arr()
                        .ok_or_else(|| anyhow!("bad sparse"))?
                        .iter()
                        .map(|i| i.as_usize().ok_or_else(|| anyhow!("bad index")))
                        .collect()
                })
                .collect::<Result<_>>()?,
        })
    }
}

/// Trace-generation parameters.
#[derive(Clone, Debug)]
pub struct TraceGenConfig {
    /// Mean arrival rate, requests/second (Poisson).
    pub rate: f64,
    pub requests: usize,
    /// Zipf exponent for sparse indices; None = uniform (paper setup).
    pub zipf_s: Option<f64>,
    pub seed: u64,
}

impl Default for TraceGenConfig {
    fn default() -> Self {
        Self {
            rate: 500.0,
            requests: 1000,
            zipf_s: Some(1.05),
            seed: 0x7124CE,
        }
    }
}

/// Generate a synthetic trace against a model config.
pub fn generate_trace(model_cfg: &DlrmConfig, gen: &TraceGenConfig) -> Vec<TracedRequest> {
    let mut rng = Pcg32::new(gen.seed);
    let zipfs: Option<Vec<Zipf>> = gen.zipf_s.map(|s| {
        model_cfg
            .tables
            .iter()
            .map(|t| Zipf::new(t.rows.min(1 << 18), s))
            .collect()
    });
    let mut at = 0f64;
    let mut out = Vec::with_capacity(gen.requests);
    for _ in 0..gen.requests {
        at += crate::bench::workload::poisson_gap(gen.rate, &mut rng) * 1e6;
        let sparse = model_cfg
            .tables
            .iter()
            .enumerate()
            .map(|(t, tc)| {
                (0..tc.pooling.max(1))
                    .map(|_| match &zipfs {
                        Some(z) => {
                            let stride = (tc.rows / (1 << 18).min(tc.rows)).max(1);
                            (z[t].sample(&mut rng) * stride) % tc.rows
                        }
                        None => rng.gen_range(0, tc.rows),
                    })
                    .collect()
            })
            .collect();
        out.push(TracedRequest {
            at_us: at as u64,
            dense: (0..model_cfg.num_dense).map(|_| rng.next_f32()).collect(),
            sparse,
        });
    }
    out
}

/// Write a trace as JSONL.
pub fn write_trace<W: Write>(w: &mut W, trace: &[TracedRequest]) -> Result<()> {
    for req in trace {
        writeln!(w, "{}", req.to_json())?;
    }
    Ok(())
}

/// Read a JSONL trace.
pub fn read_trace<R: BufRead>(r: R) -> Result<Vec<TracedRequest>> {
    let mut out = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(&line).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        out.push(TracedRequest::from_json(&j).map_err(|e| anyhow!("line {}: {e}", lineno + 1))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::TableConfig;

    fn cfg() -> DlrmConfig {
        DlrmConfig {
            num_dense: 4,
            tables: vec![
                TableConfig { rows: 1000, pooling: 5 },
                TableConfig { rows: 200, pooling: 2 },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn generate_shapes_and_monotone_arrivals() {
        let trace = generate_trace(&cfg(), &TraceGenConfig { requests: 50, ..Default::default() });
        assert_eq!(trace.len(), 50);
        let mut prev = 0;
        for req in &trace {
            assert!(req.at_us >= prev, "arrivals must be monotone");
            prev = req.at_us;
            assert_eq!(req.dense.len(), 4);
            assert_eq!(req.sparse.len(), 2);
            assert_eq!(req.sparse[0].len(), 5);
            assert!(req.sparse[0].iter().all(|&i| i < 1000));
            assert!(req.sparse[1].iter().all(|&i| i < 200));
        }
    }

    #[test]
    fn jsonl_roundtrip() {
        let trace = generate_trace(&cfg(), &TraceGenConfig { requests: 20, ..Default::default() });
        let mut buf = Vec::new();
        write_trace(&mut buf, &trace).unwrap();
        let back = read_trace(std::io::BufReader::new(&buf[..])).unwrap();
        assert_eq!(trace, back);
    }

    #[test]
    fn zipf_trace_skews_indices() {
        let trace = generate_trace(
            &cfg(),
            &TraceGenConfig { requests: 200, zipf_s: Some(1.2), ..Default::default() },
        );
        let mut counts = std::collections::HashMap::new();
        for req in &trace {
            for &i in &req.sparse[0] {
                *counts.entry(i).or_insert(0usize) += 1;
            }
        }
        let max = counts.values().max().copied().unwrap();
        assert!(max > 5, "zipf head should repeat (max count {max})");
    }

    #[test]
    fn uniform_trace_covers_range() {
        let trace = generate_trace(
            &cfg(),
            &TraceGenConfig { requests: 300, zipf_s: None, ..Default::default() },
        );
        let max_idx = trace
            .iter()
            .flat_map(|r| r.sparse[0].iter())
            .max()
            .copied()
            .unwrap();
        assert!(max_idx > 800, "uniform indices should reach high ids");
    }

    #[test]
    fn bad_lines_reported_with_lineno() {
        let data = b"{\"at_us\":1,\"dense\":[],\"sparse\":[]}\nnot json\n";
        let err = read_trace(std::io::BufReader::new(&data[..])).unwrap_err();
        assert!(format!("{err}").contains("line 2"));
    }
}
