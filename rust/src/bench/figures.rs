//! Regeneration of every table and figure in the paper's evaluation
//! (§VI): Fig 5, Table I + Fig 6a/6b, Table II, Table III, plus the §IV-C
//! analytic-vs-Monte-Carlo validation and the design-choice ablations.
//!
//! Shared by `benches/*`, `examples/*` and the `dlrm-abft bench` CLI.

use crate::abft::baselines::{Blas2Abft, EncodeA, Full32Abft};
use crate::abft::{analysis, AbftGemm, EbChecksum};
use crate::bench::cacheflush::CacheFlusher;
use crate::bench::harness::{measure_pair, overhead_pct, BenchConfig, Measurement};
use crate::bench::workload::{gen_eb_batch, table1_settings, EbSetting, IndexDist};
use crate::embedding::{embedding_bag_8, QuantTable8};
use crate::fault::campaign::{
    fig5_shapes, run_eb_campaign, run_eb_campaign_4bit, run_gemm_trial, EbCampaignConfig,
    EbTarget, GemmCampaignConfig, GemmCampaignResult, GemmTarget, Tally,
};
use crate::gemm::{gemm_exec_into, PackedB};
use crate::util::rng::Pcg32;
use crate::util::threadpool::ThreadPool;
use std::io::Write;

/// One bar of Fig 5.
#[derive(Clone, Debug)]
pub struct Fig5Row {
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub base: Measurement,
    pub protected: Measurement,
}

impl Fig5Row {
    pub fn overhead(&self) -> f64 {
        overhead_pct(&self.base, &self.protected)
    }
}

/// Fig 5: ABFT overhead for the 28 DLRM GEMM shapes. Encoding/packing is
/// done once outside the timed region (the paper's amortization argument,
/// §IV-A1 — B is encoded once for many GEMMs).
pub fn run_fig5(cfg: &BenchConfig, out: &mut dyn Write) -> Vec<Fig5Row> {
    let mut rows = Vec::new();
    writeln!(out, "# Fig 5 — ABFT overhead, low-precision GEMM (28 DLRM shapes)").unwrap();
    writeln!(out, "{:>4} {:>5} {:>5} {:>12} {:>12} {:>9}", "m", "n", "k", "base_us", "abft_us", "overhead").unwrap();
    for (m, n, k) in fig5_shapes() {
        let mut rng = Pcg32::new((m * 1_000_003 + n * 1009 + k) as u64);
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let plain = PackedB::pack(&b, k, n);
        let abft = AbftGemm::new(&b, k, n);
        let mut c_plain = vec![0i32; m * n];
        let mut c_prot = vec![0i32; m * (n + 1)];

        let mut errs = 0usize;
        let (base, protected) = measure_pair(
            cfg,
            || {},
            || {
                gemm_exec_into(&a, &plain, m, &mut c_plain);
                std::hint::black_box(&c_plain);
            },
            || {
                let verdict = abft.exec_into(&a, m, &mut c_prot);
                errs += verdict.err_count();
                std::hint::black_box(&c_prot);
            },
        );
        assert_eq!(errs, 0, "clean bench must not flag");
        let row = Fig5Row { m, n, k, base, protected };
        writeln!(
            out,
            "{:>4} {:>5} {:>5} {:>12.2} {:>12.2} {:>8.2}%",
            m,
            n,
            k,
            row.base.median() * 1e6,
            row.protected.median() * 1e6,
            row.overhead()
        )
        .unwrap();
        rows.push(row);
    }
    summarize_fig5(&rows, out);
    rows
}

fn summarize_fig5(rows: &[Fig5Row], out: &mut dyn Write) {
    let under5 = rows.iter().filter(|r| r.overhead() < 5.0).count();
    let under10 = rows.iter().filter(|r| r.overhead() < 10.0).count();
    let under20 = rows.iter().filter(|r| r.overhead() < 20.0).count();
    writeln!(
        out,
        "summary: {}/{} shapes <5%, {}/{} <10%, {}/{} <20% (paper: 7, 17, 28)",
        under5,
        rows.len(),
        under10,
        rows.len(),
        under20,
        rows.len()
    )
    .unwrap();
}

/// One row of Fig 6.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub setting: EbSetting,
    pub weighted: bool,
    pub prefetch: bool,
    pub base: Measurement,
    pub protected: Measurement,
}

impl Fig6Row {
    pub fn overhead(&self) -> f64 {
        overhead_pct(&self.base, &self.protected)
    }
}

/// Fig 6 (a: no prefetch, b: prefetch) over the Table-I settings,
/// {sum, weighted-sum} × d ∈ {32,64,128,256}. Cache flushed before every
/// sample (§VI-A2). `scale` divides the 4M-row table for quick runs.
pub fn run_fig6(cfg: &BenchConfig, scale: usize, out: &mut dyn Write) -> Vec<Fig6Row> {
    let mut rows = Vec::new();
    let mut flusher = CacheFlusher::new();
    writeln!(out, "# Fig 6 — ABFT overhead, low-precision EmbeddingBag (Table I settings)").unwrap();
    writeln!(
        out,
        "{:>6} {:>5} {:>9} {:>9} {:>12} {:>12} {:>9}",
        "rows", "dim", "weighted", "prefetch", "base_us", "abft_us", "overhead"
    )
    .unwrap();
    for mut setting in table1_settings() {
        setting.table_rows /= scale.max(1);
        let mut rng = Pcg32::new(setting.dim as u64);
        let table = QuantTable8::random(setting.table_rows, setting.dim, &mut rng);
        let checksum = EbChecksum::build_8(&table);
        for &prefetch in &[false, true] {
            for &weighted in &[false, true] {
                let (indices, offsets) = gen_eb_batch(&setting, &IndexDist::Uniform, &mut rng);
                let weights: Option<Vec<f32>> = weighted
                    .then(|| indices.iter().map(|_| 0.5 + rng.next_f32()).collect());
                let (base, protected) = measure_pair(
                    cfg,
                    || flusher.flush(),
                    || {
                        let r = embedding_bag_8(&table, &indices, &offsets, weights.as_deref(), prefetch);
                        std::hint::black_box(&r);
                    },
                    || {
                        let r = embedding_bag_8(&table, &indices, &offsets, weights.as_deref(), prefetch);
                        let flagged = checksum.check_batch(
                            &table.alpha,
                            &table.beta,
                            &indices,
                            &offsets,
                            weights.as_deref(),
                            &r,
                        );
                        std::hint::black_box((&r, &flagged));
                    },
                );
                let row = Fig6Row { setting, weighted, prefetch, base, protected };
                writeln!(
                    out,
                    "{:>6}k {:>5} {:>9} {:>9} {:>12.2} {:>12.2} {:>8.2}%",
                    setting.table_rows / 1000,
                    setting.dim,
                    weighted,
                    prefetch,
                    row.base.median() * 1e6,
                    row.protected.median() * 1e6,
                    row.overhead()
                )
                .unwrap();
                rows.push(row);
            }
        }
    }
    let max = rows.iter().map(|r| r.overhead()).fold(f64::MIN, f64::max);
    writeln!(out, "summary: max EB overhead {max:.2}% (paper: <26%)").unwrap();
    rows
}

/// §Perf: the fused-vs-naive protected EmbeddingBag comparison (the EB
/// hot-path optimization). Three arms per Table-I setting, cache flushed:
/// unprotected bag / naive Alg-2 (bag then re-walk for C_T) / fused
/// (interleaved meta, checksum inside the loop).
pub fn run_eb_fused_perf(cfg: &BenchConfig, scale: usize, out: &mut dyn Write) {
    let mut flusher = CacheFlusher::new();
    writeln!(out, "# §Perf — EB protection cost: naive Alg-2 vs fused layout (prefetch on)").unwrap();
    writeln!(
        out,
        "{:>6} {:>5} {:>12} {:>12} {:>12} {:>9} {:>9}",
        "rows", "dim", "base_us", "naive_us", "fused_us", "naiveOH", "fusedOH"
    )
    .unwrap();
    for mut setting in table1_settings() {
        setting.table_rows /= scale.max(1);
        let mut rng = Pcg32::new(setting.dim as u64 ^ 0xFEED);
        let table = QuantTable8::random(setting.table_rows, setting.dim, &mut rng);
        let checksum = EbChecksum::build_8(&table);
        let fused = checksum.clone().fuse(&table);
        let (indices, offsets) = gen_eb_batch(&setting, &IndexDist::Uniform, &mut rng);
        let d = setting.dim;

        let (base, naive) = measure_pair(
            cfg,
            || flusher.flush(),
            || {
                let r = embedding_bag_8(&table, &indices, &offsets, None, true);
                std::hint::black_box(&r);
            },
            || {
                let r = embedding_bag_8(&table, &indices, &offsets, None, true);
                let flagged =
                    checksum.check_batch(&table.alpha, &table.beta, &indices, &offsets, None, &r);
                std::hint::black_box((&r, &flagged));
            },
        );
        let (base2, fused_m) = measure_pair(
            cfg,
            || flusher.flush(),
            || {
                let r = embedding_bag_8(&table, &indices, &offsets, None, true);
                std::hint::black_box(&r);
            },
            || {
                let batch = offsets.len();
                let mut r = vec![0f32; batch * d];
                let mut any = false;
                for b in 0..batch {
                    let start = offsets[b];
                    let end = if b + 1 < batch { offsets[b + 1] } else { indices.len() };
                    any |= fused.bag_sum_checked(
                        &table,
                        &indices[start..end],
                        None,
                        true,
                        &mut r[b * d..(b + 1) * d],
                    );
                }
                std::hint::black_box((&r, any));
            },
        );
        writeln!(
            out,
            "{:>6}k {:>5} {:>12.2} {:>12.2} {:>12.2} {:>8.2}% {:>8.2}%",
            setting.table_rows / 1000,
            d,
            base.median() * 1e6,
            naive.median() * 1e6,
            fused_m.median() * 1e6,
            overhead_pct(&base, &naive),
            overhead_pct(&base2, &fused_m)
        )
        .unwrap();
    }
}

/// Table II, parallelized across shapes (deterministic per-shape streams).
pub fn run_table2(cfg: &GemmCampaignConfig, threads: usize, out: &mut dyn Write) -> GemmCampaignResult {
    let pool = ThreadPool::new(threads.max(1));
    let shapes = cfg.shapes.clone();
    let cfg2 = cfg.clone();
    let per_shape = pool.map(shapes, move |(m, n, k)| {
        let mut rng = Pcg32::new(cfg2.seed ^ ((m * 73_856_093 + n * 19_349_663 + k) as u64));
        let mut r = GemmCampaignResult::default();
        for _ in 0..cfg2.runs_per_shape {
            tally_add(&mut r.error_in_b, run_gemm_trial(m, n, k, GemmTarget::MatrixB, &cfg2, &mut rng));
            tally_add(&mut r.error_in_c, run_gemm_trial(m, n, k, GemmTarget::MatrixC, &cfg2, &mut rng));
            tally_add(&mut r.no_error, run_gemm_trial(m, n, k, GemmTarget::None, &cfg2, &mut rng));
        }
        r
    });
    let mut total = GemmCampaignResult::default();
    for r in per_shape {
        merge_tally(&mut total.error_in_b, &r.error_in_b);
        merge_tally(&mut total.error_in_c, &r.error_in_c);
        merge_tally(&mut total.no_error, &r.no_error);
    }
    writeln!(out, "# Table II — GEMM detection campaign ({} runs/arm)", total.error_in_b.total()).unwrap();
    writeln!(out, "{:<18} {:>10} {:>10} {:>9}", "", "error in B", "error in C", "no error").unwrap();
    writeln!(
        out,
        "{:<18} {:>10} {:>10} {:>9}",
        "detected runs", total.error_in_b.detected, total.error_in_c.detected, total.no_error.detected
    )
    .unwrap();
    writeln!(
        out,
        "{:<18} {:>10} {:>10} {:>9}",
        "not detected runs",
        total.error_in_b.not_detected,
        total.error_in_c.not_detected,
        total.no_error.not_detected
    )
    .unwrap();
    writeln!(
        out,
        "rates: B {:.2}% (paper 95.11%), C {:.2}% (paper 100%), FP {:.2}% (paper 0%)",
        total.error_in_b.rate() * 100.0,
        total.error_in_c.rate() * 100.0,
        total.no_error.rate() * 100.0
    )
    .unwrap();
    total
}

fn tally_add(t: &mut Tally, detected: bool) {
    if detected {
        t.detected += 1;
    } else {
        t.not_detected += 1;
    }
}

fn merge_tally(into: &mut Tally, from: &Tally) {
    into.detected += from.detected;
    into.not_detected += from.not_detected;
}

/// Table III result set.
#[derive(Clone, Debug)]
pub struct Table3Result {
    pub high_bits: Tally,
    pub low_bits: Tally,
    pub no_error: Tally,
}

/// Table III: EB detection campaign (200 high-bit, 200 low-bit, 400 clean
/// in the paper; scaled by `runs_scale`).
pub fn run_table3(cfg: &EbCampaignConfig, runs_scale: usize, out: &mut dyn Write) -> Table3Result {
    let s = runs_scale.max(1);
    let high_bits = run_eb_campaign(cfg, EbTarget::TableHigh4, 200 / s);
    let low_bits = run_eb_campaign(cfg, EbTarget::TableLow4, 200 / s);
    let no_error = run_eb_campaign(cfg, EbTarget::None, 400 / s);
    writeln!(out, "# Table III — EB detection campaign (rows={}, d={})", cfg.table_rows, cfg.dim).unwrap();
    writeln!(out, "{:<18} {:>10} {:>9} {:>9}", "", "high bits", "low bits", "no error").unwrap();
    writeln!(
        out,
        "{:<18} {:>10} {:>9} {:>9}",
        "detected runs", high_bits.detected, low_bits.detected, no_error.detected
    )
    .unwrap();
    writeln!(
        out,
        "{:<18} {:>10} {:>9} {:>9}",
        "not detected runs", high_bits.not_detected, low_bits.not_detected, no_error.not_detected
    )
    .unwrap();
    writeln!(
        out,
        "rates: high {:.1}% (paper 99.5%), low {:.1}% (paper 47%), FP {:.1}% (paper 9.5%)",
        high_bits.rate() * 100.0,
        low_bits.rate() * 100.0,
        no_error.rate() * 100.0
    )
    .unwrap();
    Table3Result { high_bits, low_bits, no_error }
}

/// Table-III extension: the same campaign over a 4-bit table (paper
/// §V-C's p=4 memory-optimized configuration).
pub fn run_table3_4bit(cfg: &EbCampaignConfig, runs_scale: usize, out: &mut dyn Write) -> Table3Result {
    let s = runs_scale.max(1);
    let high_bits = run_eb_campaign_4bit(cfg, EbTarget::TableHigh4, 200 / s);
    let low_bits = run_eb_campaign_4bit(cfg, EbTarget::TableLow4, 200 / s);
    let no_error = run_eb_campaign_4bit(cfg, EbTarget::None, 400 / s);
    writeln!(out, "# Table III ext — 4-bit EB detection (rows={}, d={})", cfg.table_rows, cfg.dim).unwrap();
    writeln!(
        out,
        "rates: high-2-bits-of-nibble {:.1}%, low-2-bits {:.1}%, FP {:.1}%",
        high_bits.rate() * 100.0,
        low_bits.rate() * 100.0,
        no_error.rate() * 100.0
    )
    .unwrap();
    Table3Result { high_bits, low_bits, no_error }
}

/// §IV-C analytic bounds vs Monte-Carlo measurement.
pub fn run_analysis(trials: usize, out: &mut dyn Write) {
    writeln!(out, "# §IV-C — analytic detection probability vs Monte-Carlo ({trials} trials/cell)").unwrap();
    writeln!(out, "{:<34} {:>4} {:>10} {:>10}", "case", "m", "analytic", "measured").unwrap();
    let (n, k) = (64usize, 48usize);
    for &m in &[1usize, 2, 4] {
        for (label, model, target, analytic) in [
            (
                "bitflip in B",
                crate::fault::FaultModel::BitFlip,
                GemmTarget::MatrixB,
                analysis::p_detect_bitflip_in_b(m),
            ),
            (
                "fluctuation in B",
                crate::fault::FaultModel::DataFluctuation,
                GemmTarget::MatrixB,
                analysis::p_detect_fluctuation_in_b(m),
            ),
            (
                "bitflip in C",
                crate::fault::FaultModel::BitFlip,
                GemmTarget::MatrixC,
                analysis::p_detect_bitflip_in_c(),
            ),
            (
                "fluctuation in C (lower bnd)",
                crate::fault::FaultModel::DataFluctuation,
                GemmTarget::MatrixC,
                analysis::p_detect_fluctuation_in_c_lower_bound(127),
            ),
        ] {
            let cfg = GemmCampaignConfig {
                shapes: vec![(m, n, k)],
                runs_per_shape: trials,
                fault_model: model,
                ..Default::default()
            };
            let mut rng = Pcg32::new(0xA11A ^ m as u64 ^ (model as u64) << 8 ^ (target == GemmTarget::MatrixB) as u64);
            let mut detected = 0usize;
            for _ in 0..trials {
                if run_gemm_trial(m, n, k, target, &cfg, &mut rng) {
                    detected += 1;
                }
            }
            let measured = detected as f64 / trials as f64;
            writeln!(
                out,
                "{:<34} {:>4} {:>9.4}% {:>9.4}%",
                label,
                m,
                analytic * 100.0,
                measured * 100.0
            )
            .unwrap();
        }
    }
}

/// Design-choice ablations (E6): modulus policy, encode side, BLAS level,
/// checksum width, DMR. Every variant is measured *interleaved* with the
/// unprotected baseline so drift cancels out of the ratio.
pub fn run_ablations(cfg: &BenchConfig, out: &mut dyn Write) {
    let (m, n, k) = (100usize, 512usize, 512usize);
    let mut rng = Pcg32::new(0xAB1A);
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    let plain = PackedB::pack(&b, k, n);
    writeln!(out, "# Ablations on ({m},{n},{k}) — interleaved vs unprotected baseline").unwrap();

    let mut report = |name: &str, mut body: Box<dyn FnMut() + '_>| {
        let mut c_base = vec![0i32; m * n];
        let (base, variant) = measure_pair(
            cfg,
            || {},
            || {
                gemm_exec_into(&a, &plain, m, &mut c_base);
                std::hint::black_box(&c_base);
            },
            || body(),
        );
        writeln!(
            out,
            "{:<36} base {:>9.2}us  variant {:>9.2}us  overhead {:>7.2}%",
            name,
            base.median() * 1e6,
            variant.median() * 1e6,
            overhead_pct(&base, &variant)
        )
        .unwrap();
    };

    // 1. BLAS-3 packed-checksum ABFT (the paper's design).
    let abft = AbftGemm::new(&b, k, n);
    let mut c_prot = vec![0i32; m * (n + 1)];
    report(
        "encode-B, mod127, BLAS-3 (paper)",
        Box::new(|| {
            let v = abft.exec_into(&a, m, &mut c_prot);
            std::hint::black_box((&c_prot, v.err_count()));
        }),
    );

    // 2. BLAS-2 variant (§IV-A3's rejected implementation).
    let blas2 = Blas2Abft::new(&b, k, n, 127);
    report(
        "encode-B, mod127, BLAS-2",
        Box::new(|| {
            let (c, bad) = blas2.exec(&a, &plain, m);
            std::hint::black_box((c, bad));
        }),
    );

    // 3. 32-bit checksum (exact, no modulo; §IV-A2's rejected width).
    let full32 = Full32Abft::new(&b, k, n);
    report(
        "encode-B, 32-bit checksum",
        Box::new(|| {
            let (c, bad) = full32.exec(&a, &plain, m);
            std::hint::black_box((c, bad));
        }),
    );

    // 4. Encode-A (re-encoded every call; §IV-A1's rejected side).
    let enc_a = EncodeA::new();
    report(
        "encode-A (per-call)",
        Box::new(|| {
            let (c, bad) = enc_a.exec(&a, &plain, m);
            std::hint::black_box((c, bad));
        }),
    );

    // 5. DMR (compute twice; §II's ≥100% strawman).
    let mut c1 = vec![0i32; m * n];
    let mut c2 = vec![0i32; m * n];
    report(
        "DMR (run twice + compare)",
        Box::new(|| {
            gemm_exec_into(&a, &plain, m, &mut c1);
            gemm_exec_into(&a, &plain, m, &mut c2);
            std::hint::black_box(c1 == c2);
        }),
    );

    // 6. Modulus detection-strength sweep (analytic).
    writeln!(out, "modulus sweep (analytic P(detect), fluctuation-in-B, m=1):").unwrap();
    for &modulus in &[127u32, 113, 31, 3] {
        debug_assert!(analysis::is_prime(modulus));
        let p = analysis::p_detect_fluctuation_in_b_general(1, modulus);
        writeln!(
            out,
            "  mod {:>3}: {:>8.4}% {}",
            modulus,
            p * 100.0,
            if modulus == 127 { "(paper's choice)" } else { "" }
        )
        .unwrap();
    }

    run_eb_bound_sweep(out);
}

/// §V-D ablation: the round-off-bound / checker-precision trade-off.
/// Sweeps rel_bound × {f32, f64} accumulation and reports low-bit
/// detection vs false positives — the dial the paper sets to 1e-5/f32.
pub fn run_eb_bound_sweep(out: &mut dyn Write) {
    use crate::abft::CheckPrecision;
    writeln!(out, "# EB bound sweep (rows=200k, d=64, pooling=100, batch=10; 100 runs/arm)").unwrap();
    writeln!(
        out,
        "{:>9} {:>5} {:>10} {:>10} {:>9}",
        "rel_bound", "acc", "high-bit%", "low-bit%", "FP%"
    )
    .unwrap();
    for &(bound, precision, label) in &[
        (1e-4f64, CheckPrecision::F32, "f32"),
        (1e-5, CheckPrecision::F32, "f32"),
        (1e-6, CheckPrecision::F32, "f32"),
        (1e-5, CheckPrecision::F64, "f64"),
        (1e-7, CheckPrecision::F64, "f64"),
    ] {
        let cfg = EbCampaignConfig {
            table_rows: 200_000,
            dim: 64,
            rel_bound: bound,
            precision,
            ..Default::default()
        };
        let high = run_eb_campaign(&cfg, EbTarget::TableHigh4, 100);
        let low = run_eb_campaign(&cfg, EbTarget::TableLow4, 100);
        let fp = run_eb_campaign(&cfg, EbTarget::None, 100);
        writeln!(
            out,
            "{:>9.0e} {:>5} {:>9.1}% {:>9.1}% {:>8.1}%",
            bound,
            label,
            high.rate() * 100.0,
            low.rate() * 100.0,
            fp.rate() * 100.0
        )
        .unwrap();
    }
    writeln!(out, "(paper's operating point: 1e-5/f32 → 99.5% / 47% / 9.5%)").unwrap();
}
