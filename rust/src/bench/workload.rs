//! Workload generators for the benchmark harness: the Fig-5 GEMM shape
//! grid, the Table-I EB settings, and synthetic serving traffic (uniform
//! and zipfian index streams, Poisson arrivals).

use crate::util::rng::{Pcg32, Zipf};

/// One Fig-6 / Table-I EmbeddingBag setting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EbSetting {
    pub table_rows: usize,
    pub dim: usize,
    pub pooling: usize,
    pub batch: usize,
}

/// Paper Table I: 4M rows; d ∈ {32, 64, 128, 256}; pooling 100; batch 10.
pub fn table1_settings() -> Vec<EbSetting> {
    [32usize, 64, 128, 256]
        .iter()
        .map(|&dim| EbSetting {
            table_rows: 4_000_000,
            dim,
            pooling: 100,
            batch: 10,
        })
        .collect()
}

/// Index distribution for synthetic sparse traffic.
#[derive(Clone, Debug)]
pub enum IndexDist {
    Uniform,
    /// Zipfian with exponent s (production CTR streams are heavily skewed).
    Zipf(f64),
}

/// Generate one batch of (indices, offsets) for an EB benchmark, pooling
/// exactly `pooling` per bag (the paper's "average pooling size").
pub fn gen_eb_batch(
    setting: &EbSetting,
    dist: &IndexDist,
    rng: &mut Pcg32,
) -> (Vec<usize>, Vec<usize>) {
    let total = setting.pooling * setting.batch;
    let indices = match dist {
        IndexDist::Uniform => (0..total)
            .map(|_| rng.gen_range(0, setting.table_rows))
            .collect(),
        IndexDist::Zipf(s) => {
            let z = Zipf::new(setting.table_rows.min(1 << 20), *s);
            // Spread the zipf head across the table with a fixed stride so
            // hot rows are not all physically adjacent.
            let stride = (setting.table_rows / z_len(&z)).max(1);
            (0..total)
                .map(|_| (z.sample(rng) * stride) % setting.table_rows)
                .collect()
        }
    };
    let offsets = (0..setting.batch).map(|b| b * setting.pooling).collect();
    (indices, offsets)
}

fn z_len(_z: &Zipf) -> usize {
    1 << 20
}

/// Poisson arrival process for the serving benches: next inter-arrival gap
/// in seconds for rate `lambda` (requests/s).
pub fn poisson_gap(lambda: f64, rng: &mut Pcg32) -> f64 {
    let u = rng.next_f64().max(1e-12);
    -u.ln() / lambda
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let s = table1_settings();
        assert_eq!(s.len(), 4);
        assert!(s.iter().all(|x| x.table_rows == 4_000_000));
        assert!(s.iter().all(|x| x.pooling == 100 && x.batch == 10));
        assert_eq!(
            s.iter().map(|x| x.dim).collect::<Vec<_>>(),
            vec![32, 64, 128, 256]
        );
    }

    #[test]
    fn eb_batch_shapes() {
        let mut rng = Pcg32::new(1);
        let setting = EbSetting {
            table_rows: 1000,
            dim: 32,
            pooling: 7,
            batch: 3,
        };
        let (idx, off) = gen_eb_batch(&setting, &IndexDist::Uniform, &mut rng);
        assert_eq!(idx.len(), 21);
        assert_eq!(off, vec![0, 7, 14]);
        assert!(idx.iter().all(|&i| i < 1000));
    }

    #[test]
    fn zipf_batch_in_range_and_skewed() {
        let mut rng = Pcg32::new(2);
        let setting = EbSetting {
            table_rows: 100_000,
            dim: 32,
            pooling: 100,
            batch: 10,
        };
        let (idx, _) = gen_eb_batch(&setting, &IndexDist::Zipf(1.1), &mut rng);
        assert!(idx.iter().all(|&i| i < 100_000));
        let distinct: std::collections::HashSet<_> = idx.iter().collect();
        assert!(distinct.len() < idx.len(), "zipf should repeat hot rows");
    }

    #[test]
    fn poisson_gaps_average_to_rate() {
        let mut rng = Pcg32::new(3);
        let lambda = 100.0;
        let n = 20_000;
        let total: f64 = (0..n).map(|_| poisson_gap(lambda, &mut rng)).sum();
        let mean = total / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.001, "mean={mean}");
    }
}
