//! Roofline analysis for the §Perf pass: measure this machine's practical
//! compute and bandwidth ceilings with microkernels, then place each hot
//! kernel on the roofline to decide whether "stop optimizing" is honest.

use crate::bench::harness::{measure, BenchConfig};
use crate::gemm::{gemm_exec_into, PackedB};
use crate::util::rng::Pcg32;
use std::io::Write;

/// Machine ceilings measured with microkernels.
#[derive(Clone, Copy, Debug)]
pub struct MachineRoof {
    /// Peak sustainable int32 multiply-accumulate rate, Gop/s (2 ops per
    /// MAC), register-resident.
    pub peak_gops: f64,
    /// Peak sustainable read bandwidth, GiB/s, streaming a buffer far
    /// beyond LLC.
    pub peak_gibs: f64,
}

/// Register-resident i32 MAC microkernel: 8 independent accumulator
/// lanes × unrolled loop — approximates the best the compiler can do on
/// this core for the GEMM inner loop's arithmetic.
pub fn measure_peak_compute(cfg: &BenchConfig) -> f64 {
    // Use the production kernel itself on an all-in-L1 problem
    // (A 32 KiB, B 64 KiB, C 128 KiB — L2-resident): no DRAM pressure, so this is the
    // practical compute ceiling *for this kernel's instruction mix* on
    // this core. (Synthetic MAC loops either get closed-form-folded by
    // LLVM or serialize on the multiply latency; the kernel's own
    // register tile is the honest probe.)
    let (m, n, k) = (128usize, 256usize, 256usize);
    let mut rng = Pcg32::new(0xF00D);
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    let packed = PackedB::pack(&b, k, n);
    let mut c = vec![0i32; m * n];
    let meas = measure(cfg, || {}, || {
        gemm_exec_into(&a, &packed, m, &mut c);
        std::hint::black_box(&c);
    });
    2.0 * (m * n * k) as f64 / meas.median() / 1e9
}

/// Streaming-read bandwidth over a 256 MiB buffer (u64 strides, summed).
pub fn measure_peak_bandwidth(cfg: &BenchConfig) -> f64 {
    let words = (256usize << 20) / 8;
    let mut rng = Pcg32::new(1);
    let buf: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let mut sink = 0u64;
    let m = measure(cfg, || {}, || {
        let mut acc = 0u64;
        for &x in &buf {
            acc = acc.wrapping_add(x);
        }
        sink = sink.wrapping_add(acc);
    });
    std::hint::black_box(sink);
    (words * 8) as f64 / m.median() / (1u64 << 30) as f64
}

/// Place one kernel on the roofline.
#[derive(Clone, Debug)]
pub struct KernelPoint {
    pub name: String,
    /// Arithmetic intensity, ops per byte moved (model).
    pub intensity: f64,
    pub measured_gops: f64,
    pub roof_gops: f64,
}

impl KernelPoint {
    pub fn efficiency(&self) -> f64 {
        self.measured_gops / self.roof_gops
    }
}

/// Full roofline report for the GEMM kernel across the paper's shapes.
pub fn run_roofline(cfg: &BenchConfig, out: &mut dyn Write) -> Vec<KernelPoint> {
    writeln!(out, "# §Perf roofline — machine ceilings + kernel placement").unwrap();
    let mut peak_gops = measure_peak_compute(cfg);
    let peak_gibs = measure_peak_bandwidth(cfg);
    writeln!(
        out,
        "machine: peak compute {peak_gops:.1} Gop/s (i32 MAC), peak read bw {peak_gibs:.1} GiB/s"
    )
    .unwrap();

    let mut raw = Vec::new();
    let mut rng = Pcg32::new(0x200F);
    for &(m, n, k) in &[(1usize, 800usize, 3200usize), (16, 512, 512), (100, 512, 512), (150, 800, 3200)] {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        let packed = PackedB::pack(&b, k, n);
        let mut c = vec![0i32; m * n];
        let meas = measure(cfg, || {}, || {
            gemm_exec_into(&a, &packed, m, &mut c);
            std::hint::black_box(&c);
        });
        let ops = 2.0 * (m * n * k) as f64;
        // Traffic model: A + B once per GEMM (B panel re-streamed from L2,
        // counted once from memory), C written once.
        let bytes = (m * k + k * n + 4 * m * n) as f64;
        let intensity = ops / bytes;
        let measured_gops = ops / meas.median() / 1e9;
        raw.push((format!("qgemm ({m},{n},{k})"), intensity, measured_gops));
    }
    // The probe can undershoot what big shapes attain (more tile reuse);
    // the honest ceiling is the best rate ever observed from this kernel.
    for (_, _, g) in &raw {
        if *g > peak_gops {
            peak_gops = *g;
        }
    }
    writeln!(out, "practical compute ceiling (best observed): {peak_gops:.1} Gop/s").unwrap();
    let mut points = Vec::new();
    for (name, intensity, measured_gops) in raw {
        let roof_gops = peak_gops.min(intensity * peak_gibs * 1.073_741_824);
        let point = KernelPoint { name, intensity, measured_gops, roof_gops };
        writeln!(
            out,
            "{:<22} AI {:>7.1} op/B  measured {:>6.2} Gop/s  roof {:>6.1}  efficiency {:>5.1}%",
            point.name,
            point.intensity,
            point.measured_gops,
            point.roof_gops,
            point.efficiency() * 100.0
        )
        .unwrap();
        points.push(point);
    }
    points
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> BenchConfig {
        BenchConfig { warmup_iters: 1, sample_iters: 3, inner_reps: 1 }
    }

    #[test]
    fn ceilings_are_positive_and_sane() {
        let gops = measure_peak_compute(&quick());
        // Debug builds are ~30-50x slower; only sanity-check positivity+bound.
        assert!(gops > 0.05 && gops < 1000.0, "gops={gops}");
    }

    #[test]
    fn roofline_points_consistent() {
        let mut sink = Vec::new();
        let cfg = quick();
        // Bandwidth microbench allocates 256 MiB; acceptable in a test.
        let points = run_roofline(&cfg, &mut sink);
        assert_eq!(points.len(), 4);
        for p in &points {
            assert!(p.measured_gops > 0.0);
            assert!(p.roof_gops > 0.0);
            assert!(p.efficiency() <= 1.0 + 1e-9, "{p:?}");
        }
    }
}
