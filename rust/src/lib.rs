//! # dlrm-abft
//!
//! Production-quality reproduction of *"Efficient Soft-Error Detection for
//! Low-precision Deep Learning Recommendation Models"* (CS.DC 2021):
//! algorithm-based fault tolerance (ABFT) for the two workhorse operators
//! of quantized DLRM inference — GEMM and EmbeddingBag — integrated as a
//! first-class feature of a serving stack.
//!
//! Layer map (see DESIGN.md):
//! * [`quant`], [`gemm`], [`embedding`] — the low-precision operator
//!   substrate (FBGEMM-lite).
//! * [`abft`] — the paper's contribution: checksum encode/verify for GEMM
//!   (Alg 1) and EB (Alg 2), detection-probability analysis, baselines.
//! * [`detect`] — unified fault-event pipeline: typed detection events,
//!   the severity-ranked recovery ladder, the auditable event journal,
//!   and the sink every detection site emits through.
//! * [`fault`] — soft-error injection + campaign runner (§VI-B).
//! * [`dlrm`] — the recommendation model built from the operators.
//! * [`shard`] — replicated shard store + router: detection-driven
//!   replica quarantine, failover, and checksum-verified repair.
//! * [`policy`] — adaptive detection control plane: per-site detection
//!   modes, telemetry, and the SLO-aware escalation controller.
//! * [`obs`] — observability plane: sampled hot-path span profiler,
//!   live measured detection-overhead accounting feeding the policy
//!   controller, Prometheus exposition.
//! * [`coordinator`] — serving: batching, ABFT verification,
//!   recompute-on-detect, metrics.
//! * [`runtime`] — PJRT loader for the jax/Pallas-lowered model artifacts.
//! * [`bench`] — harness + workload generators regenerating every paper
//!   table and figure.
//! * [`util`] — from-scratch infra (PRNG, JSON, threadpool, stats).

pub mod abft;
pub mod bench;
pub mod coordinator;
pub mod detect;
pub mod dlrm;
pub mod embedding;
pub mod fault;
pub mod gemm;
pub mod obs;
pub mod policy;
pub mod quant;
pub mod runtime;
pub mod shard;
pub mod util;
