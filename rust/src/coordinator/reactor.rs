//! Readiness-driven serving front end (PR 10): one epoll event loop in
//! place of thread-per-connection, for million-connection fan-in.
//!
//! The threaded front end in [`crate::coordinator::server`] spends one
//! OS thread (stack, scheduler slot, wakeup) per connection; past a few
//! thousand mostly-idle connections the machine is scheduling threads,
//! not scoring requests. This module keeps the whole protocol surface —
//! newline-delimited JSON, the same control ops, the same batcher →
//! engine pipeline — but multiplexes every connection onto **one
//! reactor thread** over raw `epoll` (hand-rolled `extern "C"` syscall
//! bindings; the offline build budget of this repo does not admit mio
//! or tokio, and the loop needs ~4 syscalls anyway).
//!
//! Data flow:
//!
//! * The reactor owns the listener and every connection. Per-connection
//!   state is a small machine: a read buffer accumulating bytes until a
//!   newline (parsed with the same zero-alloc
//!   [`ScoreRequest::parse_line_into`] + husk slab as the threaded
//!   path), and a write buffer drained as the socket accepts bytes.
//! * Parsed requests are **admitted** — or not — into the same bounded
//!   [`Batcher`] queues the threaded server uses. A full queue, or an
//!   overload controller in its shedding state
//!   ([`crate::policy::OverloadCtl::should_shed`]), answers
//!   `{"error":"overloaded"}` on the spot; nothing about an overloaded
//!   request ever reaches the engine.
//! * Batch loops (same count, same policy as threaded) score batches
//!   and push `(token, response, husk)` completions onto a shared
//!   vector, then wake the reactor via a self-pipe (a nonblocking
//!   `UnixStream` pair registered in the epoll set).
//! * Control ops (`{"op":"metrics"}` and friends) run on a dedicated
//!   control worker, never on the reactor thread, so a snapshot or a
//!   flight-recorder dump cannot stall a tick. Their replies ride the
//!   same completion queue. Consequence (documented contract): a
//!   pipelined client can see a control reply overtake an in-flight
//!   score; per-connection *score* order is always preserved (each
//!   connection sticks to one FIFO batch loop).
//! * Write backpressure: a connection whose write buffer passes the
//!   high-water mark stops being read (its `EPOLLIN` interest is
//!   dropped) until the buffer drains below the low-water mark — a slow
//!   reader throttles itself, not the server.
//!
//! The reactor thread doubles as the overload pacer: every
//! [`ReactorOptions::tick`] it feeds the deepest queue and the measured
//! p99 window to [`crate::coordinator::engine::Engine::overload_tick`],
//! which presses detection sites down the mode lattice *before*
//! admission sheds anything (degrade-before-drop; see
//! `crate::policy::overload`).
//!
//! Linux-only (`epoll`); the threaded server remains the default and
//! the portable fallback. `--async-io` opts in.

use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{ScoreRequest, ScoreResponse};
use crate::coordinator::server::{control_reply, err_json};
use crate::obs::flow::{self, FlowGuard};
use crate::obs::Stage;
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use anyhow::Result;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Minimal epoll bindings. These symbols live in the C library every
/// Rust binary on Linux already links; no crate needed.
mod sys {
    use std::io;
    use std::os::fd::RawFd;

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLLRDHUP: u32 = 0x2000;

    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    /// Kernel `struct epoll_event`. Packed on x86 (the kernel ABI there
    /// has no padding between `events` and `data`); naturally aligned
    /// elsewhere.
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(C, packed))]
    #[cfg_attr(not(any(target_arch = "x86", target_arch = "x86_64")), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Epoll {
        fd: RawFd,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Epoll { fd })
        }

        pub fn add(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, events, token)
        }

        pub fn modify(&self, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, events, token)
        }

        pub fn del(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent { events, data: token };
            let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Wait for readiness; retries on `EINTR`.
        pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
            loop {
                let rc = unsafe {
                    epoll_wait(self.fd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
                };
                if rc >= 0 {
                    return Ok(rc as usize);
                }
                let e = io::Error::last_os_error();
                if e.kind() != io::ErrorKind::Interrupted {
                    return Err(e);
                }
            }
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe {
                close(self.fd);
            }
        }
    }
}

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// One `read(2)` granularity.
const READ_CHUNK: usize = 16 * 1024;
/// A read buffer past this with pipelined-but-unprocessed input (or one
/// unterminated line) marks the peer as abusive; the connection drops.
const MAX_RBUF: usize = 4 << 20;
/// Write backpressure: stop reading a connection above HIGH pending
/// output bytes, resume below LOW.
const WBUF_HIGH: usize = 256 * 1024;
const WBUF_LOW: usize = 64 * 1024;
/// Husk-slab depth per connection (buffers recycled across requests).
const SLAB_CAP: usize = 64;

/// Reactor knobs (`--max-conns`; the tick paces the overload
/// controller and the queue-depth gauge).
#[derive(Clone, Copy, Debug)]
pub struct ReactorOptions {
    /// Registered-connection ceiling; an accept past it is answered
    /// `{"error":"overloaded"}` and closed. `0` = unlimited.
    pub max_conns: usize,
    /// Overload/housekeeping cadence (also the epoll wait timeout).
    pub tick: Duration,
}

impl Default for ReactorOptions {
    fn default() -> Self {
        Self { max_conns: 4096, tick: Duration::from_millis(50) }
    }
}

/// One queued unit on the async path: the request plus the token of the
/// connection its response goes back to (no per-request channel — the
/// batch loop pushes a completion and wakes the reactor).
struct AsyncPending {
    req: ScoreRequest,
    token: u64,
}

enum Completion {
    Score { token: u64, resp: ScoreResponse, husk: ScoreRequest },
    Line { token: u64, text: String },
}

/// Completion queue + self-pipe shared by batch loops, the control
/// worker, and the reactor.
struct Shared {
    completions: Mutex<Vec<Completion>>,
    wake_tx: UnixStream,
}

impl Shared {
    fn push(&self, c: Completion) {
        self.completions.lock().unwrap().push(c);
    }

    /// Nudge the reactor out of `epoll_wait`. A `WouldBlock` here means
    /// the pipe already holds an undrained wake byte — same effect.
    fn wake(&self) {
        let mut tx = &self.wake_tx;
        let _ = tx.write(&[1u8]);
    }
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet consumed up to a newline.
    rbuf: Vec<u8>,
    /// Bytes queued for the socket; `wpos..` is still unwritten.
    wbuf: Vec<u8>,
    wpos: usize,
    /// Recycled request husks (same zero-alloc contract as the threaded
    /// per-connection slab).
    slab: Vec<ScoreRequest>,
    /// Batch loop this connection hashes to (sticky for its lifetime,
    /// which keeps per-connection score order).
    lix: usize,
    /// Responses not yet queued to `wbuf` (scores in the engine +
    /// control ops on the worker).
    inflight: usize,
    /// Interest set currently registered with epoll.
    interest: u32,
    /// Reads suspended: write backpressure.
    paused: bool,
    /// Peer sent EOF; the connection closes once `inflight` and `wbuf`
    /// drain.
    peer_closed: bool,
}

impl Conn {
    fn new(stream: TcpStream, lix: usize) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: Vec::new(),
            wpos: 0,
            slab: Vec::new(),
            lix,
            inflight: 0,
            interest: sys::EPOLLIN | sys::EPOLLRDHUP,
            paused: false,
            peer_closed: false,
        }
    }

    fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

/// Everything the event loop needs besides the connection table.
struct Ctx {
    engine: Arc<Engine>,
    batchers: Vec<Arc<Batcher<AsyncPending>>>,
    control_tx: mpsc::Sender<(u64, Json)>,
    shared: Arc<Shared>,
    shutdown: Arc<AtomicBool>,
    epoll: sys::Epoll,
    opts: ReactorOptions,
    /// Per-loop queue bound (admission watermark input).
    max_queue: usize,
}

/// A running async server (reactor + batch loops + control worker).
/// Same wire protocol as [`crate::coordinator::server::Server`]; the
/// [`crate::coordinator::server::Client`] works against either.
pub struct AsyncServer {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    shared: Arc<Shared>,
    batchers: Vec<Arc<Batcher<AsyncPending>>>,
    threads: Vec<thread::JoinHandle<()>>,
}

impl AsyncServer {
    /// Bind and start serving on `addr` (port 0 for ephemeral).
    pub fn start(
        addr: &str,
        engine: Arc<Engine>,
        policy: BatchPolicy,
        opts: ReactorOptions,
    ) -> Result<AsyncServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let loops = policy.effective_loops().max(1);
        let batchers: Vec<Arc<Batcher<AsyncPending>>> = (0..loops)
            .map(|_| Arc::new(Batcher::<AsyncPending>::new(policy).with_obs(engine.obs().clone())))
            .collect();
        let (wake_rx, wake_tx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        let shared = Arc::new(Shared { completions: Mutex::new(Vec::new()), wake_tx });

        let mut threads = Vec::with_capacity(loops + 2);
        // Batch loops: identical engine path to the threaded server;
        // responses leave as completions instead of per-request channels.
        for (l, batcher) in batchers.iter().enumerate() {
            let batcher = Arc::clone(batcher);
            let engine = Arc::clone(&engine);
            let shared = Arc::clone(&shared);
            threads.push(
                thread::Builder::new()
                    .name(format!("abatch-loop-{l}"))
                    .spawn(move || {
                        while let Some(batch) = batcher.next_batch() {
                            let (reqs, tokens): (Vec<_>, Vec<_>) =
                                batch.into_iter().map(|p| (p.req, p.token)).unzip();
                            let (resps, husks) = engine.process_batch_reclaim(reqs);
                            {
                                let mut q = shared.completions.lock().unwrap();
                                for ((resp, husk), token) in
                                    resps.into_iter().zip(husks).zip(tokens)
                                {
                                    q.push(Completion::Score { token, resp, husk });
                                }
                            }
                            shared.wake();
                            engine.scrub_tick();
                        }
                    })?,
            );
        }

        // Control worker: ops execute here, off the reactor thread.
        let (control_tx, control_rx) = mpsc::channel::<(u64, Json)>();
        {
            let engine = Arc::clone(&engine);
            let shared = Arc::clone(&shared);
            threads.push(thread::Builder::new().name("control".into()).spawn(move || {
                while let Ok((token, parsed)) = control_rx.recv() {
                    let text = control_reply(&engine, &parsed).to_string();
                    shared.push(Completion::Line { token, text });
                    shared.wake();
                }
            })?);
        }

        // The reactor itself.
        let ctx = Ctx {
            engine,
            batchers: batchers.clone(),
            control_tx,
            shared: Arc::clone(&shared),
            shutdown: Arc::clone(&shutdown),
            epoll: sys::Epoll::new()?,
            opts,
            max_queue: policy.max_queue,
        };
        threads.push(thread::Builder::new().name("reactor".into()).spawn(move || {
            if let Err(e) = run_reactor(ctx, listener, wake_rx) {
                eprintln!("reactor exited with error: {e}");
            }
        })?);

        Ok(AsyncServer { addr: local, shutdown, shared, batchers, threads })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.shared.wake();
        for b in &self.batchers {
            b.close();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for AsyncServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.shared.wake();
        for b in &self.batchers {
            b.close();
        }
    }
}

fn run_reactor(ctx: Ctx, listener: TcpListener, wake_rx: UnixStream) -> std::io::Result<()> {
    ctx.epoll.add(listener.as_raw_fd(), sys::EPOLLIN, TOKEN_LISTENER)?;
    ctx.epoll.add(wake_rx.as_raw_fd(), sys::EPOLLIN, TOKEN_WAKE)?;
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut events = vec![sys::EpollEvent { events: 0, data: 0 }; 256];
    let mut scratch = vec![0u8; READ_CHUNK];
    let mut next_token = FIRST_CONN_TOKEN;
    let mut conn_seq = 0u64;
    let mut last_tick = Instant::now();
    let timeout_ms = ctx.opts.tick.as_millis().clamp(1, 1000) as i32;
    loop {
        if ctx.shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let n = ctx.epoll.wait(&mut events, timeout_ms)?;
        for i in 0..n {
            let ev = events[i];
            let token = ev.data;
            let revents = ev.events;
            match token {
                TOKEN_LISTENER => {
                    accept_ready(&ctx, &listener, &mut conns, &mut next_token, &mut conn_seq)
                }
                TOKEN_WAKE => drain_wake(&wake_rx),
                token => {
                    if let Some(mut conn) = conns.remove(&token) {
                        if conn_event(&ctx, token, &mut conn, revents, &mut scratch) {
                            conns.insert(token, conn);
                        } else {
                            let _ = ctx.epoll.del(conn.stream.as_raw_fd());
                        }
                    }
                }
            }
        }
        // Deliver whatever the batch loops / control worker finished —
        // cheap no-op when the queue is empty.
        deliver_completions(&ctx, &mut conns);
        // Overload pacing: deepest queue + measured p99 window → the
        // detection floor; admission consults the resulting state on
        // every submit.
        if last_tick.elapsed() >= ctx.opts.tick {
            last_tick = Instant::now();
            let depth = ctx.batchers.iter().map(|b| b.queue_len()).max().unwrap_or(0);
            ctx.engine.metrics.queue_depth.store(depth as u64, Ordering::Relaxed);
            ctx.engine.overload_tick(depth, ctx.max_queue);
        }
    }
}

fn accept_ready(
    ctx: &Ctx,
    listener: &TcpListener,
    conns: &mut HashMap<u64, Conn>,
    next_token: &mut u64,
    conn_seq: &mut u64,
) {
    loop {
        match listener.accept() {
            Ok((mut stream, _)) => {
                if ctx.opts.max_conns > 0 && conns.len() >= ctx.opts.max_conns {
                    // Connection-count admission: answer and close. The
                    // accepted socket is still blocking, but 24 bytes
                    // into a fresh send buffer cannot stall.
                    ctx.engine.metrics.shed.fetch_add(1, Ordering::Relaxed);
                    let _ = stream.write_all(b"{\"error\":\"overloaded\"}\n");
                    continue;
                }
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let token = *next_token;
                *next_token += 1;
                let lix = (splitmix64(*conn_seq) % ctx.batchers.len() as u64) as usize;
                *conn_seq += 1;
                if ctx
                    .epoll
                    .add(stream.as_raw_fd(), sys::EPOLLIN | sys::EPOLLRDHUP, token)
                    .is_err()
                {
                    continue;
                }
                conns.insert(token, Conn::new(stream, lix));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}

fn drain_wake(wake_rx: &UnixStream) {
    let mut buf = [0u8; 256];
    let mut rx = wake_rx;
    loop {
        match rx.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(_) => continue,
        }
    }
}

/// Dispatch one readiness event for a connection. Returns `false` when
/// the connection should be dropped.
fn conn_event(ctx: &Ctx, token: u64, conn: &mut Conn, revents: u32, scratch: &mut [u8]) -> bool {
    if revents & (sys::EPOLLERR | sys::EPOLLHUP) != 0 {
        return false;
    }
    if revents & sys::EPOLLOUT != 0 && flush_writes(conn).is_err() {
        return false;
    }
    if revents & (sys::EPOLLIN | sys::EPOLLRDHUP) != 0 && !conn.paused {
        if read_ready(conn, scratch).is_err() {
            return false;
        }
        if process_lines(ctx, token, conn).is_err() {
            return false;
        }
    }
    flush_and_continue(ctx, token, conn)
}

/// Flush, resume a backpressured reader if the buffer drained, and
/// decide whether the connection stays registered.
fn flush_and_continue(ctx: &Ctx, token: u64, conn: &mut Conn) -> bool {
    if flush_writes(conn).is_err() {
        return false;
    }
    if conn.paused && conn.pending_write() <= WBUF_LOW {
        conn.paused = false;
        if process_lines(ctx, token, conn).is_err() || flush_writes(conn).is_err() {
            return false;
        }
    }
    if conn.peer_closed && conn.inflight == 0 && conn.pending_write() == 0 {
        return false;
    }
    update_interest(&ctx.epoll, token, conn).is_ok()
}

fn read_ready(conn: &mut Conn, scratch: &mut [u8]) -> Result<(), ()> {
    loop {
        match conn.stream.read(scratch) {
            Ok(0) => {
                conn.peer_closed = true;
                return Ok(());
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&scratch[..n]);
                if conn.rbuf.len() > MAX_RBUF {
                    return Err(());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return Err(()),
        }
    }
}

/// Consume complete lines from the read buffer, stopping early if write
/// backpressure engages mid-burst.
fn process_lines(ctx: &Ctx, token: u64, conn: &mut Conn) -> Result<(), ()> {
    let mut start = 0usize;
    loop {
        if conn.pending_write() > WBUF_HIGH {
            conn.paused = true;
            break;
        }
        let Some(nl) = conn.rbuf[start..].iter().position(|&b| b == b'\n') else {
            break;
        };
        let end = start + nl;
        match std::str::from_utf8(&conn.rbuf[start..end]) {
            Err(_) => queue_line(&mut conn.wbuf, &err_json("bad utf-8").to_string()),
            Ok(raw) => {
                let line = raw.trim();
                if !line.is_empty() {
                    handle_line(
                        ctx,
                        token,
                        line,
                        conn.lix,
                        &mut conn.wbuf,
                        &mut conn.slab,
                        &mut conn.inflight,
                    );
                }
            }
        }
        start = end + 1;
    }
    if start > 0 {
        conn.rbuf.drain(..start);
    }
    if conn.rbuf.len() > MAX_RBUF {
        return Err(());
    }
    Ok(())
}

/// One inbound line: fast-path score parse (zero-alloc at steady
/// shape), else control op (handed to the worker), else generic-JSON
/// request, else error reply. Mirrors the threaded `handle_conn` body.
fn handle_line(
    ctx: &Ctx,
    token: u64,
    line: &str,
    lix: usize,
    wbuf: &mut Vec<u8>,
    slab: &mut Vec<ScoreRequest>,
    inflight: &mut usize,
) {
    let mut req = slab.pop().unwrap_or_default();
    // Each inbound line is one causal flow, same contract as the
    // threaded path; the id rides the batcher queue into the worker
    // spans (PR 10 flow propagation).
    let _flow = FlowGuard::enter(flow::mint());
    let probe = ctx.engine.obs().probe();
    let t0 = probe.map(|_| Instant::now());
    let parsed_fast = req.parse_line_into(line);
    if let (Some(p), Some(t0)) = (probe, t0) {
        p.span(Stage::Parse, 0, t0);
    }
    if parsed_fast {
        submit_score(ctx, token, lix, wbuf, inflight, req);
        return;
    }
    slab.push(req); // unused husk back to the slab
    let parsed = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            queue_line(wbuf, &err_json(&format!("bad json: {e}")).to_string());
            return;
        }
    };
    if parsed.get("op").and_then(Json::as_str).is_some() {
        *inflight += 1;
        if ctx.control_tx.send((token, parsed)).is_err() {
            *inflight -= 1;
            queue_line(wbuf, &err_json("server shutting down").to_string());
        }
        return;
    }
    match ScoreRequest::from_json(&parsed) {
        Ok(req) => submit_score(ctx, token, lix, wbuf, inflight, req),
        Err(e) => queue_line(wbuf, &err_json(&format!("bad request: {e}")).to_string()),
    }
}

/// Admission control + submit. A shed — controller-driven or
/// queue-full — is the same one-line `{"error":"overloaded"}` the
/// threaded path produces, counted in `metrics.shed`; an accepted
/// submission counts in `metrics.admitted` and bumps the connection's
/// inflight tally.
fn submit_score(
    ctx: &Ctx,
    token: u64,
    lix: usize,
    wbuf: &mut Vec<u8>,
    inflight: &mut usize,
    req: ScoreRequest,
) {
    let batcher = &ctx.batchers[lix];
    let depth = batcher.queue_len();
    ctx.engine.metrics.queue_depth.store(depth as u64, Ordering::Relaxed);
    let shed = ctx
        .engine
        .overload()
        .is_some_and(|c| c.should_shed(depth, batcher.policy.max_queue));
    if shed || batcher.submit(AsyncPending { req, token }).is_err() {
        ctx.engine.metrics.shed.fetch_add(1, Ordering::Relaxed);
        queue_line(wbuf, &err_json("overloaded").to_string());
        return;
    }
    ctx.engine.metrics.admitted.fetch_add(1, Ordering::Relaxed);
    *inflight += 1;
}

/// Drain the completion queue into the owning connections' write
/// buffers, then flush every touched connection.
fn deliver_completions(ctx: &Ctx, conns: &mut HashMap<u64, Conn>) {
    let batch = std::mem::take(&mut *ctx.shared.completions.lock().unwrap());
    if batch.is_empty() {
        return;
    }
    let mut touched: Vec<u64> = Vec::with_capacity(batch.len());
    for c in batch {
        let (token, text, husk) = match c {
            Completion::Score { token, resp, husk } => {
                (token, resp.to_json().to_string(), Some(husk))
            }
            Completion::Line { token, text } => (token, text, None),
        };
        // A completion for a token that already hung up is dropped —
        // the response was computed, the socket is gone.
        let Some(conn) = conns.get_mut(&token) else { continue };
        conn.inflight = conn.inflight.saturating_sub(1);
        if let Some(h) = husk {
            if conn.slab.len() < SLAB_CAP {
                conn.slab.push(h);
            }
        }
        queue_line(&mut conn.wbuf, &text);
        touched.push(token);
    }
    touched.sort_unstable();
    touched.dedup();
    for token in touched {
        if let Some(mut conn) = conns.remove(&token) {
            if flush_and_continue(ctx, token, &mut conn) {
                conns.insert(token, conn);
            } else {
                let _ = ctx.epoll.del(conn.stream.as_raw_fd());
            }
        }
    }
}

fn queue_line(wbuf: &mut Vec<u8>, text: &str) {
    wbuf.extend_from_slice(text.as_bytes());
    wbuf.push(b'\n');
}

/// Write as much of the pending buffer as the socket accepts.
fn flush_writes(conn: &mut Conn) -> Result<(), ()> {
    while conn.wpos < conn.wbuf.len() {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => return Err(()),
            Ok(n) => conn.wpos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return Err(()),
        }
    }
    if conn.wpos == conn.wbuf.len() {
        conn.wbuf.clear();
        conn.wpos = 0;
    } else if conn.wpos > WBUF_LOW {
        // Compact occasionally so a slow reader doesn't pin the prefix.
        conn.wbuf.drain(..conn.wpos);
        conn.wpos = 0;
    }
    Ok(())
}

/// Re-register the epoll interest set when it changed: reads unless
/// backpressured or past EOF, writes while output is pending.
fn update_interest(epoll: &sys::Epoll, token: u64, conn: &mut Conn) -> std::io::Result<()> {
    let mut want = sys::EPOLLRDHUP;
    if !conn.paused && !conn.peer_closed {
        want |= sys::EPOLLIN;
    }
    if conn.pending_write() > 0 {
        want |= sys::EPOLLOUT;
    }
    if want != conn.interest {
        epoll.modify(conn.stream.as_raw_fd(), want, token)?;
        conn.interest = want;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Client;
    use crate::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
    use crate::util::rng::Pcg32;
    use std::io::{BufRead, BufReader, BufWriter};

    fn tiny_engine() -> Arc<Engine> {
        let model = DlrmModel::random(DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![TableConfig { rows: 200, pooling: 4 }],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 5,
        });
        Arc::new(Engine::new(model))
    }

    fn sample_request(id: u64) -> ScoreRequest {
        let mut rng = Pcg32::new(id);
        ScoreRequest {
            id,
            dense: (0..4).map(|_| rng.next_f32()).collect(),
            sparse: vec![(0..4).map(|_| rng.gen_range(0, 200)).collect()],
        }
    }

    fn fast_policy() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            max_queue: 64,
            loops: 1,
        }
    }

    #[test]
    fn async_end_to_end_scores_and_control_ops() {
        let engine = tiny_engine();
        let server = AsyncServer::start(
            "127.0.0.1:0",
            Arc::clone(&engine),
            fast_policy(),
            ReactorOptions::default(),
        )
        .unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        for id in 0..5 {
            let resp = client.score(&sample_request(id)).unwrap();
            assert_eq!(resp.id, id);
            assert!((0.0..=1.0).contains(&resp.score));
            assert!(!resp.detected);
        }
        // Control ops answer off-thread through the completion queue.
        let m = client.metrics().unwrap();
        assert_eq!(m.get("requests").and_then(Json::as_usize), Some(5));
        assert_eq!(m.get("admitted").and_then(Json::as_usize), Some(5));
        assert_eq!(m.get("shed").and_then(Json::as_usize), Some(0));
        assert!(client.prom().unwrap().contains("requests"));
        let e = client.events().unwrap();
        assert_eq!(e.path(&["counts", "total"]).and_then(Json::as_usize), Some(0));
        server.stop();
    }

    #[test]
    fn async_conn_cap_sheds_at_accept() {
        let engine = tiny_engine();
        let server = AsyncServer::start(
            "127.0.0.1:0",
            Arc::clone(&engine),
            fast_policy(),
            ReactorOptions { max_conns: 1, ..Default::default() },
        )
        .unwrap();
        // First connection registers (the score round-trip proves it).
        let mut c1 = Client::connect(&server.addr).unwrap();
        c1.score(&sample_request(1)).unwrap();
        // Second connection is turned away with the one-line reply.
        let s2 = TcpStream::connect(server.addr).unwrap();
        let mut r = BufReader::new(s2);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("overloaded"), "got {line:?}");
        // The surviving connection keeps serving.
        let resp = c1.score(&sample_request(2)).unwrap();
        assert_eq!(resp.id, 2);
        assert!(engine.metrics.shed.load(Ordering::Relaxed) >= 1);
        server.stop();
    }

    #[test]
    fn async_malformed_lines_get_error_not_crash() {
        let server = AsyncServer::start(
            "127.0.0.1:0",
            tiny_engine(),
            fast_policy(),
            ReactorOptions::default(),
        )
        .unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        writeln!(w, "not json at all").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("error"));
        // Connection still usable afterwards.
        writeln!(w, "{}", sample_request(1).to_json()).unwrap();
        w.flush().unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("score"));
        server.stop();
    }
}
