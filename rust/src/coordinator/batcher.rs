//! Dynamic batching: requests accumulate in a bounded queue and are cut
//! into batches when either `max_batch` is reached or the oldest waiting
//! request has aged past `max_wait` — the standard latency/throughput
//! trade-off every serving stack (vLLM, DLRM inference tiers) exposes.

use crate::obs::{flow, FlowGuard, ObsHandle, Stage};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Queue bound **per batch loop**; beyond it submissions are rejected
    /// (backpressure).
    pub max_queue: usize,
    /// Number of independent batch loops the server runs. Connections are
    /// hashed across them, so at high connection counts the batch-cut
    /// wakeups and engine calls no longer serialize on one loop thread
    /// (ROADMAP perf open item). `0` = auto (min(4, cores)); `1` = the
    /// single-loop behavior.
    pub loops: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            max_queue: 4096,
            loops: 1,
        }
    }
}

impl BatchPolicy {
    /// Resolve `loops` to a concrete count (`0` = auto).
    pub fn effective_loops(&self) -> usize {
        if self.loops == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
                .min(4)
        } else {
            self.loops
        }
    }
}

struct Queued<T> {
    item: T,
    enqueued: Instant,
    /// Flow the submitter was working under at `submit` time; re-entered
    /// when the queue-wait span records at batch cut, so per-request
    /// attribution survives the batcher boundary instead of collapsing
    /// to flow 0.
    flow: u64,
}

struct State<T> {
    queue: VecDeque<Queued<T>>,
    closed: bool,
}

/// MPMC dynamic batcher.
pub struct Batcher<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    pub policy: BatchPolicy,
    /// Span profiler: each item's queue wait is timed at batch cut.
    /// Detached by default; the server threads the engine's handle in
    /// via [`Batcher::with_obs`].
    obs: ObsHandle,
}

/// Why `submit` failed.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    Closed,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Self {
            state: Mutex::new(State {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
            policy,
            obs: ObsHandle::detached(),
        }
    }

    /// Thread a profiler handle in (builder-style; `new` keeps its
    /// signature for standalone users).
    pub fn with_obs(mut self, obs: ObsHandle) -> Self {
        self.obs = obs;
        self
    }

    /// Enqueue one request.
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(SubmitError::Closed);
        }
        if st.queue.len() >= self.policy.max_queue {
            return Err(SubmitError::QueueFull);
        }
        st.queue.push_back(Queued {
            item,
            enqueued: Instant::now(),
            flow: flow::current(),
        });
        self.cv.notify_one();
        Ok(())
    }

    /// Block until a batch is ready (full, or oldest aged out, or closed).
    /// Returns `None` when closed and drained.
    pub fn next_batch(&self) -> Option<Vec<T>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.queue.is_empty() {
                let oldest_age = st.queue.front().unwrap().enqueued.elapsed();
                if st.queue.len() >= self.policy.max_batch
                    || oldest_age >= self.policy.max_wait
                    || st.closed
                {
                    let n = st.queue.len().min(self.policy.max_batch);
                    if let Some(p) = self.obs.probe() {
                        for q in st.queue.iter().take(n) {
                            let _flow = FlowGuard::enter(q.flow);
                            p.span(Stage::QueueWait, 0, q.enqueued);
                        }
                    }
                    return Some(st.queue.drain(..n).map(|q| q.item).collect());
                }
                // Wait out the remaining aging time (or a new arrival).
                let remaining = self.policy.max_wait - oldest_age;
                let (guard, _) = self.cv.wait_timeout(st, remaining).unwrap();
                st = guard;
            } else if st.closed {
                return None;
            } else {
                st = self.cv.wait(st).unwrap();
            }
        }
    }

    /// Close the batcher; pending items still drain via `next_batch`.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn queue_len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            max_queue: 100,
            loops: 1,
        }
    }

    #[test]
    fn full_batch_cut_immediately() {
        let b = Batcher::new(policy(4, 1000));
        for i in 0..4 {
            b.submit(i).unwrap();
        }
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
    }

    #[test]
    fn partial_batch_cut_after_max_wait() {
        let b = Batcher::new(policy(100, 10));
        b.submit(7).unwrap();
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch, vec![7]);
        assert!(t0.elapsed() >= Duration::from_millis(8));
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let b = Batcher::new(BatchPolicy {
            max_batch: 10,
            max_wait: Duration::from_secs(1),
            max_queue: 2,
            loops: 1,
        });
        b.submit(1).unwrap();
        b.submit(2).unwrap();
        assert_eq!(b.submit(3), Err(SubmitError::QueueFull));
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(policy(10, 1000));
        b.submit(1).unwrap();
        b.close();
        assert_eq!(b.next_batch(), Some(vec![1]));
        assert_eq!(b.next_batch(), None);
        assert_eq!(b.submit(2), Err(SubmitError::Closed));
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let b = Arc::new(Batcher::new(policy(8, 2)));
        let total = 200;
        let mut handles = Vec::new();
        for t in 0..4 {
            let b = Arc::clone(&b);
            handles.push(thread::spawn(move || {
                for i in 0..total / 4 {
                    while b.submit(t * 1000 + i).is_err() {
                        thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let b = Arc::clone(&b);
            thread::spawn(move || {
                let mut seen = 0;
                while let Some(batch) = b.next_batch() {
                    seen += batch.len();
                    if seen == total {
                        break;
                    }
                }
                seen
            })
        };
        for h in handles {
            h.join().unwrap();
        }
        // Give the consumer a moment, then close to unblock if needed.
        thread::sleep(Duration::from_millis(20));
        b.close();
        assert_eq!(consumer.join().unwrap(), total);
    }
}
