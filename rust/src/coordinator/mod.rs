//! Serving coordinator (L3): dynamic batching, the ABFT
//! verify→recompute→flag policy at serve time, metrics, and the TCP
//! front-end. This is what turns the paper's operator-level detection into
//! a deployable feature.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pjrt_backend;
pub mod request;
pub mod server;

pub use batcher::{BatchPolicy, Batcher, SubmitError};
pub use engine::{BatchOutcome, ChaosConfig, Engine, PolicyRuntime, ScrubTickReport, ShardServing};
pub use metrics::{policy_json, Metrics};
pub use pjrt_backend::{ArtifactShape, PjrtModelEngine};
pub use request::{ScoreRequest, ScoreResponse};
pub use server::{Client, Server};
