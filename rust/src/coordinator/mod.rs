//! Serving coordinator (L3): dynamic batching, the ABFT
//! verify→recompute→flag policy at serve time, metrics, and the TCP
//! front-end. This is what turns the paper's operator-level detection into
//! a deployable feature.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pjrt_backend;
/// Readiness-driven (epoll) serving front end; linux-only, `--async-io`.
#[cfg(target_os = "linux")]
pub mod reactor;
pub mod request;
pub mod server;

pub use batcher::{BatchPolicy, Batcher, SubmitError};
pub use engine::{BatchOutcome, ChaosConfig, Engine, PolicyRuntime, ScrubTickReport, ShardServing};
pub use metrics::{overload_json, policy_json, Metrics};
#[cfg(target_os = "linux")]
pub use reactor::{AsyncServer, ReactorOptions};
pub use pjrt_backend::{ArtifactShape, PjrtModelEngine};
pub use request::{ScoreRequest, ScoreResponse};
pub use server::{Client, Server};
