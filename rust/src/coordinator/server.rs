//! TCP serving front-end: newline-delimited JSON requests in, responses
//! out, with dynamic batching between the socket threads and the engine.
//!
//! Protocol (one JSON object per line):
//!   → `{"id": 1, "dense": [...], "sparse": [[...], ...]}`
//!   ← `{"id": 1, "score": 0.42, "detected": false, ...}`
//!
//! Control ops (each answers with one JSON line):
//!   → `{"op": "metrics"}`                  — the full metrics snapshot
//!     (counters, latency quantiles, events/shards/policy/obs blocks)
//!   → `{"op": "events", "max": N}`         — journal counts + newest rows
//!   → `{"op": "events", "since_tick": S}`  — only rows past journal
//!     sequence `S` (the reply's `next_cursor` feeds the next call; the
//!     reply's `gap` counts rows the ring already overwrote past the
//!     cursor — 0 means the follower lost nothing; `max` still caps)
//!   → `{"op": "trace", "max": N}`          — newest sampled profiler
//!     spans + per-stage latency quantiles (see `crate::obs`)
//!   → `{"op": "prom"}`                     — the metrics snapshot as
//!     Prometheus text exposition, in `{"text": "..."}`
//!   → `{"op": "flightrec"}`                — flight-recorder capture
//!     index; with `"id": N` the full `BlackBox` JSON for capture `N`,
//!     with `"clear": true` drop resident captures (see
//!     `crate::obs::flightrec`; errors when the recorder is not armed)
//!   → `{"op": "ping"}`                     — liveness
//!
//! # Sharded batch loops
//!
//! The server runs `policy.effective_loops()` independent batcher +
//! batch-loop pairs and **hashes each connection** (splitmix64 of its
//! accept sequence number) onto one of them. With a single global loop,
//! every batch cut wakes the same thread and the engine call serializes
//! behind it at high connection counts; with per-core loops the wakeups,
//! response fan-outs, and engine calls proceed in parallel — the engine
//! itself is already concurrent (shared read lock + per-worker scratch).
//! A connection sticks to its loop for its lifetime, so per-connection
//! response ordering is preserved.

use crate::coordinator::batcher::{Batcher, BatchPolicy};
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{ScoreRequest, ScoreResponse};
use crate::util::json::Json;
use crate::util::rng::splitmix64;
use anyhow::Result;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;

/// One queued unit: the request plus the channel its response goes back
/// on. The reply carries the request's husk back too — the engine moves
/// the `dense`/`sparse` buffers through scoring untouched, and the
/// connection loop slabs them for its next parse (zero-allocation
/// request path; see [`ScoreRequest::parse_line_into`]).
struct Pending {
    req: ScoreRequest,
    reply: mpsc::Sender<(ScoreResponse, ScoreRequest)>,
}

/// A running server (handle for tests and the CLI).
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept_thread: Option<thread::JoinHandle<()>>,
    batch_threads: Vec<thread::JoinHandle<()>>,
    batchers: Vec<Arc<Batcher<Pending>>>,
    /// Overload-controller pacing thread; spawned only when the engine
    /// carries an [`crate::policy::OverloadCtl`] (`--slo-p99-ms`).
    tick_thread: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on `addr` (use port 0 for an ephemeral port).
    pub fn start(addr: &str, engine: Arc<Engine>, policy: BatchPolicy) -> Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let loops = policy.effective_loops().max(1);
        let batchers: Vec<Arc<Batcher<Pending>>> = (0..loops)
            .map(|_| Arc::new(Batcher::<Pending>::new(policy).with_obs(engine.obs().clone())))
            .collect();

        // Batch loops: drain batches, run the engine, fan responses out.
        let mut batch_threads = Vec::with_capacity(loops);
        for (l, batcher) in batchers.iter().enumerate() {
            let batcher = Arc::clone(batcher);
            let engine = Arc::clone(&engine);
            batch_threads.push(
                thread::Builder::new()
                    .name(format!("batch-loop-{l}"))
                    .spawn(move || {
                        while let Some(batch) = batcher.next_batch() {
                            let (reqs, replies): (Vec<_>, Vec<_>) =
                                batch.into_iter().map(|p| (p.req, p.reply)).unzip();
                            let (resps, husks) = engine.process_batch_reclaim(reqs);
                            for ((resp, husk), reply) in
                                resps.into_iter().zip(husks).zip(replies)
                            {
                                let _ = reply.send((resp, husk));
                            }
                            // Idle-slot proactive scrubbing (incremental +
                            // thread-safe, so concurrent loops just scrub
                            // more rows per wall-clock tick).
                            engine.scrub_tick();
                        }
                    })?,
            );
        }

        // Accept loop: one thread per connection (CPU-bound inference
        // dominates; connection counts here are small). Each connection
        // is hashed onto one batch loop.
        let accept_thread = {
            let shutdown = Arc::clone(&shutdown);
            let batchers = batchers.clone();
            let engine = Arc::clone(&engine);
            thread::Builder::new().name("accept".into()).spawn(move || {
                let mut conn_seq = 0u64;
                loop {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let lix = (splitmix64(conn_seq) % batchers.len() as u64) as usize;
                            conn_seq += 1;
                            let batcher = Arc::clone(&batchers[lix]);
                            let engine = Arc::clone(&engine);
                            thread::spawn(move || {
                                let _ = handle_conn(stream, batcher, engine);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            thread::sleep(std::time::Duration::from_millis(1));
                        }
                        Err(_) => break,
                    }
                }
            })?
        };

        // Overload pacing: when the engine carries an `OverloadCtl`, a
        // low-rate ticker feeds it the deepest queue + the measured p99
        // window so detection degrades (and admission eventually sheds)
        // under sustained pressure. No controller → no thread.
        let tick_thread = if engine.overload().is_some() {
            let shutdown = Arc::clone(&shutdown);
            let batchers = batchers.clone();
            let engine = Arc::clone(&engine);
            Some(
                thread::Builder::new()
                    .name("overload-tick".into())
                    .spawn(move || {
                        while !shutdown.load(Ordering::SeqCst) {
                            thread::sleep(std::time::Duration::from_millis(50));
                            let depth =
                                batchers.iter().map(|b| b.queue_len()).max().unwrap_or(0);
                            engine
                                .metrics
                                .queue_depth
                                .store(depth as u64, Ordering::Relaxed);
                            engine.overload_tick(depth, batchers[0].policy.max_queue);
                        }
                    })?,
            )
        } else {
            None
        };

        Ok(Server {
            addr: local,
            shutdown,
            accept_thread: Some(accept_thread),
            batch_threads,
            batchers,
            tick_thread,
        })
    }

    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for b in &self.batchers {
            b.close();
        }
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        for t in self.batch_threads.drain(..) {
            let _ = t.join();
        }
        if let Some(t) = self.tick_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for b in &self.batchers {
            b.close();
        }
    }
}

fn handle_conn(stream: TcpStream, batcher: Arc<Batcher<Pending>>, engine: Arc<Engine>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    // Per-connection slab: the line buffer and the request (with its
    // dense/sparse Vecs) are reused across requests — the husk comes
    // back with each response, so at a steady request shape the whole
    // read→parse→submit path stops allocating after the first request.
    let mut line = String::new();
    let mut slab: Vec<ScoreRequest> = Vec::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            break;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let mut req = slab.pop().unwrap_or_default();
        // Each inbound line is one causal flow: the parse span this
        // thread records carries it (scoring spans carry the batch's
        // flow, minted in `Engine::score` on the batch-loop thread).
        let _flow = crate::obs::flow::FlowGuard::enter(crate::obs::flow::mint());
        let probe = engine.obs().probe();
        let t0 = probe.map(|_| std::time::Instant::now());
        let parsed_fast = req.parse_line_into(trimmed);
        if let (Some(p), Some(t0)) = (probe, t0) {
            p.span(crate::obs::Stage::Parse, 0, t0);
        }
        if parsed_fast {
            submit_and_reply(&engine, &batcher, &mut writer, req, &mut slab)?;
            continue;
        }
        slab.push(req); // unused husk back to the slab
        // Generic path: control ops, fallback-shaped requests, errors.
        let parsed = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", err_json(&format!("bad json: {e}")))?;
                writer.flush()?;
                continue;
            }
        };
        if parsed.get("op").and_then(Json::as_str).is_some() {
            writeln!(writer, "{}", control_reply(&engine, &parsed))?;
            writer.flush()?;
            continue;
        }
        match ScoreRequest::from_json(&parsed) {
            Ok(req) => {
                submit_and_reply(&engine, &batcher, &mut writer, req, &mut slab)?;
            }
            Err(e) => {
                writeln!(writer, "{}", err_json(&format!("bad request: {e}")))?;
                writer.flush()?;
            }
        }
    }
    Ok(())
}

/// Answer one control op (`{"op": ...}`) with its one-line JSON reply.
/// Shared by the threaded connection loop and the reactor's control
/// worker — the reactor runs it *off* the event thread, so a metrics
/// snapshot (whose policy block is itself try-lock bounded) never stalls
/// a reactor tick.
pub(crate) fn control_reply(engine: &Engine, parsed: &Json) -> Json {
    let op = match parsed.get("op").and_then(Json::as_str) {
        Some(op) => op,
        None => return err_json("missing op"),
    };
    match op {
        "metrics" => engine.metrics_snapshot(),
        // The fault-event journal: counts + the newest rows
        // (newest-last). `{"op":"events","max":N}` bounds the row
        // count; default 64. With `since_tick`, only rows past that
        // journal sequence come back, plus the `next_cursor` to resume
        // from.
        "events" => {
            let max = parsed.get("max").and_then(Json::as_usize).unwrap_or(64);
            match parsed.get("since_tick").and_then(Json::as_usize) {
                Some(since) => engine.events_json_since(since as u64, max),
                None => engine.events_json(max),
            }
        }
        // Profiler spans + per-stage quantiles.
        "trace" => {
            let max = parsed.get("max").and_then(Json::as_usize).unwrap_or(64);
            engine.trace_json(max)
        }
        // Prometheus text exposition of the whole snapshot.
        "prom" => Json::obj(vec![("text", Json::Str(engine.prom_text()))]),
        // Flight-recorder index / capture fetch / clear.
        "flightrec" => match engine.flightrec() {
            None => err_json("flight recorder not armed"),
            Some(rec) => {
                if parsed.get("clear").and_then(Json::as_bool) == Some(true) {
                    rec.clear();
                    rec.status_json()
                } else if let Some(id) = parsed.get("id").and_then(Json::as_usize) {
                    match rec.capture_json(id as u64) {
                        Some(j) => j,
                        None => err_json("no such capture"),
                    }
                } else {
                    rec.list_json()
                }
            }
        },
        "ping" => Json::obj(vec![("pong", Json::Bool(true))]),
        _ => err_json("unknown op"),
    }
}

/// Submit one request, await its response, write it out, and return the
/// request's husk to the connection slab (a rejected submission drops
/// the buffers — overload is not the steady state the slab optimizes).
///
/// Admission control (PR 10): a full queue rejects as before, and when
/// the engine carries an overload controller in its `Shedding` state the
/// request is turned away *before* touching the queue. Both outcomes are
/// the same one-line `{"error":"overloaded"}` reply, counted in
/// `metrics.shed`; accepted submissions count in `metrics.admitted`.
fn submit_and_reply(
    engine: &Arc<Engine>,
    batcher: &Arc<Batcher<Pending>>,
    writer: &mut BufWriter<TcpStream>,
    req: ScoreRequest,
    slab: &mut Vec<ScoreRequest>,
) -> Result<()> {
    let shed = engine
        .overload()
        .is_some_and(|c| c.should_shed(batcher.queue_len(), batcher.policy.max_queue));
    let (tx, rx) = mpsc::channel();
    if shed || batcher.submit(Pending { req, reply: tx }).is_err() {
        engine.metrics.shed.fetch_add(1, Ordering::Relaxed);
        writeln!(writer, "{}", err_json("overloaded"))?;
        writer.flush()?;
        return Ok(());
    }
    engine.metrics.admitted.fetch_add(1, Ordering::Relaxed);
    match rx.recv() {
        Ok((resp, husk)) => {
            writeln!(writer, "{}", resp.to_json())?;
            slab.push(husk);
        }
        Err(_) => writeln!(writer, "{}", err_json("engine dropped request"))?,
    }
    writer.flush()?;
    Ok(())
}

pub(crate) fn err_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::Str(msg.to_string()))])
}

/// Minimal blocking client for tests, examples and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr)?;
        Ok(Self {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    pub fn score(&mut self, req: &ScoreRequest) -> Result<ScoreResponse> {
        writeln!(self.writer, "{}", req.to_json())?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let j = Json::parse(line.trim())?;
        if let Some(err) = j.get("error").and_then(Json::as_str) {
            anyhow::bail!("server error: {err}");
        }
        ScoreResponse::from_json(&j)
    }

    pub fn metrics(&mut self) -> Result<Json> {
        writeln!(self.writer, "{{\"op\":\"metrics\"}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// Query the fault-event journal (`{"op":"events"}`).
    pub fn events(&mut self) -> Result<Json> {
        writeln!(self.writer, "{{\"op\":\"events\"}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// Cursored journal follow: only rows past `since` (a previous
    /// reply's `next_cursor`), so a poller never re-reads or misses one.
    pub fn events_since(&mut self, since: u64) -> Result<Json> {
        writeln!(self.writer, "{{\"op\":\"events\",\"since_tick\":{since}}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// Recent profiler spans + per-stage quantiles (`{"op":"trace"}`).
    pub fn trace(&mut self, max: usize) -> Result<Json> {
        writeln!(self.writer, "{{\"op\":\"trace\",\"max\":{max}}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// The flight-recorder capture index (`{"op":"flightrec"}`).
    pub fn flightrec_list(&mut self) -> Result<Json> {
        writeln!(self.writer, "{{\"op\":\"flightrec\"}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// One full `BlackBox` capture by id (`{"op":"flightrec","id":N}`).
    pub fn flightrec_capture(&mut self, id: u64) -> Result<Json> {
        writeln!(self.writer, "{{\"op\":\"flightrec\",\"id\":{id}}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// Drop resident captures (`{"op":"flightrec","clear":true}`);
    /// returns the post-clear recorder status.
    pub fn flightrec_clear(&mut self) -> Result<Json> {
        writeln!(self.writer, "{{\"op\":\"flightrec\",\"clear\":true}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        Ok(Json::parse(line.trim())?)
    }

    /// The Prometheus text exposition (`{"op":"prom"}`), unwrapped.
    pub fn prom(&mut self) -> Result<String> {
        writeln!(self.writer, "{{\"op\":\"prom\"}}")?;
        self.writer.flush()?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        let j = Json::parse(line.trim())?;
        Ok(j.get("text").and_then(Json::as_str).unwrap_or_default().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::{DlrmConfig, DlrmModel, Protection, TableConfig};
    use crate::util::rng::Pcg32;
    use std::time::Duration;

    fn tiny_engine() -> Arc<Engine> {
        let model = DlrmModel::random(DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![TableConfig { rows: 200, pooling: 4 }],
            protection: Protection::DetectRecompute,
            dense_range: (0.0, 1.0),
            seed: 5,
        });
        Arc::new(Engine::new(model))
    }

    fn sample_request(id: u64) -> ScoreRequest {
        let mut rng = Pcg32::new(id);
        ScoreRequest {
            id,
            dense: (0..4).map(|_| rng.next_f32()).collect(),
            sparse: vec![(0..4).map(|_| rng.gen_range(0, 200)).collect()],
        }
    }

    fn fast_policy() -> BatchPolicy {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            max_queue: 64,
            loops: 1,
        }
    }

    #[test]
    fn end_to_end_score_over_tcp() {
        let server = Server::start("127.0.0.1:0", tiny_engine(), fast_policy()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        for id in 0..5 {
            let resp = client.score(&sample_request(id)).unwrap();
            assert_eq!(resp.id, id);
            assert!((0.0..=1.0).contains(&resp.score));
            assert!(!resp.detected);
        }
        let m = client.metrics().unwrap();
        assert_eq!(m.get("requests").and_then(Json::as_usize), Some(5));
        assert!(m.get("events").is_some(), "snapshot embeds the journal counts");
        // The events op answers too; a clean run has an empty journal.
        let e = client.events().unwrap();
        assert_eq!(e.path(&["counts", "total"]).and_then(Json::as_usize), Some(0));
        assert!(matches!(e.get("events"), Some(Json::Arr(a)) if a.is_empty()));
        server.stop();
    }

    #[test]
    fn malformed_lines_get_error_not_crash() {
        let server = Server::start("127.0.0.1:0", tiny_engine(), fast_policy()).unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        let mut r = BufReader::new(stream);
        writeln!(w, "not json at all").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("error"));
        // Connection still usable afterwards.
        writeln!(w, "{}", sample_request(1).to_json()).unwrap();
        w.flush().unwrap();
        line.clear();
        r.read_line(&mut line).unwrap();
        assert!(line.contains("score"));
        server.stop();
    }

    #[test]
    fn sharded_batch_loops_serve_all_connections() {
        // Several loops + many connections: every request is answered,
        // responses stay correct per connection, and the request count
        // adds up (no loop loses traffic).
        let engine = tiny_engine();
        let policy = BatchPolicy { loops: 3, ..fast_policy() };
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine), policy).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..12u64)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    let mut last = 0.0;
                    for i in 0..3 {
                        let resp = c.score(&sample_request(id * 100 + i)).unwrap();
                        assert_eq!(resp.id, id * 100 + i);
                        last = resp.score;
                    }
                    last
                })
            })
            .collect();
        for h in handles {
            let score = h.join().unwrap();
            assert!((0.0..=1.0).contains(&score));
        }
        assert_eq!(
            engine.metrics.requests.load(std::sync::atomic::Ordering::Relaxed),
            36
        );
        server.stop();
    }

    #[test]
    fn flightrec_op_lists_fetches_and_clears_captures() {
        use crate::detect::{Detector, Resolution, Severity, SiteId, UnitRef};
        // Disarmed: explicit error, connection stays usable.
        let server = Server::start("127.0.0.1:0", tiny_engine(), fast_policy()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let r = client.flightrec_list().unwrap();
        assert_eq!(
            r.get("error").and_then(Json::as_str),
            Some("flight recorder not armed")
        );
        server.stop();

        // Armed: a Significant event freezes a capture the op serves.
        let engine = tiny_engine();
        engine.arm_flightrec(4, Severity::Significant);
        engine.event_sink().emit(
            SiteId::Gemm(0),
            UnitRef::GemmRow { row: 3 },
            Detector::GemmChecksum,
            Severity::Significant,
            Resolution::DetectedOnly,
        );
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine), fast_policy()).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let list = client.flightrec_list().unwrap();
        assert_eq!(
            list.path(&["status", "captures"]).and_then(Json::as_usize),
            Some(1)
        );
        let rows = list.get("captures").and_then(Json::as_arr).unwrap();
        let id = rows[0].get("id").and_then(Json::as_usize).unwrap() as u64;
        let cap = client.flightrec_capture(id).unwrap();
        assert_eq!(
            cap.path(&["event", "severity"]).and_then(Json::as_str),
            Some("significant")
        );
        assert!(client.flightrec_capture(999).unwrap().get("error").is_some());
        let cleared = client.flightrec_clear().unwrap();
        assert_eq!(cleared.get("resident").and_then(Json::as_usize), Some(0));
        let m = client.metrics().unwrap();
        assert!(
            m.get("flightrec").is_some(),
            "snapshot embeds recorder status when armed"
        );
        server.stop();
    }

    #[test]
    fn zero_loops_resolves_to_auto() {
        let policy = BatchPolicy { loops: 0, ..fast_policy() };
        assert!(policy.effective_loops() >= 1);
        let server = Server::start("127.0.0.1:0", tiny_engine(), policy).unwrap();
        let mut client = Client::connect(&server.addr).unwrap();
        let resp = client.score(&sample_request(9)).unwrap();
        assert_eq!(resp.id, 9);
        server.stop();
    }

    #[test]
    fn concurrent_clients_batched_together() {
        let engine = tiny_engine();
        let server = Server::start("127.0.0.1:0", Arc::clone(&engine), fast_policy()).unwrap();
        let addr = server.addr;
        let handles: Vec<_> = (0..8)
            .map(|id| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    c.score(&sample_request(id)).unwrap()
                })
            })
            .collect();
        for h in handles {
            let resp = h.join().unwrap();
            assert!((0.0..=1.0).contains(&resp.score));
        }
        let batches = engine.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
        assert!(batches <= 8, "batching should coalesce ({batches} batches)");
        server.stop();
    }
}
