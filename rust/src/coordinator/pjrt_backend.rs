//! PJRT serving backend: scores requests through the jax/Pallas-lowered
//! DLRM artifacts (`model_b{1,8}.hlo.txt`) instead of the native rust
//! operators — the full three-layer path, with the ABFT evidence the
//! lowered graph returns (`gemm_bad_rows`, `eb_flagged`) driving the same
//! detect → recompute → degrade policy as the native engine.
//!
//! Batching strategy: the engine owns one compiled executable per
//! available batch size and routes each incoming batch to the smallest
//! artifact that fits, padding with repeats of the last request (XLA
//! shapes are static).

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ScoreRequest, ScoreResponse};
use crate::runtime::{PjrtEngine, Tensor};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::Ordering;
use std::sync::Mutex;
use std::time::Instant;

/// Input shape contract of the model artifacts (fixed by
/// python/compile/aot.py's DEFAULT_CFG).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactShape {
    pub num_dense: usize,
    pub num_tables: usize,
    pub pooling: usize,
    pub table_rows: usize,
}

impl Default for ArtifactShape {
    fn default() -> Self {
        // Mirrors model_mod.DEFAULT_CFG.
        Self {
            num_dense: 8,
            num_tables: 2,
            pooling: 20,
            table_rows: 5000,
        }
    }
}

/// PJRT-backed scoring engine.
pub struct PjrtModelEngine {
    engine: Mutex<PjrtEngine>,
    /// Ascending batch sizes with a loaded `model_b{n}` executable.
    batch_sizes: Vec<usize>,
    pub shape: ArtifactShape,
    pub metrics: Metrics,
    /// Retry once when the artifact reports ABFT evidence.
    pub recompute_on_detect: bool,
}

impl PjrtModelEngine {
    /// Load every `model_b*.hlo.txt` from `dir`.
    pub fn load_dir(dir: &str, shape: ArtifactShape) -> Result<Self> {
        let mut engine = PjrtEngine::cpu()?;
        let loaded = engine.load_artifact_dir(dir)?;
        let mut batch_sizes: Vec<usize> = loaded
            .iter()
            .filter_map(|n| n.strip_prefix("model_b").and_then(|b| b.parse().ok()))
            .collect();
        batch_sizes.sort_unstable();
        if batch_sizes.is_empty() {
            bail!("no model_b*.hlo.txt artifacts in {dir:?} — run `make artifacts`");
        }
        Ok(Self {
            engine: Mutex::new(engine),
            batch_sizes,
            shape,
            metrics: Metrics::new(),
            recompute_on_detect: true,
        })
    }

    pub fn batch_sizes(&self) -> &[usize] {
        &self.batch_sizes
    }

    fn validate(&self, req: &ScoreRequest) -> Result<()> {
        if req.dense.len() != self.shape.num_dense {
            bail!(
                "dense width {} != artifact contract {}",
                req.dense.len(),
                self.shape.num_dense
            );
        }
        if req.sparse.len() != self.shape.num_tables {
            bail!("table count {} != {}", req.sparse.len(), self.shape.num_tables);
        }
        for (t, idx) in req.sparse.iter().enumerate() {
            if idx.len() != self.shape.pooling {
                bail!(
                    "table {t}: pooling {} != artifact contract {} (static shapes)",
                    idx.len(),
                    self.shape.pooling
                );
            }
            if let Some(&bad) = idx.iter().find(|&&i| i >= self.shape.table_rows) {
                bail!("table {t}: index {bad} out of range {}", self.shape.table_rows);
            }
        }
        Ok(())
    }

    /// Score a batch through the lowered model.
    pub fn process_batch(&self, requests: Vec<ScoreRequest>) -> Result<Vec<ScoreResponse>> {
        let t0 = Instant::now();
        let n = requests.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        for r in &requests {
            self.validate(r)?;
        }
        let &exec_batch = self
            .batch_sizes
            .iter()
            .find(|&&b| b >= n)
            .unwrap_or_else(|| self.batch_sizes.last().unwrap());
        if exec_batch < n {
            bail!(
                "batch {n} exceeds the largest artifact (b{exec_batch}); split upstream"
            );
        }

        // Pack + pad inputs.
        let s = self.shape;
        let mut dense = Vec::with_capacity(exec_batch * s.num_dense);
        let mut indices = Vec::with_capacity(exec_batch * s.num_tables * s.pooling);
        for i in 0..exec_batch {
            let req = &requests[i.min(n - 1)]; // pad with the last request
            dense.extend_from_slice(&req.dense);
            for t in 0..s.num_tables {
                indices.extend(req.sparse[t].iter().map(|&x| x as i32));
            }
        }
        let name = format!("model_b{exec_batch}");
        let inputs = [
            Tensor::F32(dense, vec![exec_batch, s.num_dense]),
            Tensor::I32(indices, vec![exec_batch, s.num_tables, s.pooling]),
        ];

        let engine = self.engine.lock().unwrap();
        let (mut scores, mut gemm_bad, mut eb_flagged) = run_model(&engine, &name, &inputs)?;
        let detected = gemm_bad > 0 || eb_flagged > 0;
        let mut recomputed = false;
        let mut degraded = false;
        if detected {
            self.metrics
                .detections
                .fetch_add((gemm_bad + eb_flagged) as u64, Ordering::Relaxed);
            if self.recompute_on_detect {
                let (s2, g2, e2) = run_model(&engine, &name, &inputs)?;
                scores = s2;
                gemm_bad = g2;
                eb_flagged = e2;
                recomputed = true;
                self.metrics.recomputes.fetch_add(1, Ordering::Relaxed);
                if gemm_bad > 0 || eb_flagged > 0 {
                    degraded = true;
                    self.metrics.degraded.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        drop(engine);

        let latency_us = t0.elapsed().as_micros() as u64;
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.fetch_add(n as u64, Ordering::Relaxed);
        self.metrics.latency.record_us(latency_us);

        Ok(requests
            .iter()
            .enumerate()
            .map(|(i, req)| ScoreResponse {
                id: req.id,
                score: scores[i],
                detected,
                recomputed,
                degraded,
                latency_us,
            })
            .collect())
    }
}

fn run_model(engine: &PjrtEngine, name: &str, inputs: &[Tensor]) -> Result<(Vec<f32>, i32, i32)> {
    let out = engine.execute(name, inputs)?;
    match (&out[0], &out[1], &out[2]) {
        (Tensor::F32(scores, _), Tensor::I32(gemm_bad, _), Tensor::I32(eb_flagged, _)) => {
            Ok((scores.clone(), gemm_bad[0], eb_flagged[0]))
        }
        other => Err(anyhow!("unexpected model outputs: {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/model_b1.hlo.txt").exists()
    }

    fn sample(shape: &ArtifactShape, id: u64, seed: u64) -> ScoreRequest {
        let mut rng = Pcg32::new(seed);
        ScoreRequest {
            id,
            dense: (0..shape.num_dense).map(|_| rng.next_f32()).collect(),
            sparse: (0..shape.num_tables)
                .map(|_| {
                    (0..shape.pooling)
                        .map(|_| rng.gen_range(0, shape.table_rows))
                        .collect()
                })
                .collect(),
        }
    }

    #[test]
    fn scores_through_artifacts() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let engine = PjrtModelEngine::load_dir("artifacts", ArtifactShape::default()).unwrap();
        assert_eq!(engine.batch_sizes(), &[1, 8]);
        let reqs: Vec<ScoreRequest> =
            (0..3).map(|i| sample(&engine.shape, i, 100 + i)).collect();
        let resps = engine.process_batch(reqs).unwrap();
        assert_eq!(resps.len(), 3);
        for r in &resps {
            assert!((0.0..=1.0).contains(&r.score));
            assert!(!r.detected, "clean artifacts must not flag");
        }
        assert_eq!(engine.metrics.requests.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn batch_padding_preserves_per_request_scores() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let engine = PjrtModelEngine::load_dir("artifacts", ArtifactShape::default()).unwrap();
        let req = sample(&engine.shape, 7, 42);
        // Score alone (b1 artifact) and inside a padded batch (b8).
        let solo = engine.process_batch(vec![req.clone()]).unwrap()[0].score;
        let mut batch = vec![req.clone()];
        for i in 0..4 {
            batch.push(sample(&engine.shape, 10 + i, 200 + i));
        }
        let batched = engine.process_batch(batch).unwrap()[0].score;
        assert!(
            (solo - batched).abs() < 1e-6,
            "static quantization: same request must score the same ({solo} vs {batched})"
        );
    }

    #[test]
    fn shape_contract_enforced() {
        if !artifacts_available() {
            eprintln!("SKIP: run `make artifacts`");
            return;
        }
        let engine = PjrtModelEngine::load_dir("artifacts", ArtifactShape::default()).unwrap();
        let mut bad = sample(&engine.shape, 1, 1);
        bad.dense.pop();
        assert!(engine.process_batch(vec![bad]).is_err());
        let mut bad = sample(&engine.shape, 1, 1);
        bad.sparse[0][0] = 999_999;
        assert!(engine.process_batch(vec![bad]).is_err());
        let mut bad = sample(&engine.shape, 1, 1);
        bad.sparse[0].pop();
        assert!(engine.process_batch(vec![bad]).is_err());
    }
}
