//! Inference engine: wraps the DLRM model with the serve-time ABFT policy
//! (verify → recompute-once → flag-degraded), metrics, and an optional
//! chaos injector that exercises the whole detection path in production
//! shape (the §VI methodology, online).
//!
//! Concurrency: inference is read-only, so the model sits behind an
//! `RwLock` and clean-path batches run under a **shared** read lock —
//! any number of threads can score concurrently (the old model-wide
//! `Mutex` serialized every request; see BENCH_PR1's 1→4→8 thread
//! scaling). The write lock is taken only by mutators: chaos
//! inject/undo drills and operator repairs (tests/CLI).

use crate::abft::Scrubber;
use crate::coordinator::metrics::{overload_json, policy_json, Metrics};
use crate::coordinator::request::{ScoreRequest, ScoreResponse};
use crate::detect::{
    Detector, EventSink, Journal, Resolution, Severity, SiteId, UnitRef, LOCAL_REPLICA,
};
use crate::dlrm::{
    DlrmModel, DlrmRequest, EbStage, InferenceReport, InferenceScratch, LocalEbStage, Protection,
};
use crate::obs::{render_prometheus, FlightRecorder, ObsHandle, Stage};
use crate::policy::{
    build_neighbors, ControllerThread, OverloadConfig, OverloadCtl, PolicyConfig, PolicyController,
    PolicyHandle, PolicySites, PolicyState, StepReport,
};
use crate::shard::{RepairWorker, ShardPlan, ShardRouter, ShardStore};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// The unsharded EB stage, shared by every non-sharded engine.
static LOCAL_EB_STAGE: LocalEbStage = LocalEbStage;

/// Online fault injection for resilience drills.
#[derive(Clone, Debug)]
pub struct ChaosConfig {
    /// Probability that a batch is served with a transiently corrupted
    /// operand (bit flipped before, restored after).
    pub p_weight_flip: f64,
    /// Probability of a transient table-code flip.
    pub p_table_flip: f64,
    pub seed: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            p_weight_flip: 0.0,
            p_table_flip: 0.0,
            seed: 0xC405,
        }
    }
}

/// Undo-record for one chaos injection.
enum ChaosUndo {
    Weight { layer: usize, idx: usize, old: i8 },
    Table { table: usize, idx: usize, old: u8 },
    /// Conditional restore of a shard-store replica byte (sharded
    /// engines): applied only if the flip is still present, because a
    /// concurrent background repair may have already rewritten the
    /// replica from a clean sibling.
    Replica { table: usize, replica: usize, idx: usize, old: u8, mask: u8 },
}

/// One batch's injection sites, drawn atomically (a single chaos-mutex
/// session) so seeded drills stay reproducible under concurrent callers.
#[derive(Default)]
struct ChaosPlan {
    /// (layer, p, j, bit)
    weight: Option<(usize, usize, usize, u32)>,
    /// (table, byte index, bit, replica). `replica` is `None` for the
    /// engine's own tables (unsharded) and `Some(r)` for a shard-store
    /// replica copy (sharded serving — table traffic never touches the
    /// engine model's tables there).
    table: Option<(usize, usize, u32, Option<usize>)>,
}

impl ChaosPlan {
    fn is_empty(&self) -> bool {
        self.weight.is_none() && self.table.is_none()
    }
}

/// Unsharded scrub state: per-table incremental scrubbers plus the
/// round-robin table cursor budget-paced ticks resume from.
struct ScrubSet {
    scrubbers: Vec<Scrubber>,
    next: usize,
}

/// Sharded-serving attachment: the replicated store, the router that
/// serves EB traffic from it, and (optionally) the background repairer.
pub struct ShardServing {
    pub store: Arc<ShardStore>,
    pub router: ShardRouter,
    /// Keeps the background repair thread alive for the engine's
    /// lifetime; dropping the engine joins it.
    pub worker: Option<RepairWorker>,
}

/// What happened to one scored batch (the serve-time ABFT policy's
/// verdict): detection, whether a recompute ran, and whether the batch
/// was served degraded (detection persisted through the retry).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BatchOutcome {
    pub detected: bool,
    pub recomputed: bool,
    pub degraded: bool,
}

/// One [`Engine::scrub_tick`]'s outcome: exactly how many rows were
/// scanned this tick (the `scrub_budget` pacing accounting) and the
/// corrupted `(table, row)` pairs found.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ScrubTickReport {
    pub rows_scanned: usize,
    pub hits: Vec<(usize, usize)>,
}

/// Adaptive-detection attachment ([`Engine::with_policy`]): the shared
/// site table, the controller (manual or background-threaded), and the
/// scrub-pacing knob the controller writes.
pub struct PolicyRuntime {
    pub sites: Arc<PolicySites>,
    controller: Arc<Mutex<PolicyController>>,
    /// Joins the background tick thread on engine drop; `None` when the
    /// config asked for manual ticking.
    _thread: Option<ControllerThread>,
}

pub struct Engine {
    /// Read-mostly: shared read lock for inference, write lock only for
    /// chaos injection/undo and repair writes.
    pub model: RwLock<DlrmModel>,
    /// Shared with the fault-event sink, which routes each detection
    /// event into the matching counter family.
    pub metrics: Arc<Metrics>,
    /// The fault-event pipeline ([`crate::detect`]): every engine
    /// carries an attached sink + journal; the model (and the shard
    /// store built from it) emit through clones of this handle.
    sink: EventSink,
    /// The span profiler + overhead accounting plane ([`crate::obs`]):
    /// always attached (sized to the model's sites), sampling off by
    /// default — a disabled probe is one relaxed load. The model and the
    /// shard store built from it time through clones of this handle.
    obs: ObsHandle,
    chaos: Option<Mutex<(ChaosConfig, Pcg32)>>,
    /// Background table scrubbers (one per table) plus the round-robin
    /// table cursor for budget-paced ticks, advanced between batches to
    /// proactively catch latent memory corruption in cold rows (see
    /// abft::scrub). None disables scrubbing. Sharded engines scrub the
    /// store's replicas instead (see [`Engine::scrub_tick`]).
    scrubbers: Option<Mutex<ScrubSet>>,
    /// When set, embedding traffic is served from the shard store via the
    /// router; the dense MLP layers stay in `model`.
    shards: Option<ShardServing>,
    /// Adaptive detection control plane ([`Engine::with_policy`]); when
    /// `None` every site runs `Full` — bit-identical to the pre-policy
    /// engine.
    policy: Option<PolicyRuntime>,
    /// Serve-side overload controller ([`Engine::with_overload`]): under
    /// sustained p99/queue pressure it presses detection sites down the
    /// lattice before admission sheds anything. `None` = no `--slo-p99-ms`.
    overload: Option<Arc<OverloadCtl>>,
    /// Per-worker inference arenas: [`Engine::score`] checks one out for
    /// the duration of a batch and returns it, so N concurrent callers
    /// settle on N pooled arenas and steady-state scoring allocates
    /// nothing (the pool itself is touched only outside the forward
    /// pass; the `Box` keeps pool pushes to one pointer move).
    scratch_pool: Mutex<Vec<Box<InferenceScratch>>>,
}

impl Engine {
    pub fn new(model: DlrmModel) -> Self {
        Self::build(model, None)
    }

    pub fn with_chaos(model: DlrmModel, chaos: ChaosConfig) -> Self {
        let rng = Pcg32::new(chaos.seed);
        Self::build(model, Some(Mutex::new((chaos, rng))))
    }

    /// Shared constructor: attaches the fault-event sink (journal at
    /// [`crate::detect::DEFAULT_JOURNAL_CAPACITY`]), wires it to the
    /// engine's metrics, and hands the model its emission handle —
    /// anything built FROM the model afterwards (the shard store) clones
    /// the same sink.
    fn build(mut model: DlrmModel, chaos: Option<Mutex<(ChaosConfig, Pcg32)>>) -> Self {
        let metrics = Arc::new(Metrics::new());
        let sink = EventSink::attached();
        sink.attach_metrics(Arc::clone(&metrics));
        model.events = sink.clone();
        // Profiler plane: attached (so `set_sampling` works at runtime)
        // but sampling off — the default serving path pays one relaxed
        // load per probe site.
        let gemm_sites = model.bottom.len() + model.top.len() + 1;
        let eb_sites = model.tables.len();
        let obs = ObsHandle::attached(gemm_sites, eb_sites, 0);
        model.obs = obs.clone();
        Self {
            model: RwLock::new(model),
            metrics,
            sink,
            obs,
            chaos,
            scrubbers: None,
            shards: None,
            policy: None,
            overload: None,
            scratch_pool: Mutex::new(Vec::new()),
        }
    }

    /// Enable background scrubbing, `stride` rows per table per tick
    /// (with a policy attached, the policy's `scrub_budget` paces ticks
    /// instead — see [`Engine::scrub_tick`]).
    pub fn with_scrubbing(mut self, stride: usize) -> Self {
        let n = self.model.read().unwrap().tables.len();
        self.scrubbers = Some(Mutex::new(ScrubSet {
            scrubbers: (0..n).map(|_| Scrubber::new(stride)).collect(),
            next: 0,
        }));
        self
    }

    /// Serve embedding traffic from a replicated shard store built from
    /// the model's tables (`scrub_stride` rows per replica table per
    /// scrub tick). Dense MLP layers keep living in the engine; scores
    /// stay bit-identical to the unsharded engine on clean data.
    pub fn with_shards(mut self, plan: ShardPlan, scrub_stride: usize) -> Self {
        let store = {
            let model = self.model.read().unwrap();
            Arc::new(ShardStore::from_model(&model, plan, scrub_stride))
        };
        self.shards = Some(ShardServing {
            router: ShardRouter::new(Arc::clone(&store)),
            store,
            worker: None,
        });
        self
    }

    /// [`Engine::with_shards`] with the plan materialized from a
    /// pluggable [`crate::shard::PlacementPolicy`] over the model's own
    /// table count — the builder-level seam for alternative placements
    /// (the default hash policy is `ShardPlan::hash_placement`).
    pub fn with_placement(
        self,
        policy: &dyn crate::shard::PlacementPolicy,
        num_shards: usize,
        replicas: usize,
        scrub_stride: usize,
    ) -> Self {
        let plan = {
            let model = self.model.read().unwrap();
            ShardPlan::from_policy(policy, model.tables.len(), num_shards, replicas)
        };
        self.with_shards(plan, scrub_stride)
    }

    /// Spawn the background [`RepairWorker`] over the shard store's
    /// repair queue. Must be called **after** [`Engine::with_shards`]
    /// (panics otherwise — a silently worker-less store would let
    /// quarantined replicas pile up). Without a worker, repairs run when
    /// the operator calls [`ShardStore::drain_repairs`].
    pub fn with_repair_worker(mut self) -> Self {
        let sh = self
            .shards
            .as_mut()
            .expect("with_repair_worker requires with_shards to be applied first");
        sh.worker = Some(RepairWorker::spawn(Arc::clone(&sh.store)));
        self
    }

    /// Attach the adaptive detection control plane ([`crate::policy`]):
    /// builds one policy site per protected operator (every MLP layer +
    /// every embedding table), threads the site table into the model's
    /// hot paths, and starts the escalation controller — as a background
    /// thread when `cfg.tick > 0`, else manually ticked via
    /// [`Engine::policy_tick`] (tests, campaigns).
    ///
    /// Call **after** [`Engine::with_shards`] when serving sharded, so
    /// the escalation neighbor map groups tables by the shard that owns
    /// them (co-sharded tables share replica memory — a fault on one is
    /// evidence about its shard-mates).
    ///
    /// Every site starts at `Full`: until the controller has observed a
    /// quiet window, behavior is bit-identical to the policy-less engine.
    pub fn with_policy(mut self, cfg: PolicyConfig) -> Self {
        let (sites, neighbors) = {
            let model = self.model.read().unwrap();
            let gemm_sites = model.bottom.len() + model.top.len() + 1;
            let eb_sites = model.tables.len();
            let sites = Arc::new(PolicySites::new(
                gemm_sites,
                eb_sites,
                cfg.bound_relax,
                cfg.scrub_budget_base,
            ));
            let groups: Option<Vec<Vec<usize>>> = self
                .shards
                .as_ref()
                .map(|sh| sh.store.shards().iter().map(|s| s.tables.clone()).collect());
            let neighbors = build_neighbors(gemm_sites, eb_sites, groups.as_deref());
            (sites, neighbors)
        };
        self.model.write().unwrap().policy = PolicyHandle::attached(Arc::clone(&sites));
        if let Some(sh) = &self.shards {
            // The store's scrubber routes its detections into the owning
            // table's telemetry through this handle (the proactive arm
            // feeds the same escalation loop the serving path does).
            sh.store.attach_policy(PolicyHandle::attached(Arc::clone(&sites)));
        }
        let mut controller = PolicyController::new(Arc::clone(&sites), neighbors, cfg.clone());
        // Feed the controller the live verify-cost measurements: once a
        // site's EWMA is warm, its measured overhead replaces the static
        // `UnitCosts` prior in the sampling-rate budget math (unless
        // `cfg.pin_unit_costs` pins the prior).
        if let Some(m) = self.obs.measured() {
            controller.attach_measured(m);
        }
        let controller = Arc::new(Mutex::new(controller));
        let thread = (cfg.tick > Duration::ZERO).then(|| {
            let sink = self.sink.clone();
            ControllerThread::spawn_with(Arc::clone(&controller), cfg.tick, move |t| {
                sink.set_ctl_tick(t)
            })
        });
        self.policy = Some(PolicyRuntime {
            sites,
            controller,
            _thread: thread,
        });
        self
    }

    /// Attach the serve-side overload controller (PR 10): `tick`s press
    /// detection sites down the policy lattice under sustained
    /// p99/queue pressure — strictly before admission sheds — and
    /// restore them with hysteresis. Call after [`Engine::with_policy`];
    /// without a policy the state machine still runs (admission gating
    /// only) but has no detection dial to turn.
    pub fn with_overload(mut self, cfg: OverloadConfig) -> Self {
        self.overload = Some(Arc::new(OverloadCtl::new(cfg)));
        self
    }

    /// The overload controller, when attached.
    pub fn overload(&self) -> Option<&Arc<OverloadCtl>> {
        self.overload.as_ref()
    }

    /// One overload control tick: roll the latency window against the
    /// SLO, advance the Normal/Degrading/Shedding machine, and apply the
    /// resulting detection floor through the policy controller. The
    /// controller lock is `try_lock` — an overload tick racing a policy
    /// tick skips floor application this round rather than stalling the
    /// server's control loop; the floor is re-applied every tick, so a
    /// skipped round heals on the next. `None` when no overload
    /// controller is attached.
    pub fn overload_tick(&self, queue_depth: usize, queue_bound: usize) -> Option<()> {
        let ctl = self.overload.as_ref()?;
        let floor = ctl.tick(self.metrics.latency.hist(), queue_depth, queue_bound);
        if let Some(rt) = &self.policy {
            if let Ok(mut c) = rt.controller.try_lock() {
                ctl.note_pressed(c.apply_overload_floor(floor));
            }
        }
        Some(())
    }

    /// Run one controller tick synchronously (manual-tick mode; also
    /// safe alongside a background thread — they serialize on the
    /// controller mutex). Lifetime escalation/decay tallies live in the
    /// site table and are mirrored into the metrics snapshot. `None`
    /// when no policy is attached.
    pub fn policy_tick(&self) -> Option<StepReport> {
        let rt = self.policy.as_ref()?;
        let mut controller = rt.controller.lock().unwrap();
        let report = controller.step();
        // Stamp the sink with the controller epoch so every subsequent
        // fault event records which escalation state it happened under
        // (`ctl_tick` in `events_json` — journal ↔ controller
        // correlation).
        self.sink.set_ctl_tick(controller.ticks());
        Some(report)
    }

    /// The policy site table, when a policy is attached (drills, benches,
    /// campaign assertions).
    pub fn policy_sites(&self) -> Option<&Arc<PolicySites>> {
        self.policy.as_ref().map(|p| &p.sites)
    }

    /// Serialize the controller's warm-start state
    /// ([`PolicyController::snapshot`] in its versioned text form);
    /// `None` without an attached policy. The serve CLI persists this to
    /// `--policy-state`.
    pub fn policy_state(&self) -> Option<String> {
        let rt = self.policy.as_ref()?;
        Some(rt.controller.lock().unwrap().snapshot().encode())
    }

    /// Restore a previously persisted controller state (the
    /// `--policy-state` file) into the attached policy. Errors — no
    /// policy attached, unparseable text, site-shape mismatch — leave the
    /// controller cold-started and untouched.
    pub fn restore_policy_state(&self, text: &str) -> Result<(), String> {
        let rt = self.policy.as_ref().ok_or("no policy attached")?;
        let state = PolicyState::parse(text)?;
        rt.controller.lock().unwrap().restore(&state)
    }

    /// Arm the fault flight recorder ([`crate::obs::flightrec`]): every
    /// event the sink journals at or above `min_severity` freezes a
    /// `BlackBox` capture (span rings + policy plane + shard health +
    /// kernel tiers) into a pool of `captures` slots. Call **after**
    /// `with_policy` / `with_shards` so their snapshot closures get
    /// wired; arming is idempotent at the sink (first recorder wins).
    /// The clean path never consults the recorder — armed-but-idle cost
    /// is zero beyond the probes that already exist.
    pub fn arm_flightrec(&self, captures: usize, min_severity: Severity) -> Arc<FlightRecorder> {
        let gemm_sites = self.obs.core().map_or(1, |c| c.num_gemm_sites());
        let rec = Arc::new(FlightRecorder::new(captures, min_severity, gemm_sites));
        if let Some(core) = self.obs.core_arc() {
            rec.attach_obs(Arc::clone(core));
        }
        if let Some(rt) = &self.policy {
            let sites = Arc::clone(&rt.sites);
            let controller = Arc::clone(&rt.controller);
            rec.attach_policy_snapshot(Box::new(move || {
                // try_lock: a freeze racing a controller tick skips the
                // policy block rather than ever stalling the fault path.
                match controller.try_lock() {
                    Ok(c) => policy_json(&sites, &c),
                    Err(_) => Json::Null,
                }
            }));
        }
        if let Some(sh) = &self.shards {
            let store = Arc::clone(&sh.store);
            rec.attach_shard_snapshot(Box::new(move || store.health_json()));
        }
        self.sink.attach_recorder(Arc::clone(&rec));
        rec
    }

    /// The armed flight recorder, when [`Engine::arm_flightrec`] ran.
    pub fn flightrec(&self) -> Option<&Arc<FlightRecorder>> {
        self.sink.recorder()
    }

    /// The shard store, when this engine serves sharded.
    pub fn shard_store(&self) -> Option<&Arc<ShardStore>> {
        self.shards.as_ref().map(|s| &s.store)
    }

    /// The fault-event sink every detection site of this engine emits
    /// through (always attached).
    pub fn event_sink(&self) -> &EventSink {
        &self.sink
    }

    /// The event journal (always present — engines attach a sink at
    /// construction).
    pub fn journal(&self) -> &Journal {
        self.sink.journal().expect("engine sink is always attached")
    }

    /// The `events` server-op payload: journal counts plus the newest
    /// `max` event rows.
    pub fn events_json(&self, max: usize) -> Json {
        self.journal().events_json(max)
    }

    /// The cursored `events` payload: only rows strictly after the
    /// journal sequence `since` (capped at the newest `max`), plus
    /// `next_cursor` for the follower's next call.
    pub fn events_json_since(&self, since: u64, max: usize) -> Json {
        self.journal().events_json_since(since, max)
    }

    /// The span profiler handle (sampling control, measured costs).
    pub fn obs(&self) -> &ObsHandle {
        &self.obs
    }

    /// The `trace` server-op payload: the newest sampled spans plus the
    /// per-stage latency quantiles.
    pub fn trace_json(&self, max: usize) -> Json {
        self.obs.trace_json(max)
    }

    /// The full metrics snapshot rendered as Prometheus text exposition
    /// (the `prom` server op).
    pub fn prom_text(&self) -> String {
        render_prometheus(&self.metrics_snapshot())
    }

    /// The EB-stage strategy this engine serves with.
    fn eb_stage(&self) -> &dyn EbStage {
        match &self.shards {
            Some(s) => &s.router,
            None => &LOCAL_EB_STAGE,
        }
    }

    /// Advance the background scrub by one tick. Called by the batch
    /// loop between batches (idle slots). Reports exactly how many rows
    /// were scanned (the `scrub_budget` pacing accounting) plus the
    /// corrupted `(table, row)` pairs found.
    ///
    /// With a policy attached, the tick scans exactly
    /// `PolicySites::scrub_budget` rows — the controller's pacing knob,
    /// raised under persistent faults — resuming deterministically where
    /// the previous tick stopped (across tables, and across replicas
    /// when sharded). Without a policy, the legacy stride behavior is
    /// kept: every table (every replica) advances one strip.
    ///
    /// Sharded engines scrub the store's replica copies instead (that is
    /// where table traffic is served from); a scrub hit quarantines the
    /// replica and queues a repair — the proactive arm of
    /// detection-driven failover.
    pub fn scrub_tick(&self) -> ScrubTickReport {
        let budget = self
            .policy
            .as_ref()
            .map(|p| p.sites.scrub_budget.load(Ordering::Relaxed));
        if let Some(sh) = &self.shards {
            // The store journals each hit as a `ScrubExact` event, and
            // the sink routes it into `metrics.scrub_hits` — only the
            // row pacing is accounted here.
            let (rows_scanned, raw_hits) = match budget {
                Some(b) => sh.store.scrub_tick_budget(b),
                None => sh.store.scrub_tick(),
            };
            self.metrics
                .scrubbed_rows
                .fetch_add(rows_scanned as u64, Ordering::Relaxed);
            return ScrubTickReport {
                rows_scanned,
                hits: raw_hits.into_iter().map(|(_s, _r, table, row)| (table, row)).collect(),
            };
        }
        let Some(scrubbers) = &self.scrubbers else {
            return ScrubTickReport::default();
        };
        // Scrubbing only reads table bytes; a shared lock keeps it off
        // the serving path's critical section.
        let model = self.model.read().unwrap();
        let mut set = scrubbers.lock().unwrap();
        let mut report = ScrubTickReport::default();
        match budget {
            Some(b) => {
                // Exact pacing: walk tables round-robin from the carried
                // cursor, spending the whole row budget (tables are
                // non-empty by construction; an all-empty model exits
                // after one idle lap).
                let ntab = model.tables.len();
                let mut idle = 0usize;
                while report.rows_scanned < b && ntab > 0 && idle < ntab {
                    let t = set.next % ntab;
                    let r = set.scrubbers[t].scrub_step_rows(
                        &model.tables[t],
                        &model.checksums[t],
                        b - report.rows_scanned,
                    );
                    if r.rows_scanned == 0 {
                        set.next = (t + 1) % ntab;
                        idle += 1;
                        continue;
                    }
                    idle = 0;
                    report.rows_scanned += r.rows_scanned;
                    report.hits.extend(r.corrupted_rows.into_iter().map(|row| (t, row)));
                    if r.wrapped {
                        set.next = (t + 1) % ntab;
                    }
                }
            }
            None => {
                for (t, (table, checksum)) in
                    model.tables.iter().zip(&model.checksums).enumerate()
                {
                    let r = set.scrubbers[t].scrub_step(table, checksum);
                    report.rows_scanned += r.rows_scanned;
                    report.hits.extend(r.corrupted_rows.into_iter().map(|row| (t, row)));
                }
            }
        }
        self.metrics
            .scrubbed_rows
            .fetch_add(report.rows_scanned as u64, Ordering::Relaxed);
        // Journal each unsharded hit. The engine's own tables have no
        // replica to fail over to — repair is an operator action (the
        // `ScrubLocal` ladder is empty), so the resolution is
        // `DetectedOnly`; the sink routes the event into
        // `metrics.scrub_hits`.
        for &(t, row) in &report.hits {
            let delta = model.checksums[t].row_delta(&model.tables[t], row);
            // Scrub detections count against the victim table's policy
            // site: a proactive hit is the same evidence of bad memory a
            // serving-path flag is, so it drives the same escalation.
            if let Some(telem) = model.policy.eb_telem(t) {
                telem.note_flags(1);
            }
            self.sink.emit(
                SiteId::Eb(t as u32),
                UnitRef::ScrubSlot { replica: LOCAL_REPLICA, row: row as u32 },
                Detector::ScrubExact,
                Severity::from_code_delta(delta),
                Resolution::DetectedOnly,
            );
        }
        report
    }

    /// Serve one batch: forward → on detection, restore-chaos + recompute
    /// once → respond, with per-request latency stamped.
    ///
    /// Allocating front-end over [`Engine::score`] (request/response
    /// marshalling); the scoring itself is allocation-free.
    pub fn process_batch(&self, requests: Vec<ScoreRequest>) -> Vec<ScoreResponse> {
        self.process_batch_reclaim(requests).0
    }

    /// [`Engine::process_batch`] that additionally hands the request
    /// buffers back: the `dense`/`sparse` `Vec`s move request → scoring
    /// → husk without a single copy, so the server's connection loops
    /// can slab-reuse them for the next parse (the zero-allocation
    /// boundary extended to the socket — see `coordinator::request`).
    /// Husks are index-aligned with the responses.
    pub fn process_batch_reclaim(
        &self,
        requests: Vec<ScoreRequest>,
    ) -> (Vec<ScoreResponse>, Vec<ScoreRequest>) {
        let t0 = Instant::now();
        let ids: Vec<u64> = requests.iter().map(|r| r.id).collect();
        let dlrm_reqs: Vec<DlrmRequest> =
            requests.into_iter().map(ScoreRequest::into_dlrm).collect();
        let mut scores = vec![0f32; dlrm_reqs.len()];
        let outcome = self.score(&dlrm_reqs, &mut scores);
        let latency_us = t0.elapsed().as_micros() as u64;

        let mut resps = Vec::with_capacity(ids.len());
        let mut husks = Vec::with_capacity(ids.len());
        for ((id, score), req) in ids.into_iter().zip(scores).zip(dlrm_reqs) {
            resps.push(ScoreResponse {
                id,
                score,
                detected: outcome.detected,
                recomputed: outcome.recomputed,
                degraded: outcome.degraded,
                latency_us,
            });
            husks.push(ScoreRequest {
                id,
                dense: req.dense,
                sparse: req.sparse,
            });
        }
        (resps, husks)
    }

    /// Score one batch into a caller-provided buffer — the zero-allocation
    /// serving core. An [`InferenceScratch`] arena is checked out of the
    /// per-worker pool for the duration of the batch, so after one warmup
    /// batch per concurrent worker (at the largest shapes) the clean path
    /// performs **no heap allocation** (enforced by
    /// `rust/tests/zero_alloc.rs`).
    ///
    /// Clean-path batches run under a shared read lock, so concurrent
    /// callers execute in parallel; only chaos drills take the write lock
    /// (injection mutates the model transiently).
    pub fn score(&self, requests: &[DlrmRequest], scores: &mut [f32]) -> BatchOutcome {
        let t0 = Instant::now();
        // Each scored batch is one causal flow: every span this thread
        // records and every fault the sink journals until the guard
        // drops carries this ID, so a flight-recorder capture can
        // reconstruct the batch's timeline.
        let _flow = crate::obs::flow::FlowGuard::enter(crate::obs::flow::mint());
        // One journal tick per scored batch: events stamp the batch they
        // occurred in.
        self.sink.advance_tick();
        let mut scratch = self
            .scratch_pool
            .lock()
            .unwrap()
            .pop()
            .unwrap_or_default();
        let outcome = if self.chaos.is_some() {
            self.run_batch_chaos(requests, &mut scratch, scores)
        } else {
            self.run_batch_clean(requests, &mut scratch, scores)
        };
        self.scratch_pool.lock().unwrap().push(scratch);
        self.metrics.batches.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .requests
            .fetch_add(requests.len() as u64, Ordering::Relaxed);
        self.metrics
            .latency
            .record_us(t0.elapsed().as_micros() as u64);
        outcome
    }

    /// Lock-free-read serving path: forward (and recompute-on-detect)
    /// under a shared lock.
    fn run_batch_clean(
        &self,
        dlrm_reqs: &[DlrmRequest],
        scratch: &mut InferenceScratch,
        scores: &mut [f32],
    ) -> BatchOutcome {
        let model = self.model.read().unwrap();
        let report = model.forward_into(dlrm_reqs, self.eb_stage(), scratch, scores);
        self.apply_detection_policy(&model, dlrm_reqs, scratch, scores, &report)
    }

    /// The engine's rung of the recovery ladder, **RetryBatch**: applied
    /// after a batch's first forward whenever the report is dirty — the
    /// recovery for every flag the per-unit rungs couldn't clear (the
    /// BoundOnly aggregate, which cannot name a row, and persistent
    /// row/bag flags that escalated past `RecomputeUnit`; see
    /// [`crate::detect::recovery`]). A retry that comes back dirty
    /// exhausts the ladder: the batch is served **Degraded**, never
    /// silently. The caller still holds its model lock, so the retry
    /// sees the same (restored, for chaos) operands.
    fn apply_detection_policy(
        &self,
        model: &DlrmModel,
        dlrm_reqs: &[DlrmRequest],
        scratch: &mut InferenceScratch,
        scores: &mut [f32],
        report: &InferenceReport,
    ) -> BatchOutcome {
        self.record_shard_events(report);
        let mut outcome = BatchOutcome {
            detected: !report.clean(),
            ..BatchOutcome::default()
        };
        if outcome.detected {
            // `metrics.detections` is fed by the event sink at emission
            // time, one per flagged row/bag — the batch policy here only
            // drives the RetryBatch ladder rung.
            if model.cfg.protection == Protection::DetectRecompute {
                // Ladder-rung span: batch retries are far too rare for
                // 1-in-n sampling, so the probe bypasses it (off still
                // wins).
                let probe = self.obs.probe_rare();
                let t0 = probe.map(|_| Instant::now());
                let report2 = model.forward_into(dlrm_reqs, self.eb_stage(), scratch, scores);
                if let (Some(p), Some(t0)) = (probe, t0) {
                    p.span(Stage::RetryBatch, 0, t0);
                }
                self.record_shard_events(&report2);
                outcome.recomputed = true;
                self.metrics.recomputes.fetch_add(1, Ordering::Relaxed);
                if !report2.clean() {
                    outcome.degraded = true;
                    self.metrics.degraded.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        outcome
    }

    /// Fold the router's recovery actions into the serving counters
    /// (they never dirty a batch, but operators must see them).
    /// Detections themselves (`shard_detections`) are fed by the event
    /// sink at emission time.
    fn record_shard_events(&self, report: &InferenceReport) {
        if report.shard_failovers > 0 {
            self.metrics
                .shard_failovers
                .fetch_add(report.shard_failovers as u64, Ordering::Relaxed);
        }
        if report.shard_quarantines > 0 {
            self.metrics
                .shard_quarantines
                .fetch_add(report.shard_quarantines as u64, Ordering::Relaxed);
        }
    }

    /// Metrics snapshot extended with the shard store's health block and
    /// the policy block (per-site modes + window stats) when attached
    /// (the `/metrics`-style payload). The lifetime escalation/decay
    /// tallies are mirrored from the site table into the flat
    /// `policy_escalations` / `policy_decays` counters first, so the
    /// snapshot is consistent whichever thread ticked the controller.
    pub fn metrics_snapshot(&self) -> Json {
        if let Some(rt) = &self.policy {
            self.metrics.policy_escalations.store(
                rt.sites.escalations.load(Ordering::Relaxed),
                Ordering::Relaxed,
            );
            self.metrics
                .policy_decays
                .store(rt.sites.decays.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        let mut snap = self.metrics.snapshot();
        if let Json::Obj(map) = &mut snap {
            map.insert("events".to_string(), self.journal().counts_json());
            map.insert("obs".to_string(), self.obs.stages_json());
            map.insert("kernel".to_string(), self.kernel_json());
            if let Some(sh) = &self.shards {
                map.insert("shards".to_string(), sh.store.health_json());
            }
            if let Some(rt) = &self.policy {
                // try_lock: snapshots are served from the reactor's
                // control worker and must stay bounded — a snapshot
                // racing a controller tick reports the policy block as
                // null (same contract as the flight recorder's freeze)
                // instead of blocking behind the tick.
                let block = match rt.controller.try_lock() {
                    Ok(controller) => policy_json(&rt.sites, &controller),
                    Err(_) => Json::Null,
                };
                map.insert("policy".to_string(), block);
            }
            if let Some(ctl) = &self.overload {
                map.insert("overload".to_string(), overload_json(ctl));
            }
            if let Some(rec) = self.sink.recorder() {
                map.insert("flightrec".to_string(), rec.status_json());
            }
        }
        snap
    }

    /// Dispatched GEMM kernel tier per protected layer, in policy site
    /// order (`gemm/0..` = bottom layers, then top layers, then the
    /// head): the host-resolved answer to "which kernel is this model
    /// actually running on this box". Tier codes are numeric so the
    /// prom rendering carries them as samples; names ride as the site
    /// label.
    fn kernel_json(&self) -> Json {
        let model = self.model.read().unwrap();
        let rows: Vec<Json> = model
            .bottom
            .iter()
            .chain(model.top.iter())
            .chain(std::iter::once(&model.head))
            .enumerate()
            .map(|(i, l)| {
                let tier = l.kernel_tier();
                Json::obj(vec![
                    ("site", Json::Str(format!("gemm/{i}"))),
                    ("tier", Json::Str(tier.as_str().to_string())),
                    ("tier_code", Json::Num(tier.code() as f64)),
                    ("k", Json::Num(l.k as f64)),
                    ("n", Json::Num(l.n as f64)),
                ])
            })
            .collect();
        Json::obj(vec![("sites", Json::Arr(rows))])
    }

    /// Chaos-drill path. All of a batch's RNG draws — the dice AND the
    /// fault coordinates — happen in one chaos-mutex session (reading
    /// model shapes under the shared lock), so seeded drills stay
    /// reproducible even with concurrent callers interleaving. The
    /// overwhelming majority of batches (at production-shape flip
    /// probabilities) draw an empty plan and serve on the shared read
    /// path like any clean batch; only a batch that actually mutates
    /// operands takes the write lock, for the whole inject → forward →
    /// restore window (readers must never observe a transiently-
    /// corrupted model).
    fn run_batch_chaos(
        &self,
        dlrm_reqs: &[DlrmRequest],
        scratch: &mut InferenceScratch,
        scores: &mut [f32],
    ) -> BatchOutcome {
        let plan = self.draw_chaos_plan();
        if plan.is_empty() {
            return self.run_batch_clean(dlrm_reqs, scratch, scores);
        }

        let mut model = self.model.write().unwrap();
        let undo = self.apply_plan(&mut model, &plan);
        let report = model.forward_into(dlrm_reqs, self.eb_stage(), scratch, scores);
        // Restore transient chaos before any retry (a transient fault
        // would not recur on real hardware either).
        self.undo_chaos(&mut model, &undo);
        self.apply_detection_policy(&model, dlrm_reqs, scratch, scores, &report)
    }

    /// Roll the dice and, when they come up, draw the fault coordinates —
    /// atomically with respect to other chaos batches. Model shapes are
    /// read under the shared lock (they are immutable after build).
    fn draw_chaos_plan(&self) -> ChaosPlan {
        let chaos = self.chaos.as_ref().expect("chaos path without config");
        let model = self.model.read().unwrap();
        let (cfg, rng) = &mut *chaos.lock().unwrap();
        let mut plan = ChaosPlan::default();
        if rng.next_f64() < cfg.p_weight_flip {
            let nlayers = model.bottom.len() + model.top.len() + 1;
            let layer = rng.gen_range(0, nlayers);
            let l = layer_ref(&model, layer);
            plan.weight = Some((
                layer,
                rng.gen_range(0, l.k),
                rng.gen_range(0, l.n),
                rng.gen_range_u32(8),
            ));
        }
        if rng.next_f64() < cfg.p_table_flip && !model.tables.is_empty() {
            let t = rng.gen_range(0, model.tables.len());
            // Sharded serving reads replica copies, not the model's
            // tables — aim the flip where the traffic actually goes.
            let replica = self
                .shards
                .as_ref()
                .map(|sh| rng.gen_range(0, sh.store.plan.replicas));
            plan.table = Some((
                t,
                rng.gen_range(0, model.tables[t].data.len()),
                rng.gen_range_u32(8),
                replica,
            ));
        }
        plan
    }

    /// Apply a drawn plan (model write lock held by the caller); the
    /// logical (p, j) is mapped through the panel-interleaved layout.
    /// Replica-targeted table flips go through the shard store's own
    /// (replica-level) write lock.
    fn apply_plan(&self, model: &mut DlrmModel, plan: &ChaosPlan) -> Vec<ChaosUndo> {
        let mut undo = Vec::new();
        if let Some((layer, p, j, bit)) = plan.weight {
            let abft = layer_mut(model, layer).abft_mut();
            let idx = abft.packed.offset(p, j);
            let data = abft.packed.data_mut();
            let old = data[idx];
            data[idx] = (old as u8 ^ (1 << bit)) as i8;
            undo.push(ChaosUndo::Weight { layer, idx, old });
        }
        if let Some((t, idx, bit, replica)) = plan.table {
            match replica {
                Some(r) => {
                    let store = &self.shards.as_ref().expect("replica plan without shards").store;
                    let old = store.chaos_flip_table_byte(t, r, idx, 1 << bit);
                    undo.push(ChaosUndo::Replica { table: t, replica: r, idx, old, mask: 1 << bit });
                }
                None => {
                    let old = model.tables[t].data[idx];
                    model.tables[t].data[idx] = old ^ (1 << bit);
                    undo.push(ChaosUndo::Table { table: t, idx, old });
                }
            }
        }
        undo
    }

    fn undo_chaos(&self, model: &mut DlrmModel, undo: &[ChaosUndo]) {
        for u in undo {
            match *u {
                ChaosUndo::Weight { layer, idx, old } => {
                    layer_mut(model, layer).abft_mut().packed.data_mut()[idx] = old;
                }
                ChaosUndo::Table { table, idx, old } => {
                    model.tables[table].data[idx] = old;
                }
                ChaosUndo::Replica { table, replica, idx, old, mask } => {
                    // Conditional: skipped when a background repair has
                    // already replaced the corrupted byte (a blind XOR
                    // would re-corrupt a Healthy replica).
                    self.shards
                        .as_ref()
                        .expect("replica undo without shards")
                        .store
                        .chaos_restore_table_byte(table, replica, idx, old, mask);
                }
            }
        }
    }
}

fn layer_mut(model: &mut DlrmModel, i: usize) -> &mut crate::dlrm::AbftLinear {
    let nb = model.bottom.len();
    let nt = model.top.len();
    if i < nb {
        &mut model.bottom[i]
    } else if i < nb + nt {
        &mut model.top[i - nb]
    } else {
        &mut model.head
    }
}

fn layer_ref(model: &DlrmModel, i: usize) -> &crate::dlrm::AbftLinear {
    let nb = model.bottom.len();
    let nt = model.top.len();
    if i < nb {
        &model.bottom[i]
    } else if i < nb + nt {
        &model.top[i - nb]
    } else {
        &model.head
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dlrm::{DlrmConfig, TableConfig};

    fn tiny_model(protection: Protection) -> DlrmModel {
        DlrmModel::random(DlrmConfig {
            num_dense: 4,
            embedding_dim: 8,
            bottom_mlp: vec![16, 8],
            top_mlp: vec![16],
            tables: vec![TableConfig { rows: 500, pooling: 5 }],
            protection,
            dense_range: (0.0, 1.0),
            seed: 11,
        })
    }

    fn make_requests(model: &DlrmModel, n: usize, seed: u64) -> Vec<ScoreRequest> {
        let mut rng = Pcg32::new(seed);
        model
            .synth_requests(n, &mut rng)
            .into_iter()
            .enumerate()
            .map(|(i, r)| ScoreRequest {
                id: i as u64,
                dense: r.dense,
                sparse: r.sparse,
            })
            .collect()
    }

    #[test]
    fn clean_batch_served() {
        let model = tiny_model(Protection::DetectRecompute);
        let reqs = make_requests(&model, 5, 1);
        let engine = Engine::new(model);
        let resps = engine.process_batch(reqs);
        assert_eq!(resps.len(), 5);
        assert!(resps.iter().all(|r| !r.detected && !r.degraded));
        assert_eq!(engine.metrics.requests.load(Ordering::Relaxed), 5);
        assert_eq!(engine.metrics.detections.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn chaos_every_batch_detected_and_recovered() {
        let model = tiny_model(Protection::DetectRecompute);
        let reqs = make_requests(&model, 4, 2);
        let clean_engine = Engine::new(tiny_model(Protection::DetectRecompute));
        let clean = clean_engine.process_batch(reqs.clone());
        let engine = Engine::with_chaos(
            model,
            ChaosConfig {
                p_weight_flip: 1.0,
                p_table_flip: 0.0,
                seed: 3,
            },
        );
        let mut detected_any = false;
        for _ in 0..10 {
            let resps = engine.process_batch(reqs.clone());
            if resps[0].detected {
                detected_any = true;
                assert!(resps[0].recomputed);
                assert!(!resps[0].degraded, "transient fault must recover");
                // Recovered scores equal clean scores.
                for (r, c) in resps.iter().zip(&clean) {
                    assert_eq!(r.score, c.score);
                }
            }
        }
        assert!(detected_any, "weight flips should be detected");
        assert!(engine.metrics.recomputes.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn chaos_with_detect_only_flags_without_recompute() {
        let model = tiny_model(Protection::Detect);
        let reqs = make_requests(&model, 4, 5);
        let engine = Engine::with_chaos(
            model,
            ChaosConfig {
                p_weight_flip: 1.0,
                p_table_flip: 0.0,
                seed: 4,
            },
        );
        let mut detected_any = false;
        for _ in 0..10 {
            let resps = engine.process_batch(reqs.clone());
            if resps[0].detected {
                detected_any = true;
                assert!(!resps[0].recomputed);
            }
        }
        assert!(detected_any);
        assert_eq!(engine.metrics.recomputes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn sharded_engine_matches_unsharded_scores() {
        let reqs = make_requests(&tiny_model(Protection::DetectRecompute), 6, 21);
        let plain = Engine::new(tiny_model(Protection::DetectRecompute));
        let sharded = Engine::new(tiny_model(Protection::DetectRecompute))
            .with_shards(crate::shard::ShardPlan::hash_placement(1, 2, 2), 64);
        let want = plain.process_batch(reqs.clone());
        let got = sharded.process_batch(reqs);
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.score, g.score, "sharded serving must be bit-identical");
            assert!(!g.detected);
        }
        let snap = sharded.metrics_snapshot();
        assert!(snap.get("shards").is_some(), "sharded snapshot must carry health");
        assert!(plain.metrics_snapshot().get("shards").is_none());
    }

    #[test]
    fn placement_policy_plugs_into_the_engine_unchanged() {
        // A non-default placement serves bit-identically (tables are
        // placed whole, so routing is the only thing that moves) and its
        // name surfaces in the health block.
        let reqs = make_requests(&tiny_model(Protection::DetectRecompute), 6, 31);
        let plain = Engine::new(tiny_model(Protection::DetectRecompute));
        let rr = Engine::new(tiny_model(Protection::DetectRecompute)).with_placement(
            &crate::shard::RoundRobinPlacement,
            2,
            2,
            64,
        );
        let want = plain.process_batch(reqs.clone());
        let got = rr.process_batch(reqs);
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.score, g.score, "placement must not change scores");
            assert!(!g.detected);
        }
        let snap = rr.metrics_snapshot();
        assert_eq!(
            snap.path(&["shards", "placement"]).and_then(Json::as_str),
            Some("round_robin")
        );
        assert_eq!(
            plain
                .metrics_snapshot()
                .path(&["kernel", "sites"])
                .and_then(Json::as_arr)
                .map(|a| a.len()),
            Some(4),
            "kernel block lists every MLP site (bottom 2 + top 1 + head)"
        );
    }

    #[test]
    fn sharded_chaos_table_flip_fails_over_transparently() {
        let reqs = make_requests(&tiny_model(Protection::DetectRecompute), 6, 22);
        let clean_engine = Engine::new(tiny_model(Protection::DetectRecompute));
        let clean = clean_engine.process_batch(reqs.clone());
        let engine = Engine::with_chaos(
            tiny_model(Protection::DetectRecompute),
            ChaosConfig {
                p_weight_flip: 0.0,
                p_table_flip: 1.0,
                seed: 23,
            },
        )
        .with_shards(crate::shard::ShardPlan::hash_placement(1, 1, 2), 64);
        // Replica flips surface when a touched row is hit; run batches
        // until the router sees one, then check the response was clean.
        let mut seen = false;
        for _ in 0..300 {
            let resps = engine.process_batch(reqs.clone());
            if engine.metrics.shard_detections.load(Ordering::Relaxed) > 0 {
                seen = true;
                // Detected corruption was routed around: the batch is
                // neither detected nor degraded, scores match clean.
                assert!(!resps[0].detected && !resps[0].degraded);
                for (r, c) in resps.iter().zip(&clean) {
                    assert_eq!(r.score, c.score);
                }
                break;
            }
        }
        assert!(seen, "replica chaos never detected by the router");
        // The quarantined replica repairs back to health.
        let store = engine.shard_store().unwrap();
        assert!(store.quarantined_replicas() >= 1);
        store.drain_repairs();
        assert_eq!(store.quarantined_replicas(), 0);
    }

    #[test]
    fn table_chaos_detected() {
        let model = tiny_model(Protection::DetectRecompute);
        let reqs = make_requests(&model, 8, 6);
        let engine = Engine::with_chaos(
            model,
            ChaosConfig {
                p_weight_flip: 0.0,
                p_table_flip: 1.0,
                seed: 7,
            },
        );
        // Table flips only surface when a touched row is corrupted; with
        // 500 rows and 8×5 lookups per batch, ~8% per batch. Run enough
        // batches to see at least one detection.
        let mut detected_any = false;
        for _ in 0..300 {
            let resps = engine.process_batch(reqs.clone());
            if resps[0].detected {
                detected_any = true;
                break;
            }
        }
        assert!(detected_any, "table chaos never detected");
    }
}
