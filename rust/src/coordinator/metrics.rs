//! Serving metrics: lock-free counters and a log-linear latency
//! histogram, snapshotted to JSON for the `/metrics`-style endpoint —
//! plus the adaptive-detection policy block (per-site modes, window
//! stats, per-mode served counters, measured vs. estimated overhead).

use crate::obs::LogLinHist;
use crate::policy::{DetectionMode, PolicyController, PolicySites};
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Request-latency histogram in microseconds, backed by the shared
/// log-linear histogram ([`crate::obs::LogLinHist`]: 4 linear
/// sub-buckets per octave, interpolated quantiles). The old pure-log2
/// buckets reported the bucket upper bound, making p99 wrong by up to
/// 2×; the API (`record_us`/`count`/`mean_us`/`quantile_us`) is
/// unchanged and still lock-free.
pub struct LatencyHistogram {
    hist: LogLinHist,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            hist: LogLinHist::new(),
        }
    }

    pub fn record_us(&self, us: u64) {
        self.hist.record(us);
    }

    pub fn count(&self) -> u64 {
        self.hist.count()
    }

    pub fn mean_us(&self) -> f64 {
        self.hist.mean()
    }

    /// Interpolated quantile in microseconds.
    pub fn quantile_us(&self, q: f64) -> u64 {
        self.hist.quantile(q)
    }

    /// The backing histogram (for windowed readers like the overload
    /// controller's [`crate::obs::HistWindow`]).
    pub fn hist(&self) -> &LogLinHist {
        &self.hist
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// All serving counters.
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Soft-error detections at local (engine-owned) sites — GEMM rows,
    /// the BoundOnly aggregate, and unsharded EB bags. Fed by the
    /// fault-event sink ([`crate::detect::EventSink`]), one per emitted
    /// event; retries that re-detect a persistent fault count again
    /// (each detection is an event).
    pub detections: AtomicU64,
    /// Batch-level recomputations triggered by a detection.
    pub recomputes: AtomicU64,
    /// Detections that persisted after recompute.
    pub degraded: AtomicU64,
    /// Embedding rows scanned by the background scrubber.
    pub scrubbed_rows: AtomicU64,
    /// Corrupted rows found by the scrubber.
    pub scrub_hits: AtomicU64,
    /// Shard-router events (sharded serving): bags flagged on a replica,
    /// shard-batches re-served from a sibling replica, and
    /// Healthy→Quarantined transitions. Under `DetectRecompute` these
    /// were recovered transparently (retry or failover) and never
    /// dirtied a batch; under detect-only protection a `shard_detections`
    /// count means the flagged value WAS served and the batch was marked
    /// detected (contrast `detections`/`degraded`).
    pub shard_detections: AtomicU64,
    pub shard_failovers: AtomicU64,
    pub shard_quarantines: AtomicU64,
    /// Adaptive-policy controller events: sites snapped to `Full`
    /// (escalations) and single lattice steps down (decays). Mirrored
    /// from the policy site table at snapshot time; 0 with no policy.
    pub policy_escalations: AtomicU64,
    pub policy_decays: AtomicU64,
    /// Admission control (PR 10): requests accepted into a batch queue,
    /// requests refused with `{"error":"overloaded"}` (queue watermark
    /// or shedding state), and a gauge of the deepest batch queue as of
    /// the last submit — all fed from the serve path with relaxed
    /// atomics, no new hot-path locks.
    pub admitted: AtomicU64,
    pub shed: AtomicU64,
    pub queue_depth: AtomicU64,
    pub latency: LatencyHistogram,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            detections: AtomicU64::new(0),
            recomputes: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            scrubbed_rows: AtomicU64::new(0),
            scrub_hits: AtomicU64::new(0),
            shard_detections: AtomicU64::new(0),
            shard_failovers: AtomicU64::new(0),
            shard_quarantines: AtomicU64::new(0),
            policy_escalations: AtomicU64::new(0),
            policy_decays: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            latency: LatencyHistogram::new(),
        }
    }

    pub fn snapshot(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests.load(Ordering::Relaxed) as f64)),
            ("batches", Json::Num(self.batches.load(Ordering::Relaxed) as f64)),
            (
                "detections",
                Json::Num(self.detections.load(Ordering::Relaxed) as f64),
            ),
            (
                "recomputes",
                Json::Num(self.recomputes.load(Ordering::Relaxed) as f64),
            ),
            ("degraded", Json::Num(self.degraded.load(Ordering::Relaxed) as f64)),
            (
                "scrubbed_rows",
                Json::Num(self.scrubbed_rows.load(Ordering::Relaxed) as f64),
            ),
            (
                "scrub_hits",
                Json::Num(self.scrub_hits.load(Ordering::Relaxed) as f64),
            ),
            (
                "shard_detections",
                Json::Num(self.shard_detections.load(Ordering::Relaxed) as f64),
            ),
            (
                "shard_failovers",
                Json::Num(self.shard_failovers.load(Ordering::Relaxed) as f64),
            ),
            (
                "shard_quarantines",
                Json::Num(self.shard_quarantines.load(Ordering::Relaxed) as f64),
            ),
            (
                "policy_escalations",
                Json::Num(self.policy_escalations.load(Ordering::Relaxed) as f64),
            ),
            (
                "policy_decays",
                Json::Num(self.policy_decays.load(Ordering::Relaxed) as f64),
            ),
            ("admitted", Json::Num(self.admitted.load(Ordering::Relaxed) as f64)),
            ("shed", Json::Num(self.shed.load(Ordering::Relaxed) as f64)),
            (
                "queue_depth",
                Json::Num(self.queue_depth.load(Ordering::Relaxed) as f64),
            ),
            ("latency_mean_us", Json::Num(self.latency.mean_us())),
            ("latency_p50_us", Json::Num(self.latency.quantile_us(0.5) as f64)),
            ("latency_p99_us", Json::Num(self.latency.quantile_us(0.99) as f64)),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// The adaptive-detection policy block of the metrics snapshot: per-mode
/// served-unit counters, lifetime controller events, the current scrub
/// budget, and one entry per site (mode + sliding-window units /
/// verified / flags + estimated overhead fraction + the live *measured*
/// full-detection overhead when the profiler has warmed that site —
/// `overhead_measured` is what the controller budgets `n*` against
/// unless `PolicyConfig::pin_unit_costs` pins the static prior).
pub fn policy_json(sites: &PolicySites, controller: &PolicyController) -> Json {
    let mode_json = |mode: DetectionMode| match mode {
        DetectionMode::Sampled(n) => Json::Str(format!("sampled_1_in_{n}")),
        m => Json::Str(m.as_str().to_string()),
    };
    let site_json = |flat: usize, label: String| {
        let site = sites.site(flat);
        let w = controller.window_stats(flat);
        Json::obj(vec![
            ("site", Json::Str(label)),
            ("mode", mode_json(site.cell.load())),
            ("window_units", Json::Num(w.units as f64)),
            ("window_verified", Json::Num(w.verified as f64)),
            ("window_flags", Json::Num(w.flags as f64)),
            (
                "overhead_est",
                Json::Num(controller.overhead_estimate(flat)),
            ),
            (
                "overhead_measured",
                match controller.measured_overhead(flat) {
                    Some(x) => Json::Num(x),
                    None => Json::Null,
                },
            ),
        ])
    };
    let mut site_rows = Vec::with_capacity(sites.len());
    for i in 0..sites.gemm.len() {
        site_rows.push(site_json(i, format!("gemm/{i}")));
    }
    for t in 0..sites.eb.len() {
        site_rows.push(site_json(sites.eb_flat(t), format!("eb/{t}")));
    }
    let served = |slot: usize| Json::Num(sites.served[slot].load(Ordering::Relaxed) as f64);
    Json::obj(vec![
        (
            "served",
            Json::obj(vec![
                ("full", served(DetectionMode::Full.slot())),
                ("sampled", served(DetectionMode::Sampled(2).slot())),
                ("bound_only", served(DetectionMode::BoundOnly.slot())),
                ("off", served(DetectionMode::Off.slot())),
            ]),
        ),
        (
            "escalations",
            Json::Num(sites.escalations.load(Ordering::Relaxed) as f64),
        ),
        ("decays", Json::Num(sites.decays.load(Ordering::Relaxed) as f64)),
        (
            "scrub_boosts",
            Json::Num(sites.scrub_boosts.load(Ordering::Relaxed) as f64),
        ),
        (
            "scrub_budget",
            Json::Num(sites.scrub_budget.load(Ordering::Relaxed) as f64),
        ),
        ("sites", Json::Arr(site_rows)),
    ])
}

/// The overload block of the metrics snapshot (PR 10): serve-side
/// pressure state, the detection floor in force, and the lifetime
/// degrade/restore tallies. Strings are skipped by the Prometheus
/// walker, so state and floor carry numeric codes alongside their
/// names.
pub fn overload_json(ctl: &crate::policy::OverloadCtl) -> Json {
    let state = ctl.state();
    let floor = ctl.floor();
    Json::obj(vec![
        ("state", Json::Str(state.as_str().to_string())),
        ("state_code", Json::Num(state.code() as f64)),
        ("floor", Json::Str(floor.as_str().to_string())),
        ("floor_level", Json::Num(floor.level() as f64)),
        ("window_p99_us", Json::Num(ctl.last_p99_us() as f64)),
        (
            "slo_p99_us",
            Json::Num(ctl.config().slo_p99_us as f64),
        ),
        ("degrade_steps", Json::Num(ctl.degrade_steps() as f64)),
        ("restore_steps", Json::Num(ctl.restore_steps() as f64)),
        ("pressed_sites", Json::Num(ctl.pressed_sites() as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::new();
        for us in [1u64, 2, 4, 8, 100, 1000, 100_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        assert!(h.mean_us() > 0.0);
        assert!(h.quantile_us(0.5) <= 256);
        assert!(h.quantile_us(1.0) >= 100_000);
    }

    #[test]
    fn interpolated_p99_is_no_longer_bucket_upper_bound() {
        // 1000 samples uniform in [1000, 2000) µs: the old log2
        // histogram reported p99 = 2048 (the bucket upper bound, ~3%
        // high at best, 2× at worst). Interpolated log-linear must land
        // within 15% of the true 1990.
        let h = LatencyHistogram::new();
        for i in 0..1000u64 {
            h.record_us(1000 + i);
        }
        let p99 = h.quantile_us(0.99) as f64;
        assert!((p99 - 1990.0).abs() / 1990.0 < 0.15, "p99 = {p99}");
    }

    #[test]
    fn zero_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
        h.record_us(0); // clamps to bucket 0
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn policy_block_reports_modes_window_stats_and_served() {
        use crate::policy::{build_neighbors, PolicyConfig, PolicyController, PolicySites};
        use std::sync::Arc;
        let sites = Arc::new(PolicySites::new(2, 1, 1e3, 128));
        sites.note_served(DetectionMode::Full, 5);
        sites.note_served(DetectionMode::Sampled(8), 3);
        sites.eb[0].cell.store(DetectionMode::Sampled(4));
        let nb = build_neighbors(2, 1, None);
        let mut c = PolicyController::new(Arc::clone(&sites), nb, PolicyConfig::default());
        sites.eb[0].telem.record(10, 3);
        c.step();
        let j = policy_json(&sites, &c);
        assert_eq!(j.path(&["served", "full"]).and_then(Json::as_usize), Some(5));
        assert_eq!(j.path(&["served", "sampled"]).and_then(Json::as_usize), Some(3));
        assert_eq!(
            j.path(&["sites", "2", "mode"]).and_then(Json::as_str),
            Some("sampled_1_in_4")
        );
        assert_eq!(
            j.path(&["sites", "2", "window_units"]).and_then(Json::as_usize),
            Some(10)
        );
        assert_eq!(j.get("scrub_budget").and_then(Json::as_usize), Some(128));
    }

    #[test]
    fn snapshot_has_all_keys() {
        let m = Metrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.latency.record_us(50);
        let s = m.snapshot();
        for key in [
            "requests",
            "batches",
            "detections",
            "recomputes",
            "degraded",
            "scrubbed_rows",
            "scrub_hits",
            "shard_detections",
            "shard_failovers",
            "shard_quarantines",
            "policy_escalations",
            "policy_decays",
            "admitted",
            "shed",
            "queue_depth",
            "latency_mean_us",
            "latency_p50_us",
            "latency_p99_us",
        ] {
            assert!(s.get(key).is_some(), "missing {key}");
        }
    }
}
