//! Wire types for the serving protocol: newline-delimited JSON over TCP.

use crate::dlrm::DlrmRequest;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// A scoring request from a client.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ScoreRequest {
    pub id: u64,
    pub dense: Vec<f32>,
    pub sparse: Vec<Vec<usize>>,
}

impl ScoreRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            (
                "dense",
                Json::Arr(self.dense.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
            (
                "sparse",
                Json::Arr(
                    self.sparse
                        .iter()
                        .map(|t| Json::Arr(t.iter().map(|&i| Json::Num(i as f64)).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let id = j
            .get("id")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("missing id"))? as u64;
        let dense = j
            .get("dense")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing dense"))?
            .iter()
            .map(|x| x.as_f64().map(|v| v as f32).ok_or_else(|| anyhow!("bad dense")))
            .collect::<Result<_>>()?;
        let sparse = j
            .get("sparse")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing sparse"))?
            .iter()
            .map(|t| {
                t.as_arr()
                    .ok_or_else(|| anyhow!("bad sparse"))?
                    .iter()
                    .map(|i| i.as_usize().ok_or_else(|| anyhow!("bad index")))
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<_>>()?;
        Ok(Self { id, dense, sparse })
    }

    pub fn into_dlrm(self) -> DlrmRequest {
        DlrmRequest {
            dense: self.dense,
            sparse: self.sparse,
        }
    }

    /// Zero-allocation fast path for the server read loop: parse one
    /// request line **into** this (reused) instance, recycling the
    /// `dense` buffer and every inner `sparse` index `Vec` (grow-only —
    /// at a steady request shape, no heap allocation after the first
    /// request; enforced by `rust/tests/zero_alloc.rs`).
    ///
    /// Accepts exactly the score-request object grammar (`id`, `dense`,
    /// `sparse` keys in any order, standard JSON numbers/whitespace).
    /// Returns `false` — with `self` left in an unspecified reusable
    /// state — for anything else (control ops like `{"op":…}`, unknown
    /// keys, malformed input): the caller falls back to the generic
    /// [`Json::parse`] path, which owns error reporting, so the two
    /// paths stay observably identical.
    pub fn parse_line_into(&mut self, line: &str) -> bool {
        let mut p = FastParser { b: line.as_bytes(), s: line, i: 0 };
        let (mut got_id, mut got_dense, mut got_sparse) = (false, false, false);
        p.ws();
        if !p.eat(b'{') {
            return false;
        }
        loop {
            p.ws();
            if p.eat(b'}') {
                break;
            }
            if (got_id || got_dense || got_sparse) && !p.eat(b',') {
                return false;
            }
            p.ws();
            let Some(key) = p.key() else { return false };
            p.ws();
            if !p.eat(b':') {
                return false;
            }
            p.ws();
            match key {
                Key::Id => {
                    let Some(v) = p.number() else { return false };
                    if v.fract() != 0.0 || v < 0.0 {
                        return false;
                    }
                    self.id = v as u64;
                    got_id = true;
                }
                Key::Dense => {
                    self.dense.clear();
                    if !p.f32_array(&mut self.dense) {
                        return false;
                    }
                    got_dense = true;
                }
                Key::Sparse => {
                    if !p.eat(b'[') {
                        return false;
                    }
                    let mut used = 0usize;
                    p.ws();
                    if !p.eat(b']') {
                        loop {
                            p.ws();
                            if used == self.sparse.len() {
                                self.sparse.push(Vec::new());
                            }
                            self.sparse[used].clear();
                            if !p.usize_array(&mut self.sparse[used]) {
                                return false;
                            }
                            used += 1;
                            p.ws();
                            if p.eat(b']') {
                                break;
                            }
                            if !p.eat(b',') {
                                return false;
                            }
                        }
                    }
                    // Steady-shape traffic never shrinks: this truncate
                    // is a no-op after the first request.
                    self.sparse.truncate(used);
                    got_sparse = true;
                }
            }
        }
        p.ws();
        got_id && got_dense && got_sparse && p.i == p.b.len()
    }
}

/// Which score-request key a fast-path object member carries.
enum Key {
    Id,
    Dense,
    Sparse,
}

/// Byte-cursor recursive-descent parser for the score-request fast path.
/// Numbers are parsed from in-place `&str` slices (no allocation).
struct FastParser<'a> {
    b: &'a [u8],
    s: &'a str,
    i: usize,
}

impl FastParser<'_> {
    fn ws(&mut self) {
        while matches!(self.b.get(self.i).copied(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> bool {
        if self.b.get(self.i) == Some(&c) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    /// `"id"` / `"dense"` / `"sparse"`; anything else (including escapes)
    /// aborts the fast path.
    fn key(&mut self) -> Option<Key> {
        for (lit, key) in [
            (&b"\"id\""[..], Key::Id),
            (&b"\"dense\""[..], Key::Dense),
            (&b"\"sparse\""[..], Key::Sparse),
        ] {
            if self.b[self.i..].starts_with(lit) {
                self.i += lit.len();
                return Some(key);
            }
        }
        None
    }

    /// One number token in the exact JSON grammar (`-?(0|[1-9][0-9]*)`
    /// `(\.[0-9]+)?([eE][+-]?[0-9]+)?`), parsed from the source slice in
    /// place. Matching the strict grammar — not everything
    /// `f64::from_str` would take (`01`, `1.`, `+1`) — keeps the fast
    /// path's accept set a subset of [`Json::parse`]'s, so every line
    /// the fast path scores would have scored identically on the
    /// generic path, and everything stricter falls back to it.
    fn number(&mut self) -> Option<f64> {
        let start = self.i;
        self.eat(b'-');
        match self.b.get(self.i).copied() {
            Some(b'0') => self.i += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.b.get(self.i).copied(), Some(b'0'..=b'9')) {
                    self.i += 1;
                }
            }
            _ => return None,
        }
        if self.eat(b'.') {
            if !matches!(self.b.get(self.i).copied(), Some(b'0'..=b'9')) {
                return None;
            }
            while matches!(self.b.get(self.i).copied(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.b.get(self.i).copied(), Some(b'e' | b'E')) {
            self.i += 1;
            if !self.eat(b'+') {
                self.eat(b'-');
            }
            if !matches!(self.b.get(self.i).copied(), Some(b'0'..=b'9')) {
                return None;
            }
            while matches!(self.b.get(self.i).copied(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        self.s.get(start..self.i)?.parse::<f64>().ok()
    }

    /// `[f, f, …]` appended to `out` (caller cleared it).
    fn f32_array(&mut self, out: &mut Vec<f32>) -> bool {
        if !self.eat(b'[') {
            return false;
        }
        self.ws();
        if self.eat(b']') {
            return true;
        }
        loop {
            self.ws();
            let Some(v) = self.number() else { return false };
            out.push(v as f32);
            self.ws();
            if self.eat(b']') {
                return true;
            }
            if !self.eat(b',') {
                return false;
            }
        }
    }

    /// `[i, i, …]` of non-negative integers appended to `out`.
    fn usize_array(&mut self, out: &mut Vec<usize>) -> bool {
        if !self.eat(b'[') {
            return false;
        }
        self.ws();
        if self.eat(b']') {
            return true;
        }
        loop {
            self.ws();
            let Some(v) = self.number() else { return false };
            if v.fract() != 0.0 || v < 0.0 {
                return false;
            }
            out.push(v as usize);
            self.ws();
            if self.eat(b']') {
                return true;
            }
            if !self.eat(b',') {
                return false;
            }
        }
    }
}

/// Response to one scoring request.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreResponse {
    pub id: u64,
    pub score: f32,
    /// A soft error was detected while serving this request's batch.
    pub detected: bool,
    /// The batch was recomputed before responding.
    pub recomputed: bool,
    /// Detection persisted after recompute (likely memory corruption).
    pub degraded: bool,
    pub latency_us: u64,
}

impl ScoreResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("score", Json::Num(self.score as f64)),
            ("detected", Json::Bool(self.detected)),
            ("recomputed", Json::Bool(self.recomputed)),
            ("degraded", Json::Bool(self.degraded)),
            ("latency_us", Json::Num(self.latency_us as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            id: j.get("id").and_then(Json::as_i64).ok_or_else(|| anyhow!("id"))? as u64,
            score: j
                .get("score")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("score"))? as f32,
            detected: j.get("detected").and_then(Json::as_bool).unwrap_or(false),
            recomputed: j.get("recomputed").and_then(Json::as_bool).unwrap_or(false),
            degraded: j.get("degraded").and_then(Json::as_bool).unwrap_or(false),
            latency_us: j.get("latency_us").and_then(Json::as_i64).unwrap_or(0) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = ScoreRequest {
            id: 9,
            dense: vec![0.5, 1.25],
            sparse: vec![vec![1, 2, 3], vec![]],
        };
        let j = r.to_json().to_string();
        let back = ScoreRequest::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn response_json_roundtrip() {
        let r = ScoreResponse {
            id: 3,
            score: 0.75,
            detected: true,
            recomputed: true,
            degraded: false,
            latency_us: 1234,
        };
        let j = r.to_json().to_string();
        let back = ScoreResponse::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn malformed_request_rejected() {
        for s in [r#"{}"#, r#"{"id": 1}"#, r#"{"id":1,"dense":[],"sparse":"x"}"#] {
            assert!(ScoreRequest::from_json(&Json::parse(s).unwrap()).is_err());
        }
    }

    #[test]
    fn fast_parse_matches_generic_path() {
        let cases = [
            r#"{"id":9,"dense":[0.5,1.25],"sparse":[[1,2,3],[]]}"#,
            r#"{ "id" : 0 , "dense" : [ ] , "sparse" : [ ] }"#,
            r#"{"sparse":[[7]],"id":12,"dense":[-1.5e-2,3]}"#,
            r#"{"id":18446744073,"dense":[1e3],"sparse":[[0],[4,4,4]]}"#,
        ];
        let mut req = ScoreRequest::default();
        for line in cases {
            assert!(req.parse_line_into(line), "fast path must accept {line}");
            let generic = ScoreRequest::from_json(&Json::parse(line).unwrap()).unwrap();
            assert_eq!(req, generic, "{line}");
        }
    }

    #[test]
    fn fast_parse_falls_back_on_everything_else() {
        let mut req = ScoreRequest::default();
        for line in [
            r#"{"op":"metrics"}"#,
            r#"{"id":1,"dense":[],"sparse":[],"extra":0}"#,
            r#"{"id":1,"dense":[]}"#,
            r#"{"id":-1,"dense":[],"sparse":[]}"#,
            r#"{"id":1.5,"dense":[],"sparse":[]}"#,
            r#"{"id":1,"dense":[],"sparse":[[-3]]}"#,
            r#"{"id":1,"dense":[],"sparse":"x"}"#,
            r#"not json at all"#,
            r#"{"id":1,"dense":[],"sparse":[]} trailing"#,
            r#"{"id":1 "dense":[],"sparse":[]}"#,
            // Strict JSON number grammar: from_str-isms must not widen
            // the accept set past Json::parse.
            r#"{"id":01,"dense":[],"sparse":[]}"#,
            r#"{"id":1,"dense":[1.],"sparse":[]}"#,
            r#"{"id":1,"dense":[+1],"sparse":[]}"#,
            r#"{"id":1,"dense":[1e],"sparse":[]}"#,
        ] {
            assert!(!req.parse_line_into(line), "fast path must reject {line}");
        }
    }

    #[test]
    fn fast_parse_reuses_buffers_across_shapes() {
        let mut req = ScoreRequest::default();
        assert!(req.parse_line_into(r#"{"id":1,"dense":[1,2,3],"sparse":[[1,2],[3]]}"#));
        assert_eq!(req.dense, vec![1.0, 2.0, 3.0]);
        assert_eq!(req.sparse, vec![vec![1, 2], vec![3]]);
        // A second, smaller request overwrites cleanly — stale state from
        // the first never leaks through.
        assert!(req.parse_line_into(r#"{"id":2,"dense":[9],"sparse":[[5]]}"#));
        assert_eq!(req.id, 2);
        assert_eq!(req.dense, vec![9.0]);
        assert_eq!(req.sparse, vec![vec![5]]);
    }
}
