//! Wire types for the serving protocol: newline-delimited JSON over TCP.

use crate::dlrm::DlrmRequest;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// A scoring request from a client.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreRequest {
    pub id: u64,
    pub dense: Vec<f32>,
    pub sparse: Vec<Vec<usize>>,
}

impl ScoreRequest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            (
                "dense",
                Json::Arr(self.dense.iter().map(|&x| Json::Num(x as f64)).collect()),
            ),
            (
                "sparse",
                Json::Arr(
                    self.sparse
                        .iter()
                        .map(|t| Json::Arr(t.iter().map(|&i| Json::Num(i as f64)).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let id = j
            .get("id")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("missing id"))? as u64;
        let dense = j
            .get("dense")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing dense"))?
            .iter()
            .map(|x| x.as_f64().map(|v| v as f32).ok_or_else(|| anyhow!("bad dense")))
            .collect::<Result<_>>()?;
        let sparse = j
            .get("sparse")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing sparse"))?
            .iter()
            .map(|t| {
                t.as_arr()
                    .ok_or_else(|| anyhow!("bad sparse"))?
                    .iter()
                    .map(|i| i.as_usize().ok_or_else(|| anyhow!("bad index")))
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<_>>()?;
        Ok(Self { id, dense, sparse })
    }

    pub fn into_dlrm(self) -> DlrmRequest {
        DlrmRequest {
            dense: self.dense,
            sparse: self.sparse,
        }
    }
}

/// Response to one scoring request.
#[derive(Clone, Debug, PartialEq)]
pub struct ScoreResponse {
    pub id: u64,
    pub score: f32,
    /// A soft error was detected while serving this request's batch.
    pub detected: bool,
    /// The batch was recomputed before responding.
    pub recomputed: bool,
    /// Detection persisted after recompute (likely memory corruption).
    pub degraded: bool,
    pub latency_us: u64,
}

impl ScoreResponse {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::Num(self.id as f64)),
            ("score", Json::Num(self.score as f64)),
            ("detected", Json::Bool(self.detected)),
            ("recomputed", Json::Bool(self.recomputed)),
            ("degraded", Json::Bool(self.degraded)),
            ("latency_us", Json::Num(self.latency_us as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            id: j.get("id").and_then(Json::as_i64).ok_or_else(|| anyhow!("id"))? as u64,
            score: j
                .get("score")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("score"))? as f32,
            detected: j.get("detected").and_then(Json::as_bool).unwrap_or(false),
            recomputed: j.get("recomputed").and_then(Json::as_bool).unwrap_or(false),
            degraded: j.get("degraded").and_then(Json::as_bool).unwrap_or(false),
            latency_us: j.get("latency_us").and_then(Json::as_i64).unwrap_or(0) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = ScoreRequest {
            id: 9,
            dense: vec![0.5, 1.25],
            sparse: vec![vec![1, 2, 3], vec![]],
        };
        let j = r.to_json().to_string();
        let back = ScoreRequest::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn response_json_roundtrip() {
        let r = ScoreResponse {
            id: 3,
            score: 0.75,
            detected: true,
            recomputed: true,
            degraded: false,
            latency_us: 1234,
        };
        let j = r.to_json().to_string();
        let back = ScoreResponse::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn malformed_request_rejected() {
        for s in [r#"{}"#, r#"{"id": 1}"#, r#"{"id":1,"dense":[],"sparse":"x"}"#] {
            assert!(ScoreRequest::from_json(&Json::parse(s).unwrap()).is_err());
        }
    }
}
