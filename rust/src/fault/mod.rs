//! Source-level soft-error injection (paper §VI-B: "randomly selecting an
//! element in the input or output and flipping a random bit in that
//! element"), plus the random-data-fluctuation model of §IV-C.

pub mod campaign;

use crate::util::rng::Pcg32;

/// The two fault models analyzed in §IV-C.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultModel {
    /// Flip one uniformly-random bit of one element.
    BitFlip,
    /// Replace one element with a uniform random value of its type.
    DataFluctuation,
}

/// Which bits of an 8-bit element a flip may land in (Table III splits
/// EB injections into the upper and lower 4 bits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BitRange {
    Any,
    High4,
    Low4,
}

impl BitRange {
    fn pick_bit(self, rng: &mut Pcg32, width: u32) -> u32 {
        match self {
            BitRange::Any => rng.gen_range_u32(width),
            BitRange::High4 => {
                debug_assert!(width == 8);
                4 + rng.gen_range_u32(4)
            }
            BitRange::Low4 => {
                debug_assert!(width == 8);
                rng.gen_range_u32(4)
            }
        }
    }
}

/// Record of one injected fault, for logging / restoration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Injection {
    pub index: usize,
    pub bit: Option<u32>,
    pub old_bits: u64,
    pub new_bits: u64,
}

/// Flip one bit of a random i8 element. Returns the injection record.
pub fn flip_i8(buf: &mut [i8], rng: &mut Pcg32) -> Injection {
    let idx = rng.gen_range(0, buf.len());
    let bit = rng.gen_range_u32(8);
    let old = buf[idx];
    buf[idx] = (old as u8 ^ (1 << bit)) as i8;
    Injection {
        index: idx,
        bit: Some(bit),
        old_bits: old as u8 as u64,
        new_bits: buf[idx] as u8 as u64,
    }
}

/// Flip one bit (in `range`) of a random u8 element.
pub fn flip_u8(buf: &mut [u8], rng: &mut Pcg32, range: BitRange) -> Injection {
    let idx = rng.gen_range(0, buf.len());
    let bit = range.pick_bit(rng, 8);
    let old = buf[idx];
    buf[idx] = old ^ (1 << bit);
    Injection {
        index: idx,
        bit: Some(bit),
        old_bits: old as u64,
        new_bits: buf[idx] as u64,
    }
}

/// Flip one bit of a random i32 element (the C_temp target of §IV-C2).
pub fn flip_i32(buf: &mut [i32], rng: &mut Pcg32) -> Injection {
    let idx = rng.gen_range(0, buf.len());
    let bit = rng.gen_range_u32(32);
    let old = buf[idx];
    buf[idx] = old ^ (1i32 << bit);
    Injection {
        index: idx,
        bit: Some(bit),
        old_bits: old as u32 as u64,
        new_bits: buf[idx] as u32 as u64,
    }
}

/// Flip one bit of a random f32 element (EB results are float).
pub fn flip_f32(buf: &mut [f32], rng: &mut Pcg32) -> Injection {
    let idx = rng.gen_range(0, buf.len());
    let bit = rng.gen_range_u32(32);
    let old = buf[idx].to_bits();
    buf[idx] = f32::from_bits(old ^ (1u32 << bit));
    Injection {
        index: idx,
        bit: Some(bit),
        old_bits: old as u64,
        new_bits: buf[idx].to_bits() as u64,
    }
}

/// Replace a random i8 element with a uniform random *different* value.
pub fn fluctuate_i8(buf: &mut [i8], rng: &mut Pcg32) -> Injection {
    let idx = rng.gen_range(0, buf.len());
    let old = buf[idx];
    let mut new = old;
    while new == old {
        new = rng.next_i8();
    }
    buf[idx] = new;
    Injection {
        index: idx,
        bit: None,
        old_bits: old as u8 as u64,
        new_bits: new as u8 as u64,
    }
}

/// Replace a random i32 element with a uniform random *different* value.
pub fn fluctuate_i32(buf: &mut [i32], rng: &mut Pcg32) -> Injection {
    let idx = rng.gen_range(0, buf.len());
    let old = buf[idx];
    let mut new = old;
    while new == old {
        new = rng.next_u32() as i32;
    }
    buf[idx] = new;
    Injection {
        index: idx,
        bit: None,
        old_bits: old as u32 as u64,
        new_bits: new as u32 as u64,
    }
}

/// Undo an injection on an i8 buffer.
pub fn restore_i8(buf: &mut [i8], inj: Injection) {
    buf[inj.index] = inj.old_bits as u8 as i8;
}

/// Undo an injection on a u8 buffer.
pub fn restore_u8(buf: &mut [u8], inj: Injection) {
    buf[inj.index] = inj.old_bits as u8;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flip_changes_exactly_one_bit() {
        let mut rng = Pcg32::new(71);
        for _ in 0..100 {
            let mut buf = vec![0i8; 64];
            rng.fill_i8(&mut buf);
            let orig = buf.clone();
            let inj = flip_i8(&mut buf, &mut rng);
            let diff: u32 = buf
                .iter()
                .zip(&orig)
                .map(|(a, b)| (*a as u8 ^ *b as u8).count_ones())
                .sum();
            assert_eq!(diff, 1);
            assert_ne!(buf[inj.index], orig[inj.index]);
        }
    }

    #[test]
    fn bit_ranges_respected() {
        let mut rng = Pcg32::new(72);
        for _ in 0..200 {
            let mut buf = vec![0u8; 16];
            let inj = flip_u8(&mut buf, &mut rng, BitRange::High4);
            assert!(inj.bit.unwrap() >= 4);
            let mut buf = vec![0u8; 16];
            let inj = flip_u8(&mut buf, &mut rng, BitRange::Low4);
            assert!(inj.bit.unwrap() < 4);
        }
    }

    #[test]
    fn fluctuation_always_changes_value() {
        let mut rng = Pcg32::new(73);
        for _ in 0..100 {
            let mut buf = vec![5i32; 8];
            let inj = fluctuate_i32(&mut buf, &mut rng);
            assert_ne!(buf[inj.index], 5);
        }
    }

    #[test]
    fn restore_roundtrip() {
        let mut rng = Pcg32::new(74);
        let mut buf = vec![0u8; 32];
        rng.fill_u8(&mut buf);
        let orig = buf.clone();
        let inj = flip_u8(&mut buf, &mut rng, BitRange::Any);
        assert_ne!(buf, orig);
        restore_u8(&mut buf, inj);
        assert_eq!(buf, orig);
    }

    #[test]
    fn flip_f32_changes_bits() {
        let mut rng = Pcg32::new(75);
        let mut buf = vec![1.5f32; 4];
        let inj = flip_f32(&mut buf, &mut rng);
        assert_ne!(buf[inj.index].to_bits(), 1.5f32.to_bits());
    }
}
