//! Fault-injection campaigns — the machinery behind Tables II and III.
//!
//! Methodology follows §VI-B exactly: source-level injection, one fault per
//! run, detection tallied over repeated runs with fresh random inputs.

use super::{flip_i32, flip_u8, restore_u8, BitRange, FaultModel};
use crate::abft::eb::CheckPrecision;
use crate::abft::{AbftGemm, EbChecksum, RowCorrection, GROUP_WIDTH};
use crate::coordinator::Engine;
use crate::detect::{
    recovery, Detector, EventSink, FaultEvent, Recovery, Resolution, Severity, SiteClass, SiteCtx,
    SiteId, UnitRef,
};
use crate::dlrm::{AbftLinear, DlrmConfig, DlrmModel, Protection, TableConfig};
use crate::embedding::{bag_sum_4, embedding_bag_8, QuantTable4, QuantTable8};
use crate::policy::{DetectionMode, PolicyConfig};
use crate::quant::{quantize_slice_u8, requantize_cols_into, RequantEpilogue, RequantSpec};
use crate::shard::{ShardPlan, ShardRouter, ShardStore};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Where a GEMM campaign injects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmTarget {
    /// Packed B payload, *after* checksum encoding (Table II "error in B").
    MatrixB,
    /// 32-bit intermediate C_temp (Table II "error in C").
    MatrixC,
    /// No injection — false-positive control (Table II "no error").
    None,
}

/// detected / not-detected counts for one arm of a campaign.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Tally {
    pub detected: usize,
    pub not_detected: usize,
}

impl Tally {
    pub fn total(&self) -> usize {
        self.detected + self.not_detected
    }

    pub fn rate(&self) -> f64 {
        if self.total() == 0 {
            0.0
        } else {
            self.detected as f64 / self.total() as f64
        }
    }

    fn add(&mut self, detected: bool) {
        if detected {
            self.detected += 1;
        } else {
            self.not_detected += 1;
        }
    }
}

/// Configuration for the Table-II GEMM campaign.
#[derive(Clone, Debug)]
pub struct GemmCampaignConfig {
    /// (m, n, k) shapes; paper uses the 28 DLRM shapes of Fig 5.
    pub shapes: Vec<(usize, usize, usize)>,
    /// Runs per shape per arm (paper: 100 → 2800 samples per arm).
    pub runs_per_shape: usize,
    pub fault_model: FaultModel,
    pub modulus: i32,
    pub seed: u64,
}

impl Default for GemmCampaignConfig {
    fn default() -> Self {
        Self {
            shapes: fig5_shapes(),
            runs_per_shape: 100,
            fault_model: FaultModel::BitFlip,
            modulus: crate::abft::DEFAULT_MODULUS,
            seed: 0xD12A,
        }
    }
}

/// The 28 DLRM GEMM shapes benchmarked in Fig 5: batch rows
/// m ∈ {1, 50, 100, 150} × seven (n, k) layer shapes common in DLRM MLPs
/// (the paper names (1, 800, 3200) explicitly; the grid is reconstructed
/// from the figure's axis).
pub fn fig5_shapes() -> Vec<(usize, usize, usize)> {
    let ms = [1usize, 50, 100, 150];
    let nks = [
        (800usize, 3200usize),
        (800, 800),
        (512, 512),
        (512, 256),
        (256, 512),
        (128, 128),
        (256, 32),
    ];
    let mut out = Vec::with_capacity(28);
    for &m in &ms {
        for &(n, k) in &nks {
            out.push((m, n, k));
        }
    }
    out
}

/// Result rows of Table II.
#[derive(Clone, Debug, Default)]
pub struct GemmCampaignResult {
    pub error_in_b: Tally,
    pub error_in_c: Tally,
    /// For the no-error arm, `detected` counts FALSE POSITIVES.
    pub no_error: Tally,
}

/// Run the full Table-II campaign.
pub fn run_gemm_campaign(cfg: &GemmCampaignConfig) -> GemmCampaignResult {
    let mut result = GemmCampaignResult::default();
    let mut rng = Pcg32::new(cfg.seed);
    for &(m, n, k) in &cfg.shapes {
        for _ in 0..cfg.runs_per_shape {
            result
                .error_in_b
                .add(run_gemm_trial(m, n, k, GemmTarget::MatrixB, cfg, &mut rng));
            result
                .error_in_c
                .add(run_gemm_trial(m, n, k, GemmTarget::MatrixC, cfg, &mut rng));
            result
                .no_error
                .add(run_gemm_trial(m, n, k, GemmTarget::None, cfg, &mut rng));
        }
    }
    result
}

/// One GEMM trial: fresh random A/B, encode, inject per `target`, verify.
/// Returns whether ABFT flagged the run.
pub fn run_gemm_trial(
    m: usize,
    n: usize,
    k: usize,
    target: GemmTarget,
    cfg: &GemmCampaignConfig,
    rng: &mut Pcg32,
) -> bool {
    let mut a = vec![0u8; m * k];
    let mut b = vec![0i8; k * n];
    rng.fill_u8(&mut a);
    rng.fill_i8(&mut b);
    let mut abft = AbftGemm::with_modulus(&b, k, n, cfg.modulus);

    if target == GemmTarget::MatrixB {
        // Inject into the packed B *payload* (never the checksum column —
        // the paper's §IV-C assumption: the much smaller checksum is
        // error-free), after encoding, as in §VI-B1. The pack is
        // panel-interleaved, so map the logical (p, j) through offset().
        let p = rng.gen_range(0, k);
        let j = rng.gen_range(0, n);
        let idx = abft.packed.offset(p, j);
        let data = abft.packed.data_mut();
        match cfg.fault_model {
            FaultModel::BitFlip => {
                let bit = rng.gen_range_u32(8);
                data[idx] = (data[idx] as u8 ^ (1 << bit)) as i8;
            }
            FaultModel::DataFluctuation => {
                let old = data[idx];
                let mut new = old;
                while new == old {
                    new = rng.next_i8();
                }
                data[idx] = new;
            }
        }
    }

    let (mut c_temp, verdict) = abft.exec(&a, m);

    match target {
        GemmTarget::MatrixB => !verdict.clean(),
        GemmTarget::None => !verdict.clean(), // any flag is a false positive
        GemmTarget::MatrixC => {
            debug_assert!(verdict.clean());
            match cfg.fault_model {
                FaultModel::BitFlip => {
                    flip_i32(&mut c_temp, rng);
                }
                FaultModel::DataFluctuation => {
                    super::fluctuate_i32(&mut c_temp, rng);
                }
            }
            !abft.verify(&c_temp, m).clean()
        }
    }
}

/// Where an EB campaign injects (Table III splits table bit flips by
/// significance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EbTarget {
    /// Bit flip in the upper 4 bits of a table code read by the batch.
    TableHigh4,
    /// Bit flip in the lower 4 bits.
    TableLow4,
    /// Bit flip anywhere in an 8-bit code read by the batch.
    TableAny,
    /// Bit flip in the f32 output vector.
    Result,
    /// No injection — false-positive control.
    None,
}

/// Configuration for the Table-III EB campaign.
#[derive(Clone, Debug)]
pub struct EbCampaignConfig {
    pub table_rows: usize,
    pub dim: usize,
    /// Lookups per bag (paper Table I: average pooling size 100).
    pub pooling: usize,
    pub batch: usize,
    pub weighted: bool,
    pub rel_bound: f64,
    /// The paper's checker accumulates in f32 (§V-D's FP/low-bit numbers
    /// depend on it); the serving path defaults to f64. See DESIGN.md.
    pub precision: CheckPrecision,
    pub seed: u64,
}

impl Default for EbCampaignConfig {
    fn default() -> Self {
        Self {
            table_rows: 4_000_000,
            dim: 64,
            pooling: 100,
            batch: 10,
            weighted: false,
            rel_bound: crate::abft::DEFAULT_REL_BOUND,
            precision: CheckPrecision::F32,
            seed: 0xEB,
        }
    }
}

/// One arm of Table III.
pub fn run_eb_campaign(cfg: &EbCampaignConfig, target: EbTarget, runs: usize) -> Tally {
    let mut rng = Pcg32::new(cfg.seed);
    let mut table = QuantTable8::random(cfg.table_rows, cfg.dim, &mut rng);
    let checksum = EbChecksum::build_8(&table)
        .with_bound(cfg.rel_bound)
        .with_precision(cfg.precision);
    let mut tally = Tally::default();
    for _ in 0..runs {
        tally.add(run_eb_trial(&mut table, &checksum, cfg, target, &mut rng));
    }
    tally
}

/// One EB trial: sample a batch of bags, inject per `target` into an
/// element that participates in the batch, run EB, verify, restore.
pub fn run_eb_trial(
    table: &mut QuantTable8,
    checksum: &EbChecksum,
    cfg: &EbCampaignConfig,
    target: EbTarget,
    rng: &mut Pcg32,
) -> bool {
    let total = cfg.pooling * cfg.batch;
    let indices: Vec<usize> = (0..total).map(|_| rng.gen_range(0, table.rows)).collect();
    let offsets: Vec<usize> = (0..cfg.batch).map(|b| b * cfg.pooling).collect();
    let weights: Option<Vec<f32>> = if cfg.weighted {
        Some((0..total).map(|_| 0.5 + rng.next_f32()).collect())
    } else {
        None
    };

    // Inject into a code belonging to a row the batch actually reads —
    // §VI-B's "randomly choose an element" over the touched working set.
    let inj = match target {
        EbTarget::TableHigh4 | EbTarget::TableLow4 | EbTarget::TableAny => {
            let victim_row = indices[rng.gen_range(0, indices.len())];
            let col = rng.gen_range(0, table.d);
            let idx = victim_row * table.d + col;
            let range = match target {
                EbTarget::TableHigh4 => BitRange::High4,
                EbTarget::TableLow4 => BitRange::Low4,
                _ => BitRange::Any,
            };
            let one = &mut table.data[idx..idx + 1];
            let mut r = flip_u8(one, rng, range);
            r.index = idx;
            Some(r)
        }
        _ => None,
    };

    let mut result = embedding_bag_8(
        table,
        &indices,
        &offsets,
        weights.as_deref(),
        false,
    );

    if target == EbTarget::Result {
        super::flip_f32(&mut result, rng);
    }

    let flagged = checksum.check_batch(
        &table.alpha,
        &table.beta,
        &indices,
        &offsets,
        weights.as_deref(),
        &result,
    );

    if let Some(inj) = inj {
        restore_u8(&mut table.data, inj);
    }
    !flagged.is_empty()
}

/// Table-III extension (paper §V-C's p=4 configuration): the EB campaign
/// over a 4-bit nibble-packed table. Bit flips hit a random *stored byte*
/// (two codes) of a row the batch reads; significance is the flipped
/// bit's position within its nibble.
pub fn run_eb_campaign_4bit(cfg: &EbCampaignConfig, target: EbTarget, runs: usize) -> Tally {
    let mut rng = Pcg32::new(cfg.seed ^ 0x4B17);
    let mut table = QuantTable4::random(cfg.table_rows, cfg.dim, &mut rng);
    let checksum = EbChecksum::build_4(&table)
        .with_bound(cfg.rel_bound)
        .with_precision(cfg.precision);
    let mut tally = Tally::default();
    let row_bytes = (cfg.dim + 1) / 2;
    for _ in 0..runs {
        let total = cfg.pooling * cfg.batch;
        let indices: Vec<usize> = (0..total).map(|_| rng.gen_range(0, table.rows)).collect();
        let offsets: Vec<usize> = (0..cfg.batch).map(|b| b * cfg.pooling).collect();

        let inj = match target {
            EbTarget::TableHigh4 | EbTarget::TableLow4 | EbTarget::TableAny => {
                let victim_row = indices[rng.gen_range(0, indices.len())];
                let byte = rng.gen_range(0, row_bytes);
                let idx = victim_row * row_bytes + byte;
                // Within each nibble: bits 2-3 are "high", 0-1 "low".
                let nib = rng.gen_range_u32(2) * 4;
                let bit = match target {
                    EbTarget::TableHigh4 => nib + 2 + rng.gen_range_u32(2),
                    EbTarget::TableLow4 => nib + rng.gen_range_u32(2),
                    _ => nib + rng.gen_range_u32(4),
                };
                let old = table.data[idx];
                table.data[idx] = old ^ (1 << bit);
                Some((idx, old))
            }
            _ => None,
        };

        let mut flagged = false;
        let mut out = vec![0f32; cfg.dim];
        for b in 0..cfg.batch {
            let start = offsets[b];
            let end = if b + 1 < cfg.batch { offsets[b + 1] } else { indices.len() };
            bag_sum_4(&table, &indices[start..end], None, false, &mut out);
            flagged |= checksum.check_bag(
                &table.alpha,
                &table.beta,
                &indices[start..end],
                None,
                &out,
            );
        }
        if let Some((idx, old)) = inj {
            table.data[idx] = old;
        }
        tally.add(flagged);
    }
    tally
}

/// Configuration for the shard-failover campaign: the serving-layer
/// extension of the §VI-B methodology. Each run injects one bit flip
/// into one stored code byte of one **replica** and drives a batch
/// through the shard router, tallying the full control loop:
/// detect → quarantine → failover → scrub sweep → repair → re-admit.
#[derive(Clone, Debug)]
pub struct ShardCampaignConfig {
    pub num_shards: usize,
    pub replicas: usize,
    pub num_tables: usize,
    pub rows: usize,
    pub dim: usize,
    pub pooling: usize,
    pub batch: usize,
    pub runs: usize,
    /// Which bits of the victim byte flips may land in (Table-III split:
    /// high bits always clear the Eq-5 bound; low bits can slip under it
    /// — the scrubber's exact integer compare catches those).
    pub bit_range: BitRange,
    pub seed: u64,
}

impl Default for ShardCampaignConfig {
    fn default() -> Self {
        Self {
            num_shards: 2,
            replicas: 2,
            num_tables: 4,
            rows: 2000,
            dim: 32,
            pooling: 20,
            batch: 8,
            runs: 40,
            bit_range: BitRange::Any,
            seed: 0x5AD,
        }
    }
}

/// Tallies from one shard campaign. Since PR 5 every detection-side
/// field is a **journal query** over the store's fault-event pipeline
/// (`detect::Journal`), not a counter diff: "the router detected" means
/// "an `EbBound` event with the injected table's site id was journaled
/// during the serve".
#[derive(Clone, Debug, Default)]
pub struct ShardCampaignResult {
    pub runs: usize,
    /// Runs whose fault was flagged by the router while serving
    /// (journal: ≥1 `EbBound` event during the serve).
    pub served_detections: usize,
    /// Runs whose fault was caught only by the post-batch scrub sweep
    /// (journal: ≥1 `ScrubExact` event; cold row, or a low-bit flip
    /// under the float bound).
    pub scrub_detections: usize,
    /// Runs neither serving nor scrub caught (must be 0 — the scrubber's
    /// integer compare is exact).
    pub undetected: usize,
    pub failovers: usize,
    pub quarantines: usize,
    pub repairs: usize,
    /// Served batches whose scores differed from the clean reference
    /// while the router HAD detected the fault (must be 0: a detected
    /// corruption never reaches a response — the journal invariant).
    pub detected_mismatches: usize,
    /// Score mismatches on runs the serving path did not detect (low-bit
    /// escapes — the paper's detection-rate story, not a failover bug).
    pub undetected_mismatches: usize,
    /// Replicas still quarantined after the end-of-run repair drain.
    pub unrepaired: usize,
    /// Journaled events that misattribute the injected fault: wrong site
    /// (≠ the injected table), or a serving resolution outside the
    /// sharded-EB ladder, or a scrub resolution outside the scrub rung
    /// pair — `Recovered(CorrectInPlace)` (dual-checksum self-heal) or
    /// `Escalated(QuarantineAndRepair)` (the repair is queued, not yet
    /// proven, when the event is journaled). Must be 0 — the event is
    /// only useful if it names the fault correctly.
    pub bad_attribution: usize,
    /// Severity split of the journaled events (informational; the
    /// Table-III-style significance classification).
    pub significant_events: usize,
    pub near_bound_events: usize,
}

/// Run the shard-failover campaign. Each run starts from a fully healthy,
/// byte-identical store (the previous run's repair restored it). All
/// detection assertions are journal queries: the injected fault must
/// surface as a [`FaultEvent`] with the correct site, a ladder-legal
/// resolution, and — when it was detected while serving — scores
/// bit-identical to the clean reference ("detected corruption is never
/// served").
pub fn run_shard_campaign(cfg: &ShardCampaignConfig) -> ShardCampaignResult {
    let mut model = DlrmModel::random(DlrmConfig {
        num_dense: 4,
        embedding_dim: cfg.dim,
        bottom_mlp: vec![16, cfg.dim],
        top_mlp: vec![16],
        tables: vec![TableConfig { rows: cfg.rows, pooling: cfg.pooling }; cfg.num_tables],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: cfg.seed ^ 0xD0D0,
    });
    // Attach the fault-event pipeline BEFORE building the store, so the
    // router (via the model) and the store's scrubbers share one
    // journal.
    model.events = EventSink::with_capacity(4096);
    let sink = model.events.clone();
    let plan = ShardPlan::hash_placement(cfg.num_tables, cfg.num_shards, cfg.replicas);
    let store = Arc::new(ShardStore::from_model(&model, plan, cfg.rows.max(1)));
    let router = ShardRouter::new(Arc::clone(&store));
    let mut rng = Pcg32::new(cfg.seed);
    let mut result = ShardCampaignResult { runs: cfg.runs, ..Default::default() };
    let journal = sink.journal().expect("campaign sink is attached");

    for _ in 0..cfg.runs {
        let reqs = model.synth_requests(cfg.batch, &mut rng);
        let (clean, _) = model.forward(&reqs);

        // One flip in one replica's copy of one table.
        let t = rng.gen_range(0, cfg.num_tables);
        let replica = rng.gen_range(0, cfg.replicas);
        let byte = rng.gen_range(0, cfg.rows * cfg.dim);
        let bit = cfg.bit_range.pick_bit(&mut rng, 8);
        store.flip_table_byte(t, replica, byte, 1 << bit);

        let pre_fail = store.stats.failovers.load(Ordering::Relaxed);
        let pre_quar = store.stats.quarantines.load(Ordering::Relaxed);

        let mark = journal.total();
        let (scores, _report) = model.forward_with(&reqs, &router);
        let serve_events = journal.since(mark);
        // Injected-fault → matching event: every serve-time event must
        // name the injected table and carry a sharded-EB-ladder
        // resolution (transient retry, failover, or — only with R=1 —
        // degrade).
        let mut served = false;
        for ev in &serve_events {
            served |= ev.detector == Detector::EbBound;
            result.note_event(ev, t, cfg.replicas);
        }
        if scores != clean {
            if served {
                result.detected_mismatches += 1;
            } else {
                result.undetected_mismatches += 1;
            }
        }
        if served {
            result.served_detections += 1;
        }
        result.failovers += (store.stats.failovers.load(Ordering::Relaxed) - pre_fail) as usize;

        // Proactive sweep: whatever serving missed (untouched row or a
        // below-bound flip), the exact integer scrub catches — as
        // `ScrubExact` events that either self-heal in place (single
        // localizable slot) or escalate to quarantine + repair.
        let mark = journal.total();
        store.scrub_full();
        let scrub_events = journal.since(mark);
        for ev in &scrub_events {
            result.note_event(ev, t, cfg.replicas);
        }
        let scrub_found = scrub_events.iter().any(|e| e.detector == Detector::ScrubExact);
        if !served && scrub_found {
            result.scrub_detections += 1;
        } else if !served {
            result.undetected += 1;
        }
        result.quarantines += (store.stats.quarantines.load(Ordering::Relaxed) - pre_quar) as usize;

        // Repair everything before the next run; repaired replicas are
        // re-copied from a clean sibling, so no manual restore is needed.
        result.repairs += store.drain_repairs();
        result.unrepaired = store.quarantined_replicas();
    }
    result
}

impl ShardCampaignResult {
    /// Check one journaled event against the injected fault: correct
    /// site (the injected table), a ladder-legal resolution for its
    /// detector, and tally its severity split.
    fn note_event(&mut self, ev: &FaultEvent, injected_table: usize, replicas: usize) {
        let site_ok = ev.site == SiteId::Eb(injected_table as u32);
        let resolution_ok = match ev.detector {
            Detector::EbBound => matches!(
                ev.resolution,
                Resolution::Recovered(Recovery::RecomputeUnit)
                    | Resolution::Recovered(Recovery::FailoverReplica)
            ) || (replicas == 1 && ev.resolution == Resolution::Degraded),
            Detector::ScrubExact => {
                // Single-slot corruptions now self-heal in place (the
                // dual EB checksum names the slot — PR 6); anything the
                // localizer declines still hands off to the quarantine +
                // repair machinery (the repair itself has not run yet
                // when the event is journaled).
                matches!(
                    ev.resolution,
                    Resolution::Recovered(Recovery::CorrectInPlace)
                        | Resolution::Escalated(Recovery::QuarantineAndRepair)
                )
            }
            _ => false,
        };
        if !site_ok || !resolution_ok {
            self.bad_attribution += 1;
        }
        match ev.severity {
            Severity::Significant => self.significant_events += 1,
            Severity::NearBound => self.near_bound_events += 1,
        }
    }
}

/// Configuration for the adaptive-policy campaign: the control-plane
/// extension of the §VI-B methodology. One persistent replica fault is
/// injected while the victim table's site is in `Sampled` mode; the
/// drill asserts the full loop: sampled check catches the fault →
/// same-replica retry → quarantine + failover (the corrupted values are
/// re-served from a clean sibling) → the controller escalates the site
/// (and its co-sharded neighbors) to `Full` within one tick → repair →
/// quiet ticks decay the site back to the budget target.
#[derive(Clone, Debug)]
pub struct AdaptiveCampaignConfig {
    pub num_tables: usize,
    pub rows: usize,
    pub dim: usize,
    pub pooling: usize,
    /// Requests per batch; keep `>= ` the EB target sample rate so every
    /// batch checks at least one bag of the victim table.
    pub batch: usize,
    pub seed: u64,
    /// Controller configuration; `tick` is forced to manual — the
    /// campaign drives deterministic ticks itself.
    pub policy: PolicyConfig,
}

impl Default for AdaptiveCampaignConfig {
    fn default() -> Self {
        Self {
            num_tables: 2,
            rows: 300,
            dim: 16,
            pooling: 8,
            batch: 8,
            seed: 0xADA,
            policy: PolicyConfig {
                cooldown_ticks: 2,
                decay_patience: 1,
                ..PolicyConfig::default()
            },
        }
    }
}

/// Tallies and checkpoints from one adaptive campaign.
#[derive(Clone, Debug, Default)]
pub struct AdaptiveCampaignResult {
    /// Budget-target sample rate of the EB class (`ceil(overhead/budget)`).
    pub target_rate: u32,
    /// Ticks for the quiet initial decay from `Full` to the target.
    pub decay_ticks: usize,
    /// Site reached the target mode before injection.
    pub decayed: bool,
    /// Ticks from the detecting batch to the site reading `Full`.
    pub escalation_ticks: usize,
    pub escalated: bool,
    /// Co-sharded neighbor table also escalated to `Full`.
    pub neighbor_escalated: bool,
    /// Batches whose fault WAS detected but whose served scores differed
    /// from clean — must be 0 (detection ⇒ failover ⇒ clean values).
    pub detected_mismatches: usize,
    /// Corrupt batches served undetected while sampled (coverage gap).
    pub sampled_escapes: usize,
    /// Ticks for the post-repair decay back to the target.
    pub redecay_ticks: usize,
    pub redecayed: bool,
}

/// Run the adaptive-policy campaign. See [`AdaptiveCampaignConfig`].
pub fn run_adaptive_campaign(cfg: &AdaptiveCampaignConfig) -> AdaptiveCampaignResult {
    let model_cfg = DlrmConfig {
        num_dense: 4,
        embedding_dim: cfg.dim,
        bottom_mlp: vec![16, cfg.dim],
        top_mlp: vec![16],
        tables: vec![TableConfig { rows: cfg.rows, pooling: cfg.pooling }; cfg.num_tables],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: cfg.seed ^ 0xADA7,
    };
    // Clean twin (same seed ⇒ bit-identical weights/tables) for
    // reference scores.
    let reference = DlrmModel::random(model_cfg.clone());
    let engine = Engine::new(DlrmModel::random(model_cfg))
        .with_shards(
            ShardPlan::hash_placement(cfg.num_tables, 1, 2),
            cfg.rows.max(1),
        )
        .with_policy(PolicyConfig { tick: Duration::ZERO, ..cfg.policy.clone() });
    let sites = Arc::clone(engine.policy_sites().expect("policy attached"));
    let store = Arc::clone(engine.shard_store().expect("sharded"));
    // Detection is observed through the engine's event journal: "the
    // sampled check caught the fault" ⇔ "an EbBound event for the
    // victim site was journaled during the batch".
    let journal = engine.journal();
    let eb_detected = |events: &[FaultEvent]| {
        events
            .iter()
            .any(|e| e.detector == Detector::EbBound && e.site == SiteId::Eb(0))
    };

    let mut rng = Pcg32::new(cfg.seed);
    let reqs = reference.synth_requests(cfg.batch, &mut rng);
    let (clean, _) = reference.forward(&reqs);
    let mut scores = vec![0f32; cfg.batch];
    let mut result = AdaptiveCampaignResult::default();

    // Budget math (mirrors the controller): target EB rate.
    let target_n = ((cfg.policy.unit_costs.eb_full_overhead / cfg.policy.overhead_budget).ceil()
        as u32)
        .clamp(1, cfg.policy.max_sample);
    result.target_rate = target_n;
    let target = DetectionMode::Sampled(target_n);

    // Phase 1: quiet traffic decays the victim site to the target.
    while sites.eb[0].cell.load() != target && result.decay_ticks < 64 {
        engine.score(&reqs, &mut scores);
        engine.policy_tick();
        result.decay_ticks += 1;
    }
    result.decayed = sites.eb[0].cell.load() == target;
    if !result.decayed {
        return result;
    }

    // Phase 2: persistent corruption of replica 0's copy of table 0 —
    // the high bit of every row's first code, so any checked bag flags.
    for row in 0..cfg.rows {
        store.flip_table_byte(0, 0, row * cfg.dim, 0x80);
    }

    // Phase 3: serve under Sampled until the sampled check catches the
    // fault, then verify the escalation lands within one tick.
    for _ in 0..8 {
        let mark = journal.total();
        engine.score(&reqs, &mut scores);
        let detected = eb_detected(&journal.since(mark));
        let mismatch = scores != clean;
        if detected {
            if mismatch {
                // Detection must imply failover to clean values.
                result.detected_mismatches += 1;
            }
            while sites.eb[0].cell.load() != DetectionMode::Full && result.escalation_ticks < 3 {
                engine.policy_tick();
                result.escalation_ticks += 1;
            }
            result.escalated = sites.eb[0].cell.load() == DetectionMode::Full;
            result.neighbor_escalated = cfg.num_tables < 2
                || sites.eb[1].cell.load() == DetectionMode::Full;
            break;
        }
        if mismatch {
            result.sampled_escapes += 1;
        }
        engine.policy_tick();
    }
    if !result.escalated {
        return result;
    }

    // Phase 4: repair the quarantined replica, then quiet ticks decay
    // the site back inside the budget.
    store.drain_repairs();
    while sites.eb[0].cell.load() != target && result.redecay_ticks < 64 {
        let mark = journal.total();
        engine.score(&reqs, &mut scores);
        if scores != clean && eb_detected(&journal.since(mark)) {
            result.detected_mismatches += 1;
        }
        engine.policy_tick();
        result.redecay_ticks += 1;
    }
    result.redecayed = sites.eb[0].cell.load() == target;
    result
}

/// Configuration for the flight-recorder campaign: the black-box drill.
/// Persistent replica corruption drives Severe (`Significant`) fault
/// events through a serving engine with the recorder armed; every
/// resident capture must be a self-contained post-mortem — the
/// triggering event, the causally-correlated span timeline of the
/// faulting batch's flow, and the policy/shard control-plane snapshots.
#[derive(Clone, Debug)]
pub struct FlightRecCampaignConfig {
    pub num_tables: usize,
    pub rows: usize,
    pub dim: usize,
    pub pooling: usize,
    /// Requests per batch.
    pub batch: usize,
    /// Max batches to serve while collecting Severe events.
    pub batches: usize,
    /// Recorder pool size (capture slots).
    pub captures: usize,
    pub seed: u64,
    /// When set, dump the resident black boxes here as
    /// `blackbox_<id>.json` (the `--flightrec-dump-dir` artifact shape).
    pub dump_dir: Option<String>,
}

impl Default for FlightRecCampaignConfig {
    fn default() -> Self {
        Self {
            num_tables: 2,
            rows: 300,
            dim: 16,
            pooling: 8,
            batch: 8,
            batches: 32,
            captures: 8,
            seed: 0xB1AC2,
            dump_dir: None,
        }
    }
}

/// Tallies from one flight-recorder campaign.
#[derive(Clone, Debug, Default)]
pub struct FlightRecCampaignResult {
    /// Severe (`Significant`) events journaled while armed.
    pub severe_events: usize,
    /// Freeze attempts the recorder made (captures taken, incl. those
    /// since evicted) — one per Severe event by construction.
    pub captures_taken: u64,
    /// Freezes skipped because the slot was busy under a reader (must
    /// stay 0 here — nothing reads captures mid-campaign).
    pub captures_missed: u64,
    /// Resident captures inspected post-campaign.
    pub resident: usize,
    /// ...containing the triggering event at/above the severity floor.
    pub with_trigger: usize,
    /// ...whose causal flow timeline is non-empty (spans recorded by
    /// the faulting batch under the same flow tag).
    pub with_flow_timeline: usize,
    /// ...carrying a policy-plane snapshot.
    pub with_policy: usize,
    /// ...carrying a shard-health snapshot.
    pub with_shards: usize,
    /// Black boxes written to `dump_dir`.
    pub dumped: usize,
}

impl FlightRecCampaignResult {
    /// Every resident capture is a complete post-mortem.
    pub fn all_complete(&self) -> bool {
        self.resident > 0
            && self.with_trigger == self.resident
            && self.with_flow_timeline == self.resident
            && self.with_policy == self.resident
            && self.with_shards == self.resident
    }
}

/// Run the flight-recorder campaign. See [`FlightRecCampaignConfig`].
pub fn run_flightrec_campaign(cfg: &FlightRecCampaignConfig) -> FlightRecCampaignResult {
    let model_cfg = DlrmConfig {
        num_dense: 4,
        embedding_dim: cfg.dim,
        bottom_mlp: vec![16, cfg.dim],
        top_mlp: vec![16],
        tables: vec![TableConfig { rows: cfg.rows, pooling: cfg.pooling }; cfg.num_tables],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: cfg.seed ^ 0xB0B,
    };
    let reference = DlrmModel::random(model_cfg.clone());
    let engine = Engine::new(DlrmModel::random(model_cfg))
        .with_shards(
            ShardPlan::hash_placement(cfg.num_tables, 1, 2),
            cfg.rows.max(1),
        )
        .with_policy(PolicyConfig { tick: Duration::ZERO, ..PolicyConfig::default() });
    // Always-on spans so the faulting batch's flow timeline is populated
    // (recovery-rung spans record before the staged events emit).
    engine.obs().set_sampling(1);
    let rec = engine.arm_flightrec(cfg.captures, Severity::Significant);
    let store = Arc::clone(engine.shard_store().expect("sharded"));
    let journal = engine.journal();

    // Persistent corruption of replica 0's copy of table 0: the high bit
    // of every row's first code, so any checked bag flags hard.
    for row in 0..cfg.rows {
        store.flip_table_byte(0, 0, row * cfg.dim, 0x80);
    }

    let mut rng = Pcg32::new(cfg.seed);
    let mut scores = vec![0f32; cfg.batch];
    let mut result = FlightRecCampaignResult::default();
    for _ in 0..cfg.batches {
        let mark = journal.total();
        let reqs = reference.synth_requests(cfg.batch, &mut rng);
        engine.score(&reqs, &mut scores);
        result.severe_events += journal
            .since(mark)
            .iter()
            .filter(|e| e.severity >= Severity::Significant)
            .count();
        engine.policy_tick();
        if result.severe_events >= cfg.captures {
            break;
        }
    }
    result.captures_taken = rec.captures_taken();
    result.captures_missed = rec
        .status_json()
        .get("missed")
        .and_then(Json::as_usize)
        .unwrap_or(0) as u64;

    // Post-mortem audit: every resident black box must carry the
    // triggering event, a non-empty causal flow timeline, and the
    // control-plane snapshots.
    if let Some(rows) = rec.list_json().get("captures").and_then(Json::as_arr) {
        for row in rows {
            let id = row.get("id").and_then(Json::as_usize).unwrap_or(0) as u64;
            let Some(cap) = rec.capture_json(id) else { continue };
            result.resident += 1;
            if cap.path(&["event", "severity"]).and_then(Json::as_str) == Some("significant") {
                result.with_trigger += 1;
            }
            if matches!(cap.get("flow_timeline"), Some(Json::Arr(a)) if !a.is_empty()) {
                result.with_flow_timeline += 1;
            }
            if cap.get("policy").is_some_and(|p| *p != Json::Null) {
                result.with_policy += 1;
            }
            if cap.get("shards").is_some_and(|s| *s != Json::Null) {
                result.with_shards += 1;
            }
        }
    }
    if let Some(dir) = &cfg.dump_dir {
        let _ = std::fs::create_dir_all(dir);
        result.dumped = rec.dump_new(std::path::Path::new(dir)).unwrap_or(0);
    }
    result
}

/// Configuration for the correction campaign: the §VI-B methodology
/// aimed at the PR-6 `CorrectInPlace` rung. Single-fault runs must be
/// *localized and algebraically fixed in place* on both correction
/// surfaces — the GEMM accumulator (group partial checksum columns) and
/// the R=1 shard store (dual EB checksum self-heal) — with outputs
/// bit-identical to a clean recompute. Multi-fault runs must be
/// *declined* and fall through to the pre-existing ladder rungs, and no
/// corrected-but-unverified value may ever reach the served bytes.
#[derive(Clone, Debug)]
pub struct CorrectionCampaignConfig {
    /// (m, n, k) GEMM shapes; defaults cover the boundaries that matter
    /// for the group layout: `n == GROUP_WIDTH` exactly (one group),
    /// multi-group, ragged last group, odd (pair-tail) k, and m = 1.
    pub shapes: Vec<(usize, usize, usize)>,
    /// Single-fault + multi-fault runs per shape.
    pub runs_per_shape: usize,
    /// R=1 store arm: table rows, embedding dim, single-slot scrub runs.
    pub rows: usize,
    pub dim: usize,
    pub scrub_runs: usize,
    pub seed: u64,
}

impl Default for CorrectionCampaignConfig {
    fn default() -> Self {
        Self {
            shapes: vec![(8, 64, 48), (3, 33, 17), (1, 128, 64), (5, 32, 31)],
            runs_per_shape: 25,
            rows: 400,
            dim: 32,
            scrub_runs: 20,
            seed: 0xC0FE,
        }
    }
}

/// Tallies from one correction campaign. Every event-side field is a
/// journal query (PR 5 discipline): "the fix was attributed correctly"
/// means "a `GemmChecksum` event with the injected row's unit and the
/// `CorrectInPlace` resolution was journaled during the walk".
#[derive(Clone, Debug, Default)]
pub struct CorrectionCampaignResult {
    /// Single-fault GEMM runs (one i32 bit flip in the accumulator).
    pub gemm_runs: usize,
    /// Runs fixed at the `CorrectInPlace` rung with the injected
    /// (row, col, delta) named exactly.
    pub corrected: usize,
    /// Corrected runs whose accumulator AND served bytes ended
    /// bit-identical to the clean references and re-verified clean.
    pub corrected_exact: usize,
    /// Single-fault runs the localizer declined (fell down the ladder).
    pub single_declined: usize,
    /// Multi-fault GEMM runs (two corrupt entries in one row).
    pub multi_runs: usize,
    /// Multi-fault runs correctly declined by the localizer.
    pub multi_declined: usize,
    /// Multi-fault runs the localizer wrongly "corrected" — must be 0
    /// (a wrong fix that survives re-verify would serve silent garbage).
    pub multi_wrongly_accepted: usize,
    /// Multi-fault runs recovered bit-exactly at the `RecomputeUnit`
    /// rung after the decline.
    pub multi_recovered: usize,
    /// Runs whose final served bytes differed from the clean forward —
    /// must be 0 (no corrected-but-unverified value is ever served).
    pub served_mismatches: usize,
    /// Journaled `Recovered(CorrectInPlace)` GEMM events.
    pub correct_events: usize,
    /// Journaled `Recovered(RecomputeUnit)` GEMM events.
    pub recompute_events: usize,
    /// Events with wrong site/unit/severity or a ladder-illegal
    /// resolution. Must be 0.
    pub bad_attribution: usize,
    /// R=1 store arm: single-slot scrub runs.
    pub scrub_runs: usize,
    /// Runs healed in place (journal: `ScrubExact` +
    /// `Recovered(CorrectInPlace)` naming the victim slot, replica still
    /// Healthy).
    pub self_heals: usize,
    /// Healed runs whose replica bytes ended bit-identical to the
    /// pre-injection reference.
    pub heal_exact: usize,
    /// Single-slot runs that failed to self-heal — must be 0.
    pub heal_failures: usize,
    /// The §IV-C sum-preserving pair fell through to quarantine (the
    /// plain checksum is blind, the weighted one flags, the localizer
    /// refuses to name a slot).
    pub cancellation_quarantined: bool,
}

/// One walk of the flagged rows through the GEMM recovery ladder —
/// exactly the `AbftLinear::forward_policied` walk, driven externally so
/// the campaign can inject into the accumulator between the kernel and
/// the verify (the layer's own scratch is not reachable from outside).
struct LadderWalk {
    /// (row, col, delta) of each `CorrectInPlace` fix.
    corrected: Vec<(usize, usize, i64)>,
    recomputed: usize,
    escalated: usize,
}

fn gemm_ladder_walk(
    layer: &AbftLinear,
    x: &[u8],
    m: usize,
    epi: &RequantEpilogue<'_>,
    site: &SiteCtx<'_>,
    c_temp: &mut [i32],
    out: &mut [u8],
) -> LadderWalk {
    let abft = layer.abft();
    let mut walk = LadderWalk { corrected: Vec::new(), recomputed: 0, escalated: 0 };
    let verdict = abft.verify(c_temp, m);
    for &row in &verdict.corrupted_rows {
        let (severity, resolution) = if let RowCorrection::Corrected { col, delta } =
            recovery::correct_gemm_row(abft, x, row, m, epi, c_temp, out)
        {
            walk.corrected.push((row, col, delta));
            (
                Severity::from_gemm_delta(delta),
                Resolution::Recovered(Recovery::CorrectInPlace),
            )
        } else {
            let before = abft.row_residual(c_temp, m, row);
            let ok = recovery::recompute_gemm_row(abft, x, row, m, epi, c_temp, out);
            let after = abft.row_residual(c_temp, m, row);
            if ok && after != before {
                walk.recomputed += 1;
                (
                    Severity::from_gemm_delta(before - after),
                    Resolution::Recovered(Recovery::RecomputeUnit),
                )
            } else {
                walk.escalated += 1;
                (
                    Severity::Significant,
                    Resolution::escalated_or_degraded(recovery::next_step(
                        SiteClass::GemmRow,
                        Recovery::RecomputeUnit,
                    )),
                )
            }
        };
        site.emit(
            UnitRef::GemmRow { row: row as u32 },
            Detector::GemmChecksum,
            severity,
            resolution,
        );
    }
    walk
}

/// Run the correction campaign. See [`CorrectionCampaignConfig`].
pub fn run_correction_campaign(cfg: &CorrectionCampaignConfig) -> CorrectionCampaignResult {
    let mut result = CorrectionCampaignResult::default();
    let sink = EventSink::with_capacity(2048);
    let journal = sink.journal().expect("campaign sink is attached");
    let mut rng = Pcg32::new(cfg.seed);

    for &(m, n, k) in &cfg.shapes {
        let layer = AbftLinear::random(k, n, false, Protection::DetectRecompute, &mut rng);
        let abft = layer.abft();
        let nt = abft.n_total();
        let site = SiteCtx::new(&sink, SiteId::Gemm(0), None);
        for _ in 0..cfg.runs_per_shape {
            // Fresh input + clean references for bit-exactness.
            let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 3.0).collect();
            let (x, xp) = quantize_slice_u8(&xf);
            let (clean_out, _) = layer.forward(&x, m, xp);
            let (clean_c, clean_verdict) = layer.forward_raw(&x, m);
            debug_assert!(clean_verdict.clean());
            let params = layer.requant_params(&x, m, xp);
            let epi = RequantEpilogue {
                spec: RequantSpec::new(xp, layer.w_qparams, layer.out_qparams, k),
                a_row_sums: &params.a_row_sums,
                b_col_sums: &params.b_col_sums,
                n_out: n,
                relu_floor: 0,
            };
            // The fused kernel would have requantized the corrupt
            // accumulator, so after each injection the victim row's
            // served bytes are rebuilt from the corrupt state — "the
            // corruption would have been served" is literal.
            let serve_row = |c_temp: &[i32], out: &mut [u8], row: usize| {
                requantize_cols_into(
                    &c_temp[row * nt..(row + 1) * nt],
                    1,
                    nt,
                    0..n,
                    &epi.a_row_sums[row..row + 1],
                    epi.b_col_sums,
                    &epi.spec,
                    epi.relu_floor,
                    &mut out[row * n..(row + 1) * n],
                );
            };

            // --- Single-fault arm: one bit flip in one accumulator
            // entry (payload or the Eq-3b checksum column itself). ---
            result.gemm_runs += 1;
            let row = rng.gen_range(0, m);
            let col = rng.gen_range(0, n + 1);
            let mut c_temp = clean_c.clone();
            let mut out = clean_out.clone();
            c_temp[row * nt + col] ^= 1 << rng.gen_range_u32(32);
            let inj_delta = c_temp[row * nt + col] as i64 - clean_c[row * nt + col] as i64;
            serve_row(&c_temp, &mut out, row);
            let mark = journal.total();
            let walk = gemm_ladder_walk(&layer, &x, m, &epi, &site, &mut c_temp, &mut out);
            match walk.corrected.as_slice() {
                [(r, c, d)] if *r == row && *c == col && *d == inj_delta => {
                    result.corrected += 1;
                    if c_temp == clean_c && out == clean_out && abft.verify(&c_temp, m).clean() {
                        result.corrected_exact += 1;
                    }
                }
                _ => result.single_declined += 1,
            }
            if out != clean_out {
                result.served_mismatches += 1;
            }
            for ev in &journal.since(mark) {
                result.note_gemm_event(ev, row, Some(inj_delta));
            }

            // --- Multi-fault arm: two corrupt entries in one row —
            // different panels when the shape has ≥ 2 groups (the
            // `MultiGroup` decline), else two slots of the single group
            // (the `MultiMismatch` decline). Either way the fix must be
            // refused and the recompute rung must finish the job. ---
            result.multi_runs += 1;
            let row = rng.gen_range(0, m);
            let (ca, cb) = if n > GROUP_WIDTH { (0, GROUP_WIDTH) } else { (0, 1) };
            let mut c_temp = clean_c.clone();
            let mut out = clean_out.clone();
            // ±2^20 ± 2^10 ≡ ±64 ± 8 (mod 127): the pair can never
            // cancel in the Eq-3b residual, so the row always flags.
            c_temp[row * nt + ca] ^= 1 << 20;
            c_temp[row * nt + cb] ^= 1 << 10;
            serve_row(&c_temp, &mut out, row);
            let mark = journal.total();
            let walk = gemm_ladder_walk(&layer, &x, m, &epi, &site, &mut c_temp, &mut out);
            if walk.corrected.is_empty() {
                result.multi_declined += 1;
            } else {
                result.multi_wrongly_accepted += 1;
            }
            if walk.recomputed >= 1 && c_temp == clean_c && out == clean_out {
                result.multi_recovered += 1;
            }
            if out != clean_out {
                result.served_mismatches += 1;
            }
            for ev in &journal.since(mark) {
                result.note_gemm_event(ev, row, None);
            }
        }
    }

    // --- R=1 store arm: single-slot flips self-heal under the dual EB
    // checksum; a §IV-C sum-preserving pair falls through to quarantine.
    let mut model = DlrmModel::random(DlrmConfig {
        num_dense: 4,
        embedding_dim: cfg.dim,
        bottom_mlp: vec![16, cfg.dim],
        top_mlp: vec![16],
        tables: vec![TableConfig { rows: cfg.rows, pooling: 8 }],
        protection: Protection::DetectRecompute,
        dense_range: (0.0, 1.0),
        seed: cfg.seed ^ 0x5E1F,
    });
    model.events = sink.clone();
    let store = ShardStore::from_model(&model, ShardPlan::hash_placement(1, 1, 1), cfg.rows.max(1));
    let reference = store.table_bytes(0, 0);
    for _ in 0..cfg.scrub_runs {
        result.scrub_runs += 1;
        let byte = rng.gen_range(0, cfg.rows * cfg.dim);
        store.flip_table_byte(0, 0, byte, 1 << rng.gen_range_u32(8));
        let mark = journal.total();
        store.scrub_full();
        let healed = journal.since(mark).iter().any(|e| {
            e.detector == Detector::ScrubExact
                && e.site == SiteId::Eb(0)
                && e.resolution == Resolution::Recovered(Recovery::CorrectInPlace)
                && matches!(e.unit,
                    UnitRef::ScrubSlot { replica: 0, row } if row as usize == byte / cfg.dim)
        });
        if healed && store.quarantined_replicas() == 0 {
            result.self_heals += 1;
            if store.table_bytes(0, 0) == reference {
                result.heal_exact += 1;
            }
        } else {
            result.heal_failures += 1;
        }
    }
    // Sum-preserving pair in one row (+5 at slot j, −5 at slot j+1): the
    // plain checksum is blind, the index-weighted one flags, and with
    // S = 0 the localizer cannot name a slot — the only sound move for
    // an R=1 store is the quarantine rung, never a guessed rewrite.
    let bytes = store.table_bytes(0, 0);
    if let Some(idx) = (0..cfg.rows * cfg.dim)
        .step_by(cfg.dim)
        .find(|&i| bytes[i] <= 250 && bytes[i + 1] >= 5)
    {
        store.flip_table_byte(0, 0, idx, bytes[idx] ^ (bytes[idx] + 5));
        store.flip_table_byte(0, 0, idx + 1, bytes[idx + 1] ^ (bytes[idx + 1] - 5));
        let mark = journal.total();
        store.scrub_full();
        result.cancellation_quarantined = store.quarantined_replicas() == 1
            && journal.since(mark).iter().any(|e| {
                e.detector == Detector::ScrubExact
                    && e.site == SiteId::Eb(0)
                    && e.resolution == Resolution::Escalated(Recovery::QuarantineAndRepair)
            });
    }
    result
}

impl CorrectionCampaignResult {
    /// Check one journaled GEMM event against the injected fault: the
    /// `gemm/0` site, the injected row's unit, and a ladder-legal
    /// resolution — `CorrectInPlace` (whose severity must classify the
    /// exact algebraic delta, when the arm knows it) or `RecomputeUnit`.
    fn note_gemm_event(&mut self, ev: &FaultEvent, injected_row: usize, correct_delta: Option<i64>) {
        let unit_ok =
            matches!(ev.unit, UnitRef::GemmRow { row } if row as usize == injected_row);
        let resolution_ok = match ev.resolution {
            Resolution::Recovered(Recovery::CorrectInPlace) => {
                self.correct_events += 1;
                correct_delta.is_none_or(|d| ev.severity == Severity::from_gemm_delta(d))
            }
            Resolution::Recovered(Recovery::RecomputeUnit) => {
                self.recompute_events += 1;
                true
            }
            // The campaign only injects transient C faults; anything
            // escalating past the recompute rung is a misattribution.
            _ => false,
        };
        if ev.site != SiteId::Gemm(0)
            || ev.detector != Detector::GemmChecksum
            || !unit_ok
            || !resolution_ok
        {
            self.bad_attribution += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> GemmCampaignConfig {
        GemmCampaignConfig {
            shapes: vec![(4, 64, 32), (1, 128, 64)],
            runs_per_shape: 25,
            ..Default::default()
        }
    }

    #[test]
    fn gemm_campaign_c_errors_always_detected() {
        let r = run_gemm_campaign(&small_cfg());
        // §IV-C2 model 1: bit flips in C are detected with probability 1.
        assert_eq!(r.error_in_c.not_detected, 0, "{r:?}");
        assert_eq!(r.error_in_c.total(), 50);
    }

    #[test]
    fn gemm_campaign_no_false_positives() {
        let r = run_gemm_campaign(&small_cfg());
        // Integer arithmetic: zero round-off → zero false positives (§VI-B1).
        assert_eq!(r.no_error.detected, 0);
    }

    #[test]
    fn gemm_campaign_b_errors_mostly_detected() {
        let r = run_gemm_campaign(&small_cfg());
        assert!(r.error_in_b.rate() > 0.85, "rate={}", r.error_in_b.rate());
    }

    #[test]
    fn eb_campaign_high_bits_nearly_all_detected() {
        let cfg = EbCampaignConfig {
            table_rows: 20_000,
            dim: 64,
            ..Default::default()
        };
        let t = run_eb_campaign(&cfg, EbTarget::TableHigh4, 50);
        assert!(t.rate() > 0.9, "rate={}", t.rate());
    }

    #[test]
    fn eb_campaign_low_bits_partial() {
        let cfg = EbCampaignConfig {
            table_rows: 20_000,
            dim: 64,
            ..Default::default()
        };
        let t = run_eb_campaign(&cfg, EbTarget::TableLow4, 60);
        // Low-significance flips sit near the bound: some escape (§VI-B2).
        assert!(t.rate() < 1.0);
        assert!(t.rate() > 0.1, "rate={}", t.rate());
    }

    #[test]
    fn shard_campaign_every_fault_caught_and_recovered() {
        let cfg = ShardCampaignConfig {
            rows: 400,
            runs: 25,
            ..Default::default()
        };
        let r = run_shard_campaign(&cfg);
        // The serving check can miss (low bits, cold rows) but the exact
        // integer scrub cannot: every injected fault is detected by one
        // of the two arms.
        assert_eq!(r.undetected, 0, "{r:?}");
        assert_eq!(r.served_detections + r.scrub_detections, r.runs, "{r:?}");
        // A detected corruption never reached a served response (journal
        // invariant: detection events ⇒ bit-identical scores).
        assert_eq!(r.detected_mismatches, 0, "{r:?}");
        // Every journaled event named the injected table and carried a
        // ladder-legal resolution.
        assert_eq!(r.bad_attribution, 0, "{r:?}");
        assert!(r.significant_events + r.near_bound_events > 0, "{r:?}");
        // Every quarantined replica was repaired from its clean sibling.
        assert_eq!(r.unrepaired, 0, "{r:?}");
        assert_eq!(r.quarantines as u64, r.repairs as u64, "{r:?}");
    }

    #[test]
    fn shard_campaign_high_bits_detected_in_serving_when_touched() {
        // High bits clear the Eq-5 bound whenever the row is read; with
        // batch×pooling lookups over few rows most runs detect in serving
        // and every served detection fails over cleanly.
        let cfg = ShardCampaignConfig {
            rows: 200,
            pooling: 40,
            runs: 20,
            bit_range: BitRange::High4,
            ..Default::default()
        };
        let r = run_shard_campaign(&cfg);
        assert!(r.served_detections > 0, "{r:?}");
        assert_eq!(r.detected_mismatches, 0, "{r:?}");
        assert_eq!(r.bad_attribution, 0, "{r:?}");
        assert!(r.failovers >= r.served_detections, "{r:?}");
    }

    #[test]
    fn adaptive_campaign_escalates_within_one_tick_and_redecays() {
        let cfg = AdaptiveCampaignConfig::default();
        let r = run_adaptive_campaign(&cfg);
        // ceil(0.20 / 0.05) — the default EB budget math.
        assert_eq!(r.target_rate, 4, "{r:?}");
        assert!(r.decayed, "site never reached the budget target: {r:?}");
        assert!(r.escalated, "injected fault never escalated the site: {r:?}");
        assert!(r.escalation_ticks <= 1, "escalation must land within one tick: {r:?}");
        assert!(r.neighbor_escalated, "co-sharded table must escalate too: {r:?}");
        // A detected corruption is never served: every detected batch
        // failed over to the clean replica before responding.
        assert_eq!(r.detected_mismatches, 0, "{r:?}");
        assert!(r.redecayed, "site must decay back after repair + quiet: {r:?}");
        assert!(r.redecay_ticks <= 16, "{r:?}");
    }

    #[test]
    fn flightrec_campaign_black_boxes_are_complete_post_mortems() {
        let dir = std::env::temp_dir().join("dlrm_abft_flightrec_campaign_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = FlightRecCampaignConfig {
            batches: 16,
            dump_dir: Some(dir.to_string_lossy().into_owned()),
            ..Default::default()
        };
        let r = run_flightrec_campaign(&cfg);
        assert!(r.severe_events > 0, "persistent corruption must journal Severe events: {r:?}");
        // Every Severe event froze a capture; none were dropped on a
        // busy slot (nothing reads captures mid-campaign).
        assert_eq!(r.captures_taken, r.severe_events as u64, "{r:?}");
        assert_eq!(r.captures_missed, 0, "{r:?}");
        // Each resident black box is a complete post-mortem: trigger,
        // causal flow timeline, policy plane, shard health.
        assert!(r.all_complete(), "incomplete black box: {r:?}");
        // Dump wrote one self-contained artifact per resident capture.
        assert_eq!(r.dumped, r.resident, "{r:?}");
        assert_eq!(std::fs::read_dir(&dir).unwrap().count(), r.dumped, "{r:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn correction_campaign_single_faults_all_corrected_in_place() {
        let r = run_correction_campaign(&CorrectionCampaignConfig::default());
        // One corrupt i32 entry (payload or checksum column) is always
        // named and fixed algebraically — never recomputed, never served.
        assert_eq!(r.gemm_runs, 100, "{r:?}");
        assert_eq!(r.corrected, r.gemm_runs, "{r:?}");
        assert_eq!(r.corrected_exact, r.corrected, "{r:?}");
        assert_eq!(r.single_declined, 0, "{r:?}");
        assert_eq!(r.served_mismatches, 0, "{r:?}");
    }

    #[test]
    fn correction_campaign_multi_faults_fall_through_and_recover() {
        let r = run_correction_campaign(&CorrectionCampaignConfig::default());
        // Two corrupt entries in one row: the localizer must refuse the
        // fix (a wrong single-entry rewrite could survive re-verify only
        // by luck) and the recompute rung must restore bit-exactness.
        assert_eq!(r.multi_wrongly_accepted, 0, "{r:?}");
        assert_eq!(r.multi_declined, r.multi_runs, "{r:?}");
        assert_eq!(r.multi_recovered, r.multi_runs, "{r:?}");
        // Journal discipline: every event carried the right site, unit,
        // severity, and a ladder-legal resolution.
        assert_eq!(r.bad_attribution, 0, "{r:?}");
        assert_eq!(r.correct_events, r.corrected, "{r:?}");
        assert_eq!(r.recompute_events, r.multi_runs, "{r:?}");
    }

    #[test]
    fn correction_campaign_r1_scrub_self_heals_and_cancellation_quarantines() {
        let r = run_correction_campaign(&CorrectionCampaignConfig::default());
        // R = 1: no sibling to fail over to — the dual-checksum localizer
        // is the only path back to Healthy, and it must take it for every
        // single-slot flip (verified byte-exact against pre-injection).
        assert_eq!(r.self_heals, r.scrub_runs, "{r:?}");
        assert_eq!(r.heal_exact, r.self_heals, "{r:?}");
        assert_eq!(r.heal_failures, 0, "{r:?}");
        // The §IV-C cancellation class: S = 0 defeats localization, so
        // the scrubber must refuse to guess and quarantine instead.
        assert!(r.cancellation_quarantined, "{r:?}");
    }

    #[test]
    fn eb_trial_restores_table() {
        let cfg = EbCampaignConfig {
            table_rows: 1000,
            dim: 32,
            pooling: 20,
            batch: 2,
            ..Default::default()
        };
        let mut rng = Pcg32::new(1);
        let mut table = QuantTable8::random(cfg.table_rows, cfg.dim, &mut rng);
        let orig = table.data.clone();
        let checksum = EbChecksum::build_8(&table);
        for _ in 0..20 {
            run_eb_trial(&mut table, &checksum, &cfg, EbTarget::TableAny, &mut rng);
            assert_eq!(table.data, orig, "injection must be restored");
        }
    }
}
