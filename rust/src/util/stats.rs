//! Small statistics toolkit for the bench harness (no external crates).

/// Summary statistics over a sample of measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p25: f64,
    pub median: f64,
    pub p75: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Self {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            p25: percentile(&sorted, 0.25),
            median: percentile(&sorted, 0.50),
            p75: percentile(&sorted, 0.75),
            p95: percentile(&sorted, 0.95),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Median absolute deviation — robust spread estimate used to decide when a
/// measurement has stabilized.
pub fn mad(samples: &[f64]) -> f64 {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let med = percentile(&sorted, 0.5);
    let mut dev: Vec<f64> = sorted.iter().map(|x| (x - med).abs()).collect();
    dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile(&dev, 0.5)
}

/// Wilson score interval for a binomial proportion — used when reporting
/// detection rates from fault campaigns.
pub fn wilson_interval(successes: usize, trials: usize, z: f64) -> (f64, f64) {
    if trials == 0 {
        return (0.0, 1.0);
    }
    let n = trials as f64;
    let p = successes as f64 / n;
    let z2 = z * z;
    let denom = 1.0 + z2 / n;
    let centre = p + z2 / (2.0 * n);
    let spread = z * ((p * (1.0 - p) + z2 / (4.0 * n)) / n).sqrt();
    (
        ((centre - spread) / denom).max(0.0),
        ((centre + spread) / denom).min(1.0),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::from(&[5.0; 10]);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.median, 5.0);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile(&sorted, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile(&sorted, 0.0), 0.0);
        assert_eq!(percentile(&sorted, 1.0), 10.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let clean = [1.0, 1.1, 0.9, 1.0, 1.05];
        let dirty = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&dirty) < 0.2, "mad={}", mad(&dirty));
        assert!(mad(&clean) < 0.2);
    }

    #[test]
    fn wilson_sane() {
        let (lo, hi) = wilson_interval(95, 100, 1.96);
        assert!(lo > 0.88 && lo < 0.95);
        assert!(hi > 0.95 && hi < 1.0);
        let (lo0, hi0) = wilson_interval(0, 100, 1.96);
        assert_eq!(lo0, 0.0);
        assert!(hi0 < 0.05);
    }
}
