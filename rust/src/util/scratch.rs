//! Reusable kernel-level scratch buffers (the bottom layer of the
//! zero-allocation inference arena — see `dlrm::scratch` for the
//! pipeline-level [`InferenceScratch`] that embeds this).
//!
//! # Aliasing / reuse invariants
//!
//! * A scratch buffer is **owned by exactly one in-flight forward pass**
//!   at a time. Nothing here is synchronized: callers that serve
//!   concurrently keep one scratch per worker (see `Engine`'s pool) and
//!   never share one across threads mid-pass.
//! * Buffers only **grow** ([`grow`] never shrinks), so after a warmup
//!   pass at the largest shapes every later pass is allocation-free.
//! * Contents are garbage between uses. Every consumer fully overwrites
//!   the prefix it asks for (`gemm_requant_exec_into` zero-fills
//!   `c_temp`; requantization writes every output byte) — callers must
//!   never read a scratch slice they did not just write.
//!
//! [`InferenceScratch`]: crate::dlrm::InferenceScratch

/// Grow-only sizing: returns `&mut buf[..len]`, resizing (with `T::default()`)
/// only when the buffer is too small. The capacity high-water mark is the
/// warmup allocation; steady state never reallocates.
#[inline]
pub fn grow<T: Default + Clone>(buf: &mut Vec<T>, len: usize) -> &mut [T] {
    if buf.len() < len {
        buf.resize(len, T::default());
    }
    &mut buf[..len]
}

/// Per-layer GEMM scratch: the 32-bit accumulator tile and the A-row sums
/// the requantization epilogue needs. One instance serves a whole MLP
/// chain — each layer regrows/overwrites the prefix it uses.
#[derive(Clone, Debug, Default)]
pub struct GemmScratch {
    /// `m × n_total` i32 accumulator (`C_temp`, checksum column included
    /// on protected layers). Valid only between a layer's GEMM and its
    /// verification/recompute — the next layer overwrites it.
    pub c_temp: Vec<i32>,
    /// Row sums of the current layer's quantized input (length m).
    pub a_row_sums: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_never_shrinks_and_reuses_capacity() {
        let mut buf: Vec<i32> = Vec::new();
        assert_eq!(grow(&mut buf, 8).len(), 8);
        let cap = buf.capacity();
        assert_eq!(grow(&mut buf, 4).len(), 4);
        assert_eq!(buf.len(), 8, "grow must not shrink the backing buffer");
        assert_eq!(grow(&mut buf, 8).len(), 8);
        assert_eq!(buf.capacity(), cap, "steady-state regrow must not realloc");
    }

    #[test]
    fn gemm_scratch_grows_independently() {
        let mut s = GemmScratch::default();
        grow(&mut s.c_temp, 64).fill(7);
        grow(&mut s.a_row_sums, 4).fill(1);
        assert_eq!(s.c_temp.len(), 64);
        assert_eq!(s.a_row_sums.len(), 4);
    }
}
