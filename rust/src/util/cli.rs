//! Hand-rolled CLI parsing (clap is not in the offline crate set):
//! `subcommand --flag value --flag value …`, typed flag extraction with
//! defaults, and unknown-flag detection.

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Parsed command line: subcommand + flag map.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    flags: HashMap<String, String>,
    /// Flags read via `get`/`flag` — used by `reject_unknown`.
    seen: std::cell::RefCell<std::collections::HashSet<String>>,
}

impl Cli {
    /// Parse `args` (without argv[0]).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut cli = Cli::default();
        if args.is_empty() {
            return Ok(cli);
        }
        if args[0].starts_with("--") {
            bail!("expected a subcommand before flags, got {:?}", args[0]);
        }
        cli.command = args[0].clone();
        let mut i = 1;
        while i < args.len() {
            let key = args[i]
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {:?}", args[i]))?;
            if key.is_empty() {
                bail!("empty flag name");
            }
            let val = args
                .get(i + 1)
                .ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
            if cli.flags.insert(key.to_string(), val.clone()).is_some() {
                bail!("duplicate flag --{key}");
            }
            i += 2;
        }
        Ok(cli)
    }

    /// Typed flag with default.
    pub fn flag<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        self.seen.borrow_mut().insert(key.to_string());
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("bad value for --{key}: {v:?}")),
        }
    }

    /// Optional flag (no default).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.seen.borrow_mut().insert(key.to_string());
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Error if any provided flag was never consumed (catches typos like
    /// `--runz 10`). Call after all `flag`/`get` lookups.
    pub fn reject_unknown(&self) -> Result<()> {
        let seen = self.seen.borrow();
        let unknown: Vec<&str> = self
            .flags
            .keys()
            .filter(|k| !seen.contains(*k))
            .map(|s| s.as_str())
            .collect();
        if !unknown.is_empty() {
            bail!("unknown flag(s): {}", unknown.join(", "));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let cli = Cli::parse(&args(&["serve", "--addr", "0.0.0.0:1", "--max-batch", "8"])).unwrap();
        assert_eq!(cli.command, "serve");
        assert_eq!(cli.get("addr"), Some("0.0.0.0:1"));
        assert_eq!(cli.flag("max-batch", 0usize).unwrap(), 8);
    }

    #[test]
    fn defaults_apply() {
        let cli = Cli::parse(&args(&["bench"])).unwrap();
        assert_eq!(cli.flag("runs", 100usize).unwrap(), 100);
        assert!(cli.get("which").is_none());
    }

    #[test]
    fn typed_parse_errors() {
        let cli = Cli::parse(&args(&["x", "--n", "abc"])).unwrap();
        assert!(cli.flag("n", 0usize).is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Cli::parse(&args(&["--flag-first", "v"])).is_err());
        assert!(Cli::parse(&args(&["cmd", "loose"])).is_err());
        assert!(Cli::parse(&args(&["cmd", "--dangling"])).is_err());
        assert!(Cli::parse(&args(&["cmd", "--a", "1", "--a", "2"])).is_err());
    }

    #[test]
    fn unknown_flags_detected() {
        let cli = Cli::parse(&args(&["cmd", "--known", "1", "--typo", "2"])).unwrap();
        let _ = cli.flag("known", 0usize).unwrap();
        let err = cli.reject_unknown().unwrap_err();
        assert!(format!("{err}").contains("typo"));
    }

    #[test]
    fn empty_args_ok() {
        let cli = Cli::parse(&[]).unwrap();
        assert_eq!(cli.command, "");
    }
}
