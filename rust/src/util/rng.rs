//! From-scratch deterministic PRNG (PCG-XSH-RR 64/32 and SplitMix64).
//!
//! The offline crate set has no `rand`; every stochastic component in this
//! repo (workload generators, fault injectors, property tests) draws from
//! this module so campaigns are reproducible from a single `u64` seed.

/// SplitMix64: used for seeding and as a cheap stream splitter.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32 — small, fast, statistically solid. Main generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub const DEFAULT_STREAM: u64 = 0xda3e_39cb_94b9_5bdb;

    /// Seed with SplitMix64 expansion so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::from_state(sm.next_u64(), sm.next_u64())
    }

    /// Derive an independent sub-stream (e.g. one per campaign run).
    pub fn split(&mut self, stream: u64) -> Self {
        let s = self.next_u64();
        Self::from_state(s, stream.wrapping_mul(2).wrapping_add(1))
    }

    fn from_state(state: u64, inc: u64) -> Self {
        let mut r = Self {
            state: 0,
            inc: (inc << 1) | 1,
        };
        r.next_u32();
        r.state = r.state.wrapping_add(state);
        r.next_u32();
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    #[inline]
    pub fn gen_range_u32(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64).wrapping_mul(bound as u64);
            let l = m as u32;
            if l >= bound || l >= (bound.wrapping_neg() % bound) {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.gen_range_u32((hi - lo) as u32) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform u8 over the full range (paper's fault-model assumption for A).
    #[inline]
    pub fn next_u8(&mut self) -> u8 {
        (self.next_u32() & 0xff) as u8
    }

    /// Uniform i8 over the full range.
    #[inline]
    pub fn next_i8(&mut self) -> i8 {
        (self.next_u32() & 0xff) as u8 as i8
    }

    /// Fill a slice with uniform u8.
    pub fn fill_u8(&mut self, buf: &mut [u8]) {
        for b in buf {
            *b = self.next_u8();
        }
    }

    /// Fill a slice with uniform i8.
    pub fn fill_i8(&mut self, buf: &mut [i8]) {
        for b in buf {
            *b = self.next_i8();
        }
    }

    /// Standard normal via Box-Muller (used for synthetic float weights).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `n` distinct indices from `[0, universe)` (partial Fisher-Yates
    /// for dense draws, rejection for sparse).
    pub fn sample_distinct(&mut self, universe: usize, n: usize) -> Vec<usize> {
        assert!(n <= universe);
        if n * 4 >= universe {
            let mut all: Vec<usize> = (0..universe).collect();
            for i in 0..n {
                let j = self.gen_range(i, universe);
                all.swap(i, j);
            }
            all.truncate(n);
            all
        } else {
            let mut seen = std::collections::HashSet::with_capacity(n * 2);
            let mut out = Vec::with_capacity(n);
            while out.len() < n {
                let x = self.gen_range(0, universe);
                if seen.insert(x) {
                    out.push(x);
                }
            }
            out
        }
    }
}

/// Zipfian sampler over `[0, n)` with exponent `s` — models the skewed
/// embedding-access distributions of production CTR traffic.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

/// Deterministic 64-bit mix (splitmix64 finalizer): stable hashing
/// across runs and processes, no `std::hash` RandomState involved. Used
/// for shard placement (`shard::plan`) and connection→batch-loop
/// assignment (`coordinator::server`).
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn range_bounds_respected() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.gen_range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Pcg32::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            seen[r.gen_range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::new(3);
        let mut sum = 0.0;
        for _ in 0..100_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn u8_uniformity_chi_square_sane() {
        let mut r = Pcg32::new(11);
        let mut counts = [0u32; 256];
        let n = 256 * 1000;
        for _ in 0..n {
            counts[r.next_u8() as usize] += 1;
        }
        let expected = (n / 256) as f64;
        let chi2: f64 = counts
            .iter()
            .map(|&c| {
                let d = c as f64 - expected;
                d * d / expected
            })
            .sum();
        // 255 dof: mean 255, sd ~22.6. Accept generous band.
        assert!(chi2 > 150.0 && chi2 < 400.0, "chi2={chi2}");
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Pcg32::new(5);
        for &(u, n) in &[(100usize, 10usize), (100, 90), (1_000_000, 100)] {
            let s = r.sample_distinct(u, n);
            assert_eq!(s.len(), n);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), n);
            assert!(s.iter().all(|&x| x < u));
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let z = Zipf::new(1000, 1.0);
        let mut r = Pcg32::new(9);
        let mut head = 0usize;
        let n = 10_000;
        for _ in 0..n {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // top-1% of ids should hold far more than 1% of mass
        assert!(head > n / 10, "head={head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
