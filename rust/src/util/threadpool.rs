//! Fixed-size thread pool over std primitives (no tokio/rayon offline).
//!
//! Used by the serving coordinator for request execution and by the fault
//! campaign runner for parallel trials.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    tx: Option<mpsc::Sender<Job>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            workers,
            tx: Some(tx),
            queued,
        }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Busy-wait (with yield) until all submitted jobs complete.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let done = Arc::new(AtomicUsize::new(0));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            let done = Arc::clone(&done);
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        while done.load(Ordering::SeqCst) < n {
            thread::yield_now();
        }
        Arc::try_unwrap(results)
            .ok()
            .expect("all workers done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.unwrap())
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not hang or panic
    }
}
