//! Fixed-size thread pool over std primitives (no tokio/rayon offline),
//! plus a borrow-friendly [`ThreadPool::scope`] used by the row-parallel
//! GEMM and bag-parallel EmbeddingBag hot paths.
//!
//! Robustness notes (post §Perf-PR triage):
//! * The in-flight counter is decremented by a **drop guard**, so a job
//!   that panics still counts down and `wait_idle`/`scope` cannot wedge.
//! * Workers run jobs under `catch_unwind`, so a panicking job no longer
//!   kills its worker thread (the pool keeps its full width for the life
//!   of the process).
//! * The queue is a `Mutex<VecDeque> + Condvar` rather than an `mpsc`
//!   channel: an idle `Receiver::recv` would pin the shared-receiver
//!   mutex, and waiting threads could not *help* drain the queue. With
//!   the condvar queue, [`ThreadPool::scope`]'s join loop pops and runs
//!   jobs itself, which is also what makes nested scopes deadlock-free.
//! * Orderings are the minimal correct set: the pool's in-flight counter
//!   uses `Release` on completion / `Acquire` on the waiting loads (the
//!   completion edge is what makes a job's writes visible to the waiter)
//!   and `Relaxed` for the pure count-up; scope joins are monitor-based
//!   (mutex + condvar), so their happens-before comes from the lock.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// First panic payload captured from a scope's jobs, re-raised at the
/// scope boundary so the original message (e.g. an out-of-range-index
/// assert from a parallel bag) is not replaced by a generic one.
type PanicSlot = Arc<Mutex<Option<Box<dyn std::any::Any + Send + 'static>>>>;

/// Per-scope completion tracking: a counted mutex + condvar, so the
/// joining thread can *block* once there is nothing left to steal,
/// instead of yield-spinning a core while the last jobs finish on
/// workers. The wait is time-bounded (see `Waiter`) so a nested scope
/// whose jobs land on the queue after we block still gets stolen.
struct ScopeSync {
    pending: Mutex<usize>,
    cv: Condvar,
}

/// Decrements a scope's pending count on drop (panic-safe) and wakes
/// the joiner when the count reaches zero.
struct ScopeGuard(Arc<ScopeSync>);

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        let mut pending = self.0.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            drop(pending);
            self.0.cv.notify_all();
        }
    }
}

/// Minimum MAC count (m·k·n_total) before a GEMM fans out over row
/// blocks on the global pool (below this, spawn overhead beats the win).
/// All fan-out gate thresholds live here so every operator's parallelism
/// decision retunes in one place (ROADMAP open item).
pub const GEMM_PAR_MIN_WORK: usize = 1 << 21;

/// Minimum total f32 accumulate count (Σ pooling · d) before a batched
/// EmbeddingBag — or the model's request-parallel EB stage — fans out.
pub const EB_PAR_MIN_WORK: usize = 1 << 17;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    queue: Arc<Queue>,
    /// Jobs submitted and not yet finished (queued + running).
    in_flight: Arc<AtomicUsize>,
    size: usize,
}

/// Decrements a counter on drop — runs even if the guarded job panics.
struct CountGuard(Arc<AtomicUsize>);

impl Drop for CountGuard {
    fn drop(&mut self) {
        // Release: pairs with the Acquire loads in the waiting loops so a
        // job's memory effects are visible once its completion is observed.
        self.0.fetch_sub(1, Ordering::Release);
    }
}

fn run_job(job: Job) {
    // A panicking job must neither kill the worker nor leak the count
    // (the count is guarded by the caller). Swallow the payload; the
    // submitter observes the panic through `Scope` or its own channel.
    let _ = catch_unwind(AssertUnwindSafe(job));
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let queue = Arc::new(Queue {
            state: Mutex::new(QueueState {
                jobs: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut st = queue.state.lock().unwrap();
                            loop {
                                if let Some(job) = st.jobs.pop_front() {
                                    break Some(job);
                                }
                                if st.shutdown {
                                    break None;
                                }
                                st = queue.cv.wait(st).unwrap();
                            }
                        };
                        match job {
                            Some(job) => {
                                let _guard = CountGuard(Arc::clone(&in_flight));
                                run_job(job);
                            }
                            None => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            workers,
            queue,
            in_flight,
            size,
        }
    }

    /// Worker-thread count.
    pub fn size(&self) -> usize {
        self.size
    }

    fn submit(&self, job: Job) {
        // Relaxed is enough for the increment: the queue mutex orders the
        // push against the pop, and completion (the edge that matters to
        // waiters) is Release in CountGuard.
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        let mut st = self.queue.state.lock().unwrap();
        assert!(!st.shutdown, "pool shut down");
        st.jobs.push_back(job);
        drop(st);
        self.queue.cv.notify_one();
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.submit(Box::new(f));
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Pop one queued job and run it on the calling thread. Returns false
    /// when the queue is empty. This is how waiting threads "help": a
    /// thread blocked in [`ThreadPool::scope`] or [`ThreadPool::wait_idle`]
    /// drains the queue instead of spinning, which also makes nested
    /// scopes deadlock-free (the waiter can always run its own
    /// outstanding jobs even when every worker is busy).
    fn try_run_one(&self) -> bool {
        let job = self.queue.state.lock().unwrap().jobs.pop_front();
        match job {
            Some(job) => {
                let _guard = CountGuard(Arc::clone(&self.in_flight));
                run_job(job);
                true
            }
            None => false,
        }
    }

    /// Wait (helping, then briefly parking) until all submitted jobs
    /// complete. Not a hot path — serving joins go through `scope`.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            if !self.try_run_one() {
                thread::park_timeout(std::time::Duration::from_micros(100));
            }
        }
    }

    /// Run a set of borrowed-data jobs and join them before returning —
    /// the `std::thread::scope` shape, but on pool workers instead of
    /// fresh threads. Jobs may borrow from the caller's stack (`'env`);
    /// the scope guarantees they finish before it returns, even if the
    /// closure or a job panics.
    ///
    /// If any spawned job panicked, the scope re-raises the first panic
    /// payload after all jobs have completed (so partial results are
    /// never silently kept and the original message survives).
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'_, 'env>) -> R,
    {
        let scope = Scope {
            pool: self,
            sync: Arc::new(ScopeSync {
                pending: Mutex::new(0),
                cv: Condvar::new(),
            }),
            panic: Arc::new(Mutex::new(None)),
            _env: std::marker::PhantomData,
        };
        // The join must run even if `f` unwinds: jobs borrow `'env` data
        // and may not outlive this frame.
        struct Waiter<'a> {
            pool: &'a ThreadPool,
            sync: Arc<ScopeSync>,
        }
        impl Drop for Waiter<'_> {
            fn drop(&mut self) {
                loop {
                    if *self.sync.pending.lock().unwrap() == 0 {
                        return;
                    }
                    // Our jobs aren't done. Help run queued work — our own
                    // jobs may sit behind unrelated ones in the FIFO, and
                    // helping is what keeps nested scopes deadlock-free.
                    // (Checking pending FIRST means a scope whose jobs
                    // already finished never picks up strangers' work.)
                    if self.pool.try_run_one() {
                        continue;
                    }
                    // Nothing stealable: block until the last job's guard
                    // wakes us. Time-bounded so jobs that reach the queue
                    // *after* we block (nested scopes spawned by our own
                    // jobs) still get stolen on the next lap instead of
                    // deadlocking a fully-busy pool.
                    let pending = self.sync.pending.lock().unwrap();
                    if *pending == 0 {
                        return;
                    }
                    let _ = self
                        .sync
                        .cv
                        .wait_timeout(pending, std::time::Duration::from_micros(200))
                        .unwrap();
                }
            }
        }
        let waiter = Waiter {
            pool: self,
            sync: Arc::clone(&scope.sync),
        };
        let r = f(&scope);
        drop(waiter); // join all spawned jobs
        if let Some(payload) = scope.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
        r
    }

    /// The shared fan-out shape of every row/bag-parallel operator in the
    /// crate (GEMM row blocks, EB bags, the model's per-request EB stage):
    /// `out` is a run of independent records of `item_len` elements each.
    /// When the gate passes (≥2 items, >1 worker, `work >= min_work`) the
    /// items are ceil-chunked into at most `size()` contiguous jobs and
    /// `f(first_item, chunk)` runs per job on the pool; otherwise the
    /// whole slice is handled by one inline `f(0, out)` call. Items must
    /// be independent — which is also what makes the parallel path
    /// bit-identical to the serial one.
    pub fn scope_chunks<T, F>(&self, out: &mut [T], item_len: usize, work: usize, min_work: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(item_len > 0 && out.len() % item_len == 0, "chunk shape");
        let items = out.len() / item_len;
        if items >= 2 && self.size() > 1 && work >= min_work {
            let jobs = self.size().min(items);
            let per = (items + jobs - 1) / jobs;
            self.scope(|s| {
                for (ji, chunk) in out.chunks_mut(per * item_len).enumerate() {
                    let f = &f;
                    s.spawn(move || f(ji * per, chunk));
                }
            });
        } else {
            f(0, out);
        }
    }

    /// Two-slice variant of [`ThreadPool::scope_chunks`] for operators
    /// that produce two outputs per item with different record widths
    /// (the fused GEMM writes an `n_total`-wide i32 accumulator row AND
    /// an `n_out`-wide u8 row per m-row). Same gate, same ceil chunking
    /// — both slices split at identical item boundaries, so the gate and
    /// chunk policy keep living in exactly one place.
    pub fn scope_chunks2<T, U, F>(
        &self,
        out_a: &mut [T],
        item_len_a: usize,
        out_b: &mut [U],
        item_len_b: usize,
        work: usize,
        min_work: usize,
        f: F,
    ) where
        T: Send,
        U: Send,
        F: Fn(usize, &mut [T], &mut [U]) + Sync,
    {
        assert!(item_len_a > 0 && out_a.len() % item_len_a == 0, "chunk shape");
        let items = out_a.len() / item_len_a;
        assert_eq!(out_b.len(), items * item_len_b, "chunk shape (second slice)");
        if items >= 2 && self.size() > 1 && work >= min_work {
            let jobs = self.size().min(items);
            let per = (items + jobs - 1) / jobs;
            self.scope(|s| {
                let mut rest_a = out_a;
                let mut rest_b = out_b;
                let mut i0 = 0usize;
                while i0 < items {
                    let n = per.min(items - i0);
                    let (ca, ta) = rest_a.split_at_mut(n * item_len_a);
                    let (cb, tb) = rest_b.split_at_mut(n * item_len_b);
                    rest_a = ta;
                    rest_b = tb;
                    let f = &f;
                    let first = i0;
                    s.spawn(move || f(first, ca, cb));
                    i0 += n;
                }
            });
        } else {
            f(0, out_a, out_b);
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Send + Sync,
    {
        let n = items.len();
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        self.scope(|s| {
            for (item, slot) in items.into_iter().zip(results.iter_mut()) {
                let f = &f;
                s.spawn(move || {
                    *slot = Some(f(item));
                });
            }
        });
        results.into_iter().map(|r| r.unwrap()).collect()
    }
}

/// Handle for spawning borrowed-data jobs inside [`ThreadPool::scope`].
pub struct Scope<'pool, 'env> {
    pool: &'pool ThreadPool,
    sync: Arc<ScopeSync>,
    panic: PanicSlot,
    // Invariant over 'env: closures may borrow anything outliving the
    // scope call, mutably or not.
    _env: std::marker::PhantomData<&'env mut &'env ()>,
}

impl<'pool, 'env> Scope<'pool, 'env> {
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        *self.sync.pending.lock().unwrap() += 1;
        let guard_sync = Arc::clone(&self.sync);
        let panic = Arc::clone(&self.panic);
        let job: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
            let _guard = ScopeGuard(guard_sync);
            if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                let mut slot = panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        });
        // SAFETY: the scope's Waiter joins every spawned job before the
        // 'env frame can be left (normally or by unwind), so the closure
        // never outlives its borrows. Erasing the lifetime is what lets it
        // ride the pool's 'static queue.
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Box<dyn FnOnce() + Send + 'static>>(
                job,
            )
        };
        self.pool.submit(job);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.queue.state.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Process-wide pool for kernel-level parallelism (row-parallel GEMM,
/// bag-parallel EB). Sized from `DLRM_ABFT_THREADS` when set, else the
/// machine's available parallelism. Lives for the process; sharing one
/// pool keeps nested operator parallelism from oversubscribing cores.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let n = std::env::var("DLRM_ABFT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
        ThreadPool::new(n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not hang or panic
    }

    #[test]
    fn panicking_job_does_not_wedge_wait_idle() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        pool.wait_idle(); // must terminate: guard decrements on unwind
        assert_eq!(pool.pending(), 0);
        // Workers survived the panic and still run jobs.
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn scope_borrows_without_static() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 1024];
        let chunk = 128;
        pool.scope(|s| {
            for (ci, out) in data.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    for (j, x) in out.iter_mut().enumerate() {
                        *x = (ci * chunk + j) as u64;
                    }
                });
            }
        });
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u64));
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // More concurrent scopes than workers: the inner scopes' join
        // loops must help drain the queue instead of blocking a worker
        // forever.
        let pool = ThreadPool::new(2);
        let pool_ref = &pool;
        let total = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..4 {
                let total = Arc::clone(&total);
                s.spawn(move || {
                    pool_ref.scope(|inner| {
                        for _ in 0..4 {
                            let total = Arc::clone(&total);
                            inner.spawn(move || {
                                total.fetch_add(1, Ordering::SeqCst);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 16);
    }

    #[test]
    #[should_panic(expected = "inner boom")]
    fn scope_propagates_original_panic_payload() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|| panic!("inner boom"));
        });
    }

    #[test]
    fn scope_chunks_covers_all_items_parallel_and_serial() {
        let pool = ThreadPool::new(4);
        for (items, item_len, min_work) in [(64usize, 8usize, 0usize), (64, 8, usize::MAX), (1, 8, 0), (5, 3, 0)] {
            let mut out = vec![0u32; items * item_len];
            pool.scope_chunks(&mut out, item_len, items * item_len, min_work, |first, chunk| {
                for (i, rec) in chunk.chunks_mut(item_len).enumerate() {
                    rec.fill((first + i) as u32 + 1);
                }
            });
            for (i, rec) in out.chunks(item_len).enumerate() {
                assert!(rec.iter().all(|&x| x == i as u32 + 1), "item {i} (items={items})");
            }
        }
    }

    #[test]
    fn scope_chunks_chunk_boundaries_are_item_aligned() {
        let pool = ThreadPool::new(3);
        let (items, item_len) = (10usize, 4usize);
        let mut out = vec![0usize; items * item_len];
        pool.scope_chunks(&mut out, item_len, usize::MAX, 0, |first, chunk| {
            assert_eq!(chunk.len() % item_len, 0);
            chunk.fill(first);
        });
        // Every record's fill value is its job's first-item index ≤ its own.
        for (i, rec) in out.chunks(item_len).enumerate() {
            assert!(rec[0] <= i);
            assert!(rec.iter().all(|&x| x == rec[0]));
        }
    }

    #[test]
    fn scope_chunks2_splits_both_slices_item_aligned() {
        let pool = ThreadPool::new(3);
        for min_work in [0usize, usize::MAX] {
            let (items, la, lb) = (10usize, 4usize, 3usize);
            let mut a = vec![0usize; items * la];
            let mut b = vec![0usize; items * lb];
            pool.scope_chunks2(&mut a, la, &mut b, lb, 1 << 30, min_work, |first, ca, cb| {
                assert_eq!(ca.len() % la, 0);
                assert_eq!(cb.len() / lb, ca.len() / la, "same item count per job");
                for (i, rec) in ca.chunks_mut(la).enumerate() {
                    rec.fill(first + i + 1);
                }
                for (i, rec) in cb.chunks_mut(lb).enumerate() {
                    rec.fill((first + i + 1) * 10);
                }
            });
            for (i, rec) in a.chunks(la).enumerate() {
                assert!(rec.iter().all(|&x| x == i + 1), "a item {i} (min_work={min_work})");
            }
            for (i, rec) in b.chunks(lb).enumerate() {
                assert!(rec.iter().all(|&x| x == (i + 1) * 10), "b item {i}");
            }
        }
    }

    #[test]
    fn global_pool_is_usable() {
        let pool = global();
        assert!(pool.size() >= 1);
        let mut x = [0usize; 16];
        pool.scope(|s| {
            for (i, slot) in x.iter_mut().enumerate() {
                s.spawn(move || *slot = i + 1);
            }
        });
        assert_eq!(x.iter().sum::<usize>(), (1..=16).sum());
    }
}
