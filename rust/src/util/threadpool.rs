//! Fixed-size thread pool over std primitives (no tokio/rayon offline),
//! plus a borrow-friendly [`ThreadPool::scope`] used by the row-parallel
//! GEMM and bag-parallel EmbeddingBag hot paths.
//!
//! Robustness notes (post §Perf-PR triage):
//! * The in-flight counter is decremented by a **drop guard**, so a job
//!   that panics still counts down and `wait_idle`/`scope` cannot wedge.
//! * Workers run jobs under `catch_unwind`, so a panicking job no longer
//!   kills its worker thread (the pool keeps its full width for the life
//!   of the process).
//! * The queue is a mutex-guarded ring + condvar rather than an `mpsc`
//!   channel: an idle `Receiver::recv` would pin the shared-receiver
//!   mutex, and waiting threads could not *help* drain the queue. With
//!   the condvar queue, [`ThreadPool::scope`]'s join loop pops and runs
//!   jobs itself, which is also what makes nested scopes deadlock-free.
//! * Orderings are the minimal correct set: the pool's in-flight counter
//!   uses `Release` on completion / `Acquire` on the waiting loads (the
//!   completion edge is what makes a job's writes visible to the waiter)
//!   and `Relaxed` for the pure count-up; scope joins are monitor-based
//!   (mutex + condvar), so their happens-before comes from the lock.
//!
//! # Zero-allocation fan-out (PR 8)
//!
//! Submitting a job allocates **nothing** in steady state: jobs are
//! type-erased into fixed [`SlotJob`] slots (closure bytes inlined up to
//! [`SLOT_DATA`] bytes; larger closures fall back to one thin box) and
//! queued on a fixed-capacity ring allocated once at pool construction,
//! with an overflow deque only for burst spills past the ring. Scope
//! joins are tracked by a [`ScopeSync`] + panic slot living **on the
//! scope's stack frame** (no per-scope `Arc`s). Every fan-out closure in
//! the crate's hot paths (GEMM row blocks, the EB stage) captures a few
//! references and indices — far under [`SLOT_DATA`] — so large-batch
//! fan-out performs zero steady-state allocations, which
//! `rust/tests/zero_alloc.rs` asserts with a counting global allocator.

use std::collections::VecDeque;
use std::mem::{align_of, size_of, ManuallyDrop, MaybeUninit};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;

/// First panic payload captured from a scope's jobs, re-raised at the
/// scope boundary so the original message (e.g. an out-of-range-index
/// assert from a parallel bag) is not replaced by a generic one.
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Per-scope completion tracking: a counted mutex + condvar, so the
/// joining thread can *block* once there is nothing left to steal,
/// instead of yield-spinning a core while the last jobs finish on
/// workers. The wait is time-bounded (see `Waiter`) so a nested scope
/// whose jobs land on the queue after we block still gets stolen.
/// Lives on the [`ThreadPool::scope`] stack frame — the scope's join
/// guarantee is exactly what makes the borrow sound.
struct ScopeSync {
    pending: Mutex<usize>,
    cv: Condvar,
}

/// Decrements a scope's pending count on drop (panic-safe) and wakes
/// the joiner when the count reaches zero.
struct ScopeGuard<'a>(&'a ScopeSync);

impl Drop for ScopeGuard<'_> {
    fn drop(&mut self) {
        let mut pending = self.0.pending.lock().unwrap();
        *pending -= 1;
        if *pending == 0 {
            drop(pending);
            self.0.cv.notify_all();
        }
    }
}

/// Minimum MAC count (m·k·n_total) before a GEMM fans out over row
/// blocks on the global pool (below this, spawn overhead beats the win).
/// All fan-out gate thresholds live here so every operator's parallelism
/// decision retunes in one place (ROADMAP open item).
pub const GEMM_PAR_MIN_WORK: usize = 1 << 21;

/// Minimum total f32 accumulate count (Σ pooling · d) before a batched
/// EmbeddingBag — or the model's request-parallel EB stage — fans out.
pub const EB_PAR_MIN_WORK: usize = 1 << 17;

/// Inline closure capacity of a [`SlotJob`], in bytes. Sized so every
/// hot-path fan-out closure (a handful of references plus indices,
/// wrapped with the scope guard's two references) fits with headroom;
/// oversized closures still work through one boxed indirection.
const SLOT_DATA: usize = 96;

/// Fixed-size closure payload. 16-byte aligned so any closure whose
/// alignment is ≤ 16 (all of ours — captures are references, integers
/// and small Copy structs) can be stored in place.
#[repr(align(16))]
struct JobPayload([MaybeUninit<u8>; SLOT_DATA]);

/// A type-erased `FnOnce() + Send` in a fixed-size slot: the closure's
/// bytes live inline when they fit (size ≤ [`SLOT_DATA`], align ≤ 16),
/// else a thin `Box<F>` pointer does. `call`/`drop_fn` are monomorphized
/// per closure type, so no fat vtable pointer and no per-job allocation
/// on the inline path.
struct SlotJob {
    /// Consumes the payload and runs the closure.
    call: unsafe fn(*mut JobPayload),
    /// Drops the payload *without* running it (queue teardown).
    drop_fn: unsafe fn(*mut JobPayload),
    data: JobPayload,
}

// SAFETY: `SlotJob::new` only ever stores an `F: Send` (or a `Box<F>` of
// one), and the payload is accessed by exactly one thread at a time.
unsafe impl Send for SlotJob {}

impl SlotJob {
    /// Erase `f` into a slot.
    ///
    /// # Safety
    /// The caller must guarantee the closure's captures outlive its
    /// execution (or destruction) — the erased type may borrow non-
    /// `'static` data, as [`Scope::spawn`] jobs do under the scope-join
    /// guarantee.
    unsafe fn new<F: FnOnce() + Send>(f: F) -> Self {
        unsafe fn call_inline<F: FnOnce()>(p: *mut JobPayload) {
            (p as *mut F).read()();
        }
        unsafe fn drop_inline<F>(p: *mut JobPayload) {
            std::ptr::drop_in_place(p as *mut F);
        }
        unsafe fn call_boxed<F: FnOnce()>(p: *mut JobPayload) {
            (p as *mut Box<F>).read()();
        }
        unsafe fn drop_boxed<F>(p: *mut JobPayload) {
            std::ptr::drop_in_place(p as *mut Box<F>);
        }
        let mut data = JobPayload([MaybeUninit::uninit(); SLOT_DATA]);
        if size_of::<F>() <= SLOT_DATA && align_of::<F>() <= align_of::<JobPayload>() {
            (data.0.as_mut_ptr() as *mut F).write(f);
            SlotJob {
                call: call_inline::<F>,
                drop_fn: drop_inline::<F>,
                data,
            }
        } else {
            (data.0.as_mut_ptr() as *mut Box<F>).write(Box::new(f));
            SlotJob {
                call: call_boxed::<F>,
                drop_fn: drop_boxed::<F>,
                data,
            }
        }
    }

    /// Run (and consume) the job.
    fn run(self) {
        let mut me = ManuallyDrop::new(self);
        // SAFETY: the payload was initialized by `new` and `ManuallyDrop`
        // prevents the destructor from double-dropping it.
        unsafe { (me.call)(&mut me.data) };
    }
}

impl Drop for SlotJob {
    fn drop(&mut self) {
        // Only reached for jobs destroyed without running (pool
        // teardown with a non-empty queue).
        unsafe { (self.drop_fn)(&mut self.data) };
    }
}

struct Queue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// FIFO job queue: a fixed ring (allocated once, never resized) with an
/// overflow deque for bursts past the ring's capacity. Strict FIFO: the
/// ring always holds the oldest jobs, so pops drain the ring first and
/// pushes divert to overflow whenever overflow is non-empty.
struct QueueState {
    ring: Box<[Option<SlotJob>]>,
    head: usize,
    len: usize,
    overflow: VecDeque<SlotJob>,
    shutdown: bool,
}

impl QueueState {
    fn push(&mut self, job: SlotJob) {
        let cap = self.ring.len();
        if self.overflow.is_empty() && self.len < cap {
            let slot = (self.head + self.len) % cap;
            self.ring[slot] = Some(job);
            self.len += 1;
        } else {
            self.overflow.push_back(job);
        }
    }

    fn pop(&mut self) -> Option<SlotJob> {
        if self.len > 0 {
            let job = self.ring[self.head].take();
            debug_assert!(job.is_some(), "ring slot empty at head");
            self.head = (self.head + 1) % self.ring.len();
            self.len -= 1;
            job
        } else {
            self.overflow.pop_front()
        }
    }
}

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    queue: Arc<Queue>,
    /// Jobs submitted and not yet finished (queued + running).
    in_flight: Arc<AtomicUsize>,
    size: usize,
}

/// Decrements a counter on drop — runs even if the guarded job panics.
struct CountGuard(Arc<AtomicUsize>);

impl Drop for CountGuard {
    fn drop(&mut self) {
        // Release: pairs with the Acquire loads in the waiting loops so a
        // job's memory effects are visible once its completion is observed.
        self.0.fetch_sub(1, Ordering::Release);
    }
}

fn run_job(job: SlotJob) {
    // A panicking job must neither kill the worker nor leak the count
    // (the count is guarded by the caller). Swallow the payload; the
    // submitter observes the panic through `Scope` or its own channel.
    let _ = catch_unwind(AssertUnwindSafe(|| job.run()));
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        // Ring capacity: every simultaneous scope_chunks fan-out spawns
        // at most `size` jobs, so 4× size (min 64) keeps steady-state
        // traffic — including a few nested scopes — off the overflow
        // deque entirely.
        let cap = (4 * size).next_power_of_two().max(64);
        let queue = Arc::new(Queue {
            state: Mutex::new(QueueState {
                ring: (0..cap).map(|_| None).collect(),
                head: 0,
                len: 0,
                overflow: VecDeque::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..size)
            .map(|i| {
                let queue = Arc::clone(&queue);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let mut st = queue.state.lock().unwrap();
                            loop {
                                if let Some(job) = st.pop() {
                                    break Some(job);
                                }
                                if st.shutdown {
                                    break None;
                                }
                                st = queue.cv.wait(st).unwrap();
                            }
                        };
                        match job {
                            Some(job) => {
                                let _guard = CountGuard(Arc::clone(&in_flight));
                                run_job(job);
                            }
                            None => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            workers,
            queue,
            in_flight,
            size,
        }
    }

    /// Worker-thread count.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Erase and enqueue a job. Allocation-free whenever the closure
    /// fits a [`SlotJob`] slot and the ring has room.
    ///
    /// # Safety
    /// The closure's captures must outlive its execution/destruction;
    /// `'static` closures ([`ThreadPool::execute`]) satisfy this
    /// trivially, scope jobs via the scope-join guarantee.
    unsafe fn submit_erased<F: FnOnce() + Send>(&self, f: F) {
        // Relaxed is enough for the increment: the queue mutex orders the
        // push against the pop, and completion (the edge that matters to
        // waiters) is Release in CountGuard.
        self.in_flight.fetch_add(1, Ordering::Relaxed);
        let job = SlotJob::new(f);
        let mut st = self.queue.state.lock().unwrap();
        assert!(!st.shutdown, "pool shut down");
        st.push(job);
        drop(st);
        self.queue.cv.notify_one();
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        // SAFETY: `'static` captures outlive everything.
        unsafe { self.submit_erased(f) };
    }

    /// Jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.in_flight.load(Ordering::Acquire)
    }

    /// Pop one queued job and run it on the calling thread. Returns false
    /// when the queue is empty. This is how waiting threads "help": a
    /// thread blocked in [`ThreadPool::scope`] or [`ThreadPool::wait_idle`]
    /// drains the queue instead of spinning, which also makes nested
    /// scopes deadlock-free (the waiter can always run its own
    /// outstanding jobs even when every worker is busy).
    fn try_run_one(&self) -> bool {
        let job = self.queue.state.lock().unwrap().pop();
        match job {
            Some(job) => {
                let _guard = CountGuard(Arc::clone(&self.in_flight));
                run_job(job);
                true
            }
            None => false,
        }
    }

    /// Wait (helping, then briefly parking) until all submitted jobs
    /// complete. Not a hot path — serving joins go through `scope`.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            if !self.try_run_one() {
                thread::park_timeout(std::time::Duration::from_micros(100));
            }
        }
    }

    /// Run a set of borrowed-data jobs and join them before returning —
    /// the `std::thread::scope` shape, but on pool workers instead of
    /// fresh threads. Jobs may borrow from the caller's stack (`'env`);
    /// the scope guarantees they finish before it returns, even if the
    /// closure or a job panics.
    ///
    /// If any spawned job panicked, the scope re-raises the first panic
    /// payload after all jobs have completed (so partial results are
    /// never silently kept and the original message survives).
    pub fn scope<'env, F, R>(&self, f: F) -> R
    where
        F: FnOnce(&Scope<'_, 'env>) -> R,
    {
        // Join-tracking state lives on this frame (not in Arcs): the
        // Waiter below guarantees every spawned job — which borrows
        // these — completes before the frame is left, normally or by
        // unwind.
        let sync = ScopeSync {
            pending: Mutex::new(0),
            cv: Condvar::new(),
        };
        let panic_slot: Mutex<Option<PanicPayload>> = Mutex::new(None);
        // The join must run even if `f` unwinds: jobs borrow `'env` data
        // and may not outlive this frame.
        struct Waiter<'a> {
            pool: &'a ThreadPool,
            sync: &'a ScopeSync,
        }
        impl Drop for Waiter<'_> {
            fn drop(&mut self) {
                loop {
                    if *self.sync.pending.lock().unwrap() == 0 {
                        return;
                    }
                    // Our jobs aren't done. Help run queued work — our own
                    // jobs may sit behind unrelated ones in the FIFO, and
                    // helping is what keeps nested scopes deadlock-free.
                    // (Checking pending FIRST means a scope whose jobs
                    // already finished never picks up strangers' work.)
                    if self.pool.try_run_one() {
                        continue;
                    }
                    // Nothing stealable: block until the last job's guard
                    // wakes us. Time-bounded so jobs that reach the queue
                    // *after* we block (nested scopes spawned by our own
                    // jobs) still get stolen on the next lap instead of
                    // deadlocking a fully-busy pool.
                    let pending = self.sync.pending.lock().unwrap();
                    if *pending == 0 {
                        return;
                    }
                    let _ = self
                        .sync
                        .cv
                        .wait_timeout(pending, std::time::Duration::from_micros(200))
                        .unwrap();
                }
            }
        }
        let waiter = Waiter {
            pool: self,
            sync: &sync,
        };
        let scope = Scope {
            pool: self,
            sync: &sync,
            panic: &panic_slot,
            _env: std::marker::PhantomData,
        };
        let r = f(&scope);
        drop(waiter); // join all spawned jobs
        if let Some(payload) = panic_slot.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
        r
    }

    /// The shared fan-out shape of every row/bag-parallel operator in the
    /// crate (GEMM row blocks, EB bags, the model's per-request EB stage):
    /// `out` is a run of independent records of `item_len` elements each.
    /// When the gate passes (≥2 items, >1 worker, `work >= min_work`) the
    /// items are ceil-chunked into at most `size()` contiguous jobs and
    /// `f(first_item, chunk)` runs per job on the pool; otherwise the
    /// whole slice is handled by one inline `f(0, out)` call. Items must
    /// be independent — which is also what makes the parallel path
    /// bit-identical to the serial one.
    pub fn scope_chunks<T, F>(&self, out: &mut [T], item_len: usize, work: usize, min_work: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(item_len > 0 && out.len() % item_len == 0, "chunk shape");
        let items = out.len() / item_len;
        if items >= 2 && self.size() > 1 && work >= min_work {
            let jobs = self.size().min(items);
            let per = (items + jobs - 1) / jobs;
            self.scope(|s| {
                for (ji, chunk) in out.chunks_mut(per * item_len).enumerate() {
                    let f = &f;
                    s.spawn(move || f(ji * per, chunk));
                }
            });
        } else {
            f(0, out);
        }
    }

    /// Two-slice variant of [`ThreadPool::scope_chunks`] for operators
    /// that produce two outputs per item with different record widths
    /// (the fused GEMM writes an `n_total`-wide i32 accumulator row AND
    /// an `n_out`-wide u8 row per m-row). Same gate, same ceil chunking
    /// — both slices split at identical item boundaries, so the gate and
    /// chunk policy keep living in exactly one place.
    pub fn scope_chunks2<T, U, F>(
        &self,
        out_a: &mut [T],
        item_len_a: usize,
        out_b: &mut [U],
        item_len_b: usize,
        work: usize,
        min_work: usize,
        f: F,
    ) where
        T: Send,
        U: Send,
        F: Fn(usize, &mut [T], &mut [U]) + Sync,
    {
        assert!(item_len_a > 0 && out_a.len() % item_len_a == 0, "chunk shape");
        let items = out_a.len() / item_len_a;
        assert_eq!(out_b.len(), items * item_len_b, "chunk shape (second slice)");
        if items >= 2 && self.size() > 1 && work >= min_work {
            let jobs = self.size().min(items);
            let per = (items + jobs - 1) / jobs;
            self.scope(|s| {
                let mut rest_a = out_a;
                let mut rest_b = out_b;
                let mut i0 = 0usize;
                while i0 < items {
                    let n = per.min(items - i0);
                    let (ca, ta) = rest_a.split_at_mut(n * item_len_a);
                    let (cb, tb) = rest_b.split_at_mut(n * item_len_b);
                    rest_a = ta;
                    rest_b = tb;
                    let f = &f;
                    let first = i0;
                    s.spawn(move || f(first, ca, cb));
                    i0 += n;
                }
            });
        } else {
            f(0, out_a, out_b);
        }
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(T) -> R + Send + Sync,
    {
        let n = items.len();
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        self.scope(|s| {
            for (item, slot) in items.into_iter().zip(results.iter_mut()) {
                let f = &f;
                s.spawn(move || {
                    *slot = Some(f(item));
                });
            }
        });
        results.into_iter().map(|r| r.unwrap()).collect()
    }
}

/// Handle for spawning borrowed-data jobs inside [`ThreadPool::scope`].
pub struct Scope<'scope, 'env> {
    pool: &'scope ThreadPool,
    sync: &'scope ScopeSync,
    panic: &'scope Mutex<Option<PanicPayload>>,
    // Invariant over 'env: closures may borrow anything outliving the
    // scope call, mutably or not.
    _env: std::marker::PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> Scope<'scope, 'env> {
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'env,
    {
        *self.sync.pending.lock().unwrap() += 1;
        let sync = self.sync;
        let panic = self.panic;
        // Workers inherit the spawning thread's flow so fan-out spans
        // (row-block GEMM, EB bags) attribute to the batch that caused
        // them instead of flow 0. One u64 capture — still far under the
        // inline job-slot budget.
        let flow = crate::obs::flow::current();
        // SAFETY: the scope's Waiter joins every spawned job before the
        // scope frame (which owns `sync`/`panic` and bounds every 'env
        // borrow) can be left, normally or by unwind — so neither the
        // wrapper's captured references nor `f`'s captures can dangle.
        unsafe {
            self.pool.submit_erased(move || {
                let _guard = ScopeGuard(sync);
                let _flow = crate::obs::flow::FlowGuard::enter(flow);
                if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                    let mut slot = panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
            });
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.queue.state.lock().unwrap();
            st.shutdown = true;
        }
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Process-wide pool for kernel-level parallelism (row-parallel GEMM,
/// bag-parallel EB). Sized from `DLRM_ABFT_THREADS` when set, else the
/// machine's available parallelism. Lives for the process; sharing one
/// pool keeps nested operator parallelism from oversubscribing cores.
pub fn global() -> &'static ThreadPool {
    static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let n = std::env::var("DLRM_ABFT_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| thread::available_parallelism().map(|n| n.get()).unwrap_or(4));
        ThreadPool::new(n)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(8);
        let out = pool.map((0..64).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not hang or panic
    }

    #[test]
    fn panicking_job_does_not_wedge_wait_idle() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        pool.wait_idle(); // must terminate: guard decrements on unwind
        assert_eq!(pool.pending(), 0);
        // Workers survived the panic and still run jobs.
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..8 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(c.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn scope_borrows_without_static() {
        let pool = ThreadPool::new(4);
        let mut data = vec![0u64; 1024];
        let chunk = 128;
        pool.scope(|s| {
            for (ci, out) in data.chunks_mut(chunk).enumerate() {
                s.spawn(move || {
                    for (j, x) in out.iter_mut().enumerate() {
                        *x = (ci * chunk + j) as u64;
                    }
                });
            }
        });
        assert!(data.iter().enumerate().all(|(i, &x)| x == i as u64));
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // More concurrent scopes than workers: the inner scopes' join
        // loops must help drain the queue instead of blocking a worker
        // forever.
        let pool = ThreadPool::new(2);
        let pool_ref = &pool;
        let total = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            for _ in 0..4 {
                let total = Arc::clone(&total);
                s.spawn(move || {
                    pool_ref.scope(|inner| {
                        for _ in 0..4 {
                            let total = Arc::clone(&total);
                            inner.spawn(move || {
                                total.fetch_add(1, Ordering::SeqCst);
                            });
                        }
                    });
                });
            }
        });
        assert_eq!(total.load(Ordering::SeqCst), 16);
    }

    #[test]
    #[should_panic(expected = "inner boom")]
    fn scope_propagates_original_panic_payload() {
        let pool = ThreadPool::new(2);
        pool.scope(|s| {
            s.spawn(|| panic!("inner boom"));
        });
    }

    #[test]
    fn scope_chunks_covers_all_items_parallel_and_serial() {
        let pool = ThreadPool::new(4);
        for (items, item_len, min_work) in [(64usize, 8usize, 0usize), (64, 8, usize::MAX), (1, 8, 0), (5, 3, 0)] {
            let mut out = vec![0u32; items * item_len];
            pool.scope_chunks(&mut out, item_len, items * item_len, min_work, |first, chunk| {
                for (i, rec) in chunk.chunks_mut(item_len).enumerate() {
                    rec.fill((first + i) as u32 + 1);
                }
            });
            for (i, rec) in out.chunks(item_len).enumerate() {
                assert!(rec.iter().all(|&x| x == i as u32 + 1), "item {i} (items={items})");
            }
        }
    }

    #[test]
    fn scope_chunks_chunk_boundaries_are_item_aligned() {
        let pool = ThreadPool::new(3);
        let (items, item_len) = (10usize, 4usize);
        let mut out = vec![0usize; items * item_len];
        pool.scope_chunks(&mut out, item_len, usize::MAX, 0, |first, chunk| {
            assert_eq!(chunk.len() % item_len, 0);
            chunk.fill(first);
        });
        // Every record's fill value is its job's first-item index ≤ its own.
        for (i, rec) in out.chunks(item_len).enumerate() {
            assert!(rec[0] <= i);
            assert!(rec.iter().all(|&x| x == rec[0]));
        }
    }

    #[test]
    fn scope_chunks2_splits_both_slices_item_aligned() {
        let pool = ThreadPool::new(3);
        for min_work in [0usize, usize::MAX] {
            let (items, la, lb) = (10usize, 4usize, 3usize);
            let mut a = vec![0usize; items * la];
            let mut b = vec![0usize; items * lb];
            pool.scope_chunks2(&mut a, la, &mut b, lb, 1 << 30, min_work, |first, ca, cb| {
                assert_eq!(ca.len() % la, 0);
                assert_eq!(cb.len() / lb, ca.len() / la, "same item count per job");
                for (i, rec) in ca.chunks_mut(la).enumerate() {
                    rec.fill(first + i + 1);
                }
                for (i, rec) in cb.chunks_mut(lb).enumerate() {
                    rec.fill((first + i + 1) * 10);
                }
            });
            for (i, rec) in a.chunks(la).enumerate() {
                assert!(rec.iter().all(|&x| x == i + 1), "a item {i} (min_work={min_work})");
            }
            for (i, rec) in b.chunks(lb).enumerate() {
                assert!(rec.iter().all(|&x| x == (i + 1) * 10), "b item {i}");
            }
        }
    }

    #[test]
    fn global_pool_is_usable() {
        let pool = global();
        assert!(pool.size() >= 1);
        let mut x = [0usize; 16];
        pool.scope(|s| {
            for (i, slot) in x.iter_mut().enumerate() {
                s.spawn(move || *slot = i + 1);
            }
        });
        assert_eq!(x.iter().sum::<usize>(), (1..=16).sum());
    }

    #[test]
    fn oversized_closures_run_through_the_boxed_path() {
        // A capture far past SLOT_DATA must still execute correctly
        // (thin-boxed into the slot) and drop cleanly when unexecuted.
        let pool = ThreadPool::new(2);
        let big = [7u64; 64]; // 512 bytes — way over the 96-byte slot
        let sum = Arc::new(AtomicUsize::new(0));
        let s2 = Arc::clone(&sum);
        pool.execute(move || {
            s2.fetch_add(big.iter().sum::<u64>() as usize, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::SeqCst), 7 * 64);
    }

    #[test]
    fn queued_jobs_drop_their_captures_on_pool_teardown() {
        // Jobs destroyed without running (shutdown with a full queue)
        // must drop captures exactly once — both inline and boxed.
        struct DropCounter(Arc<AtomicUsize>);
        impl Drop for DropCounter {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let drops = Arc::new(AtomicUsize::new(0));
        let ran = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(1);
            // One slow job keeps the worker busy; everything behind it
            // runs (or is dropped at teardown) — either way each
            // DropCounter must fire exactly once.
            pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(20)));
            for i in 0..16 {
                let d = DropCounter(Arc::clone(&drops));
                let r = Arc::clone(&ran);
                let big = [1u8; 200]; // force the boxed path for half of them
                if i % 2 == 0 {
                    pool.execute(move || {
                        let _hold = &d;
                        r.fetch_add(1, Ordering::SeqCst);
                    });
                } else {
                    pool.execute(move || {
                        let _hold = (&d, &big);
                        r.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }
            pool.wait_idle();
        }
        assert_eq!(drops.load(Ordering::SeqCst), 16, "each capture drops once");
        assert_eq!(ran.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn ring_overflow_keeps_fifo_order() {
        // Push far more jobs than the ring holds while the lone worker
        // is blocked; completion order must match submission order.
        let pool = ThreadPool::new(1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g2 = Arc::clone(&gate);
        pool.execute(move || {
            let (lock, cv) = &*g2;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
        let order = Arc::new(Mutex::new(Vec::new()));
        let total = 200usize; // ring cap is 64 for a 1-wide pool
        for i in 0..total {
            let order = Arc::clone(&order);
            pool.execute(move || order.lock().unwrap().push(i));
        }
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        pool.wait_idle();
        let order = order.lock().unwrap();
        assert_eq!(*order, (0..total).collect::<Vec<_>>());
    }
}
