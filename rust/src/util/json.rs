//! Minimal JSON parser/serializer (the offline crate set has no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; used by the
//! config system, the TCP serving protocol, and benchmark result dumps.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Path lookup: `j.path(&["model", "tables", "0"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = match cur {
                Json::Obj(m) => m.get(*k)?,
                Json::Arr(a) => a.get(k.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    out.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                out.push(
                                    char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("bad escape char")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.peek().ok_or_else(|| self.err("bad \\u"))?;
            self.pos += 1;
            v = v * 16
                + match c {
                    b'0'..=b'9' => (c - b'0') as u32,
                    b'a'..=b'f' => (c - b'a' + 10) as u32,
                    b'A'..=b'F' => (c - b'A' + 10) as u32,
                    _ => return Err(self.err("bad hex digit")),
                };
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let back = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, back);
        }
    }

    #[test]
    fn nested_structures() {
        let s = r#"{"a": [1, 2, {"b": null}], "c": "x\ny", "d": -2.5e-1}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.path(&["a", "2", "b"]), Some(&Json::Null));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("d").unwrap().as_f64(), Some(-0.25));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str(), Some("A😀"));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,]", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "[1] extra"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn integer_display_is_integral() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(42.5).to_string(), "42.5");
    }
}
