//! From-scratch infrastructure substrates (the offline crate set lacks
//! rand/serde/tokio/rayon/criterion, so we provide our own).

pub mod cli;
pub mod json;
pub mod rng;
pub mod scratch;
pub mod stats;
pub mod threadpool;

/// Monotonic wall-clock timer helper.
pub fn time_it<F: FnOnce()>(f: F) -> std::time::Duration {
    let t0 = std::time::Instant::now();
    f();
    t0.elapsed()
}
