//! AVX-512/VNNI microkernel (`vpdpbusd`) for the panel-interleaved
//! u8×i8→i32 GEMM — the top dispatch tier.
//!
//! `vpdpbusd` computes, per i32 lane, the exact dot product of 4
//! adjacent unsigned bytes with 4 adjacent signed bytes accumulated
//! into i32 — **non-saturating** (unlike its `vpdpbusds` sibling), so
//! the tier is bit-identical to the scalar kernel with no side
//! conditions: every 4-deep u8×i8 dot fits i32 with enormous headroom.
//!
//! The pack stays canonical (pair-interleaved; see `packed` module
//! docs) so ABFT offsets and fault-injection targets are unchanged; the
//! 4-deep quad layout VNNI wants is assembled **at runtime** from two
//! adjacent pair blocks with two 256-bit `unpacklo/hi_epi16` shuffles —
//! a pair block's i16 element j is column j's (even,odd) byte pair, so
//! interleaving the i16 elements of pair blocks pp and pp+1 yields
//! exactly the 4 consecutive k-bytes per column that `vpdpbusd` wants,
//! in the permuted column order `[0-3, 8-11 | 4-7, 12-15]`. The
//! accumulators live their whole life in that permuted order; a single
//! self-inverse `vpermd` at store time restores column order.
//!
//! k-remainder rows (k mod 4: a leftover pair block and/or the odd tail
//! row) are folded into the stored tile by exact scalar i32 adds —
//! integer adds commute, so the result is still bit-identical. Ragged
//! tail panels (checksum columns) go through the shared scalar panel
//! kernel like every other tier.
//!
//! 512-bit memory intrinsics (`_mm512_loadu_si512` & co.) are avoided
//! on purpose: the kernel builds zmm values from 256-bit loads
//! (`inserti64x4`) and stores through 256-bit halves (`extracti64x4`),
//! sidestepping the historically unstable pointer-type signatures of
//! the 512-bit load/store intrinsics.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;

use super::packed::{panel_rows_scalar, PackedB, NR};

/// Cached runtime check: AVX-512 foundation + VNNI (`vpdpbusd`), plus
/// AVX2 for the 256-bit shuffle/load halves (implied by F on every real
/// part, but checked for rigor).
#[inline]
pub(crate) fn available() -> bool {
    std::arch::is_x86_feature_detected!("avx512f")
        && std::arch::is_x86_feature_detected!("avx512vnni")
        && std::arch::is_x86_feature_detected!("avx2")
}

/// Multiply a row block: `c[rows × nt] = a[rows × k] · B` via VNNI for
/// the full panels; ragged tail panels accumulate via the shared scalar
/// kernel, so `c` must be pre-zeroed by the caller (the dispatcher
/// does).
///
/// # Safety
/// Caller must ensure the host passes [`available`].
#[target_feature(enable = "avx2,avx512f,avx512vnni")]
pub(crate) unsafe fn gemm_rows(a: &[u8], packed: &PackedB, rows: usize, c: &mut [i32]) {
    let k = packed.k;
    let nt = packed.n_total();
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(c.len(), rows * nt);
    let data = packed.data().as_ptr();
    let mut j0 = 0usize;
    while j0 < nt {
        let w = NR.min(nt - j0);
        if w < NR {
            panel_rows_scalar(a, packed.data(), k, nt, rows, c, j0, w);
            j0 += w;
            continue;
        }
        let panel = data.add(j0 * k);
        let mut i = 0usize;
        while i + 2 <= rows {
            panel_vnni_pair(
                a.as_ptr().add(i * k),
                a.as_ptr().add((i + 1) * k),
                panel,
                k,
                c.as_mut_ptr().add(i * nt + j0),
                c.as_mut_ptr().add((i + 1) * nt + j0),
            );
            i += 2;
        }
        if i < rows {
            panel_vnni_single(a.as_ptr().add(i * k), panel, k, c.as_mut_ptr().add(i * nt + j0));
        }
        j0 += NR;
    }
}

/// Assemble the two VNNI quad operands for pair blocks `pp` and `pp+1`
/// (k-rows `2pp..2pp+4`): z0 covers columns 0..16, z1 columns 16..32,
/// both in the permuted lane order `[0-3, 8-11 | 4-7, 12-15]` (each
/// i32 lane = 4 consecutive k-bytes of one column).
#[inline]
#[target_feature(enable = "avx2,avx512f,avx512vnni")]
unsafe fn load_quad(panel: *const i8, pp: usize) -> (__m512i, __m512i) {
    let p0 = _mm256_loadu_si256(panel.add(pp * 2 * NR) as *const __m256i);
    let p1 = _mm256_loadu_si256(panel.add(pp * 2 * NR + 32) as *const __m256i);
    let q0 = _mm256_loadu_si256(panel.add((pp + 1) * 2 * NR) as *const __m256i);
    let q1 = _mm256_loadu_si256(panel.add((pp + 1) * 2 * NR + 32) as *const __m256i);
    let z0 = _mm512_inserti64x4::<1>(
        _mm512_castsi256_si512(_mm256_unpacklo_epi16(p0, q0)),
        _mm256_unpackhi_epi16(p0, q0),
    );
    let z1 = _mm512_inserti64x4::<1>(
        _mm512_castsi256_si512(_mm256_unpacklo_epi16(p1, q1)),
        _mm256_unpackhi_epi16(p1, q1),
    );
    (z0, z1)
}

/// Broadcast 4 consecutive activation bytes (k-rows `p..p+4`) into
/// every i32 lane, byte order matching [`load_quad`]'s quads.
#[inline]
#[target_feature(enable = "avx2,avx512f,avx512vnni")]
unsafe fn broadcast_a_quad(arow: *const u8, p: usize) -> __m512i {
    let bytes = [
        *arow.add(p),
        *arow.add(p + 1),
        *arow.add(p + 2),
        *arow.add(p + 3),
    ];
    _mm512_set1_epi32(i32::from_le_bytes(bytes))
}

/// Undo the quad lane permutation and store 16 finished i32 columns.
#[inline]
#[target_feature(enable = "avx2,avx512f,avx512vnni")]
unsafe fn store_permuted(acc: __m512i, crow: *mut i32) {
    // The quad layout's column order [0-3, 8-11, 4-7, 12-15] is a
    // self-inverse permutation, so the same index vector restores it.
    let idx = _mm512_setr_epi32(0, 1, 2, 3, 8, 9, 10, 11, 4, 5, 6, 7, 12, 13, 14, 15);
    let v = _mm512_permutexvar_epi32(idx, acc);
    _mm256_storeu_si256(crow as *mut __m256i, _mm512_castsi512_si256(v));
    _mm256_storeu_si256(
        (crow as *mut __m256i).add(1),
        _mm512_extracti64x4_epi64::<1>(v),
    );
}

/// Fold the k-rows `[from, k)` of one full panel into an already-stored
/// 32-column row of C by exact scalar adds — the ≤ 3 rows VNNI's 4-deep
/// quads could not cover (a leftover pair block and/or the odd tail
/// row). Adds commute, so folding after the store is bit-identical.
#[inline]
unsafe fn fold_tail_scalar(arow: *const u8, panel: *const i8, k: usize, from: usize, crow: *mut i32) {
    let kp = k & !1;
    for p in from..k {
        let av = *arow.add(p) as i32;
        let (base, stride) = if p >= kp {
            // Odd trailing k-row: w contiguous bytes.
            (kp * NR, 1usize)
        } else {
            // Inside pair block p/2: column c at byte 2c + (p & 1).
            ((p / 2) * 2 * NR + (p % 2), 2usize)
        };
        for cix in 0..NR {
            *crow.add(cix) += av * *panel.add(base + cix * stride) as i32;
        }
    }
}

/// One row × one full panel: dot 4 k-rows at a time with `vpdpbusd`,
/// store the permuted accumulators, then fold the k-remainder.
#[inline]
#[target_feature(enable = "avx2,avx512f,avx512vnni")]
unsafe fn panel_vnni_single(a0: *const u8, panel: *const i8, k: usize, crow: *mut i32) {
    let quads = (k & !1) / 4; // complete 4-row groups = 2 pair blocks each
    let mut acc0 = _mm512_setzero_si512();
    let mut acc1 = _mm512_setzero_si512();
    for q in 0..quads {
        let (z0, z1) = load_quad(panel, 2 * q);
        let va = broadcast_a_quad(a0, 4 * q);
        acc0 = _mm512_dpbusd_epi32(acc0, va, z0);
        acc1 = _mm512_dpbusd_epi32(acc1, va, z1);
    }
    store_permuted(acc0, crow);
    store_permuted(acc1, crow.add(16));
    fold_tail_scalar(a0, panel, k, 4 * quads, crow);
}

/// Row-pair variant of [`panel_vnni_single`]: both rows share the quad
/// loads (4 zmm accumulators + 2 operands + 2 broadcasts in flight).
#[inline]
#[target_feature(enable = "avx2,avx512f,avx512vnni")]
unsafe fn panel_vnni_pair(
    a0: *const u8,
    a1: *const u8,
    panel: *const i8,
    k: usize,
    crow0: *mut i32,
    crow1: *mut i32,
) {
    let quads = (k & !1) / 4;
    let mut acc00 = _mm512_setzero_si512();
    let mut acc01 = _mm512_setzero_si512();
    let mut acc10 = _mm512_setzero_si512();
    let mut acc11 = _mm512_setzero_si512();
    for q in 0..quads {
        let (z0, z1) = load_quad(panel, 2 * q);
        let va0 = broadcast_a_quad(a0, 4 * q);
        let va1 = broadcast_a_quad(a1, 4 * q);
        acc00 = _mm512_dpbusd_epi32(acc00, va0, z0);
        acc01 = _mm512_dpbusd_epi32(acc01, va0, z1);
        acc10 = _mm512_dpbusd_epi32(acc10, va1, z0);
        acc11 = _mm512_dpbusd_epi32(acc11, va1, z1);
    }
    store_permuted(acc00, crow0);
    store_permuted(acc01, crow0.add(16));
    store_permuted(acc10, crow1);
    store_permuted(acc11, crow1.add(16));
    fold_tail_scalar(a0, panel, k, 4 * quads, crow0);
    fold_tail_scalar(a1, panel, k, 4 * quads, crow1);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_naive;
    use crate::util::rng::Pcg32;

    #[test]
    fn vnni_matches_naive_bitwise() {
        if !available() {
            eprintln!("SKIP: host has no AVX-512 VNNI");
            return;
        }
        let mut rng = Pcg32::new(0x512);
        for &(m, k, n) in &[
            (1usize, 1usize, 32usize), // odd-tail-only panel
            (1, 2, 32),                // leftover-pair-only
            (2, 3, 32),                // pair + odd tail
            (3, 4, 64),                // one clean quad
            (5, 129, 96),              // quads + pair + odd tail
            (4, 64, 33),               // full panel + 1-col ragged tail (ABFT shape)
            (7, 255, 160),
            (16, 512, 513),
        ] {
            let mut a = vec![0u8; m * k];
            let mut b = vec![0i8; k * n];
            rng.fill_u8(&mut a);
            rng.fill_i8(&mut b);
            let packed = PackedB::pack(&b, k, n);
            let mut c = vec![0i32; m * n];
            unsafe { gemm_rows(&a, &packed, m, &mut c) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn vnni_extreme_operands_stay_exact() {
        if !available() {
            eprintln!("SKIP: host has no AVX-512 VNNI");
            return;
        }
        let (m, k, n) = (2usize, 64usize, 64usize);
        let a = vec![255u8; m * k];
        for fill in [127i8, -128, -127] {
            let b = vec![fill; k * n];
            let packed = PackedB::pack(&b, k, n);
            let mut c = vec![0i32; m * n];
            unsafe { gemm_rows(&a, &packed, m, &mut c) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "fill {fill}");
        }
    }
}
