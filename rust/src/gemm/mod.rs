//! Low-precision GEMM substrate (paper §III-B).
//!
//! * [`naive`] — triple-loop oracle.
//! * [`packed`] — packed, cache-blocked production kernel with the
//!   extra-column packing hook the ABFT layer builds on.
//! * [`QuantizedLinear`] — a full FC layer: packed weights + requantization
//!   (Fig 1 pipeline), the unit the DLRM MLPs are made of.
//!
//! # Dispatch-tier contract
//!
//! [`gemm_exec_into`] / [`gemm_requant_exec_into`] route each row block
//! through one of four kernel tiers, chosen per pack by [`select_tier`]:
//!
//! | tier | inner op | gate |
//! |------|----------|------|
//! | [`KernelTier::Scalar`]  | portable i32 loops | always available |
//! | [`KernelTier::Avx2`]    | i16-widened `_mm256_madd_epi16` (exact) | `avx2` |
//! | [`KernelTier::Acc16`]   | `_mm256_maddubs_epi16` pair sums held in i16 | `avx2` + pack-time saturation proof + `k ≤ 256` |
//! | [`KernelTier::Avx512`]  | VNNI `vpdpbusd` 4-deep u8×i8 dot (exact) | `avx512f` + `avx512vnni` |
//!
//! The contract every tier must uphold, and the tier-parameterized test
//! grids enforce:
//!
//! 1. **Bit-identical i32 output.** All tiers walk the *same*
//!    panel-interleaved pack (no per-tier repacking) and accumulate in
//!    exact integer arithmetic, so `C_temp` is byte-identical to the
//!    scalar kernel on every tier — including under row-parallel
//!    fan-out (integer adds commute). AVX2/AVX-512 are exact by
//!    construction; acc16 is exact *conditionally*, guarded by the
//!    pack-time proof below.
//! 2. **Checksum columns always packed.** The ABFT Eq-3b checksum and
//!    group-checksum columns ride the trailing panel(s) of the same
//!    pack on every tier, so protected GEMM remains one kernel call
//!    and `verify`/`correct_row` stay tier-agnostic: they only read
//!    `C_temp` and the logical pack layout, never the kernel.
//! 3. **One rounding core.** Requantization goes through a single
//!    scalar-specified pipeline (`quant::requantize_cols_into`): the
//!    AVX2 fused epilogue replays its exact f32 op order in-register,
//!    and the acc16/AVX-512 tiers reuse that same epilogue from memory
//!    — so output bytes never depend on the dispatched tier.
//!
//! ## The i16 saturation argument (acc16 tier)
//!
//! `maddubs` pair sums `a₀b₀ + a₁b₁` (a ∈ u8, b ∈ i8) accumulated in
//! i16 can saturate/wrap, so the acc16 tier is only dispatched when the
//! pack carries a proof that for every stored column and every aligned
//! spill window of `spill_pairs` pair blocks,
//! `Σ 255·(|b_even| + |b_odd|) ≤ 32767`. Since every pair term and
//! every in-window partial sum is bounded in magnitude by that total,
//! neither `maddubs` nor the i16 adds can leave the i16 range for *any*
//! u8 activations — see `quant::acc16`. Ineligible packs (most
//! full-range weight layers) silently use the exact AVX2/AVX-512 tiers.
//!
//! Tier choice can be **capped** (never forced) via the
//! `DLRM_ABFT_KERNEL_TIER` env knob (`scalar|avx2|acc16|avx512`, read
//! once) or [`set_kernel_tier_override`] (tests/benches; takes
//! precedence): selection falls back tier by tier below the cap, so a
//! cap can disable hardware paths but never select an unsupported one.

#[cfg(target_arch = "x86_64")]
pub(crate) mod acc16;
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
#[cfg(target_arch = "x86_64")]
pub(crate) mod avx512;
pub mod naive;
pub mod packed;

pub use naive::gemm_naive;
pub use packed::{
    gemm_exec, gemm_exec_into, gemm_exec_into_scalar, gemm_exec_into_st, gemm_requant_exec_into,
    gemm_requant_exec_into_scalar, simd_active, PackedB,
};

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// The kernel tiers, in dispatch-priority order (highest wins when its
/// gate passes). See the module docs for the per-tier contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum KernelTier {
    /// Portable scalar loops — the bit-exactness reference.
    Scalar = 0,
    /// AVX2 i16-widened madd (PR 1 microkernel).
    Avx2 = 1,
    /// AVX2 maddubs with i16 accumulation + pack-time saturation proof.
    Acc16 = 2,
    /// AVX-512 VNNI `vpdpbusd`.
    Avx512 = 3,
}

impl KernelTier {
    /// Stable lowercase name (metrics label / env-knob value).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Avx2 => "avx2",
            KernelTier::Acc16 => "acc16",
            KernelTier::Avx512 => "avx512",
        }
    }

    /// Numeric code for metrics export (`Scalar = 0 … Avx512 = 3`).
    #[inline]
    pub fn code(self) -> u8 {
        self as u8
    }

    /// Inverse of [`KernelTier::code`].
    pub fn from_code(code: u8) -> Option<Self> {
        match code {
            0 => Some(KernelTier::Scalar),
            1 => Some(KernelTier::Avx2),
            2 => Some(KernelTier::Acc16),
            3 => Some(KernelTier::Avx512),
            _ => None,
        }
    }

    fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelTier::Scalar),
            "avx2" => Some(KernelTier::Avx2),
            "acc16" => Some(KernelTier::Acc16),
            "avx512" => Some(KernelTier::Avx512),
            _ => None,
        }
    }
}

/// `TIER_OVERRIDE` sentinel: no override installed.
const NO_OVERRIDE: u8 = u8::MAX;

/// Process-wide test/bench cap, above the env knob in precedence.
static TIER_OVERRIDE: AtomicU8 = AtomicU8::new(NO_OVERRIDE);

/// Install (or clear, with `None`) a process-wide kernel-tier **cap**
/// for tests and benches. Selection still falls back normally below the
/// cap, so capping at an unavailable tier degrades instead of breaking;
/// use [`select_tier`] to observe what actually dispatches.
pub fn set_kernel_tier_override(tier: Option<KernelTier>) {
    TIER_OVERRIDE.store(tier.map_or(NO_OVERRIDE, KernelTier::code), Ordering::Relaxed);
}

/// The effective tier cap: the test override when installed, else the
/// `DLRM_ABFT_KERNEL_TIER` env knob (read once), else no cap.
fn tier_cap() -> KernelTier {
    if let Some(t) = KernelTier::from_code(TIER_OVERRIDE.load(Ordering::Relaxed)) {
        return t;
    }
    static ENV_CAP: OnceLock<KernelTier> = OnceLock::new();
    *ENV_CAP.get_or_init(|| {
        std::env::var("DLRM_ABFT_KERNEL_TIER")
            .ok()
            .and_then(|s| KernelTier::parse(&s))
            .unwrap_or(KernelTier::Avx512)
    })
}

/// Resolve the kernel tier that will serve this pack on this host:
/// the highest tier, up to the active cap, whose gate passes (AVX-512
/// needs `avx512f`+`avx512vnni`; acc16 needs AVX2, a pack-time
/// saturation proof, and short k; AVX2 needs `avx2`). Deterministic per
/// (pack, host, cap) — the same answer the row-block dispatchers use,
/// so callers can label spans/metrics with it.
pub fn select_tier(packed: &PackedB) -> KernelTier {
    let cap = tier_cap();
    #[cfg(target_arch = "x86_64")]
    {
        if cap >= KernelTier::Avx512 && avx512::available() {
            return KernelTier::Avx512;
        }
        if cap >= KernelTier::Acc16
            && avx2::available()
            && packed.acc16_proof().is_some()
            && packed.k <= crate::quant::ACC16_SHORT_K_MAX
        {
            return KernelTier::Acc16;
        }
        if cap >= KernelTier::Avx2 && avx2::available() {
            return KernelTier::Avx2;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = (cap, packed);
    }
    KernelTier::Scalar
}

use crate::quant::{QParams, RequantEpilogue, RequantParams, RequantSpec};
use crate::util::scratch::{grow, GemmScratch};
use std::sync::Arc;

/// A quantized fully-connected layer: y = requant(x · W).
///
/// Weights are packed once at construction (they are the long-lived operand
/// — paper §IV-A1) and reused across every forward call.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub packed: PackedB,
    pub w_qparams: QParams,
    pub out_qparams: QParams,
    /// Column sums of W, precomputed at pack time for requantization
    /// (recomputing them per forward would walk the whole pack); shared
    /// into each forward's `RequantParams` by `Arc` instead of cloning.
    b_col_sums: Arc<[i32]>,
    pub k: usize,
    pub n: usize,
}

impl QuantizedLinear {
    /// Build from float weights (k×n row-major); fits weight and output
    /// lattices from the data / provided output range.
    pub fn from_float(w: &[f32], k: usize, n: usize, out_range: (f32, f32)) -> Self {
        let (wq, w_qparams) = crate::quant::quantize_slice_i8(w);
        let mut b_col_sums = vec![0i32; n];
        for p in 0..k {
            for j in 0..n {
                b_col_sums[j] += wq[p * n + j] as i32;
            }
        }
        Self {
            packed: PackedB::pack(&wq, k, n),
            w_qparams,
            out_qparams: QParams::fit_u8(out_range.0, out_range.1),
            b_col_sums: b_col_sums.into(),
            k,
            n,
        }
    }

    /// Forward: quantized input (m×k u8 + its qparams) → quantized output
    /// (m×n u8). Returns the 32-bit intermediate too (ABFT wants it).
    ///
    /// Allocating wrapper over [`QuantizedLinear::forward_into`]; serving
    /// paths hold a [`GemmScratch`] and call the `_into` form directly.
    pub fn forward(&self, x: &[u8], m: usize, x_qparams: QParams) -> (Vec<u8>, Vec<i32>) {
        let mut scratch = GemmScratch::default();
        let mut out = vec![0u8; m * self.n];
        self.forward_into(x, m, x_qparams, &mut scratch, &mut out);
        let mut c_temp = scratch.c_temp;
        c_temp.truncate(m * self.n);
        (out, c_temp)
    }

    /// Allocation-free forward through the fused GEMM+requantize kernel:
    /// the i32 accumulator lands in `scratch.c_temp` (callers that want
    /// the intermediate read it there) and the quantized output in `out`.
    pub fn forward_into(
        &self,
        x: &[u8],
        m: usize,
        x_qparams: QParams,
        scratch: &mut GemmScratch,
        out: &mut [u8],
    ) {
        assert_eq!(x.len(), m * self.k, "input shape");
        assert_eq!(out.len(), m * self.n, "output shape");
        let spec = RequantSpec::new(x_qparams, self.w_qparams, self.out_qparams, self.k);
        let GemmScratch { c_temp, a_row_sums } = scratch;
        row_sums_into(x, m, self.k, grow(a_row_sums, m));
        let epi = RequantEpilogue {
            spec,
            a_row_sums: &a_row_sums[..m],
            b_col_sums: &self.b_col_sums,
            n_out: self.n,
            relu_floor: 0,
        };
        gemm_requant_exec_into(x, &self.packed, m, &epi, grow(c_temp, m * self.n), out);
    }

    pub(crate) fn requant_params(&self, x: &[u8], m: usize, x_qparams: QParams) -> RequantParams {
        let mut a_row_sums = vec![0i32; m];
        row_sums_into(x, m, self.k, &mut a_row_sums);
        RequantParams {
            a: x_qparams,
            b: self.w_qparams,
            c: self.out_qparams,
            a_row_sums,
            b_col_sums: Arc::clone(&self.b_col_sums),
            k: self.k,
        }
    }
}

/// Row sums of an m×k u8 activation block (the Eq-1 A-row-sum term).
pub(crate) fn row_sums_into(x: &[u8], m: usize, k: usize, out: &mut [i32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m);
    for (i, s) in out.iter_mut().enumerate() {
        *s = x[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn linear_layer_end_to_end() {
        let (m, k, n) = (4, 32, 8);
        let mut rng = Pcg32::new(77);
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
        let layer = QuantizedLinear::from_float(&w, k, n, (-80.0, 80.0));
        let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 2.0).collect();
        let (xq, xp) = crate::quant::quantize_slice_u8(&xf);
        let (y, c_temp) = layer.forward(&xq, m, xp);
        assert_eq!(y.len(), m * n);
        assert_eq!(c_temp.len(), m * n);
        // Compare against float matmul within quantization noise.
        for i in 0..m {
            for j in 0..n {
                let mut exact = 0f32;
                for p in 0..k {
                    exact += xf[i * k + p] * w[p * n + j];
                }
                let approx = layer.out_qparams.dequantize_u8(y[i * n + j]);
                assert!(
                    (approx - exact).abs() < 2.5,
                    "({i},{j}): approx={approx} exact={exact}"
                );
            }
        }
    }
}
