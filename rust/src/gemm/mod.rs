//! Low-precision GEMM substrate (paper §III-B).
//!
//! * [`naive`] — triple-loop oracle.
//! * [`packed`] — packed, cache-blocked production kernel with the
//!   extra-column packing hook the ABFT layer builds on.
//! * [`QuantizedLinear`] — a full FC layer: packed weights + requantization
//!   (Fig 1 pipeline), the unit the DLRM MLPs are made of.

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
pub mod naive;
pub mod packed;

pub use naive::gemm_naive;
pub use packed::{
    gemm_exec, gemm_exec_into, gemm_exec_into_scalar, gemm_exec_into_st, gemm_requant_exec_into,
    gemm_requant_exec_into_scalar, simd_active, PackedB,
};

use crate::quant::{QParams, RequantEpilogue, RequantParams, RequantSpec};
use crate::util::scratch::{grow, GemmScratch};
use std::sync::Arc;

/// A quantized fully-connected layer: y = requant(x · W).
///
/// Weights are packed once at construction (they are the long-lived operand
/// — paper §IV-A1) and reused across every forward call.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub packed: PackedB,
    pub w_qparams: QParams,
    pub out_qparams: QParams,
    /// Column sums of W, precomputed at pack time for requantization
    /// (recomputing them per forward would walk the whole pack); shared
    /// into each forward's `RequantParams` by `Arc` instead of cloning.
    b_col_sums: Arc<[i32]>,
    pub k: usize,
    pub n: usize,
}

impl QuantizedLinear {
    /// Build from float weights (k×n row-major); fits weight and output
    /// lattices from the data / provided output range.
    pub fn from_float(w: &[f32], k: usize, n: usize, out_range: (f32, f32)) -> Self {
        let (wq, w_qparams) = crate::quant::quantize_slice_i8(w);
        let mut b_col_sums = vec![0i32; n];
        for p in 0..k {
            for j in 0..n {
                b_col_sums[j] += wq[p * n + j] as i32;
            }
        }
        Self {
            packed: PackedB::pack(&wq, k, n),
            w_qparams,
            out_qparams: QParams::fit_u8(out_range.0, out_range.1),
            b_col_sums: b_col_sums.into(),
            k,
            n,
        }
    }

    /// Forward: quantized input (m×k u8 + its qparams) → quantized output
    /// (m×n u8). Returns the 32-bit intermediate too (ABFT wants it).
    ///
    /// Allocating wrapper over [`QuantizedLinear::forward_into`]; serving
    /// paths hold a [`GemmScratch`] and call the `_into` form directly.
    pub fn forward(&self, x: &[u8], m: usize, x_qparams: QParams) -> (Vec<u8>, Vec<i32>) {
        let mut scratch = GemmScratch::default();
        let mut out = vec![0u8; m * self.n];
        self.forward_into(x, m, x_qparams, &mut scratch, &mut out);
        let mut c_temp = scratch.c_temp;
        c_temp.truncate(m * self.n);
        (out, c_temp)
    }

    /// Allocation-free forward through the fused GEMM+requantize kernel:
    /// the i32 accumulator lands in `scratch.c_temp` (callers that want
    /// the intermediate read it there) and the quantized output in `out`.
    pub fn forward_into(
        &self,
        x: &[u8],
        m: usize,
        x_qparams: QParams,
        scratch: &mut GemmScratch,
        out: &mut [u8],
    ) {
        assert_eq!(x.len(), m * self.k, "input shape");
        assert_eq!(out.len(), m * self.n, "output shape");
        let spec = RequantSpec::new(x_qparams, self.w_qparams, self.out_qparams, self.k);
        let GemmScratch { c_temp, a_row_sums } = scratch;
        row_sums_into(x, m, self.k, grow(a_row_sums, m));
        let epi = RequantEpilogue {
            spec,
            a_row_sums: &a_row_sums[..m],
            b_col_sums: &self.b_col_sums,
            n_out: self.n,
            relu_floor: 0,
        };
        gemm_requant_exec_into(x, &self.packed, m, &epi, grow(c_temp, m * self.n), out);
    }

    pub(crate) fn requant_params(&self, x: &[u8], m: usize, x_qparams: QParams) -> RequantParams {
        let mut a_row_sums = vec![0i32; m];
        row_sums_into(x, m, self.k, &mut a_row_sums);
        RequantParams {
            a: x_qparams,
            b: self.w_qparams,
            c: self.out_qparams,
            a_row_sums,
            b_col_sums: Arc::clone(&self.b_col_sums),
            k: self.k,
        }
    }
}

/// Row sums of an m×k u8 activation block (the Eq-1 A-row-sum term).
pub(crate) fn row_sums_into(x: &[u8], m: usize, k: usize, out: &mut [i32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(out.len(), m);
    for (i, s) in out.iter_mut().enumerate() {
        *s = x[i * k..(i + 1) * k].iter().map(|&v| v as i32).sum();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn linear_layer_end_to_end() {
        let (m, k, n) = (4, 32, 8);
        let mut rng = Pcg32::new(77);
        let w: Vec<f32> = (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
        let layer = QuantizedLinear::from_float(&w, k, n, (-80.0, 80.0));
        let xf: Vec<f32> = (0..m * k).map(|_| rng.next_f32() * 2.0).collect();
        let (xq, xp) = crate::quant::quantize_slice_u8(&xf);
        let (y, c_temp) = layer.forward(&xq, m, xp);
        assert_eq!(y.len(), m * n);
        assert_eq!(c_temp.len(), m * n);
        // Compare against float matmul within quantization noise.
        for i in 0..m {
            for j in 0..n {
                let mut exact = 0f32;
                for p in 0..k {
                    exact += xf[i * k + p] * w[p * n + j];
                }
                let approx = layer.out_qparams.dequantize_u8(y[i * n + j]);
                assert!(
                    (approx - exact).abs() < 2.5,
                    "({i},{j}): approx={approx} exact={exact}"
                );
            }
        }
    }
}
