//! Int16-accumulation AVX2 microkernel (the short-k "acc16" tier).
//!
//! Same panel-interleaved pack, same output bytes as the scalar and
//! AVX2 kernels — but the inner loop is one `_mm256_maddubs_epi16` per
//! 16 columns × 2 k-rows with the pair sums **accumulated in i16
//! lanes**, spilling (sign-extend + add) into the i32 accumulators only
//! every `spill_pairs` pair blocks. That halves the per-pair op count
//! versus the AVX2 i32 path (no widening loads, one madd feeding a
//! 16-lane add instead of two 8-lane i32 adds), which is where the
//! roughly-2× madd throughput on short-k layers comes from.
//!
//! `maddubs` saturates its i16 pair sum and the i16 adds can wrap, so
//! this kernel is **only dispatched under a pack-time proof**
//! (`quant::acc16`) that for every stored column and every aligned
//! spill window, `Σ 255·(|b_even|+|b_odd|) ≤ 32767` — which bounds
//! every pair term and every in-window partial sum for any u8
//! activations. Under that proof the arithmetic is exact, so the tier
//! is bit-identical to scalar by construction. The odd trailing k-row
//! is folded in exact i32 (shared `fold_tail_row`), and ragged tail
//! panels (checksum columns on non-multiple-of-32 widths) go through
//! the shared scalar panel kernel, exactly like the AVX2 tier.

#![allow(clippy::missing_safety_doc)]

use core::arch::x86_64::*;

use super::avx2::fold_tail_row;
use super::packed::{panel_rows_scalar, PackedB, NR};

/// Multiply a row block with i16 accumulation: `c[rows × nt] = a · B`.
/// `c` must be pre-zeroed (ragged panels accumulate). `spill_pairs` is
/// the pack's certified spill cadence (≥ 1).
///
/// # Safety
/// Caller must ensure AVX2 support and that `packed` carries an
/// [`crate::quant::Acc16Proof`] for `spill_pairs` (the dispatcher
/// checks both).
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn gemm_rows(
    a: &[u8],
    packed: &PackedB,
    rows: usize,
    c: &mut [i32],
    spill_pairs: usize,
) {
    let k = packed.k;
    let nt = packed.n_total();
    debug_assert_eq!(a.len(), rows * k);
    debug_assert_eq!(c.len(), rows * nt);
    debug_assert!(spill_pairs >= 1);
    let data = packed.data().as_ptr();
    let mut j0 = 0usize;
    while j0 < nt {
        let w = NR.min(nt - j0);
        if w < NR {
            panel_rows_scalar(a, packed.data(), k, nt, rows, c, j0, w);
            j0 += w;
            continue;
        }
        let panel = data.add(j0 * k);
        let mut i = 0usize;
        while i + 2 <= rows {
            let (acc0, acc1) = panel_acc16_pair(
                a.as_ptr().add(i * k),
                a.as_ptr().add((i + 1) * k),
                panel,
                k,
                spill_pairs,
            );
            store_tile(&acc0, c.as_mut_ptr().add(i * nt + j0));
            store_tile(&acc1, c.as_mut_ptr().add((i + 1) * nt + j0));
            i += 2;
        }
        if i < rows {
            let acc = panel_acc16_single(a.as_ptr().add(i * k), panel, k, spill_pairs);
            store_tile(&acc, c.as_mut_ptr().add(i * nt + j0));
        }
        j0 += NR;
    }
}

/// Store one finished 32-column i32 tile (same layout as the AVX2 tier:
/// `acc[q]` holds columns `[8q, 8q+8)`).
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn store_tile(acc: &[__m256i; 4], crow: *mut i32) {
    for (q, v) in acc.iter().enumerate() {
        _mm256_storeu_si256((crow as *mut __m256i).add(q), *v);
    }
}

/// Broadcast the (a[2pp], a[2pp+1]) u8 pair into every i16 lane, low
/// byte = even k-row — matching the pack's per-column byte order, so
/// `maddubs(va, b)` lane j is exactly `a₀·B[2pp][j] + a₁·B[2pp+1][j]`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn broadcast_a_pair_u8(arow: *const u8, pp: usize) -> __m256i {
    let lo = *arow.add(2 * pp) as u16;
    let hi = *arow.add(2 * pp + 1) as u16;
    _mm256_set1_epi16((lo | (hi << 8)) as i16)
}

/// Sign-extend the two 16-lane i16 accumulators (columns [0,16) and
/// [16,32)) and add them into the four i32 accumulators.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn spill_i16(acc: &mut [__m256i; 4], s0: __m256i, s1: __m256i) {
    acc[0] = _mm256_add_epi32(
        acc[0],
        _mm256_cvtepi16_epi32(_mm256_castsi256_si128(s0)),
    );
    acc[1] = _mm256_add_epi32(
        acc[1],
        _mm256_cvtepi16_epi32(_mm256_extracti128_si256(s0, 1)),
    );
    acc[2] = _mm256_add_epi32(
        acc[2],
        _mm256_cvtepi16_epi32(_mm256_castsi256_si128(s1)),
    );
    acc[3] = _mm256_add_epi32(
        acc[3],
        _mm256_cvtepi16_epi32(_mm256_extracti128_si256(s1, 1)),
    );
}

/// Accumulate one full-width panel for one row: maddubs pair sums in
/// i16, spilled to i32 every `spill` pair blocks and at loop end, odd-k
/// tail folded in exact i32.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn panel_acc16_single(
    a0: *const u8,
    panel: *const i8,
    k: usize,
    spill: usize,
) -> [__m256i; 4] {
    let kp = k & !1;
    let mut acc = [_mm256_setzero_si256(); 4];
    let mut s0 = _mm256_setzero_si256();
    let mut s1 = _mm256_setzero_si256();
    let mut since = 0usize;
    for pp in 0..kp / 2 {
        let b0 = _mm256_loadu_si256(panel.add(pp * 2 * NR) as *const __m256i);
        let b1 = _mm256_loadu_si256(panel.add(pp * 2 * NR + 32) as *const __m256i);
        let va = broadcast_a_pair_u8(a0, pp);
        s0 = _mm256_add_epi16(s0, _mm256_maddubs_epi16(va, b0));
        s1 = _mm256_add_epi16(s1, _mm256_maddubs_epi16(va, b1));
        since += 1;
        if since == spill {
            spill_i16(&mut acc, s0, s1);
            s0 = _mm256_setzero_si256();
            s1 = _mm256_setzero_si256();
            since = 0;
        }
    }
    if since > 0 {
        spill_i16(&mut acc, s0, s1);
    }
    if k % 2 == 1 {
        fold_tail_row(&mut acc, panel.add(kp * NR), *a0.add(k - 1) as i32);
    }
    acc
}

/// Row-pair variant of [`panel_acc16_single`]: both rows share the two
/// panel loads per pair block.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn panel_acc16_pair(
    a0: *const u8,
    a1: *const u8,
    panel: *const i8,
    k: usize,
    spill: usize,
) -> ([__m256i; 4], [__m256i; 4]) {
    let kp = k & !1;
    let mut acc0 = [_mm256_setzero_si256(); 4];
    let mut acc1 = [_mm256_setzero_si256(); 4];
    let mut s00 = _mm256_setzero_si256();
    let mut s01 = _mm256_setzero_si256();
    let mut s10 = _mm256_setzero_si256();
    let mut s11 = _mm256_setzero_si256();
    let mut since = 0usize;
    for pp in 0..kp / 2 {
        let b0 = _mm256_loadu_si256(panel.add(pp * 2 * NR) as *const __m256i);
        let b1 = _mm256_loadu_si256(panel.add(pp * 2 * NR + 32) as *const __m256i);
        let va0 = broadcast_a_pair_u8(a0, pp);
        let va1 = broadcast_a_pair_u8(a1, pp);
        s00 = _mm256_add_epi16(s00, _mm256_maddubs_epi16(va0, b0));
        s01 = _mm256_add_epi16(s01, _mm256_maddubs_epi16(va0, b1));
        s10 = _mm256_add_epi16(s10, _mm256_maddubs_epi16(va1, b0));
        s11 = _mm256_add_epi16(s11, _mm256_maddubs_epi16(va1, b1));
        since += 1;
        if since == spill {
            spill_i16(&mut acc0, s00, s01);
            spill_i16(&mut acc1, s10, s11);
            s00 = _mm256_setzero_si256();
            s01 = _mm256_setzero_si256();
            s10 = _mm256_setzero_si256();
            s11 = _mm256_setzero_si256();
            since = 0;
        }
    }
    if since > 0 {
        spill_i16(&mut acc0, s00, s01);
        spill_i16(&mut acc1, s10, s11);
    }
    if k % 2 == 1 {
        let tail = panel.add(kp * NR);
        fold_tail_row(&mut acc0, tail, *a0.add(k - 1) as i32);
        fold_tail_row(&mut acc1, tail, *a1.add(k - 1) as i32);
    }
    (acc0, acc1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_naive;
    use crate::util::rng::Pcg32;

    fn small_weights(rng: &mut Pcg32, k: usize, n: usize, mag: i8) -> Vec<i8> {
        (0..k * n)
            .map(|_| {
                let span = 2 * mag as i32 + 1;
                ((rng.next_u32() % span as u32) as i32 - mag as i32) as i8
            })
            .collect()
    }

    #[test]
    fn acc16_matches_naive_on_certified_packs() {
        if !super::super::avx2::available() {
            eprintln!("SKIP: host has no AVX2");
            return;
        }
        let mut rng = Pcg32::new(0xAC16);
        for &(m, k, n) in &[
            (1usize, 2usize, 32usize),
            (3, 63, 64),  // odd k
            (5, 256, 33), // full panel + 1-col ragged tail
            (4, 200, 96),
        ] {
            let mut a = vec![0u8; m * k];
            rng.fill_u8(&mut a);
            let b = small_weights(&mut rng, k, n, 8);
            let packed = PackedB::pack(&b, k, n);
            let proof = packed.acc16_proof().expect("±8 weights must certify");
            let mut c = vec![0i32; m * n];
            c.fill(0);
            unsafe { gemm_rows(&a, &packed, m, &mut c, proof.spill_pairs as usize) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn acc16_exact_at_the_saturation_boundary() {
        // Max-magnitude certifiable operand: uniform +64 weights give
        // |b0|+|b1| = 128 per pair (proof window 1) and, with all-255
        // activations, every pair sum is exactly +32640 — 127 shy of
        // the i16 cliff. Any cadence looser than the certified
        // window-1 spill would wrap (two sums reach 65280), so this
        // run is exact only because the proof-driven spill fires after
        // every pair block. A per-pair-block sign flip exercises the
        // −32640 side the same way. (Alternating signs *within* a pair
        // would cancel to 0 and test nothing.)
        if !super::super::avx2::available() {
            eprintln!("SKIP: host has no AVX2");
            return;
        }
        let (m, k, n) = (2usize, 256usize, 64usize);
        let a = vec![255u8; m * k];
        for flip_blocks in [false, true] {
            let b: Vec<i8> = (0..k * n)
                .map(|idx| {
                    let p = idx / n;
                    if flip_blocks && (p / 2) % 2 == 1 {
                        -64
                    } else {
                        64
                    }
                })
                .collect();
            let packed = PackedB::pack(&b, k, n);
            let proof = packed.acc16_proof().expect("boundary operand certifies");
            assert_eq!(proof.spill_pairs, 1, "boundary operand needs window 1");
            let mut c = vec![0i32; m * n];
            unsafe { gemm_rows(&a, &packed, m, &mut c, 1) };
            assert_eq!(c, gemm_naive(&a, &b, m, k, n), "flip_blocks={flip_blocks}");
        }
    }
}
