//! Naive triple-loop u8×i8→i32 GEMM — the correctness oracle every other
//! kernel in this crate is tested against.

/// `C[m×n] = A[m×k] · B[k×n]`, all row-major, i32 accumulation.
pub fn gemm_naive(a: &[u8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for p in 0..k {
                acc += a[i * k + p] as i32 * b[p * n + j] as i32;
            }
            c[i * n + j] = acc;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_like() {
        // A = [[1,2],[3,4]] (u8), B = I2 (i8)
        let a = [1u8, 2, 3, 4];
        let b = [1i8, 0, 0, 1];
        assert_eq!(gemm_naive(&a, &b, 2, 2, 2), vec![1, 2, 3, 4]);
    }

    #[test]
    fn known_product_with_negatives() {
        // A = [[2, 3]], B = [[-1], [5]] → [13]
        let a = [2u8, 3];
        let b = [-1i8, 5];
        assert_eq!(gemm_naive(&a, &b, 1, 2, 1), vec![13]);
    }

    #[test]
    fn extreme_values_no_overflow() {
        // k=4096 of 255 * -128: 4096 * 255 * -128 = -133_693_440 fits i32.
        let k = 4096;
        let a = vec![255u8; k];
        let b = vec![-128i8; k];
        assert_eq!(gemm_naive(&a, &b, 1, k, 1), vec![-133_693_440]);
    }

    #[test]
    fn empty_m_or_n() {
        assert!(gemm_naive(&[], &[1i8, 2], 0, 2, 1).is_empty());
        assert!(gemm_naive(&[1u8, 2], &[], 1, 2, 0).is_empty());
    }
}
