//! Packed u8×i8→i32 GEMM (FBGEMM-lite).
//!
//! `PackedB` is the pre-packed weight operand: B is laid out row-major with
//! an optional *extra column* appended contiguously — this is the paper's
//! §IV-A3 trick ("pack the original B and the separate vector storing row
//! sums together into blocks so the blocks look like they are from encoded
//! B′ in contiguous memory space"), which keeps the ABFT-protected GEMM a
//! single BLAS-3 call.
//!
//! The compute kernel blocks over k so a `KC × n` panel of B stays cache
//! resident while all m rows of A stream over it, and processes rows of A
//! in pairs for instruction-level parallelism. The inner j-loop is written
//! to autovectorize.

/// Cache block over the inner (k) dimension (swept 128/256/512 in the
/// §Perf pass; 128 won on this core's L1/L2).
const KC: usize = 128;

/// Pre-packed right-hand-side operand.
#[derive(Clone, Debug)]
pub struct PackedB {
    /// Row-major `k × n_total` panel data.
    pub(crate) data: Vec<i8>,
    pub k: usize,
    /// Logical (payload) column count, excluding any extra column.
    pub n: usize,
    /// Number of appended extra columns (0 or 1).
    pub extra_cols: usize,
}

impl PackedB {
    /// Pack a plain row-major `k × n` B with no extra column.
    pub fn pack(b: &[i8], k: usize, n: usize) -> Self {
        assert_eq!(b.len(), k * n);
        Self {
            data: b.to_vec(),
            k,
            n,
            extra_cols: 0,
        }
    }

    /// Pack B together with one extra i8 column (e.g. the mod-127 row-sum
    /// checksum): output layout is row-major `k × (n+1)`.
    pub fn pack_with_extra_col(b: &[i8], k: usize, n: usize, extra: &[i8]) -> Self {
        assert_eq!(b.len(), k * n);
        assert_eq!(extra.len(), k);
        let nt = n + 1;
        let mut data = vec![0i8; k * nt];
        for p in 0..k {
            data[p * nt..p * nt + n].copy_from_slice(&b[p * n..(p + 1) * n]);
            data[p * nt + n] = extra[p];
        }
        Self {
            data,
            k,
            n,
            extra_cols: 1,
        }
    }

    /// Total stored columns (payload + extra).
    #[inline]
    pub fn n_total(&self) -> usize {
        self.n + self.extra_cols
    }

    /// Bytes of packed storage.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Raw packed element at `(row, col)` over the total width.
    #[inline]
    pub fn at(&self, row: usize, col: usize) -> i8 {
        self.data[row * self.n_total() + col]
    }

    /// Raw packed bytes (row-major `k × n_total`) — the exact layout the
    /// AOT artifacts take as their encoded-operand input.
    pub fn data(&self) -> &[i8] {
        &self.data
    }

    /// Mutable access for fault injection (tests/campaigns only).
    pub fn data_mut(&mut self) -> &mut [i8] {
        &mut self.data
    }
}

/// `C[m × n_total] = A[m × k] · B_packed`, i32 accumulation, row-major C.
///
/// Output width is `packed.n_total()`: if the pack carries a checksum
/// column, C carries one too (paper: "allocate one more column for the
/// intermediate result matrix").
pub fn gemm_exec(a: &[u8], packed: &PackedB, m: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * packed.n_total()];
    gemm_exec_into(a, packed, m, &mut c);
    c
}

/// Register-tile width over the j (output column) dimension. 32 i32
/// accumulators per A row = 4 AVX2 vectors; with MR=2 rows that is 8
/// live vector accumulators, comfortably inside the 16 ymm registers.
const NR: usize = 32;

/// Same as [`gemm_exec`] but writes into a caller-provided buffer, allowing
/// the serving hot path to reuse allocations.
///
/// Kernel shape (§Perf iteration 2): k-blocked (KC) so a B panel stays
/// cache-resident, j-tiled (NR) with the accumulator tile held in
/// registers across the whole k-block — C is read/written once per
/// k-block instead of once per k step (the v1 kernel's bottleneck was
/// exactly that L1 read-modify-write traffic), and 2 rows of A share
/// every loaded B line.
pub fn gemm_exec_into(a: &[u8], packed: &PackedB, m: usize, c: &mut [i32]) {
    let k = packed.k;
    let nt = packed.n_total();
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(c.len(), m * nt, "C shape");
    c.fill(0);
    let data = &packed.data[..];

    for kb in (0..k).step_by(KC) {
        let kend = (kb + KC).min(k);
        let mut i = 0;
        while i + 2 <= m {
            let a0 = &a[i * k..(i + 1) * k];
            let a1 = &a[(i + 1) * k..(i + 2) * k];
            let (lo, hi) = c.split_at_mut((i + 1) * nt);
            let c0 = &mut lo[i * nt..];
            let c1 = &mut hi[..nt];
            let mut jb = 0;
            while jb + NR <= nt {
                let mut acc0 = [0i32; NR];
                let mut acc1 = [0i32; NR];
                for p in kb..kend {
                    let av0 = a0[p] as i32;
                    let av1 = a1[p] as i32;
                    let b = &data[p * nt + jb..p * nt + jb + NR];
                    for r in 0..NR {
                        let bw = b[r] as i32;
                        acc0[r] += av0 * bw;
                        acc1[r] += av1 * bw;
                    }
                }
                for r in 0..NR {
                    c0[jb + r] += acc0[r];
                    c1[jb + r] += acc1[r];
                }
                jb += NR;
            }
            if jb < nt {
                // Column tail (< NR wide).
                for p in kb..kend {
                    let av0 = a0[p] as i32;
                    let av1 = a1[p] as i32;
                    let b = &data[p * nt..(p + 1) * nt];
                    for r in jb..nt {
                        let bw = b[r] as i32;
                        c0[r] += av0 * bw;
                        c1[r] += av1 * bw;
                    }
                }
            }
            i += 2;
        }
        if i < m {
            // Row tail (odd m, incl. the important m=1 serving case):
            // stream full B rows — a single accumulator row has no tile
            // reuse to exploit, and strided column access would waste
            // 3/4 of every loaded B line.
            let arow = &a[i * k..(i + 1) * k];
            let crow = &mut c[i * nt..(i + 1) * nt];
            for p in kb..kend {
                let av = arow[p] as i32;
                let brow = &data[p * nt..(p + 1) * nt];
                for (x, &bv) in crow.iter_mut().zip(brow) {
                    *x += av * bv as i32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::naive::gemm_naive;
    use crate::util::rng::Pcg32;

    fn rand_case(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        (a, b)
    }

    #[test]
    fn matches_naive_across_shapes() {
        let mut rng = Pcg32::new(2024);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 3200, 800),
            (2, 7, 5),
            (3, 300, 17),
            (4, 256, 64),
            (5, 257, 63), // straddles the KC boundary
            (17, 512, 32),
        ] {
            let (a, b) = rand_case(&mut rng, m, k, n);
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(
                gemm_exec(&a, &packed, m),
                gemm_naive(&a, &b, m, k, n),
                "shape ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn extra_col_behaves_like_augmented_matrix() {
        let mut rng = Pcg32::new(11);
        let (m, k, n) = (6, 100, 40);
        let (a, b) = rand_case(&mut rng, m, k, n);
        let mut extra = vec![0i8; k];
        rng.fill_i8(&mut extra);
        // Build explicit augmented B′ and compare.
        let mut b_aug = vec![0i8; k * (n + 1)];
        for p in 0..k {
            b_aug[p * (n + 1)..p * (n + 1) + n].copy_from_slice(&b[p * n..(p + 1) * n]);
            b_aug[p * (n + 1) + n] = extra[p];
        }
        let packed = PackedB::pack_with_extra_col(&b, k, n, &extra);
        assert_eq!(packed.n_total(), n + 1);
        assert_eq!(
            gemm_exec(&a, &packed, m),
            gemm_naive(&a, &b_aug, m, k, n + 1)
        );
    }

    #[test]
    fn exec_into_reuses_buffer() {
        let mut rng = Pcg32::new(3);
        let (m, k, n) = (4, 64, 16);
        let (a, b) = rand_case(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let mut buf = vec![0xDEADi32 as i32; m * n];
        gemm_exec_into(&a, &packed, m, &mut buf);
        assert_eq!(buf, gemm_naive(&a, &b, m, k, n));
    }

    #[test]
    fn odd_row_count_tail_handled() {
        let mut rng = Pcg32::new(4);
        for m in [1usize, 3, 5, 7] {
            let (k, n) = (33, 9);
            let (a, b) = rand_case(&mut rng, m, k, n);
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(gemm_exec(&a, &packed, m), gemm_naive(&a, &b, m, k, n));
        }
    }
}
