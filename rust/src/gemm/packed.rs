//! Packed u8×i8→i32 GEMM (FBGEMM-lite) with an explicit AVX2 microkernel.
//!
//! # Packed layout (panel-interleaved)
//!
//! `PackedB` stores B in **column panels of `NR` (= 32) columns**, each
//! panel laid out **k-pair interleaved**, so the microkernel's inner loop
//! is nothing but contiguous 32-byte loads:
//!
//! ```text
//! panel q  = columns [q·NR, min((q+1)·NR, n_total))   (width w ≤ NR)
//! within a panel (k rows, pair-blocked over k):
//!   pair block pp (rows 2pp, 2pp+1), 2·w bytes:
//!     [ B[2pp][j₀+0], B[2pp+1][j₀+0], B[2pp][j₀+1], B[2pp+1][j₀+1], … ]
//!   if k is odd, one trailing w-byte row: [ B[k-1][j₀+0], … ]
//! ```
//!
//! Two consecutive k-rows of one column sit in adjacent bytes: exactly the
//! operand order `_mm256_madd_epi16` wants for the u8×i8 pairwise trick
//! (the `maddubs` shape, done via i16 widening so it is **exact** — no
//! i16 saturation, hence bit-identical to the scalar kernel). One 32-byte
//! load covers 16 columns × 2 k-rows; a full panel row-pair is two loads.
//! Total storage is exactly `k × n_total` bytes — no padding, so every
//! packed byte is payload (or checksum) and fault-injection campaigns can
//! target any byte meaningfully.
//!
//! The optional *extra column* (the paper's §IV-A3 trick: "pack the
//! original B and the separate vector storing row sums together into
//! blocks so the blocks look like they are from encoded B′ in contiguous
//! memory space") rides in the last panel like any other column, which
//! keeps the ABFT-protected GEMM a single kernel call.
//!
//! # Execution
//!
//! [`gemm_exec_into`] dispatches at runtime: AVX2 microkernel when the
//! host has it (`is_x86_feature_detected!`), portable scalar fallback
//! otherwise — both walk the same panel layout and produce bit-identical
//! i32 results (integer adds commute). Large multiplications additionally
//! fan out over m-row blocks on [`crate::util::threadpool::global`]; rows
//! are independent, so parallel results are bit-identical too.

/// Register-tile width over the j (output column) dimension: 32 i8 = one
/// 32-byte load; 32 i32 accumulators = 4 ymm per A row, and the row-pair
/// kernel's 8 live accumulators sit comfortably inside the 16 ymm regs.
pub(crate) const NR: usize = 32;

use crate::util::threadpool::GEMM_PAR_MIN_WORK;

/// Pre-packed right-hand-side operand (see module docs for the layout).
#[derive(Clone, Debug)]
pub struct PackedB {
    /// Panel-interleaved `k × n_total` bytes.
    pub(crate) data: Vec<i8>,
    pub k: usize,
    /// Logical (payload) column count, excluding any extra column.
    pub n: usize,
    /// Number of appended extra columns (0 = plain, 1 = Eq-3b checksum,
    /// 1 + G = checksum plus G column-group partial checksums).
    pub extra_cols: usize,
    /// Pack-time int16-accumulation certificate (see `quant::acc16`):
    /// present iff the acc16 kernel tier is bit-exact for this operand
    /// at the recorded spill cadence, over every stored column —
    /// checksum columns included. Weight corruption via [`PackedB::
    /// data_mut`] can invalidate it, which at worst turns an injected
    /// fault into a detected-then-recomputed fault (the ladder verifies
    /// after every correction), never a silent one.
    pub(crate) acc16: Option<crate::quant::Acc16Proof>,
}

/// Byte offset of logical element `(p, j)` in the panel-interleaved
/// layout for a `k × nt` pack.
#[inline]
pub(crate) fn panel_offset(k: usize, nt: usize, p: usize, j: usize) -> usize {
    debug_assert!(p < k && j < nt);
    let j0 = (j / NR) * NR;
    let w = NR.min(nt - j0);
    let base = j0 * k;
    let c = j - j0;
    if k % 2 == 1 && p == k - 1 {
        base + (k - 1) * w + c
    } else {
        base + (p / 2) * (2 * w) + 2 * c + (p % 2)
    }
}

impl PackedB {
    /// Pack a plain row-major `k × n` B with no extra column.
    pub fn pack(b: &[i8], k: usize, n: usize) -> Self {
        assert_eq!(b.len(), k * n);
        let mut data = vec![0i8; k * n];
        for p in 0..k {
            for j in 0..n {
                data[panel_offset(k, n, p, j)] = b[p * n + j];
            }
        }
        let mut packed = Self {
            data,
            k,
            n,
            extra_cols: 0,
            acc16: None,
        };
        let proof = crate::quant::acc16_saturation_proof(k, n, |p, j| packed.at(p, j));
        packed.acc16 = proof;
        packed
    }

    /// Pack B together with one extra i8 column (e.g. the mod-127 row-sum
    /// checksum): logical layout is `k × (n+1)`, stored panel-interleaved.
    pub fn pack_with_extra_col(b: &[i8], k: usize, n: usize, extra: &[i8]) -> Self {
        Self::pack_with_extra_cols(b, k, n, &[extra])
    }

    /// Pack B together with any number of extra i8 columns (the Eq-3b
    /// row-sum checksum plus the column-group partial checksums): logical
    /// layout is `k × (n + extras.len())`, stored panel-interleaved so the
    /// extra columns ride in the trailing panel(s) and the protected GEMM
    /// stays a single kernel call.
    pub fn pack_with_extra_cols(b: &[i8], k: usize, n: usize, extras: &[&[i8]]) -> Self {
        assert_eq!(b.len(), k * n);
        for extra in extras {
            assert_eq!(extra.len(), k, "extra column length");
        }
        let nt = n + extras.len();
        let mut data = vec![0i8; k * nt];
        for p in 0..k {
            for j in 0..n {
                data[panel_offset(k, nt, p, j)] = b[p * n + j];
            }
            for (e, extra) in extras.iter().enumerate() {
                data[panel_offset(k, nt, p, n + e)] = extra[p];
            }
        }
        let mut packed = Self {
            data,
            k,
            n,
            extra_cols: extras.len(),
            acc16: None,
        };
        let proof = crate::quant::acc16_saturation_proof(k, nt, |p, j| packed.at(p, j));
        packed.acc16 = proof;
        packed
    }

    /// The pack-time int16-accumulation certificate, when one exists
    /// (see `quant::acc16` for the saturation argument).
    #[inline]
    pub fn acc16_proof(&self) -> Option<crate::quant::Acc16Proof> {
        self.acc16
    }

    /// Total stored columns (payload + extra).
    #[inline]
    pub fn n_total(&self) -> usize {
        self.n + self.extra_cols
    }

    /// Bytes of packed storage.
    pub fn bytes(&self) -> usize {
        self.data.len()
    }

    /// Byte offset of logical element `(row, col)` in the packed buffer —
    /// the indexing bridge for fault injection and layout-aware readers.
    #[inline]
    pub fn offset(&self, row: usize, col: usize) -> usize {
        panel_offset(self.k, self.n_total(), row, col)
    }

    /// Packed element at logical `(row, col)` over the total width.
    #[inline]
    pub fn at(&self, row: usize, col: usize) -> i8 {
        self.data[self.offset(row, col)]
    }

    /// Raw packed bytes (panel-interleaved; see module docs). Every byte
    /// maps to exactly one logical element, so arbitrary byte corruption
    /// is always a payload/checksum fault.
    pub fn data(&self) -> &[i8] {
        &self.data
    }

    /// Mutable access for fault injection (tests/campaigns only); pair
    /// with [`PackedB::offset`] to target a logical element.
    pub fn data_mut(&mut self) -> &mut [i8] {
        &mut self.data
    }

    /// Re-materialize the row-major `k × n_total` matrix — the interchange
    /// layout the AOT artifacts and the snapshot format use.
    pub fn to_row_major(&self) -> Vec<i8> {
        let nt = self.n_total();
        let mut out = vec![0i8; self.k * nt];
        for p in 0..self.k {
            for j in 0..nt {
                out[p * nt + j] = self.at(p, j);
            }
        }
        out
    }
}

/// `C[m × n_total] = A[m × k] · B_packed`, i32 accumulation, row-major C.
///
/// Output width is `packed.n_total()`: if the pack carries a checksum
/// column, C carries one too (paper: "allocate one more column for the
/// intermediate result matrix").
pub fn gemm_exec(a: &[u8], packed: &PackedB, m: usize) -> Vec<i32> {
    let mut c = vec![0i32; m * packed.n_total()];
    gemm_exec_into(a, packed, m, &mut c);
    c
}

/// Same as [`gemm_exec`] but writes into a caller-provided buffer, allowing
/// the serving hot path to reuse allocations. Dispatches SIMD/scalar and
/// row-parallel execution (see module docs); results are bit-identical on
/// every path.
pub fn gemm_exec_into(a: &[u8], packed: &PackedB, m: usize, c: &mut [i32]) {
    if !gemm_prologue(a, packed, m, c) {
        return;
    }
    let k = packed.k;
    let nt = packed.n_total();
    // Row-chunked fan-out via the shared gate/chunking helper (rows are
    // independent, so the parallel path stays bit-identical).
    crate::util::threadpool::global().scope_chunks(c, nt, m * k * nt, GEMM_PAR_MIN_WORK, |row0, cb| {
        let rows = cb.len() / nt;
        gemm_rows_dispatch(&a[row0 * k..(row0 + rows) * k], packed, rows, cb);
    });
}

/// Single-thread variant of [`gemm_exec_into`] (SIMD when available, no
/// row fan-out) — lets the perf harness separate kernel speedup from
/// parallel speedup. `c` is fully overwritten.
pub fn gemm_exec_into_st(a: &[u8], packed: &PackedB, m: usize, c: &mut [i32]) {
    if gemm_prologue(a, packed, m, c) {
        gemm_rows_dispatch(a, packed, m, c);
    }
}

/// Always-scalar, always-single-thread variant: the reference the SIMD
/// path is tested against bit-for-bit, and the baseline the perf harness
/// reports speedups over. `c` is fully overwritten.
pub fn gemm_exec_into_scalar(a: &[u8], packed: &PackedB, m: usize, c: &mut [i32]) {
    if gemm_prologue(a, packed, m, c) {
        gemm_rows_scalar(a, packed, m, c);
    }
}

/// Fused protected-GEMM + requantize/ReLU epilogue: computes
/// `c_temp[m × n_total] = A·B_packed` (bit-identical to [`gemm_exec_into`])
/// **and** the requantized u8 payload `out[m × epi.n_out]` in the same
/// kernel pass — on AVX2 the accumulator tile is quantized while still in
/// registers; the fallback runs the scalar kernel followed by the shared
/// scalar requantization core over each row block. Both orderings apply
/// the identical per-element affine+round pipeline, so every dispatch
/// path produces the same bytes (see `quant::requantize_cols_into`).
///
/// Columns `epi.n_out..n_total` of `c_temp` (the ABFT checksum column,
/// when the pack carries one) are computed but never requantized — the
/// caller verifies them against the row sums *of the stored i32 tile*,
/// exactly as in the two-pass flow.
pub fn gemm_requant_exec_into(
    a: &[u8],
    packed: &PackedB,
    m: usize,
    epi: &crate::quant::RequantEpilogue<'_>,
    c_temp: &mut [i32],
    out: &mut [u8],
) {
    let k = packed.k;
    let nt = packed.n_total();
    if !fused_prologue(a, packed, m, epi, c_temp, out) {
        return;
    }
    // Row-chunked fan-out through the shared two-slice gate/chunking
    // helper (rows are independent and each block's epilogue slices its
    // own row sums, so the parallel output is bit-identical).
    crate::util::threadpool::global().scope_chunks2(
        c_temp,
        nt,
        out,
        epi.n_out,
        m * k * nt,
        GEMM_PAR_MIN_WORK,
        |row0, c_blk, o_blk| {
            let rows = c_blk.len() / nt;
            let blk_epi = crate::quant::RequantEpilogue {
                a_row_sums: &epi.a_row_sums[row0..row0 + rows],
                ..*epi
            };
            gemm_requant_rows_dispatch(
                &a[row0 * k..(row0 + rows) * k],
                packed,
                rows,
                &blk_epi,
                c_blk,
                o_blk,
            );
        },
    );
}

/// Always-scalar, single-thread variant of [`gemm_requant_exec_into`] —
/// the reference the fused SIMD epilogue is tested against bit-for-bit.
pub fn gemm_requant_exec_into_scalar(
    a: &[u8],
    packed: &PackedB,
    m: usize,
    epi: &crate::quant::RequantEpilogue<'_>,
    c_temp: &mut [i32],
    out: &mut [u8],
) {
    if fused_prologue(a, packed, m, epi, c_temp, out) {
        gemm_rows_scalar(a, packed, m, c_temp);
        requant_block_scalar(packed, m, epi, c_temp, out);
    }
}

/// Shape contract + zero fill for the fused entry points. Returns false
/// when there is no GEMM work left; degenerate-k shapes still requantize
/// the zeroed accumulator (matching the two-pass flow exactly).
fn fused_prologue(
    a: &[u8],
    packed: &PackedB,
    m: usize,
    epi: &crate::quant::RequantEpilogue<'_>,
    c_temp: &mut [i32],
    out: &mut [u8],
) -> bool {
    let nt = packed.n_total();
    assert!(epi.n_out <= nt, "payload width exceeds packed width");
    assert!(epi.b_col_sums.len() >= epi.n_out, "missing B column sums");
    assert_eq!(epi.a_row_sums.len(), m, "A row sums");
    assert_eq!(out.len(), m * epi.n_out, "out shape");
    if !gemm_prologue(a, packed, m, c_temp) {
        if m != 0 && nt != 0 && packed.k == 0 {
            requant_block_scalar(packed, m, epi, c_temp, out);
        }
        return false;
    }
    true
}

/// One fused row block, routed by [`crate::gemm::select_tier`]. The
/// AVX2 tier fuses the epilogue in-register; the acc16 and AVX-512
/// tiers compute the i32 block with their own kernels and then replay
/// the identical epilogue from memory (`avx2::requant_rows`), so every
/// tier emits the same bytes; the scalar tier runs the shared scalar
/// requantization core.
fn gemm_requant_rows_dispatch(
    a: &[u8],
    packed: &PackedB,
    rows: usize,
    epi: &crate::quant::RequantEpilogue<'_>,
    c: &mut [i32],
    out: &mut [u8],
) {
    #[cfg(target_arch = "x86_64")]
    {
        use crate::gemm::KernelTier;
        match crate::gemm::select_tier(packed) {
            KernelTier::Avx512 => {
                // SAFETY: select_tier verified AVX-512F+VNNI (and AVX2
                // for the epilogue) on this host.
                unsafe {
                    crate::gemm::avx512::gemm_rows(a, packed, rows, c);
                    crate::gemm::avx2::requant_rows(c, rows, packed.n_total(), epi, out);
                }
                return;
            }
            KernelTier::Acc16 => {
                let spill = packed
                    .acc16
                    .expect("acc16 tier selected without proof")
                    .spill_pairs as usize;
                // SAFETY: select_tier verified AVX2; the pack carries a
                // saturation proof for this spill cadence.
                unsafe {
                    crate::gemm::acc16::gemm_rows(a, packed, rows, c, spill);
                    crate::gemm::avx2::requant_rows(c, rows, packed.n_total(), epi, out);
                }
                return;
            }
            KernelTier::Avx2 => {
                // SAFETY: select_tier verified AVX2 on this host.
                unsafe { crate::gemm::avx2::gemm_rows_fused(a, packed, rows, c, out, epi) };
                return;
            }
            KernelTier::Scalar => {}
        }
    }
    gemm_rows_scalar(a, packed, rows, c);
    requant_block_scalar(packed, rows, epi, c, out);
}

/// The two-pass tail shared by the non-SIMD fused paths: requantize the
/// payload columns of an already-computed `rows × n_total` block.
fn requant_block_scalar(
    packed: &PackedB,
    rows: usize,
    epi: &crate::quant::RequantEpilogue<'_>,
    c: &[i32],
    out: &mut [u8],
) {
    crate::quant::requantize_cols_into(
        c,
        rows,
        packed.n_total(),
        0..epi.n_out,
        epi.a_row_sums,
        epi.b_col_sums,
        &epi.spec,
        epi.relu_floor,
        out,
    );
}

/// Shared entry-point preamble: shape contract, zeroed output, and the
/// degenerate-size early-out. Returns false when there is nothing to
/// compute.
fn gemm_prologue(a: &[u8], packed: &PackedB, m: usize, c: &mut [i32]) -> bool {
    let k = packed.k;
    let nt = packed.n_total();
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(c.len(), m * nt, "C shape");
    c.fill(0);
    m != 0 && k != 0 && nt != 0
}

/// True when the AVX2 microkernel serves [`gemm_exec_into`] on this host.
pub fn simd_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        crate::gemm::avx2::available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// One row block, routed by [`crate::gemm::select_tier`]. Every tier
/// walks the same panel layout and produces bit-identical i32 results.
/// `c` must be pre-zeroed.
fn gemm_rows_dispatch(a: &[u8], packed: &PackedB, rows: usize, c: &mut [i32]) {
    #[cfg(target_arch = "x86_64")]
    {
        use crate::gemm::KernelTier;
        match crate::gemm::select_tier(packed) {
            KernelTier::Avx512 => {
                // SAFETY: select_tier verified AVX-512F+VNNI support.
                unsafe { crate::gemm::avx512::gemm_rows(a, packed, rows, c) };
                return;
            }
            KernelTier::Acc16 => {
                let spill = packed
                    .acc16
                    .expect("acc16 tier selected without proof")
                    .spill_pairs as usize;
                // SAFETY: select_tier verified AVX2; the pack carries a
                // saturation proof for this spill cadence.
                unsafe { crate::gemm::acc16::gemm_rows(a, packed, rows, c, spill) };
                return;
            }
            KernelTier::Avx2 => {
                // SAFETY: select_tier verified AVX2 on this host.
                unsafe { crate::gemm::avx2::gemm_rows(a, packed, rows, c) };
                return;
            }
            KernelTier::Scalar => {}
        }
    }
    gemm_rows_scalar(a, packed, rows, c);
}

/// Portable fallback over the panel layout. `c` (rows × nt) must be
/// pre-zeroed; results accumulate panel by panel.
fn gemm_rows_scalar(a: &[u8], packed: &PackedB, rows: usize, c: &mut [i32]) {
    let k = packed.k;
    let nt = packed.n_total();
    let mut j0 = 0usize;
    while j0 < nt {
        let w = NR.min(nt - j0);
        panel_rows_scalar(a, &packed.data, k, nt, rows, c, j0, w);
        j0 += w;
    }
}

/// Scalar kernel for one panel (`w` columns starting at `j0`) over a row
/// block. Shared with the AVX2 path, which uses it for ragged tail panels
/// (`w < NR`) — e.g. the single checksum column of an encoded operand.
pub(crate) fn panel_rows_scalar(
    a: &[u8],
    data: &[i8],
    k: usize,
    nt: usize,
    rows: usize,
    c: &mut [i32],
    j0: usize,
    w: usize,
) {
    let kp = k & !1;
    let base = j0 * k;
    for i in 0..rows {
        let arow = &a[i * k..(i + 1) * k];
        let mut acc = [0i32; NR];
        let acc = &mut acc[..w];
        for pp in 0..kp / 2 {
            let a0 = arow[2 * pp] as i32;
            let a1 = arow[2 * pp + 1] as i32;
            let blk = &data[base + pp * 2 * w..base + (pp + 1) * 2 * w];
            for (cix, slot) in acc.iter_mut().enumerate() {
                *slot += a0 * blk[2 * cix] as i32 + a1 * blk[2 * cix + 1] as i32;
            }
        }
        if k % 2 == 1 {
            let al = arow[k - 1] as i32;
            let blk = &data[base + kp * w..base + kp * w + w];
            for (slot, &bv) in acc.iter_mut().zip(blk) {
                *slot += al * bv as i32;
            }
        }
        let crow = &mut c[i * nt + j0..i * nt + j0 + w];
        for (o, &v) in crow.iter_mut().zip(acc.iter()) {
            *o += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::naive::gemm_naive;
    use crate::util::rng::Pcg32;

    fn rand_case(rng: &mut Pcg32, m: usize, k: usize, n: usize) -> (Vec<u8>, Vec<i8>) {
        let mut a = vec![0u8; m * k];
        let mut b = vec![0i8; k * n];
        rng.fill_u8(&mut a);
        rng.fill_i8(&mut b);
        (a, b)
    }

    #[test]
    fn panel_offset_is_a_bijection() {
        for &(k, nt) in &[(1usize, 1usize), (2, 32), (3, 33), (7, 65), (16, 31), (5, 97)] {
            let mut seen = vec![false; k * nt];
            for p in 0..k {
                for j in 0..nt {
                    let off = panel_offset(k, nt, p, j);
                    assert!(off < k * nt, "({k},{nt}) ({p},{j}) -> {off}");
                    assert!(!seen[off], "collision at ({k},{nt}) ({p},{j})");
                    seen[off] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "holes in layout ({k},{nt})");
        }
    }

    #[test]
    fn at_reads_back_packed_values() {
        let mut rng = Pcg32::new(5);
        let (k, n) = (37, 70);
        let (_, b) = rand_case(&mut rng, 1, k, n);
        let packed = PackedB::pack(&b, k, n);
        for p in 0..k {
            for j in 0..n {
                assert_eq!(packed.at(p, j), b[p * n + j], "({p},{j})");
            }
        }
        assert_eq!(packed.to_row_major(), b);
    }

    #[test]
    fn matches_naive_across_shapes() {
        let mut rng = Pcg32::new(2024);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (1, 3200, 800),
            (2, 7, 5),
            (3, 300, 17),
            (4, 256, 64),
            (5, 257, 63), // odd k exercises the tail-row path
            (17, 512, 32),
            (2, 64, 31),  // ragged single panel
            (2, 64, 33),  // full panel + width-1 tail panel
        ] {
            let (a, b) = rand_case(&mut rng, m, k, n);
            let packed = PackedB::pack(&b, k, n);
            let want = gemm_naive(&a, &b, m, k, n);
            assert_eq!(gemm_exec(&a, &packed, m), want, "dispatch ({m},{k},{n})");
            let mut c = vec![0i32; m * n];
            gemm_exec_into_scalar(&a, &packed, m, &mut c);
            assert_eq!(c, want, "scalar ({m},{k},{n})");
        }
    }

    #[test]
    fn extra_col_behaves_like_augmented_matrix() {
        let mut rng = Pcg32::new(11);
        let (m, k, n) = (6, 100, 40);
        let (a, b) = rand_case(&mut rng, m, k, n);
        let mut extra = vec![0i8; k];
        rng.fill_i8(&mut extra);
        // Build explicit augmented B′ and compare.
        let mut b_aug = vec![0i8; k * (n + 1)];
        for p in 0..k {
            b_aug[p * (n + 1)..p * (n + 1) + n].copy_from_slice(&b[p * n..(p + 1) * n]);
            b_aug[p * (n + 1) + n] = extra[p];
        }
        let packed = PackedB::pack_with_extra_col(&b, k, n, &extra);
        assert_eq!(packed.n_total(), n + 1);
        assert_eq!(
            gemm_exec(&a, &packed, m),
            gemm_naive(&a, &b_aug, m, k, n + 1)
        );
    }

    #[test]
    fn multi_extra_cols_behave_like_augmented_matrix() {
        let mut rng = Pcg32::new(12);
        // n = 70 ⇒ the 4 extras straddle the ragged tail panel boundary.
        let (m, k, n) = (5, 53, 70);
        let (a, b) = rand_case(&mut rng, m, k, n);
        let mut extras = vec![vec![0i8; k]; 4];
        for e in extras.iter_mut() {
            rng.fill_i8(e);
        }
        let refs: Vec<&[i8]> = extras.iter().map(|e| e.as_slice()).collect();
        let ne = n + refs.len();
        let mut b_aug = vec![0i8; k * ne];
        for p in 0..k {
            b_aug[p * ne..p * ne + n].copy_from_slice(&b[p * n..(p + 1) * n]);
            for (e, extra) in extras.iter().enumerate() {
                b_aug[p * ne + n + e] = extra[p];
            }
        }
        let packed = PackedB::pack_with_extra_cols(&b, k, n, &refs);
        assert_eq!(packed.n_total(), ne);
        assert_eq!(gemm_exec(&a, &packed, m), gemm_naive(&a, &b_aug, m, k, ne));
        for p in 0..k {
            for (e, extra) in extras.iter().enumerate() {
                assert_eq!(packed.at(p, n + e), extra[p]);
            }
        }
    }

    #[test]
    fn exec_into_reuses_buffer() {
        let mut rng = Pcg32::new(3);
        let (m, k, n) = (4, 64, 16);
        let (a, b) = rand_case(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let mut buf = vec![0xDEADi32 as i32; m * n];
        gemm_exec_into(&a, &packed, m, &mut buf);
        assert_eq!(buf, gemm_naive(&a, &b, m, k, n));
    }

    #[test]
    fn odd_row_count_tail_handled() {
        let mut rng = Pcg32::new(4);
        for m in [1usize, 3, 5, 7] {
            let (k, n) = (33, 9);
            let (a, b) = rand_case(&mut rng, m, k, n);
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(gemm_exec(&a, &packed, m), gemm_naive(&a, &b, m, k, n));
        }
    }

    #[test]
    fn parallel_path_bit_identical() {
        // Big enough to cross GEMM_PAR_MIN_WORK: the row-parallel path
        // must produce the same bytes as the single-thread scalar path.
        let mut rng = Pcg32::new(6);
        let (m, k, n) = (19, 384, 320);
        assert!(m * k * n >= super::GEMM_PAR_MIN_WORK);
        let (a, b) = rand_case(&mut rng, m, k, n);
        let packed = PackedB::pack(&b, k, n);
        let mut par = vec![0i32; m * n];
        gemm_exec_into(&a, &packed, m, &mut par);
        let mut scalar = vec![0i32; m * n];
        gemm_exec_into_scalar(&a, &packed, m, &mut scalar);
        assert_eq!(par, scalar);
    }
}
